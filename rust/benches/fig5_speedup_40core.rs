//! Regenerates Figure 5 (a-d): regular vs segmented Merge Path on the
//! simulated 40-core E7-8870, 10M/50M arrays, writeback vs register.
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    for t in figures::fig5(scale) {
        t.print();
    }
    println!("\npaper reference: ~32x register vs ~28x writeback at 40 threads (50M); segmented wins on the larger arrays, regular on the smaller");
}
