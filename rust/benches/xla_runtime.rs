//! XLA runtime bench: per-artifact execution latency/throughput of the
//! AOT Pallas merge vs the native rust merge at the same shape.
//! Skips gracefully when `make artifacts` has not been run.
use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::merge_into;
use mergeflow::runtime::XlaRuntime;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let Ok(rt) = XlaRuntime::open(dir) else {
        eprintln!("skipping xla_runtime bench: run `make artifacts` first");
        return;
    };
    println!("platform: {}", rt.platform());
    let timer = BenchTimer::default();
    for meta in rt.manifest().entries().to_vec() {
        if meta.op != "merge" && meta.op != "merge-ref" {
            continue;
        }
        let exe = match rt.merge_executable(&meta.name) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("compile {} failed: {e}", meta.name);
                continue;
            }
        };
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, meta.n_a, meta.n_b, 11);
        let total = (meta.n_a + meta.n_b) as u64;
        let m = timer.measure(|| {
            let out = exe.merge(&a, &b).expect("exec failed");
            std::hint::black_box(&out);
        });
        println!("{}", report_line(&format!("xla {}", meta.name), &m, total));
        let mut out = vec![0i32; meta.n_a + meta.n_b];
        let m = timer.measure(|| merge_into(&a, &b, &mut out));
        println!("{}", report_line("native same shape", &m, total));
    }
}
