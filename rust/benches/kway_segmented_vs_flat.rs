//! Segmented flat k-way merge vs the unsegmented flat engine — the
//! k-way extension of `ablation_segment_len.rs` / `fig8_segmented_ratio.rs`.
//!
//! Two views:
//! 1. **Simulated cache misses** (k × segment length) on the scaled
//!    12-core machine: the flat engine streams `k + 1` unbounded
//!    sequences per thread and its argmin inner loop re-reads every
//!    live head per output, so once the `k + 1` live lines outrun the
//!    private cache every touch misses; the segmented engine's bounded
//!    kernel touches each element once and bounds a window's working
//!    set at `(k+1)·L`. The L sweep shows the U-shape: tiny L drowns
//!    in per-window head refills, huge L loses nothing in this model
//!    but forfeits the residency bound the real hardware cares about.
//! 2. **Real wallclock** (k × run length × segment length) for the two
//!    engines on this host, bit-identity cross-checked per shape.
//!
//! Env: MERGEFLOW_BENCH_N = total merged elements (default 4M),
//!      MERGEFLOW_BENCH_KIND = uniform|skewed|one-sided|interleaved|runs.
use mergeflow::bench::figures::sim_scale;
use mergeflow::bench::harness::{report_line, BenchTimer, Table};
use mergeflow::bench::workload::{gen_sorted_runs, WorkloadKind};
use mergeflow::mergepath::{
    loser_tree_merge, parallel_kway_merge, segmented_kway_merge, KwaySegmentedConfig,
};
use mergeflow::sim::engine::{simulate_kway_merge, KwayMergeAlgo};
use mergeflow::sim::machine::x5670_12;
use mergeflow::sim::stream::Stage;

fn main() {
    let scale = sim_scale();
    let machine = x5670_12().scaled_caches(scale);
    let l3_elems = machine.mem.l3.capacity / 4;
    let p = 8usize;

    // --- Simulated miss sweep: k × L ---------------------------------
    let sim_run_len = ((1usize << 20) / scale).clamp(1 << 12, 1 << 17);
    let mut t = Table::new(
        &format!(
            "Segmented vs flat k-way — simulated L1 misses ({sim_run_len} per run, p={p}, scaled L3 = {l3_elems} elems)"
        ),
        &["k", "flat", "seg L=C/(k+1)", "seg L/4", "seg 4L", "flat/seg ratio"],
    );
    for k in [4usize, 8, 12, 16] {
        let runs = gen_sorted_runs(WorkloadKind::Uniform, k, sim_run_len, 7);
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let auto_l = (l3_elems / (k + 1)).max(64);
        let miss = |algo: KwayMergeAlgo| {
            simulate_kway_merge(&machine, algo, &refs, true, Stage::Both, p)
                .mem
                .l1
                .misses()
        };
        let flat = miss(KwayMergeAlgo::Flat);
        let seg = miss(KwayMergeAlgo::Segmented { segment_elems: auto_l });
        let seg_small = miss(KwayMergeAlgo::Segmented { segment_elems: (auto_l / 4).max(16) });
        let seg_large = miss(KwayMergeAlgo::Segmented { segment_elems: auto_l * 4 });
        t.row(&[
            k.to_string(),
            flat.to_string(),
            seg.to_string(),
            seg_small.to_string(),
            seg_large.to_string(),
            format!("{:.2}", flat as f64 / seg.max(1) as f64),
        ]);
    }
    t.print();
    println!("ratios > 1 mean the segmented engine misses less; the gap opens once k + 1 stream lines outrun the scaled private L1");

    // --- Real wallclock sweep: k × run length × L --------------------
    let n_total: usize = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize << 20);
    let kind = std::env::var("MERGEFLOW_BENCH_KIND")
        .ok()
        .and_then(|v| WorkloadKind::parse(&v))
        .unwrap_or(WorkloadKind::Uniform);
    let timer = BenchTimer::quick();
    println!("\nworkload: {} x {n_total} total elements", kind.name());
    for k in [4usize, 12, 32] {
        for run_len in [n_total / k, n_total / k / 8] {
            let runs = gen_sorted_runs(kind, k, run_len.max(1), 42);
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            let total: usize = refs.iter().map(|r| r.len()).sum();
            println!("\n--- k = {k} runs of {} ({total} total) ---", total / k);
            for p in [1usize, 4, 8] {
                let m = timer.measure(|| {
                    let mut out = vec![0i32; total];
                    parallel_kway_merge(&refs, &mut out, p, None);
                    std::hint::black_box(&out);
                });
                println!("{}", report_line(&format!("flat p={p}"), &m, total as u64));
                // L sweep around the L2-resident pick (256 KiB / 4B / (k+1)).
                let l2_elems = (256usize << 10) / 4;
                for l in [l2_elems / (k + 1), 4 * l2_elems / (k + 1), 1 << 16] {
                    let cfg = KwaySegmentedConfig { segment_elems: l.max(64), threads: p };
                    let m = timer.measure(|| {
                        let mut out = vec![0i32; total];
                        segmented_kway_merge(&refs, &mut out, cfg, None);
                        std::hint::black_box(&out);
                    });
                    println!(
                        "{}",
                        report_line(
                            &format!("seg  p={p} L={}", cfg.segment_elems),
                            &m,
                            total as u64
                        )
                    );
                }
            }
            // Cross-check once per shape: segmented == sequential loser tree.
            let mut seq = vec![0i32; total];
            loser_tree_merge(&refs, &mut seq);
            let mut out = vec![0i32; total];
            segmented_kway_merge(
                &refs,
                &mut out,
                KwaySegmentedConfig { segment_elems: 1 << 14, threads: 8 },
                None,
            );
            assert_eq!(seq, out, "segmented engine diverged at k={k}");
        }
    }
}
