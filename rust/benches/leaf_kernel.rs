//! Leaf-kernel microbench: scalar vs branchless vs hybrid vs SIMD
//! bounded merges, across workload shapes, run lengths and duplicate
//! densities. Every timed configuration is first cross-checked
//! bit-for-bit against the two-finger `merge_into` oracle, so a
//! miscompiled or misdispatched kernel fails loudly instead of
//! producing fast garbage.
//!
//! The SIMD rows only appear with `--features simd` on a CPU with
//! SSE4.2 (otherwise `MergeKernel::Simd` resolves to branchless and is
//! reported under that name — the degradation itself is visible in the
//! kernel column).
use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::merge::merge_into;
use mergeflow::mergepath::{LeafKernel, MergeKernel};
use mergeflow::rng::Xoshiro256;

const REQUESTS: [MergeKernel; 4] = [
    MergeKernel::Scalar,
    MergeKernel::Branchless,
    MergeKernel::Hybrid,
    MergeKernel::Simd,
];

/// Run all four kernels over one `(a, b)` pair, verifying each against
/// the oracle before timing it.
fn sweep_i32(timer: &BenchTimer, a: &[i32], b: &[i32], label: &str) {
    let n = a.len() + b.len();
    let mut expected = vec![0i32; n];
    merge_into(a, b, &mut expected);
    let mut out = vec![0i32; n];
    for req in REQUESTS {
        let kernel = LeafKernel::<i32>::select(req);
        kernel.merge(a, b, &mut out, n);
        assert_eq!(out, expected, "kernel {} diverged on {label}", kernel.kind().name());
        let m = timer.measure(|| kernel.merge(a, b, &mut out, n));
        println!(
            "{}",
            report_line(&format!("{label} {}", kernel.kind().name()), &m, n as u64)
        );
    }
}

fn sweep_u64(timer: &BenchTimer, a: &[u64], b: &[u64], label: &str) {
    let n = a.len() + b.len();
    let mut expected = vec![0u64; n];
    merge_into(a, b, &mut expected);
    let mut out = vec![0u64; n];
    for req in REQUESTS {
        let kernel = LeafKernel::<u64>::select(req);
        kernel.merge(a, b, &mut out, n);
        assert_eq!(out, expected, "kernel {} diverged on {label}", kernel.kind().name());
        let m = timer.measure(|| kernel.merge(a, b, &mut out, n));
        println!(
            "{}",
            report_line(&format!("{label} {}", kernel.kind().name()), &m, n as u64)
        );
    }
}

/// Sorted run of `len` keys drawn from `universe` distinct values —
/// `universe` is the duplicate-density dial (smaller = denser ties).
fn dup_run(rng: &mut Xoshiro256, len: usize, universe: u64) -> Vec<u64> {
    let mut v: Vec<u64> = (0..len).map(|_| rng.below(universe)).collect();
    v.sort_unstable();
    v
}

fn main() {
    let n = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1usize << 20);
    let timer = BenchTimer::default();

    println!("--- workload shapes (i32, |A|=|B|={}) ---", n / 2);
    for kind in WorkloadKind::all() {
        let (a, b) = gen_sorted_pair(kind, n / 2, n / 2, 42);
        sweep_i32(&timer, &a, &b, kind.name());
    }

    println!("\n--- run lengths (i32 uniform) ---");
    for len in [1usize << 10, 1 << 14, 1 << 18, 1 << 22] {
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, len / 2, len / 2, 7);
        sweep_i32(&timer, &a, &b, &format!("n={len}"));
    }

    println!("\n--- duplicate density (u64, |A|=|B|={}) ---", n / 2);
    let mut rng = Xoshiro256::seeded(0xD0_D0);
    for universe in [4u64, 64, 4096, 1 << 40] {
        let a = dup_run(&mut rng, n / 2, universe);
        let b = dup_run(&mut rng, n / 2, universe);
        sweep_u64(&timer, &a, &b, &format!("dups~1/{universe}"));
    }
}
