//! Rank-sharded compaction vs the single-job flat k-way engine,
//! end to end through the coordinator.
//!
//! Both paths do the same Θ(N) merge work over the same runs; what
//! changes is the execution shape. The flat engine runs one job whose
//! `threads_per_job` segments fork-join inside a single worker slot;
//! the sharded path splits the job by output rank into `S` independent
//! sub-jobs that the pool schedules like any other work. Sharding is
//! expected to win when jobs are much larger than
//! `compact_shard_min_len` (more schedulable units than workers →
//! better overlap with concurrent traffic, and per-shard loser-tree
//! merges instead of a partition + fork-join round per job), and to
//! cost a little on borderline sizes (planning + per-shard dispatch
//! overhead). This bench locates that boundary.
//!
//! Env: MERGEFLOW_BENCH_N    = total merged elements (default 8M),
//!      MERGEFLOW_BENCH_K    = runs per compaction (default 16),
//!      MERGEFLOW_BENCH_KIND = uniform|skewed|one-sided|interleaved|runs.

use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_runs, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};

/// `min_len == 0` builds the unsharded (flat-engine) baseline — the
/// sharding bool is the off switch now that 0 means auto-tune.
fn service(compact_shard_min_len: usize) -> MergeService {
    let cfg = MergeflowConfig {
        workers: 8,
        // threads_per_job = 2 keeps S = total/min_len exact for the
        // labels below (the threads floor in shard_count never kicks
        // in), and makes the contrast representative: per-job threads
        // for the flat engine vs job-level parallelism for shards.
        threads_per_job: 2,
        queue_capacity: 1024,
        max_batch: 32,
        batch_timeout_us: 100,
        backend: Backend::Native,
        // Unsegmented engines: this bench isolates sharded-vs-flat.
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 128,
        compact_sharding: compact_shard_min_len != 0,
        compact_shard_min_len,
        // Whole-run feeds, no eager dispatch: this bench isolates the
        // shard-size knob, so the streamed route must stay out of it.
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        // No budget / no in-place: the allocating kernels are the baseline.
        memory_budget: 0,
        inplace: InplaceMode::Never,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    };
    MergeService::start(cfg).expect("service start")
}

fn main() {
    let n_total: usize = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8usize << 20);
    let k: usize = std::env::var("MERGEFLOW_BENCH_K")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let kind = std::env::var("MERGEFLOW_BENCH_KIND")
        .ok()
        .and_then(|v| WorkloadKind::parse(&v))
        .unwrap_or(WorkloadKind::Uniform);
    let timer = BenchTimer::quick();
    println!("workload: {} x {n_total} total elements, k = {k} runs", kind.name());

    let runs = gen_sorted_runs(kind, k, n_total / k, 42);
    let total: usize = runs.iter().map(|r| r.len()).sum();

    // Every timed iteration below pays one runs.clone() to build the
    // owned job (JobKind::Compact consumes its input, and pre-building
    // up to max_iters copies of the working set is not viable). The
    // clone is the same additive constant for every row; this baseline
    // measures it so readers can subtract it when comparing rows near
    // the crossover.
    let m = timer.measure(|| {
        let c = runs.clone();
        std::hint::black_box(&c);
    });
    println!("{}", report_line("input clone (bias in all rows)", &m, total as u64));

    // min_len = 0 is the unsharded flat engine; the rest sweep the
    // shard size from "2 shards" down to "64 shards".
    for (label, min_len) in [
        ("flat      (1 job)", 0usize),
        ("sharded   S≈2", total / 2),
        ("sharded   S≈4", total / 4),
        ("sharded   S≈8", total / 8),
        ("sharded   S≈16", total / 16),
        ("sharded   S≈64", total / 64),
    ] {
        let svc = service(min_len);
        // One warm-up + correctness probe per configuration.
        let probe = svc
            .submit_blocking(JobKind::Compact { runs: runs.clone() })
            .expect("probe job");
        let expected_backend =
            if min_len == 0 { "native-kway" } else { "native-kway-sharded" };
        assert_eq!(probe.backend, expected_backend, "{label}");
        let m = timer.measure(|| {
            let res = svc
                .submit_blocking(JobKind::Compact { runs: runs.clone() })
                .expect("bench job");
            std::hint::black_box(&res.output);
        });
        println!("{}", report_line(label, &m, total as u64));
        svc.shutdown();
    }

    // Cross-check once: sharded output == flat output, bit for bit.
    let flat = service(0)
        .submit_blocking(JobKind::Compact { runs: runs.clone() })
        .expect("flat job")
        .output;
    let sharded = service(total / 8)
        .submit_blocking(JobKind::Compact { runs })
        .expect("sharded job")
        .output;
    assert_eq!(flat, sharded, "sharded compaction diverged from the flat engine");
    println!("cross-check ok: sharded == flat ({total} elements)");
}
