//! Hot-path microbenches: single-core merge throughput of every kernel
//! variant against the std-sort floor, across workload shapes.
//! This is the §Perf L3 driver (see EXPERIMENTS.md §Perf).
use mergeflow::baselines::{bitonic_merge, concat_sort_merge};
use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::merge::{branchless_merge_bounded, hybrid_merge_bounded, merge_bounded};
use mergeflow::mergepath::{gallop_merge_into, merge_into, parallel_merge, segmented_parallel_merge, SegmentedConfig};

fn main() {
    let n = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize << 20);
    let timer = BenchTimer::default();
    for kind in [WorkloadKind::Uniform, WorkloadKind::Runs, WorkloadKind::OneSided] {
        println!("\n--- workload: {} (|A|=|B|={}) ---", kind.name(), n / 2);
        let (a, b) = gen_sorted_pair(kind, n / 2, n / 2, 42);
        let mut out = vec![0i32; n];
        let total = n as u64;

        let m = timer.measure(|| merge_into(&a, &b, &mut out));
        println!("{}", report_line("merge_into (two-finger)", &m, total));
        let m = timer.measure(|| merge_bounded(&a, &b, &mut out, n));
        println!("{}", report_line("merge_bounded", &m, total));
        let m = timer.measure(|| branchless_merge_bounded(&a, &b, &mut out, n));
        println!("{}", report_line("branchless_merge", &m, total));
        let m = timer.measure(|| hybrid_merge_bounded(&a, &b, &mut out, n));
        println!("{}", report_line("hybrid_merge (production kernel)", &m, total));
        let m = timer.measure(|| gallop_merge_into(&a, &b, &mut out));
        println!("{}", report_line("gallop_merge", &m, total));
        let m = timer.measure(|| parallel_merge(&a, &b, &mut out, 1));
        println!("{}", report_line("parallel_merge p=1", &m, total));
        let m = timer.measure(|| {
            segmented_parallel_merge(
                &a, &b, &mut out,
                SegmentedConfig { segment_len: 1 << 20, threads: 1 },
            )
        });
        println!("{}", report_line("segmented p=1 L=1M", &m, total));
        let m = timer.measure(|| concat_sort_merge(&a, &b, &mut out));
        println!("{}", report_line("concat+sort floor", &m, total));
        if n <= 1 << 20 {
            let m = timer.measure(|| bitonic_merge(&a, &b, &mut out, 1));
            println!("{}", report_line("bitonic network", &m, total));
        }
    }
}
