//! Ablation: SPM path-segment length L sweep (DESIGN.md calls out the
//! L = C/3 choice of Prop. 15) on the simulated 12-core machine plus
//! real single-core wallclock. Shows the U-shape: tiny L drowns in
//! per-segment partition/barrier overhead, huge L loses the cache
//! residency that motivates SPM.
use mergeflow::bench::figures::sim_scale;
use mergeflow::bench::harness::{report_line, BenchTimer, Table};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::{segmented_parallel_merge, SegmentedConfig};
use mergeflow::sim::engine::{simulate_merge, MergeAlgo, SimWorkload};
use mergeflow::sim::machine::x5670_12;
use mergeflow::sim::stream::Stage;

fn main() {
    let scale = sim_scale();
    let machine = x5670_12().scaled_caches(scale);
    let l3_elems = machine.mem.l3.capacity / 4;
    let n = ((50usize << 20) / scale).max(1 << 14);
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, n, n, 99);
    let w = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both };

    let mut t = Table::new(
        &format!(
            "SPM segment-length ablation (|A|=|B|={n}, p=8, scaled L3 = {l3_elems} elems; Prop. 15 pick = L3/3 = {})",
            l3_elems / 3
        ),
        &["L (elements)", "cycles", "L1 misses", "L3 misses", "barriers"],
    );
    let picks = [
        l3_elems / 48,
        l3_elems / 12,
        l3_elems / 3, // the paper's C/3
        l3_elems,
        4 * l3_elems,
    ];
    for l in picks {
        let r = simulate_merge(&machine, MergeAlgo::Segmented { segment_len: l.max(64) }, &w, 8);
        t.row(&[
            l.to_string(),
            r.cycles.to_string(),
            r.mem.l1.misses().to_string(),
            r.mem.l3.misses().to_string(),
            r.barriers.to_string(),
        ]);
    }
    t.print();

    println!("\nReal single-core wallclock (4M outputs):");
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 2 << 20, 2 << 20, 7);
    let mut out = vec![0i32; 4 << 20];
    let timer = BenchTimer::quick();
    for l in [1usize << 12, 1 << 16, 1 << 20, 1 << 22] {
        let m = timer.measure(|| {
            segmented_parallel_merge(
                &a,
                &b,
                &mut out,
                SegmentedConfig { segment_len: l, threads: 1 },
            )
        });
        println!("{}", report_line(&format!("SPM L={l}"), &m, 4 << 20));
    }
}
