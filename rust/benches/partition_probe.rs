//! §6.1 probe: partition (diagonal intersection) time growth with
//! thread count — simulated cycles plus real single-core wallclock of
//! the partition routine itself.
use mergeflow::bench::figures;
use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::mergepath::partition_merge_path;

fn main() {
    let scale = figures::sim_scale();
    figures::partition_probe(scale).print();

    println!("\nReal wallclock of partition_merge_path (10M-element arrays):");
    let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 10 << 20, 10 << 20, 7);
    let timer = BenchTimer::default();
    for p in [2usize, 8, 40, 400] {
        let m = timer.measure(|| {
            let segs = partition_merge_path(&a, &b, p);
            std::hint::black_box(&segs);
        });
        println!("{}", report_line(&format!("partition p={p}"), &m, p as u64));
    }
}
