//! Flat single-pass k-way Merge Path vs the pairwise-tree engine for
//! LSM-style compaction shapes: k ∈ {4, 8, 16, 64} sorted runs,
//! p ∈ {1..16} threads.
//!
//! The tree makes ⌈log₂ k⌉ full read+write passes over memory; the flat
//! engine makes exactly one, at the price of a k-way (loser-tree) inner
//! loop. Expectation: the flat engine pulls ahead as k grows (more tree
//! passes to amortise) — the §4.3 memory-traffic argument applied to
//! compaction.
//!
//! Env: MERGEFLOW_BENCH_N = total merged elements (default 4M),
//!      MERGEFLOW_BENCH_KIND = uniform|skewed|one-sided|interleaved|runs.
use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::bench::workload::{gen_sorted_runs, WorkloadKind};
use mergeflow::mergepath::{loser_tree_merge, parallel_kway_merge, parallel_tree_merge_refs};

fn main() {
    let n_total: usize = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize << 20);
    let kind = std::env::var("MERGEFLOW_BENCH_KIND")
        .ok()
        .and_then(|v| WorkloadKind::parse(&v))
        .unwrap_or(WorkloadKind::Uniform);
    let timer = BenchTimer::quick();
    println!("workload: {} x {n_total} total elements", kind.name());
    for k in [4usize, 8, 16, 64] {
        let runs = gen_sorted_runs(kind, k, n_total / k, 42);
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let total: usize = refs.iter().map(|r| r.len()).sum();
        println!("\n--- k = {k} runs of {} ({total} total) ---", total / k);
        // Every engine allocates its output inside the timed region, as
        // the coordinator does per job. (The flat/seq closures also pay
        // a zero fill that `run_compaction`'s uninit buffers avoid —
        // a bias *against* the flat engine, so its wins are conservative.)
        let m = timer.measure(|| {
            let mut out = vec![0i32; total];
            loser_tree_merge(&refs, &mut out);
            std::hint::black_box(&out);
        });
        println!("{}", report_line("loser_tree (seq, 1 pass)", &m, total as u64));
        let tree_passes = k.next_power_of_two().trailing_zeros();
        for p in [1usize, 2, 4, 8, 16] {
            let m = timer.measure(|| {
                let v = parallel_tree_merge_refs(&refs, p, None);
                std::hint::black_box(&v);
            });
            let name = format!("tree  p={p} ({tree_passes} passes)");
            println!("{}", report_line(&name, &m, total as u64));
            let m = timer.measure(|| {
                let mut out = vec![0i32; total];
                parallel_kway_merge(&refs, &mut out, p, None);
                std::hint::black_box(&out);
            });
            let name = format!("flat  p={p} (1 pass)");
            println!("{}", report_line(&name, &m, total as u64));
        }
        // Cross-check once per shape: flat == sequential loser tree.
        let mut seq = vec![0i32; total];
        loser_tree_merge(&refs, &mut seq);
        let mut out = vec![0i32; total];
        parallel_kway_merge(&refs, &mut out, 8, None);
        assert_eq!(seq, out, "flat engine diverged from the loser tree at k={k}");
    }
}
