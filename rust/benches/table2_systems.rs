//! Regenerates Table 2: the evaluation systems (simulated geometries).
use mergeflow::bench::figures;

fn main() {
    figures::table2().print();
}
