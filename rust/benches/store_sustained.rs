//! Sustained spill + background-compaction throughput of the
//! persistent run store, end to end through the coordinator.
//!
//! Two questions: (1) raw spill bandwidth — how fast do sealed runs
//! become durable level-0 run files (encode + CRC + fsync per run)?
//! (2) steady-state cost — with compaction folded in, what does a
//! record cost on its whole journey from spill to its settled level?
//! The second number is the one a capacity plan needs: it includes the
//! re-read, re-merge, and re-write amplification the policy implies.
//!
//! Env: MERGEFLOW_BENCH_N      = records per spilled run (default 256K),
//!      MERGEFLOW_BENCH_RUNS   = runs spilled per iteration (default 8),
//!      MERGEFLOW_BENCH_POLICY = tiered|leveled (default tiered).

use mergeflow::bench::harness::{report_line, BenchTimer};
use mergeflow::config::{
    Backend, InplaceMode, MergeKernel, MergeflowConfig, StoreConfig, StorePolicy,
};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::store::{RunStore, StoreBridge};
use std::sync::Arc;

fn service() -> MergeService {
    let cfg = MergeflowConfig {
        workers: 4,
        threads_per_job: 2,
        queue_capacity: 1024,
        max_batch: 32,
        batch_timeout_us: 100,
        backend: Backend::Native,
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 128,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Never,
        kernel: MergeKernel::Auto,
        // Single dispatcher shard, calibration probes off:
        // deterministic control plane and knob values.
        dispatch_shards: 1,
        dispatch_steal: true,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    };
    MergeService::start(cfg).expect("service start")
}

struct TempDir(std::path::PathBuf);

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn main() {
    let run_len: usize = std::env::var("MERGEFLOW_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256 << 10);
    let runs_per_iter: usize = std::env::var("MERGEFLOW_BENCH_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let policy = match std::env::var("MERGEFLOW_BENCH_POLICY").ok().as_deref() {
        Some("leveled") => StorePolicy::Leveled,
        _ => StorePolicy::Tiered,
    };
    let dir = std::env::temp_dir()
        .join(format!("mergeflow-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let _guard = TempDir(dir.clone());
    let store_cfg = StoreConfig {
        dir: dir.to_string_lossy().into_owned(),
        policy,
        level0_max_runs: runs_per_iter.max(2),
        level_fanout: 8,
        block_bytes: 256 << 10,
        compact_backoff_ms: 1,
    };
    let timer = BenchTimer::quick();
    println!(
        "workload: {runs_per_iter} runs x {run_len} records per iteration, policy {policy}",
        policy = store_cfg.policy
    );

    // Pre-built sorted runs; each iteration clones (owned job input) —
    // measured first so readers can subtract the bias.
    let runs: Vec<Vec<i32>> = (0..runs_per_iter)
        .map(|r| (0..run_len as i32).map(|i| i * 2 + r as i32 % 2).collect())
        .collect();
    let per_iter = (runs_per_iter * run_len) as u64;
    let m = timer.measure(|| {
        let c = runs.clone();
        std::hint::black_box(&c);
    });
    println!("{}", report_line("input clone (bias in all rows)", &m, per_iter));

    // Row 1: raw spill bandwidth — runs become durable L0 files and
    // nothing else happens: no scheduler thread is started and no
    // flush is issued, so L0 just accumulates and the timer sees only
    // encode + CRC + fsync + manifest commit per run.
    {
        let svc = Arc::new(service());
        let store = Arc::new(RunStore::<i32>::open(&store_cfg).expect("open store"));
        svc.attach_store(Arc::new(StoreBridge::new(Arc::clone(&store), svc.stats_arc())))
            .expect("attach store");
        let m = timer.measure(|| {
            for run in &runs {
                let r = svc
                    .submit_blocking(JobKind::Spill { run: run.clone() })
                    .expect("spill job");
                std::hint::black_box(&r.output);
            }
        });
        println!("{}", report_line("spill      (durable L0)", &m, per_iter));
        svc.shutdown();
    }

    // Row 2: steady state — every iteration spills a full threshold's
    // worth of runs and then drains to policy, so the measured cost
    // includes the whole compaction journey (read back + merge +
    // rewrite + manifest churn).
    {
        let dir2 = dir.join("steady");
        let store_cfg =
            StoreConfig { dir: dir2.to_string_lossy().into_owned(), ..store_cfg.clone() };
        let svc = Arc::new(service());
        let store = Arc::new(RunStore::<i32>::open(&store_cfg).expect("open store"));
        svc.attach_store(Arc::new(StoreBridge::new(Arc::clone(&store), svc.stats_arc())))
            .expect("attach store");
        let m = timer.measure(|| {
            for run in &runs {
                svc.submit_blocking(JobKind::Spill { run: run.clone() })
                    .expect("spill job");
            }
            let r = svc.submit_blocking(JobKind::Flush).expect("flush job");
            std::hint::black_box(&r.backend);
        });
        println!("{}", report_line("spill+flush (to policy)", &m, per_iter));
        println!("{}", svc.stats().snapshot());
        svc.shutdown();
    }
}
