//! Control-plane throughput: floods of tiny jobs through 1 vs N
//! dispatcher shards, with and without work stealing.
//!
//! Tiny jobs make dispatch overhead the bottleneck — the merge itself
//! is tens of nanoseconds, so jobs/sec measures the cost of admission,
//! batch assembly, routing and dispatch. The interesting comparisons:
//! shards=1 (the legacy single dispatcher) vs shards>=2, and stealing
//! on vs off under a skew where one shard's queue runs hot.
//!
//! Env: MERGEFLOW_BENCH_JOBS     = jobs per run       (default 20000),
//!      MERGEFLOW_BENCH_JOB_SIZE = elems per side     (default 64),
//!      MERGEFLOW_BENCH_SHARDS   = max shards swept   (default 4).

use mergeflow::bench::workload::{gen_sorted_pair, WorkloadKind};
use mergeflow::config::{Backend, InplaceMode, MergeKernel, MergeflowConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::metrics::{fmt_ns, fmt_throughput};
use std::time::Instant;

fn config(shards: usize, steal: bool) -> MergeflowConfig {
    MergeflowConfig {
        workers: 4,
        threads_per_job: 1,
        queue_capacity: 4096,
        max_batch: 64,
        batch_timeout_us: 50,
        backend: Backend::Native,
        segmented: false,
        segment_len: 0,
        kway_segment_elems: 0,
        cache_bytes: 0,
        kway_flat_max_k: 64,
        compact_sharding: false,
        compact_shard_min_len: 0,
        compact_chunk_len: 0,
        compact_eager_min_len: 0,
        memory_budget: 0,
        inplace: InplaceMode::Never,
        kernel: MergeKernel::Auto,
        dispatch_shards: shards,
        dispatch_steal: steal,
        calibrate: false,
        shard_floor: 1 << 18,
        artifacts_dir: "artifacts".into(),
    }
}

/// One run: flood `jobs` tiny merges through the service, wait for
/// all, report jobs/sec and the p99 admission->plan queue age.
fn run(shards: usize, steal: bool, jobs: usize, job_size: usize) {
    let svc = MergeService::start(config(shards, steal)).expect("service start");
    // A small pool of pre-generated inputs, cycled: generation cost
    // stays out of the submit loop.
    let inputs: Vec<(Vec<i32>, Vec<i32>)> = (0..64u64)
        .map(|s| gen_sorted_pair(WorkloadKind::Uniform, job_size, job_size, s))
        .collect();

    // Warmup so pool threads and queues are hot before timing.
    for (a, b) in inputs.iter().take(16) {
        let h = svc
            .submit(JobKind::Merge { a: a.clone(), b: b.clone() })
            .expect("warmup submit");
        std::hint::black_box(h.wait().expect("warmup merge"));
    }

    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let (a, b) = &inputs[i % inputs.len()];
        match svc.submit(JobKind::Merge { a: a.clone(), b: b.clone() }) {
            Ok(h) => handles.push(h),
            // Queue full: apply backpressure by draining the oldest
            // handle, then retry once.
            Err(_) => {
                if let Some(h) = handles.pop() {
                    std::hint::black_box(h.wait().expect("merge"));
                }
                let (a, b) = &inputs[i % inputs.len()];
                let h = svc
                    .submit(JobKind::Merge { a: a.clone(), b: b.clone() })
                    .expect("submit after drain");
                handles.push(h);
            }
        }
    }
    for h in handles {
        std::hint::black_box(h.wait().expect("merge"));
    }
    let elapsed_ns = t0.elapsed().as_nanos().max(1) as u64;

    let stats = svc.stats();
    let p99_age = stats.stage_admission.quantile(0.99);
    let stolen: u64 = (0..stats.dispatch_shard_count())
        .map(|i| stats.dispatch_shard(i).unwrap().stolen_jobs.get())
        .sum();
    println!(
        "dispatch_throughput shards={shards} steal={} jobs={jobs} size={job_size}: \
         {}  p99-queue-age={}  stolen={stolen}  ({} total)",
        if steal { "on" } else { "off" },
        fmt_throughput(jobs as u64, elapsed_ns).replace("e/s", " jobs/s"),
        fmt_ns(p99_age),
        fmt_ns(elapsed_ns),
    );
    svc.shutdown();
}

fn main() {
    let jobs: usize = std::env::var("MERGEFLOW_BENCH_JOBS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let job_size: usize = std::env::var("MERGEFLOW_BENCH_JOB_SIZE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let max_shards: usize = std::env::var("MERGEFLOW_BENCH_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    println!("== dispatch throughput: tiny-job floods through the sharded control plane ==");
    run(1, false, jobs, job_size);
    let mut n = 2;
    while n <= max_shards {
        run(n, false, jobs, job_size);
        run(n, true, jobs, job_size);
        n *= 2;
    }
}
