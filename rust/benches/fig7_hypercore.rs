//! Regenerates Figure 7 (a, b): Merge Path speedups on the Plurality
//! HyperCore model (32 cores, shared banked cache).
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    for t in figures::fig7(scale) {
        t.print();
    }
    println!("\npaper reference: near-linear to 16 cores for all sizes; the largest arrays dip at 32 cores for the regular algorithm only");
}
