//! Regenerates Figure 4: Merge Path speedup on the simulated 12-core
//! X5670 system for 1M / 10M / 100M-element arrays.
//! Scale via MERGEFLOW_SIM_SCALE (default 64; 1 = paper-size inputs).
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    figures::fig4(scale).print();
    println!("\npaper reference: near-linear, ~11.7x at 12 threads, slight dip for the largest arrays");
}
