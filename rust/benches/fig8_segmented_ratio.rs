//! Regenerates Figure 8: regular/segmented cycle ratio on the
//! HyperCore (values > 1 mean segmented is faster).
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    figures::fig8(scale).print();
    println!("\npaper reference: segmented pulls ahead as arrays outgrow the shared cache; regular wins for cache-resident sizes");
}
