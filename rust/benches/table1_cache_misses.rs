//! Regenerates Table 1: cache misses per parallel-merge algorithm,
//! split into partition and merge stages (measured on the simulator).
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    figures::table1(scale).print();
    println!("\npaper reference: partition O(p log N) for [9]/[8]/[2]&MP vs O(p N/C log C) for SPM; merge stage Omega(N) for all; SPM has the lowest total bound and no inter-core line sharing");
}
