//! Regenerates Table 1: cache misses per parallel-merge algorithm,
//! split into partition and merge stages (measured on the simulator) —
//! plus the k-way companion table comparing the flat compaction engine
//! against its segmented (cache-efficient) variant on a cache-busting
//! shape.
use mergeflow::bench::figures;

fn main() {
    let scale = figures::sim_scale();
    figures::table1(scale).print();
    println!("\npaper reference: partition O(p log N) for [9]/[8]/[2]&MP vs O(p N/C log C) for SPM; merge stage Omega(N) for all; SPM has the lowest total bound and no inter-core line sharing");
    println!();
    figures::table1_kway(scale).print();
    println!("\nk-way claim (Alg 3 generalised): with k + 1 live stream lines past the private cache, the flat argmin re-reads every head per output and thrashes; the segmented engine's bounded kernel touches each element once and keeps the (k+1)*L window set resident — fewer total misses on this shape (pinned by figures::tests::table1_kway_segmented_reduces_misses)");
}
