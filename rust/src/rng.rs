//! Deterministic pseudo-random number generation (no external crates).
//!
//! SplitMix64 for seeding and xoshiro256** as the workhorse generator —
//! both public-domain algorithms by Blackman & Vigna. Used by the
//! workload generators ([`crate::bench::workload`]) and the in-tree
//! property-testing runner ([`crate::testutil`]). Everything is seeded,
//! so every test and benchmark is reproducible bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (recommended by the xoshiro authors).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper bits, which are the strongest).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's multiply-shift method
    /// (with rejection to remove bias). `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection sampling on the widening multiply.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let low = m as u64;
            if low >= bound || low >= low.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform `i32` over the full range.
    pub fn next_i32(&mut self) -> i32 {
        self.next_u32() as i32
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 0 from the SplitMix64 reference code.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_distinct_seeds_distinct_streams() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::seeded(7);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_hits_all_small_values() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.below(4) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seeded(11);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_bounds() {
        let mut r = Xoshiro256::seeded(17);
        for _ in 0..500 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }
}
