//! Multi-level memory hierarchy: private L1/L2 per core, shared L3 per
//! socket, MESI-lite directory coherence, and per-socket DRAM traffic
//! accounting.
//!
//! Cost model notes (see DESIGN.md §2):
//! - Sequential-stream DRAM fills are charged a *stream* cost
//!   (`stream_fill` cycles) — hardware prefetchers hide most of the
//!   latency for the merge loop's three sequential streams.
//! - Random accesses (the partition stage's binary-search probes) pay
//!   the full `dram_latency`.
//! - Writes are write-allocate; dirty evictions from L3 count as DRAM
//!   writeback bytes. The paper's "with write backs" mode additionally
//!   flushes at the end ([`MemHierarchy::flush_all`]).
//! - A write to a line resident in another core's private cache sends
//!   invalidations (false sharing shows up here at line granularity).

use super::cache::{CacheConfig, CacheStats, SetAssocCache};
use std::collections::HashMap;

/// Read or write, sequential (prefetchable) or random.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// Sequential-stream read (prefetch-friendly).
    Read,
    /// Random-access read (binary-search probe).
    ReadRand,
    /// Sequential-stream write (write-allocate).
    Write,
}

impl AccessKind {
    /// Whether this access dirties the line.
    pub fn is_write(&self) -> bool {
        matches!(self, AccessKind::Write)
    }
}

/// Latency/geometry parameters for the hierarchy.
#[derive(Debug, Clone, Copy)]
pub struct MemSpec {
    /// Private L1 per core.
    pub l1: CacheConfig,
    /// L1 hit latency (cycles).
    pub l1_latency: u64,
    /// Private L2 per core.
    pub l2: CacheConfig,
    /// L2 hit latency.
    pub l2_latency: u64,
    /// Shared L3 per socket.
    pub l3: CacheConfig,
    /// L3 hit latency.
    pub l3_latency: u64,
    /// DRAM latency for random accesses.
    pub dram_latency: u64,
    /// Effective cycles per line fill for sequential streams
    /// (prefetcher-hidden latency).
    pub stream_fill: u64,
    /// Cost (cycles, charged to the writer) per coherence invalidation.
    pub invalidation_cost: u64,
}

/// Aggregated statistics.
#[derive(Debug, Clone, Default)]
pub struct MemStats {
    /// L1 stats summed over cores.
    pub l1: CacheStats,
    /// L2 stats summed over cores.
    pub l2: CacheStats,
    /// L3 stats summed over sockets.
    pub l3: CacheStats,
    /// DRAM line fills.
    pub dram_fills: u64,
    /// DRAM bytes moved (fills + writebacks), per socket.
    pub dram_bytes_per_socket: Vec<u64>,
    /// Coherence invalidations sent.
    pub invalidations: u64,
}

impl MemStats {
    /// Total DRAM bytes over all sockets.
    pub fn dram_bytes(&self) -> u64 {
        self.dram_bytes_per_socket.iter().sum()
    }
}

struct CorePrivate {
    l1: SetAssocCache,
    l2: SetAssocCache,
}

/// The full hierarchy for one machine.
pub struct MemHierarchy {
    spec: MemSpec,
    cores: Vec<CorePrivate>,
    sockets: Vec<SetAssocCache>,
    core_socket: Vec<usize>,
    /// line id → bitmask of cores whose private caches may hold it.
    directory: HashMap<u64, u64>,
    invalidations: u64,
    dram_fills: u64,
    dram_bytes_per_socket: Vec<u64>,
    line: u64,
}

impl MemHierarchy {
    /// Build a hierarchy for `cores` cores spread over `sockets`
    /// sockets. Mapping is *scatter* (round-robin: core `i` → socket
    /// `i % sockets`), matching the NUMA-interleaved thread placement
    /// the paper's 40-core runs used ("NUMA Contral package") — it
    /// spreads memory traffic across all sockets' channels at every
    /// thread count.
    pub fn new(spec: MemSpec, cores: usize, sockets: usize) -> Self {
        assert!(cores >= 1 && sockets >= 1);
        let core_socket: Vec<usize> = (0..cores).map(|c| c % sockets).collect();
        Self {
            spec,
            cores: (0..cores)
                .map(|_| CorePrivate {
                    l1: SetAssocCache::new(spec.l1),
                    l2: SetAssocCache::new(spec.l2),
                })
                .collect(),
            sockets: (0..sockets).map(|_| SetAssocCache::new(spec.l3)).collect(),
            core_socket,
            directory: HashMap::new(),
            invalidations: 0,
            dram_fills: 0,
            dram_bytes_per_socket: vec![0; sockets],
            line: spec.l1.line as u64,
        }
    }

    /// Socket of a core.
    pub fn socket_of(&self, core: usize) -> usize {
        self.core_socket[core]
    }

    /// Simulate one access by `core`; returns its cost in cycles.
    pub fn access(&mut self, core: usize, addr: u64, kind: AccessKind) -> u64 {
        let spec = self.spec;
        let line_id = addr / self.line;
        let mut cost = 0u64;

        // Coherence: writes invalidate other cores' private copies.
        if kind.is_write() {
            let mask = self.directory.entry(line_id).or_insert(0);
            let others = *mask & !(1u64 << core);
            if others != 0 {
                let mut m = others;
                while m != 0 {
                    let other = m.trailing_zeros() as usize;
                    m &= m - 1;
                    self.cores[other].l1.invalidate(addr);
                    self.cores[other].l2.invalidate(addr);
                    self.invalidations += 1;
                    cost += spec.invalidation_cost;
                }
            }
            *self.directory.get_mut(&line_id).unwrap() = 1u64 << core;
        }

        // L1.
        let l1_hit = self.cores[core].l1.access(addr, kind.is_write());
        cost += spec.l1_latency;
        if l1_hit {
            return cost;
        }
        // Register this core as a sharer (fill on the way back).
        if !kind.is_write() {
            *self.directory.entry(line_id).or_insert(0) |= 1u64 << core;
        }

        // L2.
        let l2_hit = self.cores[core].l2.access(addr, kind.is_write());
        cost += spec.l2_latency;
        if l2_hit {
            return cost;
        }

        // L3 (shared per socket).
        let socket = self.core_socket[core];
        let l3_before_wb = self.sockets[socket].stats().writebacks;
        let l3_hit = self.sockets[socket].access(addr, kind.is_write());
        cost += spec.l3_latency;
        // L3 dirty evictions go to DRAM.
        let wb = self.sockets[socket].stats().writebacks - l3_before_wb;
        self.dram_bytes_per_socket[socket] += wb * self.line;
        if l3_hit {
            return cost;
        }

        // DRAM.
        self.dram_fills += 1;
        self.dram_bytes_per_socket[socket] += self.line;
        cost += match kind {
            AccessKind::ReadRand => spec.dram_latency,
            _ => spec.stream_fill,
        };
        cost
    }

    /// Flush all caches (writeback mode end-of-run accounting). Returns
    /// total lines written back from L3s to DRAM.
    pub fn flush_all(&mut self) -> u64 {
        // Private-cache dirty lines drain into L3 (not counted as DRAM),
        // then L3 flush counts DRAM bytes.
        for core in &mut self.cores {
            core.l1.flush();
            core.l2.flush();
        }
        let mut total = 0u64;
        for (s, l3) in self.sockets.iter_mut().enumerate() {
            let wb = l3.flush();
            self.dram_bytes_per_socket[s] += wb * self.line;
            total += wb;
        }
        total
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> MemStats {
        let mut st = MemStats {
            dram_fills: self.dram_fills,
            dram_bytes_per_socket: self.dram_bytes_per_socket.clone(),
            invalidations: self.invalidations,
            ..Default::default()
        };
        for c in &self.cores {
            st.l1.merge(&c.l1.stats());
            st.l2.merge(&c.l2.stats());
        }
        for s in &self.sockets {
            st.l3.merge(&s.stats());
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::ReplacementPolicy;

    fn tiny_spec() -> MemSpec {
        let mk = |cap: usize, ways: usize| CacheConfig {
            capacity: cap,
            line: 64,
            ways,
            policy: ReplacementPolicy::Lru,
        };
        MemSpec {
            l1: mk(512, 2),
            l1_latency: 4,
            l2: mk(2048, 4),
            l2_latency: 12,
            l3: mk(8192, 8),
            l3_latency: 40,
            dram_latency: 200,
            stream_fill: 30,
            invalidation_cost: 80,
        }
    }

    #[test]
    fn hit_path_costs_add_up() {
        let mut m = MemHierarchy::new(tiny_spec(), 2, 1);
        // Cold miss: L1+L2+L3+stream fill.
        let c0 = m.access(0, 0, AccessKind::Read);
        assert_eq!(c0, 4 + 12 + 40 + 30);
        // Now in L1.
        let c1 = m.access(0, 0, AccessKind::Read);
        assert_eq!(c1, 4);
        // Random cold miss pays full DRAM latency.
        let c2 = m.access(0, 4096, AccessKind::ReadRand);
        assert_eq!(c2, 4 + 12 + 40 + 200);
    }

    #[test]
    fn l3_shared_within_socket() {
        let mut m = MemHierarchy::new(tiny_spec(), 2, 1);
        m.access(0, 0, AccessKind::Read); // core 0 pulls into shared L3
        let c = m.access(1, 0, AccessKind::Read); // core 1: L3 hit
        assert_eq!(c, 4 + 12 + 40);
    }

    #[test]
    fn l3_not_shared_across_sockets() {
        let mut m = MemHierarchy::new(tiny_spec(), 2, 2);
        m.access(0, 0, AccessKind::Read);
        let c = m.access(1, 0, AccessKind::Read); // other socket: DRAM again
        assert_eq!(c, 4 + 12 + 40 + 30);
        assert_eq!(m.stats().dram_fills, 2);
    }

    #[test]
    fn write_invalidates_other_cores() {
        let mut m = MemHierarchy::new(tiny_spec(), 2, 1);
        m.access(0, 0, AccessKind::Read); // core 0 caches line
        m.access(1, 0, AccessKind::Read); // core 1 caches line
        let c = m.access(1, 0, AccessKind::Write); // invalidate core 0
        assert!(c >= 80, "writer pays invalidation cost, got {c}");
        assert_eq!(m.stats().invalidations, 1);
        // Core 0 must re-fetch.
        let c0 = m.access(0, 0, AccessKind::Read);
        assert!(c0 > 4, "core 0's copy was invalidated");
    }

    #[test]
    fn false_sharing_same_line_different_addrs() {
        let mut m = MemHierarchy::new(tiny_spec(), 2, 1);
        m.access(0, 0, AccessKind::Write); // core 0 writes byte 0
        let c = m.access(1, 32, AccessKind::Write); // core 1, same 64B line!
        assert!(c >= 80);
        assert_eq!(m.stats().invalidations, 1);
    }

    #[test]
    fn dram_byte_accounting_and_flush() {
        let mut m = MemHierarchy::new(tiny_spec(), 1, 1);
        for i in 0..64u64 {
            m.access(0, i * 64, AccessKind::Write); // 64 dirty lines
        }
        let before = m.stats().dram_bytes();
        assert!(before >= 64 * 64); // all fills counted
        m.flush_all();
        let after = m.stats().dram_bytes();
        // Flush adds writeback bytes for dirty lines still resident.
        assert!(after > before);
    }

    #[test]
    fn socket_mapping_scatter() {
        let m = MemHierarchy::new(tiny_spec(), 8, 2);
        assert_eq!(m.socket_of(0), 0);
        assert_eq!(m.socket_of(1), 1);
        assert_eq!(m.socket_of(2), 0);
        assert_eq!(m.socket_of(7), 1);
    }
}
