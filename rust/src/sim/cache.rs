//! Set-associative cache model with LRU/FIFO replacement and the §4.2
//! miss taxonomy (compulsory / capacity / conflict).
//!
//! Addresses are byte addresses; the cache operates on lines. Miss
//! classification follows Hill's standard method: a miss is
//! *compulsory* if the line was never referenced before, *capacity* if
//! a fully-associative LRU cache of the same size would also miss, and
//! *conflict* otherwise. The fully-associative shadow is maintained
//! lazily (an ordered recency list over line ids), which is exact and
//! costs `O(1)` amortized via a hash map + sequence numbers.

use std::collections::HashMap;

/// Replacement policy (§4.2 "Cache replacement policy").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplacementPolicy {
    /// Least-recently-used.
    Lru,
    /// First-in-first-out.
    Fifo,
}

/// Geometry + policy of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Total capacity in bytes.
    pub capacity: usize,
    /// Line size in bytes.
    pub line: usize,
    /// Associativity (ways); `capacity / line / ways` sets must be ≥ 1.
    pub ways: usize,
    /// Replacement policy.
    pub policy: ReplacementPolicy,
}

impl CacheConfig {
    /// Number of sets.
    pub fn sets(&self) -> usize {
        (self.capacity / self.line / self.ways).max(1)
    }

    /// Capacity in lines.
    pub fn lines(&self) -> usize {
        self.capacity / self.line
    }
}

/// Hit/miss counters, split by the §4.2 taxonomy.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Accesses that hit.
    pub hits: u64,
    /// Compulsory (cold) misses.
    pub compulsory: u64,
    /// Capacity misses (fully-associative shadow also missed).
    pub capacity: u64,
    /// Conflict misses (shadow would have hit).
    pub conflict: u64,
    /// Dirty lines written back on eviction.
    pub writebacks: u64,
}

impl CacheStats {
    /// Total misses.
    pub fn misses(&self) -> u64 {
        self.compulsory + self.capacity + self.conflict
    }

    /// Total accesses.
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses()
    }

    /// Miss rate in [0, 1].
    pub fn miss_rate(&self) -> f64 {
        let a = self.accesses();
        if a == 0 {
            0.0
        } else {
            self.misses() as f64 / a as f64
        }
    }

    /// Accumulate another stats block.
    pub fn merge(&mut self, o: &CacheStats) {
        self.hits += o.hits;
        self.compulsory += o.compulsory;
        self.capacity += o.capacity;
        self.conflict += o.conflict;
        self.writebacks += o.writebacks;
    }
}

#[derive(Debug, Clone, Copy)]
struct Way {
    tag: u64, // full line id (addr / line); u64::MAX = invalid
    stamp: u64, // recency (LRU) or insertion (FIFO) sequence number
    dirty: bool,
}

const INVALID: u64 = u64::MAX;

/// One set-associative cache level.
#[derive(Debug)]
pub struct SetAssocCache {
    cfg: CacheConfig,
    sets: Vec<Vec<Way>>, // sets × ways
    seq: u64,
    stats: CacheStats,
    // Miss classification state:
    seen: HashMap<u64, ()>, // lines ever referenced (compulsory check)
    shadow: ShadowLru,      // fully-associative same-capacity LRU
}

impl SetAssocCache {
    /// Build an empty cache.
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.line.is_power_of_two(), "line size must be a power of two");
        assert!(cfg.ways >= 1);
        let sets = cfg.sets();
        Self {
            cfg,
            sets: vec![
                vec![Way { tag: INVALID, stamp: 0, dirty: false }; cfg.ways];
                sets
            ],
            seq: 0,
            stats: CacheStats::default(),
            seen: HashMap::new(),
            shadow: ShadowLru::new(cfg.lines()),
        }
    }

    /// Geometry.
    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    /// Counters so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset counters (not contents).
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Line id for a byte address.
    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr / self.cfg.line as u64
    }

    /// Access `addr`; returns `true` on hit. On miss the line is
    /// filled (allocate-on-write too: write-allocate policy). `write`
    /// marks the line dirty; evicting a dirty line counts a writeback.
    pub fn access(&mut self, addr: u64, write: bool) -> bool {
        let line = self.line_of(addr);
        let set_idx = (line % self.sets.len() as u64) as usize;
        self.seq += 1;
        let seq = self.seq;
        let policy = self.cfg.policy;

        let shadow_hit = self.shadow.touch(line);

        let set = &mut self.sets[set_idx];
        if let Some(way) = set.iter_mut().find(|w| w.tag == line) {
            if policy == ReplacementPolicy::Lru {
                way.stamp = seq;
            }
            way.dirty |= write;
            self.stats.hits += 1;
            return true;
        }

        // Miss: classify.
        if self.seen.insert(line, ()).is_none() {
            self.stats.compulsory += 1;
        } else if shadow_hit {
            self.stats.conflict += 1;
        } else {
            self.stats.capacity += 1;
        }

        // Fill: pick victim (invalid first, else min stamp).
        let victim = set
            .iter_mut()
            .min_by_key(|w| if w.tag == INVALID { (0, 0) } else { (1, w.stamp) })
            .expect("ways >= 1");
        if victim.tag != INVALID && victim.dirty {
            self.stats.writebacks += 1;
        }
        victim.tag = line;
        victim.stamp = seq;
        victim.dirty = write;
        false
    }

    /// Invalidate a line if present (coherence); returns `true` if the
    /// line was present and dirty (owner must write back).
    pub fn invalidate(&mut self, addr: u64) -> bool {
        let line = self.line_of(addr);
        let set_idx = (line % self.sets.len() as u64) as usize;
        let set = &mut self.sets[set_idx];
        if let Some(way) = set.iter_mut().find(|w| w.tag == line) {
            let was_dirty = way.dirty;
            way.tag = INVALID;
            way.dirty = false;
            if was_dirty {
                self.stats.writebacks += 1;
            }
            return was_dirty;
        }
        false
    }

    /// Whether the line holding `addr` is resident.
    pub fn contains(&self, addr: u64) -> bool {
        let line = addr / self.cfg.line as u64;
        let set_idx = (line % self.sets.len() as u64) as usize;
        self.sets[set_idx].iter().any(|w| w.tag == line)
    }

    /// Flush everything, counting writebacks of dirty lines. Models the
    /// paper's "write backs" measurement mode (Fig 5a/5b include the
    /// final traffic, 5c/5d do not).
    pub fn flush(&mut self) -> u64 {
        let mut wb = 0;
        for set in &mut self.sets {
            for way in set.iter_mut() {
                if way.tag != INVALID && way.dirty {
                    wb += 1;
                }
                way.tag = INVALID;
                way.dirty = false;
            }
        }
        self.stats.writebacks += wb;
        wb
    }
}

/// Exact fully-associative LRU shadow for conflict/capacity
/// classification: a hash map from line → recency stamp plus a BTreeMap
/// from stamp → line for O(log n) eviction of the oldest.
#[derive(Debug)]
struct ShadowLru {
    capacity_lines: usize,
    stamp_of: HashMap<u64, u64>,
    by_stamp: std::collections::BTreeMap<u64, u64>,
    seq: u64,
}

impl ShadowLru {
    fn new(capacity_lines: usize) -> Self {
        Self {
            capacity_lines: capacity_lines.max(1),
            stamp_of: HashMap::new(),
            by_stamp: std::collections::BTreeMap::new(),
            seq: 0,
        }
    }

    /// Touch a line; returns `true` if it was resident (shadow hit).
    fn touch(&mut self, line: u64) -> bool {
        self.seq += 1;
        let hit = if let Some(old) = self.stamp_of.insert(line, self.seq) {
            self.by_stamp.remove(&old);
            true
        } else {
            false
        };
        self.by_stamp.insert(self.seq, line);
        if self.stamp_of.len() > self.capacity_lines {
            // Evict LRU.
            let (&oldest, &victim) = self.by_stamp.iter().next().expect("non-empty");
            self.by_stamp.remove(&oldest);
            self.stamp_of.remove(&victim);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: usize, line: usize, ways: usize, policy: ReplacementPolicy) -> CacheConfig {
        CacheConfig { capacity, line, ways, policy }
    }

    #[test]
    fn geometry() {
        let c = cfg(32 * 1024, 64, 8, ReplacementPolicy::Lru);
        assert_eq!(c.sets(), 64);
        assert_eq!(c.lines(), 512);
    }

    #[test]
    fn first_access_is_compulsory_miss_then_hit() {
        let mut c = SetAssocCache::new(cfg(1024, 64, 2, ReplacementPolicy::Lru));
        assert!(!c.access(0, false));
        assert!(c.access(0, false));
        assert!(c.access(63, false)); // same line
        assert!(!c.access(64, false)); // next line
        let s = c.stats();
        assert_eq!(s.compulsory, 2);
        assert_eq!(s.hits, 2);
        assert_eq!(s.capacity + s.conflict, 0);
    }

    #[test]
    fn lru_evicts_least_recent() {
        // 2 ways, 1 set (capacity 128B, line 64B).
        let mut c = SetAssocCache::new(cfg(128, 64, 2, ReplacementPolicy::Lru));
        c.access(0, false); // line 0
        c.access(64, false); // line 1
        c.access(0, false); // touch line 0 → line 1 is LRU
        c.access(128, false); // evicts line 1
        assert!(c.contains(0));
        assert!(!c.contains(64));
        assert!(c.contains(128));
    }

    #[test]
    fn fifo_evicts_first_in() {
        let mut c = SetAssocCache::new(cfg(128, 64, 2, ReplacementPolicy::Fifo));
        c.access(0, false);
        c.access(64, false);
        c.access(0, false); // FIFO ignores recency
        c.access(128, false); // evicts line 0 (first in)
        assert!(!c.contains(0));
        assert!(c.contains(64));
        assert!(c.contains(128));
    }

    #[test]
    fn conflict_miss_classification() {
        // Direct-mapped, 2 sets (128B, 64B lines, 1 way): lines 0 and 2
        // collide in set 0 while capacity (2 lines) is sufficient.
        let mut c = SetAssocCache::new(cfg(128, 64, 1, ReplacementPolicy::Lru));
        c.access(0, false); // line 0 compulsory
        c.access(128, false); // line 2 compulsory (set 0 conflict with line 0)
        c.access(0, false); // line 0 again: shadow (2-line LRU) still holds it
        let s = c.stats();
        assert_eq!(s.compulsory, 2);
        assert_eq!(s.conflict, 1);
        assert_eq!(s.capacity, 0);
    }

    #[test]
    fn capacity_miss_classification() {
        // 1 line total; stream over 3 lines → revisits are capacity misses.
        let mut c = SetAssocCache::new(cfg(64, 64, 1, ReplacementPolicy::Lru));
        for round in 0..2 {
            for line in 0..3u64 {
                c.access(line * 64, false);
                let _ = round;
            }
        }
        let s = c.stats();
        assert_eq!(s.compulsory, 3);
        assert_eq!(s.capacity, 3);
        assert_eq!(s.conflict, 0);
    }

    #[test]
    fn writeback_on_dirty_eviction_and_flush() {
        let mut c = SetAssocCache::new(cfg(64, 64, 1, ReplacementPolicy::Lru));
        c.access(0, true); // dirty line 0
        c.access(64, false); // evicts dirty line 0 → writeback
        assert_eq!(c.stats().writebacks, 1);
        c.access(128, true); // dirty line 2 (evicts clean line 1, no wb)
        assert_eq!(c.stats().writebacks, 1);
        let wb = c.flush();
        assert_eq!(wb, 1); // line 2 flushed dirty
        assert_eq!(c.stats().writebacks, 2);
        assert!(!c.contains(128));
    }

    #[test]
    fn invalidate_reports_dirtiness() {
        let mut c = SetAssocCache::new(cfg(256, 64, 4, ReplacementPolicy::Lru));
        c.access(0, true);
        c.access(64, false);
        assert!(c.invalidate(0)); // dirty
        assert!(!c.invalidate(64)); // clean
        assert!(!c.invalidate(192)); // absent
        assert!(!c.contains(0));
    }

    #[test]
    fn streaming_miss_rate_is_one_per_line() {
        // Sequential scan of 4096 bytes with 64B lines: 1 miss per 16
        // 4-byte elements (the §4.2 "contiguous data" observation).
        let mut c = SetAssocCache::new(cfg(8 * 1024, 64, 8, ReplacementPolicy::Lru));
        for i in 0..1024u64 {
            c.access(i * 4, false);
        }
        let s = c.stats();
        assert_eq!(s.misses(), 1024 * 4 / 64);
        assert_eq!(s.hits, 1024 - 64);
    }

    #[test]
    fn three_way_associativity_avoids_merge_conflicts() {
        // Prop. 15: three streams (A, B, S) at arbitrary bases, each
        // C/3 long, cannot conflict in a 3-way cache. Simulate the SPM
        // window access pattern and assert zero conflict misses.
        let line = 64usize;
        let capacity = 3 * 1024 * line; // 3072 lines, 1024 sets of 3
        let mut c = SetAssocCache::new(cfg(capacity, line, 3, ReplacementPolicy::Lru));
        let l = capacity / 3; // window bytes per array = C/3
        // Awkward, unaligned bases:
        let base_a = 0u64;
        let base_b = 10_000_000 + 64 * 7;
        let base_s = 99_000_000 + 64 * 13;
        for i in 0..(l as u64 / 4) {
            c.access(base_a + i * 4, false);
            c.access(base_b + i * 4, false);
            c.access(base_s + i * 4, true);
        }
        assert_eq!(c.stats().conflict, 0, "{:?}", c.stats());
    }
}
