//! Deterministic machine simulators.
//!
//! The paper's evaluation ran on hardware this build environment does
//! not have (12-core X5670, 40-core E7-8870, Plurality HyperCore FPGA —
//! Table 2) and the build host exposes a single core, so *speedup*
//! cannot be measured as wallclock. Instead, every figure is
//! regenerated through a virtual-time execution model driven by the
//! **real access patterns** of the algorithms:
//!
//! - [`cache`] — set-associative cache with LRU/FIFO replacement and
//!   compulsory/capacity/conflict miss classification (§4.2).
//! - [`mem`] — a full private-L1/L2 + shared-per-socket-L3 hierarchy
//!   with a MESI-lite directory (invalidations, false sharing) and
//!   per-socket DRAM bandwidth accounting.
//! - [`machine`] — the Table 2 machine models plus the HyperCore.
//! - [`stream`] — per-thread memory access streams for each algorithm
//!   (Merge Path, SPM, Shiloach–Vishkin, Akl–Santoro, bitonic), built
//!   from the same partition code the real implementations use.
//! - [`engine`] — the virtual-time engine: round-robin interleaving of
//!   thread streams through the hierarchy, makespan + bandwidth bound.
//! - [`hypercore`] — the Plurality shared banked-cache UMA model
//!   (§6.2): bank-conflict serialization, no private caches,
//!   few-cycle dispatch.
//!
//! Approximations are documented in DESIGN.md §2; every simulated
//! algorithm's *output* is asserted equal to the real implementation's
//! in tests, so the access streams are faithful by construction.

pub mod cache;
pub mod engine;
pub mod hypercore;
pub mod machine;
pub mod mem;
pub mod stream;

pub use cache::{CacheConfig, CacheStats, ReplacementPolicy, SetAssocCache};
pub use engine::{
    simulate_kway_merge, simulate_merge, KwayMergeAlgo, MergeAlgo, SimReport, SimWorkload,
};
pub use hypercore::{simulate_hypercore, HyperCoreSpec};
pub use machine::MachineSpec;
pub use mem::{AccessKind, MemHierarchy, MemStats};
