//! Machine models — the paper's Table 2 systems plus defaults used by
//! the figure benches.

use super::cache::{CacheConfig, ReplacementPolicy};
use super::mem::MemSpec;

/// A simulated shared-memory x86 machine (Table 2 geometry).
#[derive(Debug, Clone, Copy)]
pub struct MachineSpec {
    /// Human-readable name (appears in the bench tables).
    pub name: &'static str,
    /// Number of sockets (each with its own L3 + memory channels).
    pub sockets: usize,
    /// Cores per socket.
    pub cores_per_socket: usize,
    /// Memory hierarchy parameters.
    pub mem: MemSpec,
    /// Per-socket DRAM bandwidth in bytes/cycle.
    pub dram_bytes_per_cycle: f64,
    /// Barrier cost model: `base + per_level · ⌈log₂ p⌉` cycles.
    pub barrier_base: u64,
    /// Per-tree-level barrier cost.
    pub barrier_per_level: u64,
    /// One-time parallel-region fork cost (OpenMP dispatch).
    pub fork_cost: u64,
    /// Non-memory cycles per merge step (compare + branch + bump).
    pub cpi_step: u64,
    /// Non-memory cycles per binary-search probe.
    pub cpi_probe: u64,
}

impl MachineSpec {
    /// Total cores.
    pub fn cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Barrier cost for `p` participants.
    pub fn barrier_cost(&self, p: usize) -> u64 {
        if p <= 1 {
            return 0;
        }
        let levels = usize::BITS - (p - 1).leading_zeros();
        self.barrier_base + self.barrier_per_level * levels as u64
    }

    /// Scale the machine for `1/scale`-size simulations: the benches
    /// shrink the paper's array sizes by `scale` to keep simulation
    /// time sane; shrinking cache capacities by the same factor
    /// preserves every N/C ratio, and shrinking the fixed
    /// synchronization costs (barrier, fork) preserves every
    /// sync-to-work ratio — see DESIGN.md §2. Associativity, line size
    /// and per-access latencies are unchanged.
    pub fn scaled_caches(mut self, scale: usize) -> Self {
        assert!(scale >= 1);
        let fix = |c: &mut CacheConfig| {
            c.capacity = (c.capacity / scale).max(c.line * c.ways);
        };
        fix(&mut self.mem.l1);
        fix(&mut self.mem.l2);
        fix(&mut self.mem.l3);
        self.barrier_base = (self.barrier_base / scale as u64).max(1);
        self.barrier_per_level = (self.barrier_per_level / scale as u64).max(1);
        self.fork_cost = (self.fork_cost / scale as u64).max(1);
        self
    }
}

const LINE: usize = 64;

fn cache(capacity: usize, ways: usize) -> CacheConfig {
    CacheConfig {
        capacity,
        line: LINE,
        ways,
        policy: ReplacementPolicy::Lru,
    }
}

/// 12-core system of Fig. 4: 2 × Intel X5670 (6 cores/socket),
/// 32KB L1, 256KB L2, 12MB shared L3, 12GB DDR3 (Table 2, row 1).
pub fn x5670_12() -> MachineSpec {
    MachineSpec {
        name: "2x X5670 (12 cores)",
        sockets: 2,
        cores_per_socket: 6,
        mem: MemSpec {
            l1: cache(32 * 1024, 8),
            l1_latency: 4,
            l2: cache(256 * 1024, 8),
            l2_latency: 11,
            l3: cache(12 * 1024 * 1024, 16),
            l3_latency: 40,
            dram_latency: 180,
            stream_fill: 24,
            invalidation_cost: 120,
        },
        // Effective achievable stream bandwidth per socket (mixed
        // read/write streams reach well below the 32 GB/s peak):
        // ~16 GB/s at 2.93 GHz ≈ 5.5 B/cycle.
        dram_bytes_per_cycle: 5.5,
        barrier_base: 1200,
        barrier_per_level: 600,
        fork_cost: 8000,
        cpi_step: 3,
        cpi_probe: 4,
    }
}

/// 40-core system of Fig. 5: 4 × Intel E7-8870 (10 cores/socket),
/// 32KB L1, 256KB L2, 30MB shared L3, 256GB (Table 2, row 2).
/// Cross-socket coherence is pricier (4-socket ring).
pub fn e7_8870_40() -> MachineSpec {
    MachineSpec {
        name: "4x E7-8870 (40 cores)",
        sockets: 4,
        cores_per_socket: 10,
        mem: MemSpec {
            l1: cache(32 * 1024, 8),
            l1_latency: 4,
            l2: cache(256 * 1024, 8),
            l2_latency: 12,
            l3: cache(30 * 1024 * 1024, 20),
            l3_latency: 45,
            dram_latency: 220,
            stream_fill: 26,
            invalidation_cost: 300,
        },
        // Effective achievable stream bandwidth per socket: ~8 GB/s
        // at 2.4 GHz ≈ 3.5 B/cycle (Westmere-EX mixed read/write
        // streams under full-socket load reach a fraction of peak).
        dram_bytes_per_cycle: 3.5,
        barrier_base: 2000,
        barrier_per_level: 900,
        fork_cost: 12000,
        cpi_step: 3,
        cpi_probe: 4,
    }
}

/// Table 2 as printed by the `table2_systems` bench.
pub fn table2_rows() -> Vec<[String; 8]> {
    let specs = [x5670_12(), e7_8870_40()];
    specs
        .iter()
        .map(|s| {
            [
                s.name.to_string(),
                s.sockets.to_string(),
                s.cores_per_socket.to_string(),
                s.cores().to_string(),
                format!("{}KB", s.mem.l1.capacity / 1024),
                format!("{}KB", s.mem.l2.capacity / 1024),
                format!("{}MB", s.mem.l3.capacity / 1024 / 1024),
                format!("{:.0} B/cyc/socket", s.dram_bytes_per_cycle),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_geometry_matches_paper() {
        let a = x5670_12();
        assert_eq!(a.cores(), 12);
        assert_eq!(a.mem.l1.capacity, 32 * 1024);
        assert_eq!(a.mem.l2.capacity, 256 * 1024);
        assert_eq!(a.mem.l3.capacity, 12 * 1024 * 1024);
        let b = e7_8870_40();
        assert_eq!(b.cores(), 40);
        assert_eq!(b.mem.l3.capacity, 30 * 1024 * 1024);
    }

    #[test]
    fn barrier_cost_grows_with_p() {
        let m = x5670_12();
        assert_eq!(m.barrier_cost(1), 0);
        assert!(m.barrier_cost(2) < m.barrier_cost(12));
        assert!(m.barrier_cost(12) > 0);
    }

    #[test]
    fn scaled_caches_preserve_ratio() {
        let m = e7_8870_40().scaled_caches(16);
        assert_eq!(m.mem.l3.capacity, 30 * 1024 * 1024 / 16);
        assert_eq!(m.mem.l1.capacity, 2 * 1024);
        // Never below one full set row.
        let tiny = x5670_12().scaled_caches(1 << 20);
        assert!(tiny.mem.l1.capacity >= 64 * 8);
    }

    #[test]
    fn table2_rows_shape() {
        let rows = table2_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][3], "12");
        assert_eq!(rows[1][3], "40");
    }
}
