//! Per-thread memory access streams for the simulated algorithms.
//!
//! Each function replays one thread's work — steering on the *real*
//! data with the *same* partition routines the live implementations
//! use — and records the memory events. The virtual-time engine then
//! charges them against the machine model.
//!
//! Event conventions (cf. §4.2 of the paper): the two-finger merge
//! reads one new element per step (the loser of the previous comparison
//! stays in a register) and writes one output element; binary-search
//! probes are random accesses (2 reads per probe: one in `A`, one in
//! `B`).

use crate::mergepath::diagonal::{diagonal_intersection, PathPoint};

/// One memory event (addresses are simulated byte addresses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Sequential read.
    Read(u64),
    /// Random (binary-search) read.
    ReadRand(u64),
    /// Sequential write.
    Write(u64),
    /// Synchronization point (all threads of the region).
    Barrier,
}

/// Which pipeline stage to record (Table 1 splits partition vs merge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Only the partition-stage probes.
    Partition,
    /// Only the merge loop.
    Merge,
    /// Everything.
    Both,
}

impl Stage {
    fn partition(&self) -> bool {
        matches!(self, Stage::Partition | Stage::Both)
    }
    fn merge(&self) -> bool {
        matches!(self, Stage::Merge | Stage::Both)
    }
}

/// Address layout of the three arrays in simulated memory.
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    /// Base address of `A`.
    pub base_a: u64,
    /// Base address of `B`.
    pub base_b: u64,
    /// Base address of the output `S`.
    pub base_s: u64,
    /// Element size in bytes (the paper's experiments use 32-bit ints).
    pub elem: u64,
}

impl Layout {
    /// A, B, S laid out consecutively, each base aligned to a 64-byte
    /// cache line (as any real allocator returns for large arrays),
    /// 4-byte elements.
    pub fn contiguous(na: usize, nb: usize) -> Self {
        let elem = 4u64;
        let align = |x: u64| x.div_ceil(64) * 64;
        let base_b = align(na as u64 * elem);
        let base_s = align(base_b + nb as u64 * elem);
        Self { base_a: 0, base_b, base_s, elem }
    }

    #[inline]
    fn a(&self, i: usize) -> u64 {
        self.base_a + i as u64 * self.elem
    }
    #[inline]
    fn b(&self, j: usize) -> u64 {
        self.base_b + j as u64 * self.elem
    }
    #[inline]
    fn s(&self, k: usize) -> u64 {
        self.base_s + k as u64 * self.elem
    }
}

/// Mirror of [`diagonal_intersection`]'s binary search that records its
/// probe pattern. Debug-asserted to agree with the real routine.
fn emit_diagonal_search(
    a: &[i32],
    b: &[i32],
    diag: usize,
    layout: &Layout,
    out: &mut Vec<Ev>,
) -> PathPoint {
    let mut lo = diag.saturating_sub(b.len());
    let mut hi = diag.min(a.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        out.push(Ev::ReadRand(layout.a(mid)));
        out.push(Ev::ReadRand(layout.b(diag - 1 - mid)));
        if a[mid] <= b[diag - 1 - mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    let pt = PathPoint { a: lo, b: diag - lo };
    debug_assert_eq!(pt, diagonal_intersection(a, b, diag));
    pt
}

/// Replay a bounded two-finger merge of `len` outputs starting at
/// `(a0, b0)` (global indices) writing to output index `out0`.
/// One sequential read per consumed element, one write per output
/// (skipped when `writeback` is false — the paper's register mode).
#[allow(clippy::too_many_arguments)]
fn emit_merge(
    a: &[i32],
    b: &[i32],
    a0: usize,
    b0: usize,
    out0: usize,
    len: usize,
    writeback: bool,
    layout: &Layout,
    out: &mut Vec<Ev>,
) {
    let (mut i, mut j) = (a0, b0);
    for k in 0..len {
        let take_a = i < a.len() && (j >= b.len() || a[i] <= b[j]);
        if take_a {
            out.push(Ev::Read(layout.a(i)));
            i += 1;
        } else {
            out.push(Ev::Read(layout.b(j)));
            j += 1;
        }
        if writeback {
            out.push(Ev::Write(layout.s(out0 + k)));
        }
    }
}

/// Thread `tid`'s events for the regular Merge Path (Alg 1).
pub fn merge_path_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let n = a.len() + b.len();
    let d0 = tid * n / p;
    let d1 = (tid + 1) * n / p;
    let mut out = Vec::new();
    let start = if stage.partition() {
        emit_diagonal_search(a, b, d0, layout, &mut out)
    } else {
        diagonal_intersection(a, b, d0)
    };
    if stage.merge() {
        emit_merge(a, b, start.a, start.b, d0, d1 - d0, writeback, layout, &mut out);
    }
    out
}

/// Thread `tid`'s events for Segmented Parallel Merge (Alg 3) with
/// path-segment length `l`. A [`Ev::Barrier`] separates segments.
#[allow(clippy::too_many_arguments)]
pub fn spm_events(
    a: &[i32],
    b: &[i32],
    l: usize,
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p && l > 0);
    let n = a.len() + b.len();
    let mut out = Vec::new();
    let (mut a0, mut b0, mut done) = (0usize, 0usize, 0usize);
    while done < n {
        let wlen = l.min(n - done);
        let a_win = &a[a0..(a0 + wlen).min(a.len())];
        let b_win = &b[b0..(b0 + wlen).min(b.len())];
        let wl = Layout {
            base_a: layout.a(a0),
            base_b: layout.b(b0),
            base_s: layout.s(done),
            elem: layout.elem,
        };
        let d0 = tid * wlen / p;
        let d1 = (tid + 1) * wlen / p;
        let start = if stage.partition() {
            emit_diagonal_search(a_win, b_win, d0, &wl, &mut out)
        } else {
            diagonal_intersection(a_win, b_win, d0)
        };
        if stage.merge() {
            emit_merge(
                a_win, b_win, start.a, start.b, d0, d1 - d0, writeback, &wl, &mut out,
            );
        }
        // Advance the cursor. §4.3: "each of the p cores must compute
        // its starting points (in A and in B) independently" — every
        // thread replicates the window-end search (CREW reads), which
        // keeps the per-segment load symmetric instead of creating a
        // leader straggler at the barrier.
        let end = if stage.partition() {
            emit_diagonal_search(a_win, b_win, wlen, &wl, &mut out)
        } else {
            diagonal_intersection(a_win, b_win, wlen)
        };
        a0 += end.a;
        b0 += end.b;
        done += wlen;
        out.push(Ev::Barrier);
    }
    out
}

/// Thread `tid`'s events for Shiloach–Vishkin (round-robin chunk deal,
/// same decomposition as [`crate::baselines::shiloach_vishkin`]).
pub fn sv_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let chunks = crate::baselines::shiloach_vishkin::sv_chunks(a, b, p);
    let mut out = Vec::new();
    if stage.partition() && tid < p.saturating_sub(1).max(1) {
        // Fragment-boundary ranking: boundary i+1 is searched by thread
        // i — one lower_bound in B for the A boundary, one upper_bound
        // in A for the B boundary. Emit the probe pattern (log₂ n each).
        let i = tid + 1;
        if i < p {
            let ai = i * a.len() / p;
            if ai > 0 && ai < a.len() {
                emit_binary_probes(b.len(), |m| layout.b(m), &mut out);
                out.push(Ev::ReadRand(layout.a(ai)));
            }
            let bj = i * b.len() / p;
            if bj > 0 && bj < b.len() {
                emit_binary_probes(a.len(), |m| layout.a(m), &mut out);
                out.push(Ev::ReadRand(layout.b(bj)));
            }
        }
    }
    if stage.merge() {
        for (idx, c) in chunks.iter().enumerate() {
            if crate::baselines::shiloach_vishkin::sv_owner(idx, p) != tid {
                continue;
            }
            emit_merge(
                a,
                b,
                c.a0,
                c.b0,
                c.out0,
                (c.a1 - c.a0) + (c.b1 - c.b0),
                writeback,
                layout,
                &mut out,
            );
        }
    }
    out
}

/// Thread `tid`'s events for Akl–Santoro: `⌈log₂ p⌉` *dependent*
/// bisection rounds (a barrier after each), then sequential merges of
/// the assigned parts.
pub fn akl_santoro_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let (parts, rounds) = crate::baselines::akl_santoro::as_partitions(a, b, p);
    let mut out = Vec::new();
    if stage.partition() {
        // Round r has 2^r median searches; thread `tid` performs those
        // with index ≡ tid (mod p). Each search is ~log₂(part length)
        // probes; we charge probes over the whole arrays as an upper
        // bound on the first rounds, halving each round.
        let mut span = a.len() + b.len();
        for r in 0..rounds {
            let searches = 1usize << r;
            let mut s = tid;
            while s < searches {
                emit_binary_probes(span.max(2), |m| layout.a(m % a.len().max(1)), &mut out);
                s += p;
            }
            span = (span / 2).max(2);
            out.push(Ev::Barrier);
        }
    }
    if stage.merge() {
        let mut idx = tid;
        while idx < parts.len() {
            let pt = parts[idx];
            emit_merge(
                a,
                b,
                pt.a0,
                pt.b0,
                pt.out0,
                (pt.a1 - pt.a0) + (pt.b1 - pt.b0),
                writeback,
                layout,
                &mut out,
            );
            idx += p;
        }
    }
    out
}

/// Address layout of `k` runs plus the output in simulated memory —
/// the k-way analogue of [`Layout`].
#[derive(Debug, Clone)]
pub struct KwayLayout {
    /// Base address of each run.
    pub bases: Vec<u64>,
    /// Base address of the output `S`.
    pub base_s: u64,
    /// Element size in bytes.
    pub elem: u64,
}

impl KwayLayout {
    /// Runs then output laid out consecutively, each base aligned to a
    /// 64-byte cache line, 4-byte elements.
    pub fn contiguous(lens: &[usize]) -> Self {
        let elem = 4u64;
        let align = |x: u64| x.div_ceil(64) * 64;
        let mut bases = Vec::with_capacity(lens.len());
        let mut at = 0u64;
        for &len in lens {
            bases.push(at);
            at = align(at + len as u64 * elem);
        }
        Self { bases, base_s: at, elem }
    }

    #[inline]
    fn run(&self, j: usize, i: usize) -> u64 {
        self.bases[j] + i as u64 * self.elem
    }
    #[inline]
    fn s(&self, k: usize) -> u64 {
        self.base_s + k as u64 * self.elem
    }
}

/// Probe-emitting mirror of
/// [`kway_rank_split`](crate::mergepath::kway_rank_split): same bound
/// maintenance, with every binary-search probe (and pivot read)
/// recorded as a random access. Debug-asserted to agree with the real
/// routine.
fn emit_kway_rank_split(
    runs: &[&[i32]],
    rank: usize,
    layout: &KwayLayout,
    out: &mut Vec<Ev>,
) -> Vec<usize> {
    let k = runs.len();
    let mut lo = vec![0usize; k];
    let mut hi: Vec<usize> = runs.iter().map(|r| r.len().min(rank)).collect();
    let mut before = vec![0usize; k];
    loop {
        let mut sum_lo = 0usize;
        let mut sum_hi = 0usize;
        let mut jp = usize::MAX;
        let mut widest = 0usize;
        for j in 0..k {
            sum_lo += lo[j];
            sum_hi += hi[j];
            let w = hi[j] - lo[j];
            if w > widest {
                widest = w;
                jp = j;
            }
        }
        let cut = if sum_lo == rank {
            lo
        } else if sum_hi == rank {
            hi
        } else {
            assert!(jp != usize::MAX, "selection bounds collapsed inconsistently");
            let m = lo[jp] + (hi[jp] - lo[jp] - 1) / 2;
            out.push(Ev::ReadRand(layout.run(jp, m)));
            let pv = runs[jp][m];
            for j in 0..k {
                before[j] = if j == jp {
                    m
                } else {
                    // partition_point over run j, probes recorded.
                    let le = j < jp; // ties count for higher-priority runs
                    let (mut plo, mut phi) = (0usize, runs[j].len());
                    while plo < phi {
                        let mid = plo + (phi - plo) / 2;
                        out.push(Ev::ReadRand(layout.run(j, mid)));
                        let v = runs[j][mid];
                        if v < pv || (le && v == pv) {
                            plo = mid + 1;
                        } else {
                            phi = mid;
                        }
                    }
                    plo
                };
            }
            let pos: usize = before.iter().sum();
            if pos < rank {
                for j in 0..k {
                    if j == jp {
                        lo[jp] = lo[jp].max(m + 1);
                    } else {
                        lo[j] = lo[j].max(before[j].min(hi[j]));
                    }
                }
            } else {
                for j in 0..k {
                    if j == jp {
                        hi[jp] = hi[jp].min(m);
                    } else {
                        hi[j] = hi[j].min(before[j].max(lo[j]));
                    }
                }
            }
            continue;
        };
        debug_assert_eq!(cut, crate::mergepath::kway_rank_split(runs, rank));
        return cut;
    }
}

/// Thread `tid`'s events for the **unsegmented flat k-way engine**
/// ([`parallel_kway_merge`](crate::mergepath::parallel_kway_merge)):
/// the global partition's rank selection for this thread's boundary
/// (they run concurrently, one per thread `tid ≥ 1`), then the
/// per-segment sequential k-way merge.
///
/// The merge loop mirrors
/// [`loser_tree_merge`](crate::mergepath::loser_tree_merge)'s memory
/// behaviour: for `k ≤ 16` the linear argmin **re-reads every live run
/// head per output** — `k + 1` live lines that thrash once they outrun
/// the cache, the §4.3 failure mode the segmented engine exists to
/// avoid; for `k > 16` the binary heap caches head values, touching
/// each input element once (heap-node traffic is local and not
/// modelled).
pub fn kway_flat_events(
    runs: &[&[i32]],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &KwayLayout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let k = runs.len();
    let n: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = Vec::new();
    let (start, end) = kway_segment_bounds(runs, p, tid, stage, layout, &mut out);
    if !stage.merge() {
        return out;
    }
    let mut cursors = start;
    let d0 = tid * n / p;
    let d1 = (tid + 1) * n / p;
    if k <= 16 {
        // Linear argmin: every live head is re-read per output.
        for d in d0..d1 {
            let mut best = usize::MAX;
            let mut best_key: Option<i32> = None;
            for j in 0..k {
                if cursors[j] < end[j] {
                    out.push(Ev::Read(layout.run(j, cursors[j])));
                    let v = runs[j][cursors[j]];
                    let better = match best_key {
                        Some(b) => v < b,
                        None => true,
                    };
                    if better {
                        best = j;
                        best_key = Some(v);
                    }
                }
            }
            cursors[best] += 1;
            if writeback {
                out.push(Ev::Write(layout.s(d)));
            }
        }
    } else {
        // Heap engine: initial fill reads one head per run, then one
        // read per consumed element (pushed as its run's next head).
        let mut heads: Vec<Option<i32>> = (0..k)
            .map(|j| {
                (cursors[j] < end[j]).then(|| {
                    out.push(Ev::Read(layout.run(j, cursors[j])));
                    runs[j][cursors[j]]
                })
            })
            .collect();
        for d in d0..d1 {
            let (best, _) = heads
                .iter()
                .enumerate()
                .filter_map(|(j, h)| h.as_ref().map(|&v| (j, v)))
                .min_by_key(|&(j, v)| (v, j))
                .expect("segment longer than its inputs");
            cursors[best] += 1;
            heads[best] = (cursors[best] < end[best]).then(|| {
                out.push(Ev::Read(layout.run(best, cursors[best])));
                runs[best][cursors[best]]
            });
            if writeback {
                out.push(Ev::Write(layout.s(d)));
            }
        }
    }
    out
}

/// Thread `tid`'s events for the **segmented flat k-way engine**
/// ([`segmented_kway_merge`](crate::mergepath::segmented_kway_merge)):
/// the same global partition, then the thread's rank segment walked in
/// `segment_elems`-output path windows, each merged by the bounded
/// cursor-carrying kernel
/// ([`loser_tree_merge_bounded`](crate::mergepath::loser_tree_merge_bounded)):
/// `k` head reads at window start (the local head-value refill — an
/// upper bound: the state-carrying
/// [`loser_tree_merge_segmented`](crate::mergepath::loser_tree_merge_segmented)
/// skips even those, so the model is conservative against the
/// segmented engine), then exactly one read per consumed element — the
/// `(k+1)·L` working-set bound in event form. No inter-thread
/// barriers: each thread windows its own segment, the cursors are the
/// window-local frontier.
#[allow(clippy::too_many_arguments)]
pub fn kway_segmented_events(
    runs: &[&[i32]],
    segment_elems: usize,
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &KwayLayout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p && segment_elems > 0);
    let k = runs.len();
    let n: usize = runs.iter().map(|r| r.len()).sum();
    let mut out = Vec::new();
    let (start, end) = kway_segment_bounds(runs, p, tid, stage, layout, &mut out);
    if !stage.merge() {
        return out;
    }
    let mut cursors = start;
    let d0 = tid * n / p;
    let d1 = (tid + 1) * n / p;
    let mut d = d0;
    while d < d1 {
        let wlen = segment_elems.min(d1 - d);
        // Window-start refill: read every live head into the local
        // head-value array (the bounded kernel's only re-touches).
        let mut heads: Vec<Option<i32>> = (0..k)
            .map(|j| {
                (cursors[j] < end[j]).then(|| {
                    out.push(Ev::Read(layout.run(j, cursors[j])));
                    runs[j][cursors[j]]
                })
            })
            .collect();
        for _ in 0..wlen {
            let (best, _) = heads
                .iter()
                .enumerate()
                .filter_map(|(j, h)| h.as_ref().map(|&v| (j, v)))
                .min_by_key(|&(j, v)| (v, j))
                .expect("window longer than its inputs");
            cursors[best] += 1;
            heads[best] = (cursors[best] < end[best]).then(|| {
                out.push(Ev::Read(layout.run(best, cursors[best])));
                runs[best][cursors[best]]
            });
            if writeback {
                out.push(Ev::Write(layout.s(d)));
            }
            d += 1;
        }
    }
    out
}

/// Shared partition stage of both k-way engines: thread `tid ≥ 1`
/// performs the rank selection for boundary `tid·n/p` (the selections
/// run concurrently, CREW-style, exactly as
/// [`partition_kway_merge_path_with_pool`](crate::mergepath::partition_kway_merge_path_with_pool)
/// schedules them), emitting its probes when the partition stage is
/// recorded. Returns this thread's per-run `(start, end)` cuts.
fn kway_segment_bounds(
    runs: &[&[i32]],
    p: usize,
    tid: usize,
    stage: Stage,
    layout: &KwayLayout,
    out: &mut Vec<Ev>,
) -> (Vec<usize>, Vec<usize>) {
    let n: usize = runs.iter().map(|r| r.len()).sum();
    let start = if tid == 0 {
        vec![0usize; runs.len()]
    } else if stage.partition() {
        emit_kway_rank_split(runs, tid * n / p, layout, out)
    } else {
        crate::mergepath::kway_rank_split(runs, tid * n / p)
    };
    // The segment's end cut steers the replay but is thread tid+1's
    // boundary (each boundary is searched exactly once across the
    // region) — probes are not re-emitted here.
    let end = if tid + 1 == p {
        runs.iter().map(|r| r.len()).collect()
    } else {
        crate::mergepath::kway_rank_split(runs, (tid + 1) * n / p)
    };
    (start, end)
}

/// Emit the access pattern of a binary search over `n` slots.
fn emit_binary_probes(n: usize, addr_of: impl Fn(usize) -> u64, out: &mut Vec<Ev>) {
    let (mut lo, mut hi) = (0usize, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        out.push(Ev::ReadRand(addr_of(mid)));
        // Probe pattern only; direction is irrelevant for cost, pick one
        // deterministically to terminate.
        if mid % 2 == 0 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i32> {
        let mut v: Vec<i32> = (0..n).map(|_| rng.below(universe) as i32).collect();
        v.sort_unstable();
        v
    }

    fn count_reads(evs: &[Ev]) -> usize {
        evs.iter().filter(|e| matches!(e, Ev::Read(_))).count()
    }
    fn count_writes(evs: &[Ev]) -> usize {
        evs.iter().filter(|e| matches!(e, Ev::Write(_))).count()
    }

    #[test]
    fn merge_path_streams_cover_exactly_n() {
        let mut rng = Xoshiro256::seeded(0xE1);
        let a = random_sorted(&mut rng, 503, 1000);
        let b = random_sorted(&mut rng, 301, 1000);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        for p in [1, 4, 7] {
            let mut reads = 0;
            let mut writes = 0;
            for tid in 0..p {
                let evs = merge_path_events(&a, &b, p, tid, true, Stage::Both, &layout);
                reads += count_reads(&evs);
                writes += count_writes(&evs);
            }
            assert_eq!(reads, n, "p={p}");
            assert_eq!(writes, n, "p={p}");
        }
    }

    #[test]
    fn register_mode_has_no_writes() {
        let mut rng = Xoshiro256::seeded(0xE2);
        let a = random_sorted(&mut rng, 100, 50);
        let b = random_sorted(&mut rng, 100, 50);
        let layout = Layout::contiguous(100, 100);
        for tid in 0..4 {
            let evs = merge_path_events(&a, &b, 4, tid, false, Stage::Both, &layout);
            assert_eq!(count_writes(&evs), 0);
        }
    }

    #[test]
    fn spm_streams_cover_exactly_n_and_barrier_per_segment() {
        let mut rng = Xoshiro256::seeded(0xE3);
        let a = random_sorted(&mut rng, 400, 500);
        let b = random_sorted(&mut rng, 330, 500);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        let l = 100;
        let p = 4;
        let mut reads = 0;
        let mut writes = 0;
        for tid in 0..p {
            let evs = spm_events(&a, &b, l, p, tid, true, Stage::Both, &layout);
            reads += count_reads(&evs);
            writes += count_writes(&evs);
            let barriers = evs.iter().filter(|e| matches!(e, Ev::Barrier)).count();
            assert_eq!(barriers, n.div_ceil(l), "tid={tid}");
        }
        assert_eq!(reads, n);
        assert_eq!(writes, n);
    }

    #[test]
    fn partition_stage_probe_counts_are_logarithmic() {
        let mut rng = Xoshiro256::seeded(0xE4);
        let a = random_sorted(&mut rng, 1 << 12, 1 << 20);
        let b = random_sorted(&mut rng, 1 << 12, 1 << 20);
        let layout = Layout::contiguous(a.len(), b.len());
        // Thread p/2 searches the main diagonal: ≤ 2·log₂(min) probes.
        let evs = merge_path_events(&a, &b, 8, 4, true, Stage::Partition, &layout);
        let probes = evs.iter().filter(|e| matches!(e, Ev::ReadRand(_))).count();
        assert!(probes <= 2 * 13, "probes={probes}");
        assert!(probes >= 2, "main diagonal needs at least one probe");
        assert_eq!(count_reads(&evs), 0);
        assert_eq!(count_writes(&evs), 0);
    }

    #[test]
    fn sv_and_as_streams_cover_exactly_n() {
        let mut rng = Xoshiro256::seeded(0xE5);
        let a = random_sorted(&mut rng, 511, 300);
        let b = random_sorted(&mut rng, 257, 300);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        for p in [1, 3, 8] {
            let (mut r_sv, mut w_sv, mut r_as, mut w_as) = (0, 0, 0, 0);
            for tid in 0..p {
                let evs = sv_events(&a, &b, p, tid, true, Stage::Merge, &layout);
                r_sv += count_reads(&evs);
                w_sv += count_writes(&evs);
                let evs = akl_santoro_events(&a, &b, p, tid, true, Stage::Merge, &layout);
                r_as += count_reads(&evs);
                w_as += count_writes(&evs);
            }
            assert_eq!((r_sv, w_sv), (n, n), "sv p={p}");
            assert_eq!((r_as, w_as), (n, n), "as p={p}");
        }
    }

    #[test]
    fn kway_streams_write_every_output_once() {
        let mut rng = Xoshiro256::seeded(0xE6);
        let runs: Vec<Vec<i32>> = (0..7)
            .map(|_| random_sorted(&mut rng, 311, 4000))
            .collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let layout = KwayLayout::contiguous(&[311; 7]);
        let n = 7 * 311;
        for p in [1usize, 3, 8] {
            let (mut fw, mut sw) = (0usize, 0usize);
            for tid in 0..p {
                let fe = kway_flat_events(&refs, p, tid, true, Stage::Both, &layout);
                fw += fe.iter().filter(|e| matches!(e, Ev::Write(_))).count();
                let se =
                    kway_segmented_events(&refs, 64, p, tid, true, Stage::Both, &layout);
                sw += se.iter().filter(|e| matches!(e, Ev::Write(_))).count();
                // Writes land in the output array, reads in the runs.
                for e in fe.iter().chain(se.iter()) {
                    match e {
                        Ev::Write(a) => assert!(*a >= layout.base_s),
                        Ev::Read(a) => assert!(*a < layout.base_s),
                        _ => {}
                    }
                }
            }
            assert_eq!(fw, n, "flat p={p}");
            assert_eq!(sw, n, "segmented p={p}");
        }
    }

    #[test]
    fn kway_partition_stage_is_rank_split_probes_only() {
        let mut rng = Xoshiro256::seeded(0xE7);
        let runs: Vec<Vec<i32>> = (0..5)
            .map(|_| random_sorted(&mut rng, 400, 1 << 16))
            .collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let layout = KwayLayout::contiguous(&[400; 5]);
        // Thread 0 owns no boundary: empty partition stream.
        let evs = kway_flat_events(&refs, 4, 0, true, Stage::Partition, &layout);
        assert!(evs.is_empty());
        // Interior threads emit only random probes, identically for
        // both engines (shared partition stage).
        for tid in 1..4 {
            let fe = kway_flat_events(&refs, 4, tid, true, Stage::Partition, &layout);
            assert!(!fe.is_empty());
            assert!(fe.iter().all(|e| matches!(e, Ev::ReadRand(_))), "tid={tid}");
            let se =
                kway_segmented_events(&refs, 100, 4, tid, true, Stage::Partition, &layout);
            assert_eq!(fe, se, "tid={tid}");
        }
    }

    #[test]
    fn kway_layout_bases_are_line_aligned_and_disjoint() {
        let layout = KwayLayout::contiguous(&[100, 3, 0, 77]);
        assert_eq!(layout.bases.len(), 4);
        for w in layout.bases.windows(2) {
            assert!(w[1] % 64 == 0 && w[1] >= w[0]);
        }
        assert!(layout.base_s >= *layout.bases.last().unwrap() + 77 * 4);
        assert_eq!(layout.base_s % 64, 0);
    }

    #[test]
    fn addresses_land_in_the_right_arrays() {
        let a = vec![1i32, 3, 5];
        let b = vec![2i32, 4, 6];
        let layout = Layout::contiguous(3, 3);
        let evs = merge_path_events(&a, &b, 1, 0, true, Stage::Both, &layout);
        for e in &evs {
            match e {
                Ev::Read(addr) => assert!(*addr < layout.base_s),
                Ev::Write(addr) => {
                    assert!(*addr >= layout.base_s && *addr < layout.base_s + 24)
                }
                _ => {}
            }
        }
    }
}
