//! Per-thread memory access streams for the simulated algorithms.
//!
//! Each function replays one thread's work — steering on the *real*
//! data with the *same* partition routines the live implementations
//! use — and records the memory events. The virtual-time engine then
//! charges them against the machine model.
//!
//! Event conventions (cf. §4.2 of the paper): the two-finger merge
//! reads one new element per step (the loser of the previous comparison
//! stays in a register) and writes one output element; binary-search
//! probes are random accesses (2 reads per probe: one in `A`, one in
//! `B`).

use crate::mergepath::diagonal::{diagonal_intersection, PathPoint};

/// One memory event (addresses are simulated byte addresses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Sequential read.
    Read(u64),
    /// Random (binary-search) read.
    ReadRand(u64),
    /// Sequential write.
    Write(u64),
    /// Synchronization point (all threads of the region).
    Barrier,
}

/// Which pipeline stage to record (Table 1 splits partition vs merge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Only the partition-stage probes.
    Partition,
    /// Only the merge loop.
    Merge,
    /// Everything.
    Both,
}

impl Stage {
    fn partition(&self) -> bool {
        matches!(self, Stage::Partition | Stage::Both)
    }
    fn merge(&self) -> bool {
        matches!(self, Stage::Merge | Stage::Both)
    }
}

/// Address layout of the three arrays in simulated memory.
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    /// Base address of `A`.
    pub base_a: u64,
    /// Base address of `B`.
    pub base_b: u64,
    /// Base address of the output `S`.
    pub base_s: u64,
    /// Element size in bytes (the paper's experiments use 32-bit ints).
    pub elem: u64,
}

impl Layout {
    /// A, B, S laid out consecutively, each base aligned to a 64-byte
    /// cache line (as any real allocator returns for large arrays),
    /// 4-byte elements.
    pub fn contiguous(na: usize, nb: usize) -> Self {
        let elem = 4u64;
        let align = |x: u64| x.div_ceil(64) * 64;
        let base_b = align(na as u64 * elem);
        let base_s = align(base_b + nb as u64 * elem);
        Self { base_a: 0, base_b, base_s, elem }
    }

    #[inline]
    fn a(&self, i: usize) -> u64 {
        self.base_a + i as u64 * self.elem
    }
    #[inline]
    fn b(&self, j: usize) -> u64 {
        self.base_b + j as u64 * self.elem
    }
    #[inline]
    fn s(&self, k: usize) -> u64 {
        self.base_s + k as u64 * self.elem
    }
}

/// Mirror of [`diagonal_intersection`]'s binary search that records its
/// probe pattern. Debug-asserted to agree with the real routine.
fn emit_diagonal_search(
    a: &[i32],
    b: &[i32],
    diag: usize,
    layout: &Layout,
    out: &mut Vec<Ev>,
) -> PathPoint {
    let mut lo = diag.saturating_sub(b.len());
    let mut hi = diag.min(a.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        out.push(Ev::ReadRand(layout.a(mid)));
        out.push(Ev::ReadRand(layout.b(diag - 1 - mid)));
        if a[mid] <= b[diag - 1 - mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    let pt = PathPoint { a: lo, b: diag - lo };
    debug_assert_eq!(pt, diagonal_intersection(a, b, diag));
    pt
}

/// Replay a bounded two-finger merge of `len` outputs starting at
/// `(a0, b0)` (global indices) writing to output index `out0`.
/// One sequential read per consumed element, one write per output
/// (skipped when `writeback` is false — the paper's register mode).
#[allow(clippy::too_many_arguments)]
fn emit_merge(
    a: &[i32],
    b: &[i32],
    a0: usize,
    b0: usize,
    out0: usize,
    len: usize,
    writeback: bool,
    layout: &Layout,
    out: &mut Vec<Ev>,
) {
    let (mut i, mut j) = (a0, b0);
    for k in 0..len {
        let take_a = i < a.len() && (j >= b.len() || a[i] <= b[j]);
        if take_a {
            out.push(Ev::Read(layout.a(i)));
            i += 1;
        } else {
            out.push(Ev::Read(layout.b(j)));
            j += 1;
        }
        if writeback {
            out.push(Ev::Write(layout.s(out0 + k)));
        }
    }
}

/// Thread `tid`'s events for the regular Merge Path (Alg 1).
pub fn merge_path_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let n = a.len() + b.len();
    let d0 = tid * n / p;
    let d1 = (tid + 1) * n / p;
    let mut out = Vec::new();
    let start = if stage.partition() {
        emit_diagonal_search(a, b, d0, layout, &mut out)
    } else {
        diagonal_intersection(a, b, d0)
    };
    if stage.merge() {
        emit_merge(a, b, start.a, start.b, d0, d1 - d0, writeback, layout, &mut out);
    }
    out
}

/// Thread `tid`'s events for Segmented Parallel Merge (Alg 3) with
/// path-segment length `l`. A [`Ev::Barrier`] separates segments.
#[allow(clippy::too_many_arguments)]
pub fn spm_events(
    a: &[i32],
    b: &[i32],
    l: usize,
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p && l > 0);
    let n = a.len() + b.len();
    let mut out = Vec::new();
    let (mut a0, mut b0, mut done) = (0usize, 0usize, 0usize);
    while done < n {
        let wlen = l.min(n - done);
        let a_win = &a[a0..(a0 + wlen).min(a.len())];
        let b_win = &b[b0..(b0 + wlen).min(b.len())];
        let wl = Layout {
            base_a: layout.a(a0),
            base_b: layout.b(b0),
            base_s: layout.s(done),
            elem: layout.elem,
        };
        let d0 = tid * wlen / p;
        let d1 = (tid + 1) * wlen / p;
        let start = if stage.partition() {
            emit_diagonal_search(a_win, b_win, d0, &wl, &mut out)
        } else {
            diagonal_intersection(a_win, b_win, d0)
        };
        if stage.merge() {
            emit_merge(
                a_win, b_win, start.a, start.b, d0, d1 - d0, writeback, &wl, &mut out,
            );
        }
        // Advance the cursor. §4.3: "each of the p cores must compute
        // its starting points (in A and in B) independently" — every
        // thread replicates the window-end search (CREW reads), which
        // keeps the per-segment load symmetric instead of creating a
        // leader straggler at the barrier.
        let end = if stage.partition() {
            emit_diagonal_search(a_win, b_win, wlen, &wl, &mut out)
        } else {
            diagonal_intersection(a_win, b_win, wlen)
        };
        a0 += end.a;
        b0 += end.b;
        done += wlen;
        out.push(Ev::Barrier);
    }
    out
}

/// Thread `tid`'s events for Shiloach–Vishkin (round-robin chunk deal,
/// same decomposition as [`crate::baselines::shiloach_vishkin`]).
pub fn sv_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let chunks = crate::baselines::shiloach_vishkin::sv_chunks(a, b, p);
    let mut out = Vec::new();
    if stage.partition() && tid < p.saturating_sub(1).max(1) {
        // Fragment-boundary ranking: boundary i+1 is searched by thread
        // i — one lower_bound in B for the A boundary, one upper_bound
        // in A for the B boundary. Emit the probe pattern (log₂ n each).
        let i = tid + 1;
        if i < p {
            let ai = i * a.len() / p;
            if ai > 0 && ai < a.len() {
                emit_binary_probes(b.len(), |m| layout.b(m), &mut out);
                out.push(Ev::ReadRand(layout.a(ai)));
            }
            let bj = i * b.len() / p;
            if bj > 0 && bj < b.len() {
                emit_binary_probes(a.len(), |m| layout.a(m), &mut out);
                out.push(Ev::ReadRand(layout.b(bj)));
            }
        }
    }
    if stage.merge() {
        for (idx, c) in chunks.iter().enumerate() {
            if crate::baselines::shiloach_vishkin::sv_owner(idx, p) != tid {
                continue;
            }
            emit_merge(
                a,
                b,
                c.a0,
                c.b0,
                c.out0,
                (c.a1 - c.a0) + (c.b1 - c.b0),
                writeback,
                layout,
                &mut out,
            );
        }
    }
    out
}

/// Thread `tid`'s events for Akl–Santoro: `⌈log₂ p⌉` *dependent*
/// bisection rounds (a barrier after each), then sequential merges of
/// the assigned parts.
pub fn akl_santoro_events(
    a: &[i32],
    b: &[i32],
    p: usize,
    tid: usize,
    writeback: bool,
    stage: Stage,
    layout: &Layout,
) -> Vec<Ev> {
    assert!(p > 0 && tid < p);
    let (parts, rounds) = crate::baselines::akl_santoro::as_partitions(a, b, p);
    let mut out = Vec::new();
    if stage.partition() {
        // Round r has 2^r median searches; thread `tid` performs those
        // with index ≡ tid (mod p). Each search is ~log₂(part length)
        // probes; we charge probes over the whole arrays as an upper
        // bound on the first rounds, halving each round.
        let mut span = a.len() + b.len();
        for r in 0..rounds {
            let searches = 1usize << r;
            let mut s = tid;
            while s < searches {
                emit_binary_probes(span.max(2), |m| layout.a(m % a.len().max(1)), &mut out);
                s += p;
            }
            span = (span / 2).max(2);
            out.push(Ev::Barrier);
        }
    }
    if stage.merge() {
        let mut idx = tid;
        while idx < parts.len() {
            let pt = parts[idx];
            emit_merge(
                a,
                b,
                pt.a0,
                pt.b0,
                pt.out0,
                (pt.a1 - pt.a0) + (pt.b1 - pt.b0),
                writeback,
                layout,
                &mut out,
            );
            idx += p;
        }
    }
    out
}

/// Emit the access pattern of a binary search over `n` slots.
fn emit_binary_probes(n: usize, addr_of: impl Fn(usize) -> u64, out: &mut Vec<Ev>) {
    let (mut lo, mut hi) = (0usize, n);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        out.push(Ev::ReadRand(addr_of(mid)));
        // Probe pattern only; direction is irrelevant for cost, pick one
        // deterministically to terminate.
        if mid % 2 == 0 {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i32> {
        let mut v: Vec<i32> = (0..n).map(|_| rng.below(universe) as i32).collect();
        v.sort_unstable();
        v
    }

    fn count_reads(evs: &[Ev]) -> usize {
        evs.iter().filter(|e| matches!(e, Ev::Read(_))).count()
    }
    fn count_writes(evs: &[Ev]) -> usize {
        evs.iter().filter(|e| matches!(e, Ev::Write(_))).count()
    }

    #[test]
    fn merge_path_streams_cover_exactly_n() {
        let mut rng = Xoshiro256::seeded(0xE1);
        let a = random_sorted(&mut rng, 503, 1000);
        let b = random_sorted(&mut rng, 301, 1000);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        for p in [1, 4, 7] {
            let mut reads = 0;
            let mut writes = 0;
            for tid in 0..p {
                let evs = merge_path_events(&a, &b, p, tid, true, Stage::Both, &layout);
                reads += count_reads(&evs);
                writes += count_writes(&evs);
            }
            assert_eq!(reads, n, "p={p}");
            assert_eq!(writes, n, "p={p}");
        }
    }

    #[test]
    fn register_mode_has_no_writes() {
        let mut rng = Xoshiro256::seeded(0xE2);
        let a = random_sorted(&mut rng, 100, 50);
        let b = random_sorted(&mut rng, 100, 50);
        let layout = Layout::contiguous(100, 100);
        for tid in 0..4 {
            let evs = merge_path_events(&a, &b, 4, tid, false, Stage::Both, &layout);
            assert_eq!(count_writes(&evs), 0);
        }
    }

    #[test]
    fn spm_streams_cover_exactly_n_and_barrier_per_segment() {
        let mut rng = Xoshiro256::seeded(0xE3);
        let a = random_sorted(&mut rng, 400, 500);
        let b = random_sorted(&mut rng, 330, 500);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        let l = 100;
        let p = 4;
        let mut reads = 0;
        let mut writes = 0;
        for tid in 0..p {
            let evs = spm_events(&a, &b, l, p, tid, true, Stage::Both, &layout);
            reads += count_reads(&evs);
            writes += count_writes(&evs);
            let barriers = evs.iter().filter(|e| matches!(e, Ev::Barrier)).count();
            assert_eq!(barriers, n.div_ceil(l), "tid={tid}");
        }
        assert_eq!(reads, n);
        assert_eq!(writes, n);
    }

    #[test]
    fn partition_stage_probe_counts_are_logarithmic() {
        let mut rng = Xoshiro256::seeded(0xE4);
        let a = random_sorted(&mut rng, 1 << 12, 1 << 20);
        let b = random_sorted(&mut rng, 1 << 12, 1 << 20);
        let layout = Layout::contiguous(a.len(), b.len());
        // Thread p/2 searches the main diagonal: ≤ 2·log₂(min) probes.
        let evs = merge_path_events(&a, &b, 8, 4, true, Stage::Partition, &layout);
        let probes = evs.iter().filter(|e| matches!(e, Ev::ReadRand(_))).count();
        assert!(probes <= 2 * 13, "probes={probes}");
        assert!(probes >= 2, "main diagonal needs at least one probe");
        assert_eq!(count_reads(&evs), 0);
        assert_eq!(count_writes(&evs), 0);
    }

    #[test]
    fn sv_and_as_streams_cover_exactly_n() {
        let mut rng = Xoshiro256::seeded(0xE5);
        let a = random_sorted(&mut rng, 511, 300);
        let b = random_sorted(&mut rng, 257, 300);
        let layout = Layout::contiguous(a.len(), b.len());
        let n = a.len() + b.len();
        for p in [1, 3, 8] {
            let (mut r_sv, mut w_sv, mut r_as, mut w_as) = (0, 0, 0, 0);
            for tid in 0..p {
                let evs = sv_events(&a, &b, p, tid, true, Stage::Merge, &layout);
                r_sv += count_reads(&evs);
                w_sv += count_writes(&evs);
                let evs = akl_santoro_events(&a, &b, p, tid, true, Stage::Merge, &layout);
                r_as += count_reads(&evs);
                w_as += count_writes(&evs);
            }
            assert_eq!((r_sv, w_sv), (n, n), "sv p={p}");
            assert_eq!((r_as, w_as), (n, n), "as p={p}");
        }
    }

    #[test]
    fn addresses_land_in_the_right_arrays() {
        let a = vec![1i32, 3, 5];
        let b = vec![2i32, 4, 6];
        let layout = Layout::contiguous(3, 3);
        let evs = merge_path_events(&a, &b, 1, 0, true, Stage::Both, &layout);
        for e in &evs {
            match e {
                Ev::Read(addr) => assert!(*addr < layout.base_s),
                Ev::Write(addr) => {
                    assert!(*addr >= layout.base_s && *addr < layout.base_s + 24)
                }
                _ => {}
            }
        }
    }
}
