//! Virtual-time engine: interleaves per-thread event streams through
//! the memory hierarchy in clock order (a discrete-event simulation)
//! and reports makespan, bandwidth-bounded cycles and cache statistics.
//!
//! This regenerates the paper's speedup figures without the paper's
//! hardware: `speedup(p) = cycles(1) / cycles(p)` with every term
//! derived from the algorithms' real access traces.

use super::machine::MachineSpec;
use super::mem::{AccessKind, MemHierarchy, MemStats};
use super::stream::{self, Ev, KwayLayout, Layout, Stage};

/// Which merge algorithm to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeAlgo {
    /// Regular Merge Path (paper Alg 1).
    MergePath,
    /// Segmented Parallel Merge with the given path-segment length
    /// (paper Alg 3); the figure benches derive `segment_len` from the
    /// paper's "#segments" parameter as `N / segments`.
    Segmented {
        /// Path-segment length `L` in elements.
        segment_len: usize,
    },
    /// Shiloach–Vishkin [9].
    ShiloachVishkin,
    /// Akl–Santoro [8].
    AklSantoro,
}

impl MergeAlgo {
    /// Short name for tables.
    pub fn name(&self) -> String {
        match self {
            MergeAlgo::MergePath => "merge-path".into(),
            MergeAlgo::Segmented { segment_len } => format!("spm(L={segment_len})"),
            MergeAlgo::ShiloachVishkin => "shiloach-vishkin".into(),
            MergeAlgo::AklSantoro => "akl-santoro".into(),
        }
    }
}

/// Inputs for one simulation run.
#[derive(Debug, Clone)]
pub struct SimWorkload<'a> {
    /// Sorted input `A` (32-bit keys, as in the paper's experiments).
    pub a: &'a [i32],
    /// Sorted input `B`.
    pub b: &'a [i32],
    /// Whether merged output is written to memory (Fig 5a/b) or kept
    /// in a register (Fig 5c/d).
    pub writeback: bool,
    /// Stage filter (Table 1 separates partition and merge stages).
    pub stage: Stage,
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Final cycle count: `max(compute makespan, bandwidth bound)` plus
    /// fork overhead.
    pub cycles: u64,
    /// Compute/latency makespan (max over threads).
    pub makespan: u64,
    /// Per-socket bandwidth bound in cycles.
    pub bw_bound: u64,
    /// Per-thread finish times.
    pub per_thread: Vec<u64>,
    /// Memory statistics.
    pub mem: MemStats,
    /// Number of barrier episodes executed.
    pub barriers: u64,
}

impl SimReport {
    /// Total cache misses at the given level ("l1"/"l2"/"l3").
    pub fn misses(&self, level: &str) -> u64 {
        match level {
            "l1" => self.mem.l1.misses(),
            "l2" => self.mem.l2.misses(),
            "l3" => self.mem.l3.misses(),
            _ => panic!("unknown level {level}"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ThreadState {
    Running,
    AtBarrier,
    Done,
}

/// Run `p` event streams through the hierarchy of `machine`.
pub fn run_streams(machine: &MachineSpec, streams: Vec<Vec<Ev>>, writeback: bool) -> SimReport {
    let p = streams.len();
    assert!(p >= 1);
    assert!(
        p <= machine.cores(),
        "requested {p} threads on a {}-core machine",
        machine.cores()
    );
    // Threads are scattered round-robin across sockets (NUMA
    // interleave); with fewer threads than sockets only the occupied
    // sockets are instantiated so per-socket bandwidth aggregates
    // correctly.
    let spanned_sockets = p.min(machine.sockets);
    let mut mem = MemHierarchy::new(machine.mem, p, spanned_sockets);

    let mut clocks = vec![0u64; p];
    let mut cursors = vec![0usize; p];
    let mut states = vec![ThreadState::Running; p];
    let mut barriers_done = 0u64;

    loop {
        // Pick the running thread with the smallest clock (deterministic
        // tie-break by tid).
        let mut next: Option<usize> = None;
        for tid in 0..p {
            let earlier = match next {
                Some(n) => clocks[tid] < clocks[n],
                None => true,
            };
            if states[tid] == ThreadState::Running && earlier {
                next = Some(tid);
            }
        }
        let Some(tid) = next else {
            // No runnable thread: either all done, or all at a barrier.
            let waiting: Vec<usize> = (0..p)
                .filter(|&t| states[t] == ThreadState::AtBarrier)
                .collect();
            if waiting.is_empty() {
                break; // all done
            }
            // Release the barrier: everyone resumes at the max clock
            // plus the barrier cost.
            let release = waiting
                .iter()
                .map(|&t| clocks[t])
                .max()
                .unwrap()
                .saturating_add(machine.barrier_cost(p));
            for &t in &waiting {
                clocks[t] = release.max(clocks[t]);
                states[t] = ThreadState::Running;
            }
            barriers_done += 1;
            continue;
        };

        let stream = &streams[tid];
        if cursors[tid] >= stream.len() {
            states[tid] = ThreadState::Done;
            continue;
        }
        let ev = stream[cursors[tid]];
        cursors[tid] += 1;
        match ev {
            Ev::Read(addr) => {
                clocks[tid] +=
                    mem.access(tid, addr, AccessKind::Read) + machine.cpi_step;
            }
            Ev::ReadRand(addr) => {
                clocks[tid] +=
                    mem.access(tid, addr, AccessKind::ReadRand) + machine.cpi_probe;
            }
            Ev::Write(addr) => {
                clocks[tid] +=
                    mem.access(tid, addr, AccessKind::Write) + machine.cpi_step;
            }
            Ev::Barrier => {
                states[tid] = ThreadState::AtBarrier;
            }
        }
    }

    if writeback {
        mem.flush_all();
    }
    let stats = mem.stats();
    let makespan = clocks.iter().copied().max().unwrap_or(0);
    let bw_bound = stats
        .dram_bytes_per_socket
        .iter()
        .map(|&bytes| (bytes as f64 / machine.dram_bytes_per_cycle) as u64)
        .max()
        .unwrap_or(0);
    let cycles = makespan.max(bw_bound) + machine.fork_cost + machine.barrier_cost(p);
    SimReport {
        cycles,
        makespan,
        bw_bound,
        per_thread: clocks,
        mem: stats,
        barriers: barriers_done,
    }
}

/// Simulate one merge with `p` threads on `machine`.
pub fn simulate_merge(
    machine: &MachineSpec,
    algo: MergeAlgo,
    w: &SimWorkload<'_>,
    p: usize,
) -> SimReport {
    let layout = Layout::contiguous(w.a.len(), w.b.len());
    let streams: Vec<Vec<Ev>> = (0..p)
        .map(|tid| match algo {
            MergeAlgo::MergePath => {
                stream::merge_path_events(w.a, w.b, p, tid, w.writeback, w.stage, &layout)
            }
            MergeAlgo::Segmented { segment_len } => stream::spm_events(
                w.a,
                w.b,
                segment_len,
                p,
                tid,
                w.writeback,
                w.stage,
                &layout,
            ),
            MergeAlgo::ShiloachVishkin => {
                stream::sv_events(w.a, w.b, p, tid, w.writeback, w.stage, &layout)
            }
            MergeAlgo::AklSantoro => {
                stream::akl_santoro_events(w.a, w.b, p, tid, w.writeback, w.stage, &layout)
            }
        })
        .collect();
    run_streams(machine, streams, w.writeback)
}

/// Which k-way merge engine to simulate (the compaction hot path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KwayMergeAlgo {
    /// The unsegmented flat single-pass engine
    /// ([`parallel_kway_merge`](crate::mergepath::parallel_kway_merge)):
    /// per thread, `k + 1` unbounded sequences through the argmin /
    /// heap loser tree.
    Flat,
    /// The segmented flat engine
    /// ([`segmented_kway_merge`](crate::mergepath::segmented_kway_merge)):
    /// each thread's rank segment walked in bounded path windows via
    /// the cursor-carrying kernel.
    Segmented {
        /// Output elements per path window (`L`).
        segment_elems: usize,
    },
}

impl KwayMergeAlgo {
    /// Short name for tables.
    pub fn name(&self) -> String {
        match self {
            KwayMergeAlgo::Flat => "flat".into(),
            KwayMergeAlgo::Segmented { segment_elems } => format!("seg(L={segment_elems})"),
        }
    }
}

/// Simulate one k-way compaction merge with `p` threads on `machine`.
/// Runs are laid out consecutively ([`KwayLayout::contiguous`]); the
/// partition stage records the `p − 1` concurrent rank selections, the
/// merge stage the engines' real per-thread access patterns (see
/// [`stream::kway_flat_events`] / [`stream::kway_segmented_events`]).
pub fn simulate_kway_merge(
    machine: &MachineSpec,
    algo: KwayMergeAlgo,
    runs: &[&[i32]],
    writeback: bool,
    stage: Stage,
    p: usize,
) -> SimReport {
    let lens: Vec<usize> = runs.iter().map(|r| r.len()).collect();
    let layout = KwayLayout::contiguous(&lens);
    let streams: Vec<Vec<Ev>> = (0..p)
        .map(|tid| match algo {
            KwayMergeAlgo::Flat => {
                stream::kway_flat_events(runs, p, tid, writeback, stage, &layout)
            }
            KwayMergeAlgo::Segmented { segment_elems } => stream::kway_segmented_events(
                runs,
                segment_elems,
                p,
                tid,
                writeback,
                stage,
                &layout,
            ),
        })
        .collect();
    run_streams(machine, streams, writeback)
}

/// Convenience: speedup curve `cycles(1)/cycles(p)` over `ps`.
pub fn speedup_curve(
    machine: &MachineSpec,
    algo: MergeAlgo,
    w: &SimWorkload<'_>,
    ps: &[usize],
) -> Vec<(usize, f64)> {
    let base = simulate_merge(machine, algo, w, 1).cycles.max(1);
    ps.iter()
        .map(|&p| {
            let c = simulate_merge(machine, algo, w, p).cycles.max(1);
            (p, base as f64 / c as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::sim::machine::x5670_12;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i32> {
        let mut v: Vec<i32> = (0..n).map(|_| rng.below(universe) as i32).collect();
        v.sort_unstable();
        v
    }

    fn workload(a: &[i32], b: &[i32], writeback: bool) -> SimWorkload<'static> {
        // Tests leak the arrays deliberately (tiny, test-only).
        let a: &'static [i32] = Box::leak(a.to_vec().into_boxed_slice());
        let b: &'static [i32] = Box::leak(b.to_vec().into_boxed_slice());
        SimWorkload { a, b, writeback, stage: Stage::Both }
    }

    #[test]
    fn deterministic() {
        let mut rng = Xoshiro256::seeded(0x11);
        let a = random_sorted(&mut rng, 5000, 1 << 20);
        let b = random_sorted(&mut rng, 5000, 1 << 20);
        let m = x5670_12().scaled_caches(64);
        let w = workload(&a, &b, true);
        let r1 = simulate_merge(&m, MergeAlgo::MergePath, &w, 4);
        let r2 = simulate_merge(&m, MergeAlgo::MergePath, &w, 4);
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(r1.mem.l1.misses(), r2.mem.l1.misses());
    }

    #[test]
    fn speedup_with_more_threads() {
        let mut rng = Xoshiro256::seeded(0x12);
        let a = random_sorted(&mut rng, 200_000, 1 << 28);
        let b = random_sorted(&mut rng, 200_000, 1 << 28);
        let m = x5670_12().scaled_caches(16);
        let w = workload(&a, &b, true);
        let curve = speedup_curve(&m, MergeAlgo::MergePath, &w, &[2, 4, 8, 12]);
        // Monotone-ish increase and near-linear at small p.
        assert!(curve[0].1 > 1.5, "2-thread speedup {curve:?}");
        assert!(curve[1].1 > curve[0].1, "{curve:?}");
        let s12 = curve.last().unwrap().1;
        assert!(s12 > 4.0, "12-thread speedup too low: {curve:?}");
    }

    #[test]
    fn register_mode_moves_fewer_bytes() {
        let mut rng = Xoshiro256::seeded(0x13);
        let a = random_sorted(&mut rng, 50_000, 1 << 28);
        let b = random_sorted(&mut rng, 50_000, 1 << 28);
        let m = x5670_12().scaled_caches(16);
        let wb = simulate_merge(&m, MergeAlgo::MergePath, &workload(&a, &b, true), 4);
        let reg = simulate_merge(&m, MergeAlgo::MergePath, &workload(&a, &b, false), 4);
        assert!(reg.mem.dram_bytes() < wb.mem.dram_bytes());
        assert!(reg.cycles <= wb.cycles);
    }

    #[test]
    fn spm_has_no_more_l3_misses_than_regular_on_big_arrays() {
        let mut rng = Xoshiro256::seeded(0x14);
        // Arrays several times the (scaled) L3.
        let n = 400_000usize;
        let a = random_sorted(&mut rng, n, 1 << 28);
        let b = random_sorted(&mut rng, n, 1 << 28);
        let m = x5670_12().scaled_caches(64); // L3 = 192 KiB = 48K elems
        let l3_elems = m.mem.l3.capacity / 4;
        let w = workload(&a, &b, true);
        let reg = simulate_merge(&m, MergeAlgo::MergePath, &w, 8);
        let spm = simulate_merge(
            &m,
            MergeAlgo::Segmented { segment_len: l3_elems / 3 },
            &w,
            8,
        );
        assert!(
            spm.mem.l3.misses() <= reg.mem.l3.misses() + (n as u64 / 100),
            "spm {} vs regular {}",
            spm.mem.l3.misses(),
            reg.mem.l3.misses()
        );
    }

    #[test]
    fn barriers_counted_for_spm() {
        let mut rng = Xoshiro256::seeded(0x15);
        let a = random_sorted(&mut rng, 10_000, 1 << 20);
        let b = random_sorted(&mut rng, 10_000, 1 << 20);
        let m = x5670_12().scaled_caches(64);
        let w = workload(&a, &b, true);
        let r = simulate_merge(&m, MergeAlgo::Segmented { segment_len: 1000 }, &w, 4);
        assert_eq!(r.barriers, 20, "one barrier per segment");
    }

    #[test]
    fn partition_stage_is_cheap() {
        let mut rng = Xoshiro256::seeded(0x16);
        let a = random_sorted(&mut rng, 100_000, 1 << 28);
        let b = random_sorted(&mut rng, 100_000, 1 << 28);
        let m = x5670_12().scaled_caches(16);
        let part = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Partition };
        let both = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both };
        let rp = simulate_merge(&m, MergeAlgo::MergePath, &part, 8);
        let rb = simulate_merge(&m, MergeAlgo::MergePath, &both, 8);
        assert!(
            rp.makespan * 10 < rb.makespan,
            "partition {} vs total {}",
            rp.makespan,
            rb.makespan
        );
    }

    #[test]
    fn kway_engines_produce_identical_element_traffic() {
        // Both engines consume every input element and write every
        // output exactly once; the segmented engine additionally
        // re-reads the k window-start heads. Sanity-check totals so the
        // miss comparison below compares like with like.
        use crate::sim::stream::{kway_flat_events, kway_segmented_events};
        let mut rng = Xoshiro256::seeded(0x17);
        let runs: Vec<Vec<i32>> =
            (0..5).map(|_| random_sorted(&mut rng, 2000, 1 << 20)).collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let layout = crate::sim::stream::KwayLayout::contiguous(&[2000; 5]);
        let p = 4;
        let (mut flat_w, mut seg_w, mut flat_r, mut seg_r) = (0usize, 0usize, 0usize, 0usize);
        for tid in 0..p {
            let fe = kway_flat_events(&refs, p, tid, true, Stage::Merge, &layout);
            let se = kway_segmented_events(&refs, 128, p, tid, true, Stage::Merge, &layout);
            flat_w += fe.iter().filter(|e| matches!(e, Ev::Write(_))).count();
            seg_w += se.iter().filter(|e| matches!(e, Ev::Write(_))).count();
            flat_r += fe.iter().filter(|e| matches!(e, Ev::Read(_))).count();
            seg_r += se.iter().filter(|e| matches!(e, Ev::Read(_))).count();
        }
        assert_eq!(flat_w, 10_000, "one write per output");
        assert_eq!(seg_w, 10_000);
        // Argmin re-reads every live head per output...
        assert!(flat_r > 3 * 10_000, "flat reads {flat_r}");
        // ...the bounded kernel reads each element once plus k per
        // window (10_000/128 windows → < 1.1 reads per output).
        assert!(seg_r < 11_000, "segmented reads {seg_r}");
    }

    #[test]
    fn segmented_kway_fewer_misses_on_cache_busting_shape() {
        // The acceptance shape: k + 1 live stream lines exceed the
        // scaled private L1 (8 lines on the 1/64 x5670), so the flat
        // argmin's per-output head re-reads all miss while the bounded
        // kernel touches each element once. The segmented engine must
        // show a decisive simulated L1-miss reduction.
        let mut rng = Xoshiro256::seeded(0x18);
        let runs: Vec<Vec<i32>> =
            (0..12).map(|_| random_sorted(&mut rng, 15_000, 1 << 28)).collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let m = x5670_12().scaled_caches(64);
        let l3_elems = m.mem.l3.capacity / 4;
        let l = (l3_elems / (refs.len() + 1)).max(64);
        let p = 8;
        let flat =
            simulate_kway_merge(&m, KwayMergeAlgo::Flat, &refs, true, Stage::Both, p);
        let seg = simulate_kway_merge(
            &m,
            KwayMergeAlgo::Segmented { segment_elems: l },
            &refs,
            true,
            Stage::Both,
            p,
        );
        assert!(
            seg.mem.l1.misses() * 2 < flat.mem.l1.misses(),
            "segmented {} vs flat {} L1 misses",
            seg.mem.l1.misses(),
            flat.mem.l1.misses()
        );
        // DRAM traffic stays a stream of the same data either way.
        assert!(seg.mem.dram_bytes() <= flat.mem.dram_bytes() + (15_000 * 12 / 4) as u64);
    }

    #[test]
    fn kway_sim_deterministic_and_partition_stage_matches() {
        let mut rng = Xoshiro256::seeded(0x19);
        let runs: Vec<Vec<i32>> =
            (0..6).map(|_| random_sorted(&mut rng, 5000, 1 << 20)).collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let m = x5670_12().scaled_caches(64);
        let r1 = simulate_kway_merge(&m, KwayMergeAlgo::Flat, &refs, true, Stage::Both, 4);
        let r2 = simulate_kway_merge(&m, KwayMergeAlgo::Flat, &refs, true, Stage::Both, 4);
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(r1.mem.l1.misses(), r2.mem.l1.misses());
        // Both engines share the partition stage bit for bit.
        let fp = simulate_kway_merge(&m, KwayMergeAlgo::Flat, &refs, true, Stage::Partition, 4);
        let sp = simulate_kway_merge(
            &m,
            KwayMergeAlgo::Segmented { segment_elems: 512 },
            &refs,
            true,
            Stage::Partition,
            4,
        );
        assert_eq!(fp.mem.l1.misses(), sp.mem.l1.misses());
        assert_eq!(fp.cycles, sp.cycles);
    }

    #[test]
    fn sv_imbalance_slower_than_merge_path() {
        // Skewed arrays (all of B inside A's first fragment): SV hands
        // one thread far more than N/p while Merge Path stays exact.
        let n = 100_000;
        let a: Vec<i32> = (0..n).collect();
        let b: Vec<i32> = vec![100i32; n as usize];
        let m = x5670_12().scaled_caches(16);
        let w = workload(&a, &b, true);
        let mp = simulate_merge(&m, MergeAlgo::MergePath, &w, 8);
        let sv = simulate_merge(&m, MergeAlgo::ShiloachVishkin, &w, 8);
        assert!(
            sv.makespan as f64 >= 1.3 * mp.makespan as f64,
            "sv {} vs mp {}",
            sv.makespan,
            mp.makespan
        );
    }
}
