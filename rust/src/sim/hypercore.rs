//! Plurality HyperCore model (§6.2): a UMA many-core with **no private
//! caches** — all cores reach a shared, multi-bank cache through a
//! low-latency combinational interconnect, plus off-chip DRAM.
//!
//! Modeled mechanisms (exactly the ones the paper attributes results
//! to):
//! - shared cache, so **no coherence traffic at all** (CREW algorithms
//!   pay nothing for sharing);
//! - more banks than cores with line-interleaved addresses → conflicts
//!   only when two cores hit the same bank in the same cycle, which the
//!   model serializes (bank busy-until times);
//! - the FPGA version's **direct-mapped** 1MB cache (so collision
//!   freedom cannot be guaranteed — the paper's Fig 7b caveat);
//! - a hardware scheduler that dispatches a task "within a handful of
//!   cycles" → tiny fork/barrier costs.

use super::cache::{CacheConfig, ReplacementPolicy, SetAssocCache};
use super::engine::{MergeAlgo, SimWorkload};
use super::stream::{Ev, Layout};

/// HyperCore geometry/latency parameters.
#[derive(Debug, Clone, Copy)]
pub struct HyperCoreSpec {
    /// Number of cores (the FPGA version: 32).
    pub cores: usize,
    /// Shared cache capacity in bytes (FPGA: 1MB).
    pub cache_capacity: usize,
    /// Shared cache associativity (FPGA: direct-mapped = 1).
    pub cache_ways: usize,
    /// Line size in bytes.
    pub line: usize,
    /// Number of cache banks (more banks than cores).
    pub banks: usize,
    /// Shared-cache hit latency (cycles).
    pub hit_latency: u64,
    /// Off-chip miss latency (cycles).
    pub miss_latency: u64,
    /// Scheduler dispatch cost per parallel region ("handful of cycles").
    pub dispatch: u64,
    /// Barrier cost (synchronizer/scheduler, very fast).
    pub barrier: u64,
    /// Compute cycles per merge step.
    pub cpi_step: u64,
    /// Compute cycles per search probe.
    pub cpi_probe: u64,
}

/// The FPGA configuration used in §6.2: 32 cores, 1MB direct-mapped
/// shared cache.
pub fn hypercore_fpga32() -> HyperCoreSpec {
    HyperCoreSpec {
        cores: 32,
        cache_capacity: 1024 * 1024,
        cache_ways: 1,
        line: 64,
        banks: 64,
        hit_latency: 3,
        miss_latency: 250,
        dispatch: 8,
        barrier: 12,
        cpi_step: 3,
        cpi_probe: 4,
    }
}

/// Result of a HyperCore run.
#[derive(Debug, Clone)]
pub struct HyperCoreReport {
    /// Total cycles (makespan + dispatch).
    pub cycles: u64,
    /// Per-thread finish times.
    pub per_thread: Vec<u64>,
    /// Shared-cache stats.
    pub cache: super::cache::CacheStats,
    /// Accesses delayed by a busy bank.
    pub bank_conflicts: u64,
    /// Barriers executed.
    pub barriers: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    Running,
    AtBarrier,
    Done,
}

/// Run per-thread event streams on the HyperCore model.
pub fn run_hypercore(spec: &HyperCoreSpec, streams: Vec<Vec<Ev>>) -> HyperCoreReport {
    let p = streams.len();
    assert!(p >= 1 && p <= spec.cores);
    let mut cache = SetAssocCache::new(CacheConfig {
        capacity: spec.cache_capacity,
        line: spec.line,
        ways: spec.cache_ways,
        policy: ReplacementPolicy::Lru, // direct-mapped when ways = 1
    });
    let mut bank_free = vec![0u64; spec.banks];
    let mut clocks = vec![0u64; p];
    let mut cursors = vec![0usize; p];
    let mut states = vec![St::Running; p];
    let mut conflicts = 0u64;
    let mut barriers = 0u64;

    loop {
        let mut next: Option<usize> = None;
        for tid in 0..p {
            let earlier = match next {
                Some(n) => clocks[tid] < clocks[n],
                None => true,
            };
            if states[tid] == St::Running && earlier {
                next = Some(tid);
            }
        }
        let Some(tid) = next else {
            let waiting: Vec<usize> =
                (0..p).filter(|&t| states[t] == St::AtBarrier).collect();
            if waiting.is_empty() {
                break;
            }
            let release = waiting.iter().map(|&t| clocks[t]).max().unwrap() + spec.barrier;
            for &t in &waiting {
                clocks[t] = release;
                states[t] = St::Running;
            }
            barriers += 1;
            continue;
        };
        let s = &streams[tid];
        if cursors[tid] >= s.len() {
            states[tid] = St::Done;
            continue;
        }
        let ev = s[cursors[tid]];
        cursors[tid] += 1;
        match ev {
            Ev::Read(addr) | Ev::ReadRand(addr) | Ev::Write(addr) => {
                let line = addr / spec.line as u64;
                let bank = (line % spec.banks as u64) as usize;
                // Bank serialization: wait for the bank, then occupy it
                // for one cycle.
                let start = clocks[tid].max(bank_free[bank]);
                if start > clocks[tid] {
                    conflicts += 1;
                }
                bank_free[bank] = start + 1;
                let write = matches!(ev, Ev::Write(_));
                let hit = cache.access(addr, write);
                let lat = if hit { spec.hit_latency } else { spec.miss_latency };
                let cpi = if matches!(ev, Ev::ReadRand(_)) {
                    spec.cpi_probe
                } else {
                    spec.cpi_step
                };
                clocks[tid] = start + lat + cpi;
            }
            Ev::Barrier => states[tid] = St::AtBarrier,
        }
    }

    let makespan = clocks.iter().copied().max().unwrap_or(0);
    HyperCoreReport {
        cycles: makespan + spec.dispatch,
        per_thread: clocks,
        cache: cache.stats(),
        bank_conflicts: conflicts,
        barriers,
    }
}

/// Simulate one merge on the HyperCore (register-sink mode — §6.2: the
/// FPGA "has a latency issue on memory write back", so the paper's runs
/// stored results to a register; we default to the same).
pub fn simulate_hypercore(
    spec: &HyperCoreSpec,
    algo: MergeAlgo,
    w: &SimWorkload<'_>,
    p: usize,
) -> HyperCoreReport {
    let layout = Layout::contiguous(w.a.len(), w.b.len());
    let streams: Vec<Vec<Ev>> = (0..p)
        .map(|tid| match algo {
            MergeAlgo::MergePath => super::stream::merge_path_events(
                w.a, w.b, p, tid, w.writeback, w.stage, &layout,
            ),
            MergeAlgo::Segmented { segment_len } => super::stream::spm_events(
                w.a, w.b, segment_len, p, tid, w.writeback, w.stage, &layout,
            ),
            MergeAlgo::ShiloachVishkin => super::stream::sv_events(
                w.a, w.b, p, tid, w.writeback, w.stage, &layout,
            ),
            MergeAlgo::AklSantoro => super::stream::akl_santoro_events(
                w.a, w.b, p, tid, w.writeback, w.stage, &layout,
            ),
        })
        .collect();
    run_hypercore(spec, streams)
}

/// Speedup curve on the HyperCore.
pub fn hypercore_speedup_curve(
    spec: &HyperCoreSpec,
    algo: MergeAlgo,
    w: &SimWorkload<'_>,
    ps: &[usize],
) -> Vec<(usize, f64)> {
    let base = simulate_hypercore(spec, algo, w, 1).cycles.max(1);
    ps.iter()
        .map(|&p| {
            let c = simulate_hypercore(spec, algo, w, p).cycles.max(1);
            (p, base as f64 / c as f64)
        })
        .collect()
}

/// MachineSpec-compatible description row for Table 2 extensions.
pub fn hypercore_row(spec: &HyperCoreSpec) -> [String; 8] {
    [
        "Plurality HyperCore (FPGA)".into(),
        "1".into(),
        spec.cores.to_string(),
        spec.cores.to_string(),
        "-".into(),
        "-".into(),
        format!("{}MB shared, {}-way", spec.cache_capacity / 1024 / 1024, spec.cache_ways),
        format!("{} banks", spec.banks),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::sim::stream::Stage;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i32> {
        let mut v: Vec<i32> = (0..n).map(|_| rng.below(universe) as i32).collect();
        v.sort_unstable();
        v
    }

    fn wl<'x>(a: &'x [i32], b: &'x [i32]) -> SimWorkload<'x> {
        SimWorkload { a, b, writeback: false, stage: Stage::Both }
    }

    #[test]
    fn near_linear_to_16_cores_small_arrays() {
        let mut rng = Xoshiro256::seeded(0x21);
        // 32K elements per array — fits the 1MB shared cache (§6.2).
        let a = random_sorted(&mut rng, 32 * 1024, 1 << 28);
        let b = random_sorted(&mut rng, 32 * 1024, 1 << 28);
        let spec = hypercore_fpga32();
        let w = wl(&a, &b);
        let curve =
            hypercore_speedup_curve(&spec, MergeAlgo::MergePath, &w, &[2, 4, 8, 16]);
        for (p, s) in &curve {
            assert!(
                *s > 0.7 * *p as f64,
                "speedup at p={p} is {s:.2}, expected near-linear ({curve:?})"
            );
        }
    }

    #[test]
    fn segmented_beats_regular_on_large_arrays_at_32() {
        let mut rng = Xoshiro256::seeded(0x22);
        // 1M elements per array — 8MB total footprint ≫ 1MB cache.
        let n = 1 << 19; // scaled to keep test time sane
        let a = random_sorted(&mut rng, n, 1 << 30);
        let b = random_sorted(&mut rng, n, 1 << 30);
        let mut spec = hypercore_fpga32();
        spec.cache_capacity /= 4; // keep N/C of the paper's 1M case
        let w = wl(&a, &b);
        let cache_elems = spec.cache_capacity / 4;
        let reg = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, 32);
        let seg = simulate_hypercore(
            &spec,
            MergeAlgo::Segmented { segment_len: cache_elems / 3 },
            &w,
            32,
        );
        assert!(
            seg.cache.misses() <= reg.cache.misses(),
            "segmented misses {} > regular {}",
            seg.cache.misses(),
            reg.cache.misses()
        );
    }

    #[test]
    fn bank_conflicts_grow_with_cores() {
        let mut rng = Xoshiro256::seeded(0x23);
        let a = random_sorted(&mut rng, 64 * 1024, 1 << 28);
        let b = random_sorted(&mut rng, 64 * 1024, 1 << 28);
        let spec = hypercore_fpga32();
        let w = wl(&a, &b);
        let r4 = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, 4);
        let r32 = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, 32);
        assert!(r32.bank_conflicts >= r4.bank_conflicts);
    }

    #[test]
    fn dispatch_overhead_is_tiny() {
        let spec = hypercore_fpga32();
        assert!(spec.dispatch < 20);
        assert!(spec.barrier < 50);
    }

    #[test]
    fn deterministic() {
        let mut rng = Xoshiro256::seeded(0x24);
        let a = random_sorted(&mut rng, 10_000, 1 << 20);
        let b = random_sorted(&mut rng, 10_000, 1 << 20);
        let spec = hypercore_fpga32();
        let w = wl(&a, &b);
        let r1 = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, 8);
        let r2 = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, 8);
        assert_eq!(r1.cycles, r2.cycles);
    }
}
