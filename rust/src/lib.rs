//! # mergeflow
//!
//! A production-oriented reproduction of **"Merge Path — A Visually
//! Intuitive Approach to Parallel Merging"** (Green, Odeh, Birk, 2014).
//!
//! The crate provides, as a layered framework:
//!
//! - [`mergepath`] — the paper's core contribution: cross-diagonal
//!   partitioning of the merge path (Alg 2 / Thm 14), lock-free perfectly
//!   load-balanced parallel merge (Alg 1), the cache-efficient *Segmented
//!   Parallel Merge* (Alg 3 / §4), and the parallel + cache-efficient
//!   sorts built on them (§3, §4.4).
//! - [`baselines`] — the comparison algorithms of §5: Shiloach–Vishkin,
//!   Akl–Santoro, Deo–Sarkar, bitonic networks, and the (incorrect) naive
//!   equal split.
//! - [`exec`] — the PRAM-style execution substrate: persistent worker
//!   pool, sense-reversing barrier, scoped parallel-for.
//! - [`sim`] — deterministic machine simulators used to regenerate the
//!   paper's evaluation on hardware we do not have: set-associative
//!   cache + MESI-lite coherence (x86, Table 2) and the Plurality
//!   HyperCore banked shared cache (§6.2), driven by real access traces
//!   through a virtual-time engine.
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   merge kernels (`artifacts/*.hlo.txt`), L1/L2 of the stack.
//! - [`record`] — the typed-record API: the [`Record`] trait (ordered
//!   key + opaque payload), scalar/pair/float-key implementations, and
//!   the key-only ordering adapter that carries the coordinator's
//!   stability contract (equal keys keep run-index-then-offset order).
//! - [`coordinator`] — the serving layer, generic over keyed records:
//!   merge/sort/compaction job queue, dynamic batcher, backend router,
//!   worker pool, metrics, and rank-sharded compaction
//!   ([`coordinator::shard`]) that splits giant compactions into
//!   independent equisized sub-jobs by output rank.
//! - [`bench`] — workload generators and the table/figure harness that
//!   regenerates every table and figure of the paper's §6.
//! - [`server`] — the wire layer: the coordinator surface served over
//!   TCP/Unix sockets as a length-prefixed framed protocol, with
//!   per-tenant admission quotas, lease-based liveness, and a typed
//!   loopback client.
//!
//! Start with `docs/ARCHITECTURE.md` for the module-by-module map onto
//! the paper's algorithms and the coordinator's job flow
//! (`submit → queue → execute_job → shard / flat / tree`), and
//! `README.md` for a build/test/bench quickstart.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod mergepath;
pub mod metrics;
pub mod record;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod store;
pub mod testutil;

pub use record::{ByKey, F32Key, F64Key, KeyedI32, Record, XlaSeam};

/// Crate-wide error type. Display/Error/From are hand-implemented —
/// the offline image has no crates.io access, so no `thiserror`.
#[derive(Debug)]
pub enum Error {
    /// Input arrays violated a documented precondition (e.g. unsorted).
    InvalidInput(String),
    /// Configuration file / CLI errors.
    Config(String),
    /// PJRT / XLA runtime errors.
    Runtime(String),
    /// Coordinator service errors (queue closed, job rejected, ...).
    Service(String),
    /// I/O errors (artifact loading, config files).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Allocate a `Vec<T>` of `len` uninitialized elements — the shared
/// write-only merge-output buffer idiom (a zero fill would be a full
/// extra write pass over output memory).
///
/// # Safety contract (by convention, not the type system)
/// The caller must overwrite every element before any read; only use
/// with `Copy` payloads on outputs that an engine fully tiles.
pub(crate) fn uninit_vec<T: Copy>(len: usize) -> Vec<T> {
    let mut v = Vec::with_capacity(len);
    // SAFETY: callers overwrite all `len` elements before reading.
    #[allow(clippy::uninit_vec)]
    unsafe {
        v.set_len(len);
    }
    v
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
