//! # mergeflow
//!
//! A production-oriented reproduction of **"Merge Path — A Visually
//! Intuitive Approach to Parallel Merging"** (Green, Odeh, Birk, 2014).
//!
//! The crate provides, as a layered framework:
//!
//! - [`mergepath`] — the paper's core contribution: cross-diagonal
//!   partitioning of the merge path (Alg 2 / Thm 14), lock-free perfectly
//!   load-balanced parallel merge (Alg 1), the cache-efficient *Segmented
//!   Parallel Merge* (Alg 3 / §4), and the parallel + cache-efficient
//!   sorts built on them (§3, §4.4).
//! - [`baselines`] — the comparison algorithms of §5: Shiloach–Vishkin,
//!   Akl–Santoro, Deo–Sarkar, bitonic networks, and the (incorrect) naive
//!   equal split.
//! - [`exec`] — the PRAM-style execution substrate: persistent worker
//!   pool, sense-reversing barrier, scoped parallel-for.
//! - [`sim`] — deterministic machine simulators used to regenerate the
//!   paper's evaluation on hardware we do not have: set-associative
//!   cache + MESI-lite coherence (x86, Table 2) and the Plurality
//!   HyperCore banked shared cache (§6.2), driven by real access traces
//!   through a virtual-time engine.
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   merge kernels (`artifacts/*.hlo.txt`), L1/L2 of the stack.
//! - [`coordinator`] — the serving layer: merge/sort/compaction job
//!   queue, dynamic batcher, backend router, worker pool, metrics.
//! - [`bench`] — workload generators and the table/figure harness that
//!   regenerates every table and figure of the paper's §6.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod mergepath;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod testutil;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Input arrays violated a documented precondition (e.g. unsorted).
    #[error("invalid input: {0}")]
    InvalidInput(String),
    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),
    /// PJRT / XLA runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Coordinator service errors (queue closed, job rejected, ...).
    #[error("service error: {0}")]
    Service(String),
    /// I/O errors (artifact loading, config files).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
