//! Persistent worker pool.
//!
//! The coordinator ([`crate::coordinator`]) keeps long-lived workers so
//! per-job latency does not pay thread-spawn cost, and the parallel
//! merge/sort entry points accept a pool to amortize spawning across
//! merge rounds (`*_with_pool` variants).
//!
//! Scoped (borrowing) tasks are executed with a completion latch: the
//! submitting call does not return until every task of the batch has
//! run, which is what makes the lifetime erasure sound. A panicking
//! scoped task is re-raised on the submitter; workers themselves
//! survive any task's panic (a dead worker would silently shrink pool
//! capacity), so panics are contained to the batch or job they belong
//! to.
//!
//! ## Nested `run_scoped` (calling the pool from inside a worker)
//!
//! Coordinator jobs execute *on* pool workers, and a job's merge engine
//! may itself call [`WorkerPool::run_scoped`] to parallelize its
//! segments on the same pool. A naive latch wait would deadlock: every
//! worker could end up blocked inside a wait while the tasks that would
//! release those latches sit behind them in the queue. `run_scoped`
//! therefore uses a *helping* wait — while its latch is open, the
//! submitting thread pulls queued tasks and executes them itself. Any
//! blocked submitter keeps draining the queue, so some thread always
//! makes progress and nesting to arbitrary depth cannot deadlock.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Latch counting outstanding tasks of one `run_scoped` batch.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panics: AtomicUsize,
}

impl Latch {
    fn new(n: usize) -> Self {
        Self {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
            panics: AtomicUsize::new(0),
        }
    }

    fn count_down(&self, panicked: bool) {
        if panicked {
            self.panics.fetch_add(1, Ordering::SeqCst);
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }

    /// Wait until done or `timeout` elapses; true iff done.
    fn wait_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self.cv.wait_timeout(rem, deadline - now).unwrap();
            rem = guard;
        }
        true
    }
}

/// A fixed-size pool of OS threads executing submitted closures.
pub struct WorkerPool {
    sender: Option<Sender<Task>>,
    /// Shared with the workers so a blocked `run_scoped` submitter can
    /// steal queued tasks (the helping wait).
    receiver: Arc<Mutex<Receiver<Task>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("size", &self.size).finish()
    }
}

impl WorkerPool {
    /// Spawn a pool with `size` worker threads (≥ 1).
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Task>();
        // A single shared receiver guarded by a mutex: workers take turns
        // pulling tasks. Contention is negligible at our task granularity
        // (tasks are whole merge segments, not elements).
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for worker_id in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mergeflow-worker-{worker_id}"))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match task {
                            // A panicking task must not take the worker
                            // down with it: scoped batches report panics
                            // through their latch (re-raised on the
                            // submitter), and a raw job closure's drop
                            // guards/channels fire during this unwind —
                            // killing the thread would only leak pool
                            // capacity and eventually wedge dispatch.
                            Ok(task) => {
                                if std::panic::catch_unwind(AssertUnwindSafe(task))
                                    .is_err()
                                {
                                    eprintln!(
                                        "mergeflow: pool task panicked; worker continues"
                                    );
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("failed to spawn worker thread"),
            );
        }
        Self {
            sender: Some(tx),
            receiver: rx,
            handles,
            size,
        }
    }

    /// Pull one queued task without blocking. `None` when the queue is
    /// empty *or* when an idle worker holds the receiver lock (it is
    /// parked inside `recv` and will run the next submitted task itself,
    /// so there is nothing useful to steal).
    fn try_steal(&self) -> Option<Task> {
        let guard = self.receiver.try_lock().ok()?;
        guard.try_recv().ok()
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a `'static` fire-and-forget task.
    pub fn submit(&self, task: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(task))
            .expect("worker channel closed");
    }

    /// Run `n` borrowed closures to completion on the pool (fork-join).
    ///
    /// Blocks until all `n` tasks finish; panics (re-raised here) if any
    /// task panicked. Soundness of the lifetime erasure: tasks cannot
    /// outlive this call because of the latch wait.
    ///
    /// Safe to call from *inside* a pool worker: while the latch is
    /// open, the submitting thread helps by executing queued tasks (its
    /// own batch's or anyone else's), so nested fork-join on a fully
    /// busy pool still makes progress instead of deadlocking (see the
    /// module docs).
    pub fn run_scoped<'env, F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync + 'env,
    {
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        // Erase lifetimes: we guarantee `f` outlives all tasks by waiting
        // on the latch before returning.
        let f_ptr: &(dyn Fn(usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for i in 0..n {
            let latch = Arc::clone(&latch);
            self.submit(move || {
                let result =
                    std::panic::catch_unwind(AssertUnwindSafe(|| f_static(i)));
                latch.count_down(result.is_err());
            });
        }
        // Helping wait. The short condvar timeout only matters when the
        // queue is empty but our tasks are still running on other
        // threads; completion itself wakes the wait immediately.
        loop {
            if latch.is_done() {
                break;
            }
            match self.try_steal() {
                // A stolen task must not unwind through this frame:
                // tasks of *our* batch still borrow `f` until the latch
                // closes. Our own batch's tasks report panics through
                // the latch; a stolen *foreign* task's panic belongs to
                // whoever submitted it (its drop guards / channels fire
                // during the unwind we catch here), not to this batch —
                // re-raising it would fail an innocent caller, so log
                // and keep helping.
                Some(task) => {
                    if std::panic::catch_unwind(AssertUnwindSafe(task)).is_err() {
                        eprintln!(
                            "mergeflow: stolen pool task panicked during helping wait"
                        );
                    }
                }
                None => {
                    latch.wait_timeout(Duration::from_micros(500));
                }
            }
        }
        if latch.panics.load(Ordering::SeqCst) > 0 {
            panic!("worker task panicked in run_scoped");
        }
    }

    /// Gracefully shut the pool down, joining all workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.sender.take(); // close channel → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn submit_runs_tasks() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = WorkerPool::new(4);
        let data: Vec<usize> = (0..100).collect();
        let sum = AtomicUsize::new(0);
        pool.run_scoped(10, |i| {
            let chunk = &data[i * 10..(i + 1) * 10];
            sum.fetch_add(chunk.iter().sum::<usize>(), Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn run_scoped_zero_tasks() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(0, |_| unreachable!());
    }

    #[test]
    #[should_panic(expected = "worker task panicked")]
    fn run_scoped_propagates_panic() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(4, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn nested_run_scoped_does_not_deadlock() {
        // Every worker enters a nested run_scoped while the pool is
        // already saturated by the outer batch — without the helping
        // wait this deadlocks (all workers blocked on latches, subtasks
        // stuck behind them in the queue).
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(4, |_| {
            pool.run_scoped(3, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(counter.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn deeply_nested_run_scoped_single_worker() {
        // One worker, three levels of nesting: only the helping wait can
        // execute the inner batches at all.
        let pool = WorkerPool::new(1);
        let counter = AtomicUsize::new(0);
        pool.run_scoped(2, |_| {
            pool.run_scoped(2, |_| {
                pool.run_scoped(2, |_| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            });
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn nested_run_scoped_propagates_inner_panic() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(2, |i| {
            pool.run_scoped(2, |j| {
                if i == 1 && j == 1 {
                    panic!("inner boom");
                }
            });
        });
    }

    #[test]
    fn pool_reusable_across_batches() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            pool.run_scoped(8, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 40);
    }
}
