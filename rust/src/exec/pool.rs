//! Persistent worker pool.
//!
//! The coordinator ([`crate::coordinator`]) keeps long-lived workers so
//! per-job latency does not pay thread-spawn cost, and the parallel
//! merge/sort entry points accept a pool to amortize spawning across
//! merge rounds (`*_with_pool` variants).
//!
//! Scoped (borrowing) tasks are executed with a completion latch: the
//! submitting call does not return until every task of the batch has
//! run, which is what makes the lifetime erasure sound. A panicking
//! task poisons the pool and the panic is re-raised on the submitter.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Latch counting outstanding tasks of one `run_scoped` batch.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panics: AtomicUsize,
}

impl Latch {
    fn new(n: usize) -> Self {
        Self {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
            panics: AtomicUsize::new(0),
        }
    }

    fn count_down(&self, panicked: bool) {
        if panicked {
            self.panics.fetch_add(1, Ordering::SeqCst);
        }
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.cv.wait(rem).unwrap();
        }
    }
}

struct Shared {
    queue: Mutex<Option<Receiver<Task>>>, // receiver is moved out by workers
}

/// A fixed-size pool of OS threads executing submitted closures.
pub struct WorkerPool {
    sender: Option<Sender<Task>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    size: usize,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("size", &self.size).finish()
    }
}

impl WorkerPool {
    /// Spawn a pool with `size` worker threads (≥ 1).
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Task>();
        let shared = Arc::new(Shared {
            queue: Mutex::new(Some(rx)),
        });
        // A single shared receiver guarded by a mutex: workers take turns
        // pulling tasks. Contention is negligible at our task granularity
        // (tasks are whole merge segments, not elements).
        let rx = shared.queue.lock().unwrap().take().unwrap();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for worker_id in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mergeflow-worker-{worker_id}"))
                    .spawn(move || loop {
                        let task = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match task {
                            Ok(task) => task(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("failed to spawn worker thread"),
            );
        }
        Self {
            sender: Some(tx),
            handles,
            size,
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a `'static` fire-and-forget task.
    pub fn submit(&self, task: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(task))
            .expect("worker channel closed");
    }

    /// Run `n` borrowed closures to completion on the pool (fork-join).
    ///
    /// Blocks until all `n` tasks finish; panics (re-raised here) if any
    /// task panicked. Soundness of the lifetime erasure: tasks cannot
    /// outlive this call because of the latch wait.
    pub fn run_scoped<'env, F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync + 'env,
    {
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        // Erase lifetimes: we guarantee `f` outlives all tasks by waiting
        // on the latch before returning.
        let f_ptr: &(dyn Fn(usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for i in 0..n {
            let latch = Arc::clone(&latch);
            self.submit(move || {
                let result =
                    std::panic::catch_unwind(AssertUnwindSafe(|| f_static(i)));
                latch.count_down(result.is_err());
            });
        }
        latch.wait();
        if latch.panics.load(Ordering::SeqCst) > 0 {
            panic!("worker task panicked in run_scoped");
        }
    }

    /// Gracefully shut the pool down, joining all workers.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.sender.take(); // close channel → workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn submit_runs_tasks() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn run_scoped_borrows_stack_data() {
        let pool = WorkerPool::new(4);
        let data: Vec<usize> = (0..100).collect();
        let sum = AtomicUsize::new(0);
        pool.run_scoped(10, |i| {
            let chunk = &data[i * 10..(i + 1) * 10];
            sum.fetch_add(chunk.iter().sum::<usize>(), Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }

    #[test]
    fn run_scoped_zero_tasks() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(0, |_| unreachable!());
    }

    #[test]
    #[should_panic(expected = "worker task panicked")]
    fn run_scoped_propagates_panic() {
        let pool = WorkerPool::new(2);
        pool.run_scoped(4, |i| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_reusable_across_batches() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            pool.run_scoped(8, |_| {
                counter.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(counter.load(Ordering::SeqCst), 40);
    }
}
