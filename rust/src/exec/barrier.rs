//! Sense-reversing centralized barrier.
//!
//! Algorithm 1 and Algorithm 3 of the paper end each parallel region
//! with a `Barrier`. `std::sync::Barrier` exists, but the
//! sense-reversing variant is the one whose cost the simulator models
//! (one atomic RMW per participant per phase + a broadcast flip), so we
//! implement it explicitly and expose phase counters for the metrics.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// A reusable sense-reversing barrier for a fixed number of parties.
#[derive(Debug)]
pub struct SenseBarrier {
    parties: usize,
    count: AtomicUsize,
    sense: AtomicBool,
    /// Completed phases (generations); useful for tests and metrics.
    generations: AtomicUsize,
}

impl SenseBarrier {
    /// Barrier for `parties` threads (must be ≥ 1).
    pub fn new(parties: usize) -> Self {
        assert!(parties > 0);
        Self {
            parties,
            count: AtomicUsize::new(0),
            sense: AtomicBool::new(false),
            generations: AtomicUsize::new(0),
        }
    }

    /// Number of participating threads.
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Completed generations so far.
    pub fn generations(&self) -> usize {
        self.generations.load(Ordering::Acquire)
    }

    /// Block until all `parties` threads have called `wait` for this
    /// generation. Returns `true` for exactly one "leader" thread per
    /// generation (the last arriver), mirroring
    /// `std::sync::BarrierWaitResult::is_leader`.
    pub fn wait(&self) -> bool {
        let local_sense = !self.sense.load(Ordering::Acquire);
        let arrived = self.count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == self.parties {
            // Last arriver: reset and release everyone.
            self.count.store(0, Ordering::Release);
            self.generations.fetch_add(1, Ordering::AcqRel);
            self.sense.store(local_sense, Ordering::Release);
            true
        } else {
            // Spin with yield; parties are expected to arrive promptly in
            // fork-join regions (and the host may be single-core).
            let mut spins = 0u32;
            while self.sense.load(Ordering::Acquire) != local_sense {
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn single_party_never_blocks() {
        let b = SenseBarrier::new(1);
        for _ in 0..10 {
            assert!(b.wait());
        }
        assert_eq!(b.generations(), 10);
    }

    #[test]
    fn synchronizes_phases() {
        const P: usize = 4;
        const ROUNDS: usize = 25;
        let barrier = Arc::new(SenseBarrier::new(P));
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..P {
            let barrier = Arc::clone(&barrier);
            let counter = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                for round in 0..ROUNDS {
                    counter.fetch_add(1, Ordering::SeqCst);
                    barrier.wait();
                    // After the barrier every thread must observe all P
                    // increments of this round.
                    assert!(counter.load(Ordering::SeqCst) >= (round + 1) * P);
                    barrier.wait();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), P * ROUNDS);
        assert_eq!(barrier.generations(), 2 * ROUNDS);
    }

    #[test]
    fn exactly_one_leader_per_generation() {
        const P: usize = 6;
        let barrier = Arc::new(SenseBarrier::new(P));
        let leaders = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..P {
            let barrier = Arc::clone(&barrier);
            let leaders = Arc::clone(&leaders);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    if barrier.wait() {
                        leaders.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(leaders.load(Ordering::SeqCst), 10);
    }
}
