//! PRAM-style execution substrate: a persistent worker pool, a
//! sense-reversing barrier, and scoped fork-join helpers.
//!
//! The paper assumes CREW PRAM with OpenMP-style fork-join regions; this
//! module provides the equivalent on `std::thread`. (rayon/tokio are not
//! available in the offline build image — see DESIGN.md §2.)

pub mod barrier;
pub mod pool;

pub use barrier::SenseBarrier;
pub use pool::WorkerPool;

/// Run `f(tid)` on `p` OS threads (fork-join), borrowing the caller's
/// stack data. Thread 0 runs on the calling thread to save one spawn.
///
/// Panics in any worker propagate to the caller after all workers
/// complete (no detached threads left behind).
pub fn fork_join<F>(p: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(p > 0);
    if p == 1 {
        f(0);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = (1..p)
            .map(|tid| s.spawn(move || f(tid)))
            .collect();
        f(0);
        for h in handles {
            // Propagate worker panics (join returns Err on panic).
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    });
}

/// Split `data` into `p` near-equal contiguous chunks and run
/// `f(tid, chunk)` on `p` threads. Chunk `i` covers
/// `[i·n/p, (i+1)·n/p)`, matching the partitioning convention used by
/// [`crate::mergepath::partition::partition_merge_path`].
pub fn parallel_chunks<T, F>(data: &mut [T], p: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(p > 0);
    let n = data.len();
    let mut rest = data;
    let mut parts: Vec<(usize, &mut [T])> = Vec::with_capacity(p);
    let mut start = 0usize;
    for i in 0..p {
        let end = (i + 1) * n / p;
        let (head, tail) = rest.split_at_mut(end - start);
        parts.push((i, head));
        rest = tail;
        start = end;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::with_capacity(p.saturating_sub(1));
        let mut first: Option<(usize, &mut [T])> = None;
        for (i, chunk) in parts {
            if i == 0 {
                first = Some((i, chunk));
            } else {
                handles.push(s.spawn(move || f(i, chunk)));
            }
        }
        if let Some((i, chunk)) = first {
            f(i, chunk);
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fork_join_runs_all_tids() {
        let hit = AtomicUsize::new(0);
        fork_join(8, |tid| {
            hit.fetch_or(1 << tid, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 0xFF);
    }

    #[test]
    fn fork_join_single_thread() {
        let hit = AtomicUsize::new(0);
        fork_join(1, |tid| {
            assert_eq!(tid, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[should_panic(expected = "worker boom")]
    fn fork_join_propagates_panics() {
        fork_join(4, |tid| {
            if tid == 2 {
                panic!("worker boom");
            }
        });
    }

    #[test]
    fn parallel_chunks_disjoint_cover() {
        let mut v = vec![0usize; 103];
        parallel_chunks(&mut v, 7, |tid, chunk| {
            for x in chunk.iter_mut() {
                *x += tid + 1; // every cell written exactly once
            }
        });
        // All cells written exactly once (no cell left 0, none doubled).
        assert!(v.iter().all(|&x| (1..=7).contains(&x)));
        // Sizes near-equal: each chunk is 103/7 = 14 or 15.
        let mut counts = [0usize; 8];
        for &x in &v {
            counts[x] += 1;
        }
        for c in &counts[1..] {
            assert!((14..=15).contains(c));
        }
    }

    #[test]
    fn parallel_chunks_empty() {
        let mut v: Vec<u8> = vec![];
        parallel_chunks(&mut v, 4, |_, chunk| assert!(chunk.is_empty()));
    }
}
