//! Persistent run store: disk-backed sorted runs under an LSM-style
//! level structure, with crash-safe manifest generations and a
//! background level-compaction scheduler.
//!
//! The store is the durability layer below the in-memory compaction
//! engine. Sealed runs are *spilled* to level 0 as append-only run
//! files ([`format`]); a versioned manifest ([`manifest`]) records
//! which files are live at which level; the [`scheduler`] scores
//! levels under the configured [`StorePolicy`], streams overlapping
//! run sets through the coordinator's `open_compaction` sessions
//! block-by-block (never materializing a whole run), and installs the
//! merged output via a new manifest generation *before* deleting its
//! inputs. Crash recovery is therefore always "load the highest
//! complete generation, delete everything it doesn't reference".
//!
//! Fault injection (tests only, compiled in but dormant): the
//! [`FailPoint`](crate::testutil::FailPoint) names honored here are
//! `store.spill.precommit` (crash after writing a run file, before the
//! manifest commit), `store.manifest.torn` (crash mid-manifest-write,
//! leaving a truncated image), and `store.compact.predelete` (crash
//! after installing a compaction output, before deleting its inputs).

pub mod format;
pub mod manifest;
pub mod scheduler;

pub use crate::config::{StoreConfig, StorePolicy};
pub use format::{read_footer, verify_run, RunFileInfo, RunReader, RunWriter};
pub use manifest::{manifest_name, peek_wire_id, run_file_name, RunMeta};
pub use scheduler::LevelScheduler;

use crate::coordinator::{MergeService, ServiceStats, StoreSink};
use crate::server::frame::WireRecord;
use crate::testutil::FailPoint;
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

struct StoreState<R> {
    runs: Vec<RunMeta<R>>,
    generation: u64,
    next_file_id: u64,
}

/// Totals returned by [`RunStore::verify`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyReport {
    /// Run files fully scanned.
    pub runs: u64,
    /// Records across all runs.
    pub records: u64,
    /// Bytes across all run files.
    pub bytes: u64,
}

/// Disk-backed store of sorted runs organized into LSM levels.
///
/// All mutation goes through the manifest protocol: write new run
/// files first, commit a manifest generation naming the new live set,
/// and only then delete obsolete files. The `state` mutex serializes
/// manifest commits (an fsync under the lock — deliberate: generation
/// order *is* the correctness story); `compact_lock` additionally
/// serializes whole compaction passes so the background scheduler and
/// a synchronous `FLUSH` never pick overlapping input sets.
pub struct RunStore<R: WireRecord> {
    dir: PathBuf,
    cfg: StoreConfig,
    state: Mutex<StoreState<R>>,
    compact_lock: Mutex<()>,
}

impl<R: WireRecord> RunStore<R> {
    /// Open (creating the directory if needed) and run crash recovery:
    /// load the highest complete manifest generation, delete orphans.
    pub fn open(cfg: &StoreConfig) -> Result<Self> {
        if !cfg.enabled() {
            return Err(Error::Config("store.dir is empty — store disabled".into()));
        }
        let dir = PathBuf::from(&cfg.dir);
        std::fs::create_dir_all(&dir)?;
        let (generation, runs) = manifest::recover::<R>(&dir)?;
        let next_file_id = runs.iter().map(|r| r.file_id).max().map_or(0, |m| m + 1);
        Ok(Self {
            dir,
            cfg: cfg.clone(),
            state: Mutex::new(StoreState { runs, generation, next_file_id }),
            compact_lock: Mutex::new(()),
        })
    }

    /// Store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Store configuration this instance was opened with.
    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    /// Current manifest generation.
    pub fn generation(&self) -> u64 {
        self.state.lock().unwrap().generation
    }

    /// Number of live runs.
    pub fn run_count(&self) -> usize {
        self.state.lock().unwrap().runs.len()
    }

    /// `(generation, live runs)` snapshot.
    pub fn snapshot(&self) -> (u64, Vec<RunMeta<R>>) {
        let st = self.state.lock().unwrap();
        (st.generation, st.runs.clone())
    }

    /// Live runs grouped by level (index = level; empty levels kept).
    pub fn levels(&self) -> Vec<Vec<RunMeta<R>>> {
        let st = self.state.lock().unwrap();
        let depth = st.runs.iter().map(|r| r.level as usize + 1).max().unwrap_or(0);
        let mut by_level: Vec<Vec<RunMeta<R>>> = vec![Vec::new(); depth];
        for r in &st.runs {
            by_level[r.level as usize].push(*r);
        }
        for level in &mut by_level {
            level.sort_by_key(|r| r.file_id);
        }
        by_level
    }

    fn run_path(&self, file_id: u64) -> PathBuf {
        self.dir.join(run_file_name(file_id))
    }

    /// Buffered chunked reader over one live run.
    pub fn reader(&self, meta: &RunMeta<R>) -> Result<RunReader<R>> {
        RunReader::open(&self.run_path(meta.file_id))
    }

    fn allocate_file_id(&self) -> u64 {
        let mut st = self.state.lock().unwrap();
        let id = st.next_file_id;
        st.next_file_id += 1;
        id
    }

    /// Spill one sealed, sorted run to level 0. The run file is
    /// written and fsynced first; the manifest commit that makes it
    /// live happens second — a crash between the two leaves an orphan
    /// that the next recovery deletes (failpoint
    /// `store.spill.precommit` exercises exactly that window).
    pub fn spill(&self, records: &[R]) -> Result<RunMeta<R>> {
        if records.is_empty() {
            return Err(Error::InvalidInput("refusing to spill an empty run".into()));
        }
        let file_id = self.allocate_file_id();
        let path = self.run_path(file_id);
        let info = format::write_run(&path, records, &self.cfg)?;
        if FailPoint::hit("store.spill.precommit") {
            return Err(Error::Service(format!(
                "failpoint store.spill.precommit: crashed before manifest commit of {}",
                path.display()
            )));
        }
        let meta = RunMeta {
            file_id,
            level: 0,
            count: info.count,
            bytes: info.bytes,
            min: info.first,
            max: info.last,
        };
        let mut st = self.state.lock().unwrap();
        let mut next = st.runs.clone();
        next.push(meta);
        manifest::commit(&self.dir, st.generation + 1, &next)?;
        st.generation += 1;
        st.runs = next;
        Ok(meta)
    }

    /// Serialize a whole compaction pass (scheduler vs. synchronous
    /// flush) — hold the guard across pick + merge + install.
    pub fn compaction_permit(&self) -> MutexGuard<'_, ()> {
        self.compact_lock.lock().unwrap()
    }

    /// Install a compaction output: write the merged run at
    /// `to_level`, commit a manifest generation that swaps it in for
    /// `input_ids`, and only then delete the input files. A crash in
    /// the install/delete window (failpoint `store.compact.predelete`)
    /// leaves the *new* generation authoritative and the inputs as
    /// orphans for recovery to reclaim — never data loss, never
    /// duplicates.
    pub fn install_compaction(
        &self,
        input_ids: &[u64],
        output: &[R],
        to_level: u32,
    ) -> Result<RunMeta<R>> {
        if output.is_empty() {
            return Err(Error::InvalidInput(
                "refusing to install an empty compaction output".into(),
            ));
        }
        let file_id = self.allocate_file_id();
        let path = self.run_path(file_id);
        let info = format::write_run(&path, output, &self.cfg)?;
        let meta = RunMeta {
            file_id,
            level: to_level,
            count: info.count,
            bytes: info.bytes,
            min: info.first,
            max: info.last,
        };
        {
            let mut st = self.state.lock().unwrap();
            for id in input_ids {
                if !st.runs.iter().any(|r| r.file_id == *id) {
                    return Err(Error::Service(format!(
                        "compaction input run {id} is no longer live"
                    )));
                }
            }
            let mut next: Vec<RunMeta<R>> =
                st.runs.iter().filter(|r| !input_ids.contains(&r.file_id)).copied().collect();
            next.push(meta);
            manifest::commit(&self.dir, st.generation + 1, &next)?;
            st.generation += 1;
            st.runs = next;
        }
        if FailPoint::hit("store.compact.predelete") {
            return Err(Error::Service(
                "failpoint store.compact.predelete: crashed before deleting inputs".into(),
            ));
        }
        for id in input_ids {
            let _ = std::fs::remove_file(self.run_path(*id));
        }
        Ok(meta)
    }

    /// Re-verify every live run file end to end (header, every block
    /// CRC, footer), cross-checking counts against the manifest.
    pub fn verify(&self) -> Result<VerifyReport> {
        let (_, runs) = self.snapshot();
        let mut report = VerifyReport { runs: 0, records: 0, bytes: 0 };
        for meta in &runs {
            let path = self.run_path(meta.file_id);
            let info = verify_run::<R>(&path)?;
            if info.count != meta.count || info.bytes != meta.bytes {
                return Err(Error::InvalidInput(format!(
                    "run {} disagrees with manifest: file has {} records / {} bytes, \
                     manifest says {} / {}",
                    path.display(),
                    info.count,
                    info.bytes,
                    meta.count,
                    meta.bytes
                )));
            }
            report.runs += 1;
            report.records += info.count;
            report.bytes += info.bytes;
        }
        Ok(report)
    }

    /// Human-readable listing: generation, per-level run counts, and
    /// (when `verbose`) each run's id, count, bytes, and key range.
    pub fn describe(&self, verbose: bool) -> String {
        use std::fmt::Write as _;
        let (gen, runs) = self.snapshot();
        let total_records: u64 = runs.iter().map(|r| r.count).sum();
        let total_bytes: u64 = runs.iter().map(|r| r.bytes).sum();
        let mut out = String::new();
        let _ = writeln!(
            out,
            "store {}: generation={gen} runs={} records={total_records} bytes={total_bytes} \
             policy={}",
            self.dir.display(),
            runs.len(),
            self.cfg.policy
        );
        for (level, level_runs) in self.levels().iter().enumerate() {
            let records: u64 = level_runs.iter().map(|r| r.count).sum();
            let bytes: u64 = level_runs.iter().map(|r| r.bytes).sum();
            let _ = writeln!(
                out,
                "  L{level}: {} runs, {records} records, {bytes} bytes",
                level_runs.len()
            );
            if verbose {
                for r in level_runs {
                    let _ = writeln!(
                        out,
                        "    {}  count={} bytes={} keys=[{:?} .. {:?}]",
                        run_file_name(r.file_id),
                        r.count,
                        r.bytes,
                        r.min.key(),
                        r.max.key()
                    );
                }
            }
        }
        out
    }
}

/// Adapter that plugs a [`RunStore`] into the coordinator as its
/// [`StoreSink`]: `JobKind::Spill` jobs land here from pool workers,
/// `JobKind::Flush` drives synchronous compaction passes, and store
/// counters are mirrored into [`ServiceStats`].
pub struct StoreBridge<R: WireRecord> {
    store: Arc<RunStore<R>>,
    stats: Arc<ServiceStats>,
}

impl<R: WireRecord> StoreBridge<R> {
    /// Build the bridge and seed the stats gauges from the recovered
    /// store state (runs and generation survive restarts; counters
    /// must agree with what `STORE_STATS` reports).
    pub fn new(store: Arc<RunStore<R>>, stats: Arc<ServiceStats>) -> Self {
        let (gen, runs) = store.snapshot();
        stats.store_runs.add(runs.len() as u64);
        stats.store_generation.add(gen);
        Self { store, stats }
    }

    /// The wrapped store.
    pub fn store(&self) -> &Arc<RunStore<R>> {
        &self.store
    }
}

impl<R: WireRecord> StoreSink<R> for StoreBridge<R> {
    fn spill(&self, run: &[R]) -> Result<u64> {
        let meta = self.store.spill(run)?;
        self.stats.store_spills.inc();
        self.stats.store_spilled_bytes.add(meta.bytes);
        self.stats.store_runs.add(1);
        self.stats.store_generation.inc();
        Ok(meta.bytes)
    }

    fn flush(&self, svc: &MergeService<R>) -> Result<u64> {
        self.stats.store_flushes.inc();
        scheduler::flush_until_quiescent(&self.store, svc, &self.stats)
    }

    fn stats_text(&self) -> String {
        self.store.describe(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TempDir(PathBuf);
    impl TempDir {
        fn new(name: &str) -> Self {
            let dir = std::env::temp_dir()
                .join(format!("mergeflow-store-mod-{}-{name}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);
            Self(dir)
        }
        fn cfg(&self) -> StoreConfig {
            StoreConfig {
                dir: self.0.to_string_lossy().into_owned(),
                block_bytes: 64,
                ..StoreConfig::default()
            }
        }
    }
    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn spill_reopen_round_trip() {
        let t = TempDir::new("spill-reopen");
        let store = RunStore::<i32>::open(&t.cfg()).unwrap();
        let a: Vec<i32> = (0..500).collect();
        let b: Vec<i32> = (250..750).collect();
        store.spill(&a).unwrap();
        store.spill(&b).unwrap();
        assert_eq!((store.generation(), store.run_count()), (2, 2));
        drop(store);
        let store = RunStore::<i32>::open(&t.cfg()).unwrap();
        assert_eq!((store.generation(), store.run_count()), (2, 2));
        let (_, runs) = store.snapshot();
        let mut got = Vec::new();
        for meta in &runs {
            let mut rd = store.reader(meta).unwrap();
            let mut run = Vec::new();
            while let Some(block) = rd.next_block().unwrap() {
                run.extend(block);
            }
            assert_eq!(run.len() as u64, meta.count);
            got.push(run);
        }
        assert_eq!(got, vec![a, b]);
        let report = store.verify().unwrap();
        assert_eq!((report.runs, report.records), (2, 1000));
    }

    #[test]
    fn install_compaction_swaps_inputs_for_output() {
        let t = TempDir::new("install");
        let store = RunStore::<i32>::open(&t.cfg()).unwrap();
        let m1 = store.spill(&(0..100).collect::<Vec<i32>>()).unwrap();
        let m2 = store.spill(&(50..150).collect::<Vec<i32>>()).unwrap();
        let mut merged: Vec<i32> = (0..100).chain(50..150).collect();
        merged.sort_unstable();
        let out = store
            .install_compaction(&[m1.file_id, m2.file_id], &merged, 1)
            .unwrap();
        assert_eq!(out.level, 1);
        assert_eq!(store.run_count(), 1);
        assert_eq!(store.generation(), 3);
        assert!(!t.0.join(run_file_name(m1.file_id)).exists());
        assert!(!t.0.join(run_file_name(m2.file_id)).exists());
        let levels = store.levels();
        assert_eq!(levels[0].len(), 0);
        assert_eq!(levels[1].len(), 1);
        assert_eq!(levels[1][0].count, 200);
        let text = store.describe(true);
        assert!(text.contains("generation=3"), "describe lists generation: {text}");
        assert!(text.contains("L1: 1 runs"), "describe lists levels: {text}");
    }

    #[test]
    fn empty_spill_and_disabled_config_are_refused() {
        let t = TempDir::new("refused");
        let store = RunStore::<i32>::open(&t.cfg()).unwrap();
        assert!(store.spill(&[]).is_err());
        let disabled = StoreConfig::default();
        assert!(RunStore::<i32>::open(&disabled).is_err());
    }
}
