//! Background level-compaction scheduler.
//!
//! A single thread repeatedly scores the store's levels under the
//! configured [`StorePolicy`], picks a set of input runs, streams them
//! block-by-block through the coordinator's `open_compaction` session
//! (so the merge is budget-admitted and flow-controlled exactly like
//! any client workload), installs the merged output via a new manifest
//! generation, and only then lets the store delete the inputs.
//!
//! Policies:
//!
//! * `tiered` — the lowest level holding at least its run threshold
//!   (`level0_max_runs` at L0, `level_fanout` deeper) has *all* its
//!   runs merged into one run at the next level. Write-optimized:
//!   every record is rewritten once per level it descends.
//! * `leveled` — levels are scored `runs / limit(L)` with
//!   `limit(L) = level0_max_runs · level_fanout^L`; the worst level at
//!   or over its limit contributes up to `level_fanout` of its oldest
//!   runs plus every key-range-overlapping run of the next level, all
//!   merged into a single run at the next level. Read-optimized: deep
//!   levels converge toward few, wide runs. (Simplification vs.
//!   textbook leveled compaction: output is one run and levels are not
//!   forced to be non-overlapping — runs are always independent sorted
//!   runs, so this affects compaction economics, never correctness.)
//!
//! BUSY / budget rejections from the service surface as
//! `Error::Service`; the scheduler counts a backoff and retries after
//! `compact_backoff_ms`. A pass that finds nothing to do counts a
//! skip and sleeps the same backoff.

use super::{RunMeta, RunStore, StoreConfig, StorePolicy};
use crate::coordinator::{MergeService, ServiceStats};
use crate::server::frame::WireRecord;
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest number of runs merged in one pass (bounds session fan-in
/// and the dispatcher's planning cost for pathological backlogs).
const MAX_COMPACTION_K: usize = 64;

/// Retry bound for [`flush_until_quiescent`] — a flush that sees this
/// many consecutive BUSY/budget rejections gives up instead of
/// spinning forever against a service that is shutting down.
const FLUSH_MAX_BACKOFFS: u32 = 1000;

fn group_by_level<R: WireRecord>(runs: &[RunMeta<R>]) -> Vec<Vec<RunMeta<R>>> {
    let depth = runs.iter().map(|r| r.level as usize + 1).max().unwrap_or(0);
    let mut levels: Vec<Vec<RunMeta<R>>> = vec![Vec::new(); depth];
    for r in runs {
        levels[r.level as usize].push(*r);
    }
    for level in &mut levels {
        level.sort_by_key(|r| r.file_id);
    }
    levels
}

/// Score the levels and pick `(inputs, output_level)` for the next
/// compaction, or `None` when every level is within policy.
pub(crate) fn pick<R: WireRecord>(
    runs: &[RunMeta<R>],
    cfg: &StoreConfig,
) -> Option<(Vec<RunMeta<R>>, u32)> {
    let levels = group_by_level(runs);
    match cfg.policy {
        StorePolicy::Tiered => {
            for (l, level_runs) in levels.iter().enumerate() {
                let threshold = if l == 0 { cfg.level0_max_runs } else { cfg.level_fanout };
                if level_runs.len() >= threshold {
                    let mut inputs = level_runs.clone();
                    inputs.truncate(MAX_COMPACTION_K);
                    return Some((inputs, l as u32 + 1));
                }
            }
            None
        }
        StorePolicy::Leveled => {
            let mut worst: Option<(usize, f64)> = None;
            for (l, level_runs) in levels.iter().enumerate() {
                if level_runs.is_empty() {
                    continue;
                }
                let limit = (cfg.level0_max_runs as u64)
                    .saturating_mul((cfg.level_fanout as u64).saturating_pow(l as u32))
                    .max(1);
                let score = level_runs.len() as f64 / limit as f64;
                if score >= 1.0 && worst.map_or(true, |(_, s)| score > s) {
                    worst = Some((l, score));
                }
            }
            let (l, _) = worst?;
            let mut inputs: Vec<RunMeta<R>> =
                levels[l].iter().take(cfg.level_fanout).copied().collect();
            if let Some(next) = levels.get(l + 1) {
                for r in next {
                    if inputs.iter().any(|sel| sel.level as usize == l && sel.overlaps(r)) {
                        inputs.push(*r);
                    }
                }
            }
            inputs.truncate(MAX_COMPACTION_K);
            Some((inputs, l as u32 + 1))
        }
    }
}

/// One compaction attempt: pick inputs, stream them through a
/// compaction session, install the output. Returns `Ok(true)` if a
/// compaction was installed, `Ok(false)` if the store is within
/// policy (nothing to do). `Error::Service` means the service refused
/// admission (BUSY / budget) — retry after backoff.
pub fn run_pass<R: WireRecord>(
    store: &RunStore<R>,
    svc: &MergeService<R>,
    stats: &ServiceStats,
) -> Result<bool> {
    let _permit = store.compaction_permit();
    let (_, runs) = store.snapshot();
    let Some((inputs, to_level)) = pick(&runs, store.config()) else {
        stats.scheduler_skips.inc();
        return Ok(false);
    };
    let mut session = svc.open_compaction(inputs.len())?;
    let mut in_bytes = 0u64;
    for (i, meta) in inputs.iter().enumerate() {
        let mut reader = store.reader(meta)?;
        while let Some(block) = reader.next_block()? {
            session.feed(i, block)?;
        }
        session.seal_run(i)?;
        in_bytes += meta.bytes;
    }
    let merged = session.seal()?.wait()?;
    let input_ids: Vec<u64> = inputs.iter().map(|m| m.file_id).collect();
    store.install_compaction(&input_ids, &merged.output, to_level)?;
    stats.store_compactions.inc();
    stats.store_compacted_bytes.add(in_bytes);
    stats.store_runs.sub(input_ids.len() as u64 - 1);
    stats.store_generation.inc();
    stats.scheduler_passes.inc();
    Ok(true)
}

/// Run compaction passes until the store is within policy; the
/// synchronous engine behind the `FLUSH` wire verb. Returns the
/// number of compactions installed. BUSY/budget rejections back off
/// and retry (bounded), other errors propagate.
pub fn flush_until_quiescent<R: WireRecord>(
    store: &RunStore<R>,
    svc: &MergeService<R>,
    stats: &ServiceStats,
) -> Result<u64> {
    let backoff = Duration::from_millis(store.config().compact_backoff_ms.max(1));
    let mut installed = 0u64;
    let mut backoffs = 0u32;
    loop {
        match run_pass(store, svc, stats) {
            Ok(true) => {
                installed += 1;
                backoffs = 0;
            }
            Ok(false) => return Ok(installed),
            Err(Error::Service(msg)) => {
                stats.scheduler_backoffs.inc();
                backoffs += 1;
                if backoffs >= FLUSH_MAX_BACKOFFS {
                    return Err(Error::Service(format!(
                        "flush gave up after {backoffs} rejected compaction attempts \
                         (last: {msg})"
                    )));
                }
                std::thread::sleep(backoff);
            }
            Err(e) => return Err(e),
        }
    }
}

/// Handle to the background scheduler thread. Stop it explicitly with
/// [`LevelScheduler::stop`] (also run on drop) *before* tearing down
/// the service it feeds.
pub struct LevelScheduler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl LevelScheduler {
    /// Spawn the scheduler thread over `store`, submitting compaction
    /// work to `svc`. Backoff cadence comes from the store's
    /// `compact_backoff_ms`.
    pub fn start<R: WireRecord>(store: Arc<RunStore<R>>, svc: Arc<MergeService<R>>) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("mergeflow-store-scheduler".into())
            .spawn(move || {
                let stats = svc.stats_arc();
                let backoff =
                    Duration::from_millis(store.config().compact_backoff_ms.max(1));
                while !stop_flag.load(Ordering::Relaxed) {
                    match run_pass(&store, &svc, &stats) {
                        // Installed one — immediately look for more.
                        Ok(true) => {}
                        Ok(false) => sleep_unless_stopped(&stop_flag, backoff),
                        Err(Error::Service(_)) => {
                            stats.scheduler_backoffs.inc();
                            sleep_unless_stopped(&stop_flag, backoff);
                        }
                        Err(e) => {
                            eprintln!("mergeflow: store scheduler error: {e}");
                            sleep_unless_stopped(&stop_flag, backoff);
                        }
                    }
                }
            })
            .expect("spawn store scheduler thread");
        Self { stop, handle: Some(handle) }
    }

    /// Signal the thread to stop and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for LevelScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Sleep `total` in short slices so a stop request never waits out a
/// full backoff.
fn sleep_unless_stopped(stop: &AtomicBool, total: Duration) {
    let slice = Duration::from_millis(5);
    let mut remaining = total;
    while !stop.load(Ordering::Relaxed) && remaining > Duration::ZERO {
        let step = remaining.min(slice);
        std::thread::sleep(step);
        remaining -= step;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(file_id: u64, level: u32, min: i32, max: i32) -> RunMeta<i32> {
        RunMeta { file_id, level, count: 16, bytes: 64, min, max }
    }

    fn cfg(policy: StorePolicy) -> StoreConfig {
        StoreConfig {
            policy,
            level0_max_runs: 4,
            level_fanout: 2,
            ..StoreConfig::default()
        }
    }

    #[test]
    fn tiered_waits_for_the_level0_threshold() {
        let cfg = cfg(StorePolicy::Tiered);
        let runs: Vec<_> = (0..3).map(|i| meta(i, 0, 0, 100)).collect();
        assert!(pick(&runs, &cfg).is_none(), "3 < level0_max_runs");
        let runs: Vec<_> = (0..4).map(|i| meta(i, 0, 0, 100)).collect();
        let (inputs, to) = pick(&runs, &cfg).unwrap();
        assert_eq!((inputs.len(), to), (4, 1));
    }

    #[test]
    fn tiered_prefers_the_lowest_eligible_level() {
        let cfg = cfg(StorePolicy::Tiered);
        let mut runs: Vec<_> = (0..4).map(|i| meta(i, 0, 0, 100)).collect();
        runs.extend((10..12).map(|i| meta(i, 1, 0, 100)));
        let (inputs, to) = pick(&runs, &cfg).unwrap();
        assert_eq!(to, 1, "L0 backlog compacts before L1");
        assert!(inputs.iter().all(|r| r.level == 0));
        // With L0 quiet, the L1 backlog (2 >= fanout) is chosen.
        let runs: Vec<_> = (10..12).map(|i| meta(i, 1, 0, 100)).collect();
        let (inputs, to) = pick(&runs, &cfg).unwrap();
        assert_eq!((inputs.len(), to), (2, 2));
    }

    #[test]
    fn leveled_pulls_overlapping_next_level_runs() {
        let cfg = cfg(StorePolicy::Leveled);
        let mut runs: Vec<_> = (0..4).map(|i| meta(i, 0, 0, 50)).collect();
        runs.push(meta(10, 1, 40, 60)); // overlaps the selection
        runs.push(meta(11, 1, 200, 300)); // disjoint — must stay put
        let (inputs, to) = pick(&runs, &cfg).unwrap();
        assert_eq!(to, 1);
        let ids: Vec<u64> = inputs.iter().map(|r| r.file_id).collect();
        // fanout=2 oldest L0 runs + the one overlapping L1 run.
        assert_eq!(ids, vec![0, 1, 10]);
    }

    #[test]
    fn leveled_within_limits_is_quiet() {
        let cfg = cfg(StorePolicy::Leveled);
        let runs: Vec<_> = (0..3).map(|i| meta(i, 0, 0, 50)).collect();
        assert!(pick(&runs, &cfg).is_none());
        // limit(L1) = 4·2 = 8, so 7 runs at L1 is within policy.
        let runs: Vec<_> = (0..7).map(|i| meta(i, 1, 0, 50)).collect();
        assert!(pick(&runs, &cfg).is_none());
        let runs: Vec<_> = (0..8).map(|i| meta(i, 1, 0, 50)).collect();
        let (inputs, to) = pick(&runs, &cfg).unwrap();
        assert_eq!((inputs.len(), to), (2, 2), "fanout oldest runs move down");
    }

    #[test]
    fn empty_store_picks_nothing() {
        assert!(pick::<i32>(&[], &cfg(StorePolicy::Tiered)).is_none());
        assert!(pick::<i32>(&[], &cfg(StorePolicy::Leveled)).is_none());
    }
}
