//! Versioned manifest: the single source of truth for which run files
//! are live, at which level, at a given generation.
//!
//! `MANIFEST-<gen>` layout (all integers LE):
//!
//! ```text
//! magic "MFMAN1\0\0" | gen u64 | wire_id u32 | wire_bytes u32 | run_count u32
//! per run: file_id u64 | level u32 | count u64 | bytes u64 | min rec | max rec
//! trailing crc32 u32 (over everything before it)
//! ```
//!
//! Commit protocol: write the full image to `MANIFEST-<gen>.tmp`,
//! fsync the file, atomically rename to `MANIFEST-<gen>`, fsync the
//! directory. A crash at any point leaves either the previous
//! generation intact or the new one complete; recovery loads the
//! highest CRC-valid generation and deletes everything else (stale
//! manifests, temp files, run files the chosen generation does not
//! reference). Rerunning recovery is idempotent.

use super::format::crc32;
use crate::server::frame::WireRecord;
use crate::testutil::FailPoint;
use crate::{Error, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

pub(crate) const MANIFEST_MAGIC: [u8; 8] = *b"MFMAN1\0\0";
const MANIFEST_PREFIX: &str = "MANIFEST-";

/// One live run file as recorded in the manifest.
#[derive(Debug, Clone, Copy)]
pub struct RunMeta<R> {
    /// Stable file id; the file on disk is `run-<id>.mfr`.
    pub file_id: u64,
    /// LSM level (0 = freshly spilled).
    pub level: u32,
    /// Records in the run.
    pub count: u64,
    /// File size in bytes.
    pub bytes: u64,
    /// Minimum-key record.
    pub min: R,
    /// Maximum-key record.
    pub max: R,
}

impl<R: WireRecord> RunMeta<R> {
    /// Key-range overlap test (inclusive on both ends).
    pub fn overlaps(&self, other: &Self) -> bool {
        !(self.max.key() < other.min.key() || other.max.key() < self.min.key())
    }
}

/// On-disk name of a run file.
pub fn run_file_name(file_id: u64) -> String {
    format!("run-{file_id:016}.mfr")
}

/// On-disk name of a manifest generation.
pub fn manifest_name(gen: u64) -> String {
    format!("{MANIFEST_PREFIX}{gen:016}")
}

fn encode<R: WireRecord>(gen: u64, runs: &[RunMeta<R>]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(28 + runs.len() * (28 + 2 * R::WIRE_BYTES));
    buf.extend_from_slice(&MANIFEST_MAGIC);
    buf.extend_from_slice(&gen.to_le_bytes());
    buf.extend_from_slice(&R::WIRE_ID.to_le_bytes());
    buf.extend_from_slice(&(R::WIRE_BYTES as u32).to_le_bytes());
    buf.extend_from_slice(&(runs.len() as u32).to_le_bytes());
    for r in runs {
        buf.extend_from_slice(&r.file_id.to_le_bytes());
        buf.extend_from_slice(&r.level.to_le_bytes());
        buf.extend_from_slice(&r.count.to_le_bytes());
        buf.extend_from_slice(&r.bytes.to_le_bytes());
        r.min.encode(&mut buf);
        r.max.encode(&mut buf);
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// CRC + magic check without knowing the record type; returns
/// `(gen, wire_id)` header fields if the image is complete.
fn validate_raw(bytes: &[u8]) -> Option<(u64, u32)> {
    if bytes.len() < 28 + 4 || bytes[..8] != MANIFEST_MAGIC {
        return None;
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    if crc32(body) != u32::from_le_bytes(tail.try_into().unwrap()) {
        return None;
    }
    let gen = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let wire_id = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    Some((gen, wire_id))
}

fn decode<R: WireRecord>(bytes: &[u8], path: &Path) -> Result<(u64, Vec<RunMeta<R>>)> {
    let bad = |what: &str| {
        Error::InvalidInput(format!("corrupt manifest {}: {what}", path.display()))
    };
    let (gen, wire_id) = validate_raw(bytes).ok_or_else(|| bad("bad magic or crc"))?;
    if wire_id != R::WIRE_ID {
        return Err(bad(&format!(
            "record type mismatch: manifest has wire_id={wire_id}, expected {}",
            R::WIRE_ID
        )));
    }
    let wire_bytes = u32::from_le_bytes(bytes[20..24].try_into().unwrap());
    if wire_bytes as usize != R::WIRE_BYTES {
        return Err(bad("record width mismatch"));
    }
    let run_count = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    let entry = 28 + 2 * R::WIRE_BYTES;
    if bytes.len() != 28 + run_count * entry + 4 {
        return Err(bad("length does not match run count"));
    }
    let mut runs = Vec::with_capacity(run_count);
    let mut at = 28;
    for _ in 0..run_count {
        let e = &bytes[at..at + entry];
        runs.push(RunMeta {
            file_id: u64::from_le_bytes(e[..8].try_into().unwrap()),
            level: u32::from_le_bytes(e[8..12].try_into().unwrap()),
            count: u64::from_le_bytes(e[12..20].try_into().unwrap()),
            bytes: u64::from_le_bytes(e[20..28].try_into().unwrap()),
            min: R::decode(&e[28..28 + R::WIRE_BYTES]),
            max: R::decode(&e[28 + R::WIRE_BYTES..]),
        });
        at += entry;
    }
    Ok((gen, runs))
}

/// Durably commit generation `gen`: temp file, fsync, rename, fsync
/// dir. Failpoint `store.manifest.torn` simulates a crash mid-write by
/// leaving a truncated image at the *final* name and erroring.
pub fn commit<R: WireRecord>(dir: &Path, gen: u64, runs: &[RunMeta<R>]) -> Result<()> {
    let image = encode(gen, runs);
    let final_path = dir.join(manifest_name(gen));
    if FailPoint::hit("store.manifest.torn") {
        std::fs::write(&final_path, &image[..image.len() / 2])?;
        return Err(Error::Service(format!(
            "failpoint store.manifest.torn: crashed writing {}",
            final_path.display()
        )));
    }
    let tmp_path = dir.join(format!("{}.tmp", manifest_name(gen)));
    let mut tmp = std::fs::File::create(&tmp_path)?;
    tmp.write_all(&image)?;
    tmp.sync_all()?;
    drop(tmp);
    std::fs::rename(&tmp_path, &final_path)?;
    // Persist the rename itself (directory metadata) where the
    // platform supports opening directories; best-effort elsewhere.
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Directory scan result fed into recovery.
struct Scan {
    /// `(gen, path)` for every `MANIFEST-*` file (tmp files excluded).
    manifests: Vec<(u64, PathBuf)>,
    /// Leftover `MANIFEST-*.tmp` files.
    temps: Vec<PathBuf>,
    /// `(file_id, path)` for every `run-*.mfr` file.
    runs: Vec<(u64, PathBuf)>,
}

fn scan(dir: &Path) -> Result<Scan> {
    let mut s = Scan { manifests: Vec::new(), temps: Vec::new(), runs: Vec::new() };
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if let Some(rest) = name.strip_prefix(MANIFEST_PREFIX) {
            if let Some(gen) = rest.strip_suffix(".tmp") {
                if gen.parse::<u64>().is_ok() {
                    s.temps.push(path);
                }
            } else if let Ok(gen) = rest.parse::<u64>() {
                s.manifests.push((gen, path));
            }
        } else if let Some(id) = name
            .strip_prefix("run-")
            .and_then(|r| r.strip_suffix(".mfr"))
            .and_then(|r| r.parse::<u64>().ok())
        {
            s.runs.push((id, path));
        }
    }
    Ok(s)
}

/// Load the highest complete manifest generation and delete everything
/// it does not account for: torn/stale manifests, leftover temp files,
/// and orphaned run files. Returns `(gen, runs)`; an empty or virgin
/// directory yields `(0, [])`. Idempotent — rerunning changes nothing.
pub fn recover<R: WireRecord>(dir: &Path) -> Result<(u64, Vec<RunMeta<R>>)> {
    let mut s = scan(dir)?;
    s.manifests.sort_by(|a, b| b.0.cmp(&a.0));
    let mut chosen: Option<(u64, Vec<RunMeta<R>>)> = None;
    for (gen, path) in &s.manifests {
        if chosen.is_some() {
            // Stale generation shadowed by a newer complete one.
            let _ = std::fs::remove_file(path);
            continue;
        }
        let bytes = std::fs::read(path)?;
        match decode::<R>(&bytes, path) {
            Ok((g, runs)) if g == *gen => chosen = Some((g, runs)),
            // Torn or mislabeled image: discard and fall back.
            _ => {
                let _ = std::fs::remove_file(path);
            }
        }
    }
    for path in &s.temps {
        let _ = std::fs::remove_file(path);
    }
    let (gen, runs) = chosen.unwrap_or((0, Vec::new()));
    let live: std::collections::HashSet<u64> = runs.iter().map(|r| r.file_id).collect();
    for (id, path) in &s.runs {
        if !live.contains(id) {
            let _ = std::fs::remove_file(path);
        }
    }
    // Every referenced run must exist — a manifest pointing at a
    // missing file means the directory was tampered with, not a
    // crash this protocol can produce.
    for r in &runs {
        let p = dir.join(run_file_name(r.file_id));
        if !p.exists() {
            return Err(Error::InvalidInput(format!(
                "manifest generation {gen} references missing run file {}",
                p.display()
            )));
        }
    }
    Ok((gen, runs))
}

/// Peek the record type of a store directory without knowing `R`:
/// returns `Some(wire_id)` from the newest complete manifest, `None`
/// if no valid manifest exists. Never modifies the directory (unlike
/// [`recover`]), so the CLI can dispatch on it safely.
pub fn peek_wire_id(dir: &Path) -> Result<Option<u32>> {
    let mut s = scan(dir)?;
    s.manifests.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in &s.manifests {
        let bytes = std::fs::read(path)?;
        if let Some((_, wire_id)) = validate_raw(&bytes) {
            return Ok(Some(wire_id));
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mergeflow-manifest-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn meta(file_id: u64, level: u32, min: i32, max: i32) -> RunMeta<i32> {
        RunMeta { file_id, level, count: 10, bytes: 100, min, max }
    }

    #[test]
    fn commit_and_recover_round_trip() {
        let dir = tmp("roundtrip");
        let runs = vec![meta(1, 0, 0, 9), meta(2, 1, -5, 3)];
        commit(&dir, 1, &runs).unwrap();
        // Touch the referenced run files so recovery's existence check
        // passes; add an orphan that must be reclaimed.
        for id in [1u64, 2] {
            std::fs::write(dir.join(run_file_name(id)), b"x").unwrap();
        }
        let orphan = dir.join(run_file_name(99));
        std::fs::write(&orphan, b"x").unwrap();
        let (gen, got) = recover::<i32>(&dir).unwrap();
        assert_eq!(gen, 1);
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].file_id, got[0].level, got[0].min, got[0].max), (1, 0, 0, 9));
        assert!(!orphan.exists(), "orphan run reclaimed");
        assert_eq!(peek_wire_id(&dir).unwrap(), Some(<i32 as WireRecord>::WIRE_ID));
        // Idempotent.
        let (gen2, got2) = recover::<i32>(&dir).unwrap();
        assert_eq!((gen2, got2.len()), (1, 2));
    }

    #[test]
    fn torn_manifest_falls_back_a_generation() {
        let dir = tmp("torn");
        commit(&dir, 1, &[meta(1, 0, 0, 9)]).unwrap();
        std::fs::write(dir.join(run_file_name(1)), b"x").unwrap();
        // Torn image at generation 2 + a leftover temp file.
        let img = encode(2, &[meta(1, 0, 0, 9), meta(2, 0, 10, 19)]);
        std::fs::write(dir.join(manifest_name(2)), &img[..img.len() / 2]).unwrap();
        std::fs::write(dir.join(format!("{}.tmp", manifest_name(3))), b"junk").unwrap();
        std::fs::write(dir.join(run_file_name(2)), b"x").unwrap(); // orphan of gen 2
        let (gen, runs) = recover::<i32>(&dir).unwrap();
        assert_eq!(gen, 1);
        assert_eq!(runs.len(), 1);
        assert!(!dir.join(manifest_name(2)).exists(), "torn manifest removed");
        assert!(!dir.join(format!("{}.tmp", manifest_name(3))).exists());
        assert!(!dir.join(run_file_name(2)).exists(), "gen-2 orphan removed");
    }

    #[test]
    fn empty_dir_recovers_to_generation_zero() {
        let dir = tmp("empty");
        let (gen, runs) = recover::<i32>(&dir).unwrap();
        assert_eq!((gen, runs.len()), (0, 0));
        assert_eq!(peek_wire_id(&dir).unwrap(), None);
    }

    #[test]
    fn overlap_test_is_inclusive() {
        let a = meta(1, 0, 0, 10);
        assert!(a.overlaps(&meta(2, 0, 10, 20)));
        assert!(a.overlaps(&meta(2, 0, -5, 0)));
        assert!(!a.overlaps(&meta(2, 0, 11, 20)));
    }
}
