//! Append-only run file format: fixed-width [`WireRecord`] LE payload
//! blocks with per-block CRC32, and a footer carrying the record
//! count and key range.
//!
//! File layout:
//!
//! ```text
//! ┌──────────────────┬───────────────┬──────────────────┐
//! │ magic "MFRUN1\0\0" │ wire_id u32   │ wire_bytes u32   │  header (16 B)
//! ├──────────────────┴───────────────┴──────────────────┤
//! │ count u32 │ crc32 u32 │ count × WIRE_BYTES records  │  block (repeated)
//! ├─────────────────────────────────────────────────────┤
//! │ 0xFFFFFFFF │ count u64 │ first rec │ last rec │ crc │  footer
//! │ magic "MFEND1\0\0"                                  │
//! └─────────────────────────────────────────────────────┘
//! ```
//!
//! All integers are little-endian. A block never declares
//! `u32::MAX` records (the writer caps block size far below it), so
//! the footer marker is unambiguous to a sequential reader. Records
//! within and across blocks are non-decreasing by key — the writer
//! enforces it, so a run file is a sorted run by construction and its
//! blocks can feed [`CompactionSession::feed`]
//! (crate::coordinator::CompactionSession::feed) directly, one block
//! per chunk, without materializing the whole run.

use super::StoreConfig;
use crate::server::frame::WireRecord;
use crate::{Error, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Run file header magic.
pub(crate) const RUN_MAGIC: [u8; 8] = *b"MFRUN1\0\0";
/// Run file trailing magic (after the footer).
pub(crate) const RUN_END_MAGIC: [u8; 8] = *b"MFEND1\0\0";
/// Block-count value that marks the footer instead of a block.
const FOOTER_MARKER: u32 = u32::MAX;

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3, table-driven; hand-rolled — no crc crates in the
// offline image).
// ---------------------------------------------------------------------

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = make_crc_table();

/// CRC32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ u32::from(b)) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------
// Writer.
// ---------------------------------------------------------------------

/// Summary of a finished run file (what the manifest records).
#[derive(Debug, Clone, Copy)]
pub struct RunFileInfo<R> {
    /// Records in the run.
    pub count: u64,
    /// File size in bytes (header + blocks + footer).
    pub bytes: u64,
    /// First (minimum-key) record.
    pub first: R,
    /// Last (maximum-key) record.
    pub last: R,
}

/// Streaming writer for one run file. Feed sorted records with
/// [`RunWriter::append`] (monotonicity is enforced across calls), then
/// [`RunWriter::finish`] to write the footer and fsync.
pub struct RunWriter<R: WireRecord> {
    file: BufWriter<File>,
    path: PathBuf,
    block: Vec<u8>,
    block_records: u32,
    block_bytes: usize,
    count: u64,
    first: Option<R>,
    last: Option<R>,
}

impl<R: WireRecord> RunWriter<R> {
    /// Create `path` (truncating any previous file) and write the
    /// header. `block_bytes` bounds each block's payload.
    pub fn create(path: &Path, block_bytes: usize) -> Result<Self> {
        let file = File::create(path)?;
        let mut w = BufWriter::new(file);
        w.write_all(&RUN_MAGIC)?;
        w.write_all(&R::WIRE_ID.to_le_bytes())?;
        w.write_all(&(R::WIRE_BYTES as u32).to_le_bytes())?;
        Ok(Self {
            file: w,
            path: path.to_path_buf(),
            block: Vec::with_capacity(block_bytes.max(R::WIRE_BYTES)),
            block_records: 0,
            block_bytes: block_bytes.max(R::WIRE_BYTES),
            count: 0,
            first: None,
            last: None,
        })
    }

    /// Append sorted records; keys must be non-decreasing across every
    /// call (a run file *is* a sorted run — violating that here would
    /// poison every future compaction over the file).
    pub fn append(&mut self, records: &[R]) -> Result<()> {
        for r in records {
            if let Some(last) = &self.last {
                if r.key() < last.key() {
                    return Err(Error::InvalidInput(format!(
                        "run records out of order: {r:?} after {last:?}"
                    )));
                }
            }
            if self.first.is_none() {
                self.first = Some(*r);
            }
            self.last = Some(*r);
            r.encode(&mut self.block);
            self.block_records += 1;
            self.count += 1;
            if self.block.len() >= self.block_bytes {
                self.flush_block()?;
            }
        }
        Ok(())
    }

    fn flush_block(&mut self) -> Result<()> {
        if self.block_records == 0 {
            return Ok(());
        }
        self.file.write_all(&self.block_records.to_le_bytes())?;
        self.file.write_all(&crc32(&self.block).to_le_bytes())?;
        self.file.write_all(&self.block)?;
        self.block.clear();
        self.block_records = 0;
        Ok(())
    }

    /// Flush the last block, write the footer, fsync, and return the
    /// run summary. Empty runs are refused — the store never spills
    /// them, and a zero-record file would have no key range.
    pub fn finish(mut self) -> Result<RunFileInfo<R>> {
        self.flush_block()?;
        let (Some(first), Some(last)) = (self.first, self.last) else {
            return Err(Error::InvalidInput("refusing to write an empty run".into()));
        };
        let mut footer = Vec::with_capacity(8 + 2 * R::WIRE_BYTES);
        footer.extend_from_slice(&self.count.to_le_bytes());
        first.encode(&mut footer);
        last.encode(&mut footer);
        self.file.write_all(&FOOTER_MARKER.to_le_bytes())?;
        self.file.write_all(&footer)?;
        self.file.write_all(&crc32(&footer).to_le_bytes())?;
        self.file.write_all(&RUN_END_MAGIC)?;
        self.file.flush()?;
        let file = self.file.into_inner().map_err(|e| Error::Io(e.into_error()))?;
        file.sync_all()?;
        let bytes = std::fs::metadata(&self.path)?.len();
        Ok(RunFileInfo { count: self.count, bytes, first, last })
    }
}

// ---------------------------------------------------------------------
// Reader.
// ---------------------------------------------------------------------

/// Buffered, chunked reader over one run file. [`RunReader::next_block`]
/// yields one CRC-validated block at a time, so a compaction feeding
/// from disk holds O(block) of a run resident, never the whole run.
pub struct RunReader<R: WireRecord> {
    file: BufReader<File>,
    path: PathBuf,
    read: u64,
    done: bool,
    _record: std::marker::PhantomData<R>,
}

impl<R: WireRecord> RunReader<R> {
    /// Open `path` and validate the header (magic, wire id, width).
    pub fn open(path: &Path) -> Result<Self> {
        let mut file = BufReader::new(File::open(path)?);
        let mut header = [0u8; 16];
        file.read_exact(&mut header).map_err(|_| corrupt(path, "truncated header"))?;
        if header[..8] != RUN_MAGIC {
            return Err(corrupt(path, "bad magic"));
        }
        let wire_id = u32::from_le_bytes(header[8..12].try_into().unwrap());
        let wire_bytes = u32::from_le_bytes(header[12..16].try_into().unwrap());
        if wire_id != R::WIRE_ID || wire_bytes as usize != R::WIRE_BYTES {
            return Err(corrupt(
                path,
                &format!(
                    "record type mismatch: file has wire_id={wire_id} ({wire_bytes} B), \
                     reader expects {} ({} B)",
                    R::WIRE_ID,
                    R::WIRE_BYTES
                ),
            ));
        }
        Ok(Self {
            file,
            path: path.to_path_buf(),
            read: 0,
            done: false,
            _record: std::marker::PhantomData,
        })
    }

    /// Next CRC-validated block of records, or `None` after the footer
    /// (which is itself validated: count, CRC, trailing magic).
    pub fn next_block(&mut self) -> Result<Option<Vec<R>>> {
        if self.done {
            return Ok(None);
        }
        let mut count = [0u8; 4];
        self.file
            .read_exact(&mut count)
            .map_err(|_| corrupt(&self.path, "truncated at block boundary"))?;
        let count = u32::from_le_bytes(count);
        if count == FOOTER_MARKER {
            self.read_footer()?;
            self.done = true;
            return Ok(None);
        }
        let mut crc = [0u8; 4];
        self.file
            .read_exact(&mut crc)
            .map_err(|_| corrupt(&self.path, "truncated block header"))?;
        let want_crc = u32::from_le_bytes(crc);
        let mut payload = vec![0u8; count as usize * R::WIRE_BYTES];
        self.file
            .read_exact(&mut payload)
            .map_err(|_| corrupt(&self.path, "truncated block payload"))?;
        if crc32(&payload) != want_crc {
            return Err(corrupt(&self.path, "block crc mismatch"));
        }
        let mut out = Vec::with_capacity(count as usize);
        for chunk in payload.chunks_exact(R::WIRE_BYTES) {
            out.push(R::decode(chunk));
        }
        self.read += u64::from(count);
        Ok(Some(out))
    }

    fn read_footer(&mut self) -> Result<RunFileInfo<R>> {
        let mut footer = vec![0u8; 8 + 2 * R::WIRE_BYTES];
        self.file
            .read_exact(&mut footer)
            .map_err(|_| corrupt(&self.path, "truncated footer"))?;
        let mut tail = [0u8; 12];
        self.file
            .read_exact(&mut tail)
            .map_err(|_| corrupt(&self.path, "truncated footer tail"))?;
        if crc32(&footer) != u32::from_le_bytes(tail[..4].try_into().unwrap()) {
            return Err(corrupt(&self.path, "footer crc mismatch"));
        }
        if tail[4..] != RUN_END_MAGIC {
            return Err(corrupt(&self.path, "bad end magic"));
        }
        let count = u64::from_le_bytes(footer[..8].try_into().unwrap());
        if count != self.read {
            return Err(corrupt(
                &self.path,
                &format!("footer count {count} != {} records read", self.read),
            ));
        }
        let first = R::decode(&footer[8..8 + R::WIRE_BYTES]);
        let last = R::decode(&footer[8 + R::WIRE_BYTES..]);
        let bytes = std::fs::metadata(&self.path)?.len();
        Ok(RunFileInfo { count, bytes, first, last })
    }
}

/// Read and validate only the footer (seek from the end) — how
/// recovery cross-checks a manifest entry without scanning the run.
pub fn read_footer<R: WireRecord>(path: &Path) -> Result<RunFileInfo<R>> {
    let footer_len = (4 + 8 + 2 * R::WIRE_BYTES + 4 + 8) as u64;
    let mut file = File::open(path)?;
    let len = file.metadata()?.len();
    if len < 16 + footer_len {
        return Err(corrupt(path, "file too short for a footer"));
    }
    file.seek(SeekFrom::End(-(footer_len as i64)))?;
    let mut buf = vec![0u8; footer_len as usize];
    file.read_exact(&mut buf)?;
    if u32::from_le_bytes(buf[..4].try_into().unwrap()) != FOOTER_MARKER {
        return Err(corrupt(path, "missing footer marker"));
    }
    let body = &buf[4..4 + 8 + 2 * R::WIRE_BYTES];
    let crc_at = 4 + 8 + 2 * R::WIRE_BYTES;
    if crc32(body) != u32::from_le_bytes(buf[crc_at..crc_at + 4].try_into().unwrap()) {
        return Err(corrupt(path, "footer crc mismatch"));
    }
    if buf[crc_at + 4..] != RUN_END_MAGIC {
        return Err(corrupt(path, "bad end magic"));
    }
    let count = u64::from_le_bytes(body[..8].try_into().unwrap());
    let first = R::decode(&body[8..8 + R::WIRE_BYTES]);
    let last = R::decode(&body[8 + R::WIRE_BYTES..]);
    Ok(RunFileInfo { count, bytes: len, first, last })
}

/// Full-file verification: walk every block (validating each CRC) to
/// the footer. Returns the footer summary on success.
pub fn verify_run<R: WireRecord>(path: &Path) -> Result<RunFileInfo<R>> {
    let mut reader = RunReader::<R>::open(path)?;
    let mut prev: Option<R> = None;
    while let Some(block) = reader.next_block()? {
        for r in &block {
            if let Some(p) = &prev {
                if r.key() < p.key() {
                    return Err(corrupt(path, "records out of key order"));
                }
            }
            prev = Some(*r);
        }
    }
    read_footer::<R>(path)
}

/// Convenience writer: one call for an in-memory sorted run.
pub fn write_run<R: WireRecord>(
    path: &Path,
    records: &[R],
    cfg: &StoreConfig,
) -> Result<RunFileInfo<R>> {
    let mut w = RunWriter::<R>::create(path, cfg.block_bytes)?;
    w.append(records)?;
    w.finish()
}

fn corrupt(path: &Path, what: &str) -> Error {
    Error::InvalidInput(format!("corrupt run file {}: {what}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mergeflow-format-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("run.mfr")
    }

    fn small_cfg() -> StoreConfig {
        StoreConfig { block_bytes: 64, ..StoreConfig::default() }
    }

    #[test]
    fn crc32_known_vectors() {
        // The standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn run_round_trips_in_blocks() {
        let path = tmp("roundtrip");
        let records: Vec<i32> = (0..1000).collect();
        let info = write_run(&path, &records, &small_cfg()).unwrap();
        assert_eq!(info.count, 1000);
        assert_eq!((info.first, info.last), (0, 999));
        let mut reader = RunReader::<i32>::open(&path).unwrap();
        let mut got = Vec::new();
        let mut blocks = 0;
        while let Some(block) = reader.next_block().unwrap() {
            assert!(block.len() * 4 <= 64 + 4, "blocks bounded by block_bytes");
            got.extend(block);
            blocks += 1;
        }
        assert_eq!(got, records);
        assert!(blocks > 1, "small block_bytes must split the run");
        // Footer-only read agrees.
        let f = read_footer::<i32>(&path).unwrap();
        assert_eq!((f.count, f.first, f.last), (1000, 0, 999));
        verify_run::<i32>(&path).unwrap();
    }

    #[test]
    fn pair_records_round_trip() {
        let path = tmp("pairs");
        let records: Vec<(u64, u64)> = (0..300u64).map(|k| (k / 3, k)).collect();
        write_run(&path, &records, &small_cfg()).unwrap();
        let mut reader = RunReader::<(u64, u64)>::open(&path).unwrap();
        let mut got = Vec::new();
        while let Some(block) = reader.next_block().unwrap() {
            got.extend(block);
        }
        assert_eq!(got, records);
    }

    #[test]
    fn unsorted_append_and_empty_finish_are_refused() {
        let path = tmp("refused");
        let mut w = RunWriter::<i32>::create(&path, 64).unwrap();
        w.append(&[5, 6]).unwrap();
        assert!(w.append(&[4]).is_err(), "key regression across appends");
        let w = RunWriter::<i32>::create(&path, 64).unwrap();
        assert!(w.finish().is_err(), "empty run refused");
    }

    #[test]
    fn corruption_is_detected() {
        let path = tmp("corrupt");
        let records: Vec<i32> = (0..500).collect();
        write_run(&path, &records, &small_cfg()).unwrap();
        // Flip one payload byte mid-file.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(verify_run::<i32>(&path).is_err());
        // Truncation is detected too.
        let ok = std::fs::read(&path).unwrap();
        std::fs::write(&path, &ok[..ok.len() - 7]).unwrap();
        assert!(verify_run::<i32>(&path).is_err());
        // Wrong record type at open.
        write_run(&path, &records, &small_cfg()).unwrap();
        assert!(RunReader::<u64>::open(&path).is_err());
    }
}
