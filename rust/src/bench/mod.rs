//! Benchmark support: workload generators and the table/figure printer
//! used by every `cargo bench` target (criterion is unavailable
//! offline; the benches are `harness = false` binaries built on this
//! module).

pub mod figures;
pub mod harness;
pub mod workload;

pub use harness::{BenchTimer, Table};
pub use workload::{gen_sorted_pair, gen_unsorted, WorkloadKind};
