//! Regeneration of every table and figure in the paper's §6, shared by
//! the `cargo bench` targets and the `mergeflow figure/table` CLI.
//!
//! The paper's array sizes are simulated at `1/scale` with caches
//! scaled identically (`MachineSpec::scaled_caches`), preserving every
//! N/C ratio — see DESIGN.md §2. Set `MERGEFLOW_SIM_SCALE` to override
//! the default scale of 64 (1 = paper-size arrays; slow).

use super::harness::{fmt_elems, fmt_speedup, Table};
use super::workload::{gen_sorted_pair, gen_sorted_runs, WorkloadKind};
use crate::sim::engine::{
    simulate_kway_merge, simulate_merge, speedup_curve, KwayMergeAlgo, MergeAlgo,
    SimWorkload,
};
use crate::sim::hypercore::{hypercore_fpga32, hypercore_speedup_curve, simulate_hypercore};
use crate::sim::machine::{e7_8870_40, table2_rows, x5670_12};
use crate::sim::stream::Stage;

/// Simulation scale factor (array sizes and cache sizes divided by it).
pub fn sim_scale() -> usize {
    std::env::var("MERGEFLOW_SIM_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(64)
}

const SEED: u64 = 0x4D50_2014; // "MP", 2014

fn workload(n_each: usize) -> (Vec<i32>, Vec<i32>) {
    gen_sorted_pair(WorkloadKind::Uniform, n_each, n_each, SEED)
}

/// Figure 4: Merge Path speedup on the 12-core system; array sizes
/// 1M / 10M / 100M elements each, threads 1..12.
pub fn fig4(scale: usize) -> Table {
    let machine = x5670_12().scaled_caches(scale);
    let sizes = [1usize << 20, 10 << 20, 100 << 20];
    let threads = [2usize, 4, 6, 8, 10, 12];
    let mut t = Table::new(
        &format!("Fig 4 — Merge Path speedup, {} (scale 1/{scale})", machine.name),
        &["size", "t=2", "t=4", "t=6", "t=8", "t=10", "t=12"],
    );
    for size in sizes {
        let n = (size / scale).max(1 << 10);
        let (a, b) = workload(n);
        let w = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both };
        let curve = speedup_curve(&machine, MergeAlgo::MergePath, &w, &threads);
        let mut row = vec![fmt_elems(size)];
        row.extend(curve.iter().map(|(_, s)| fmt_speedup(*s)));
        t.row(&row);
    }
    t
}

/// Figure 5: regular vs segmented Merge Path on the 40-core system;
/// 10M / 50M per array; with writeback (a, b) and register sink (c, d);
/// the segmented algorithm divides the output into 2 / 5 / 10 segments.
pub fn fig5(scale: usize) -> Vec<Table> {
    let machine = e7_8870_40().scaled_caches(scale);
    let threads = [10usize, 20, 40];
    let mut tables = Vec::new();
    for (panel, (size, writeback)) in [
        ("5(a) 10M, writeback", (10usize << 20, true)),
        ("5(b) 50M, writeback", (50 << 20, true)),
        ("5(c) 10M, register", (10 << 20, false)),
        ("5(d) 50M, register", (50 << 20, false)),
    ] {
        let n = (size / scale).max(1 << 10);
        let (a, b) = workload(n);
        let w = SimWorkload { a: &a, b: &b, writeback, stage: Stage::Both };
        let out_len = 2 * n;
        let algos: Vec<(String, MergeAlgo)> = vec![
            ("regular".into(), MergeAlgo::MergePath),
            ("seg=2".into(), MergeAlgo::Segmented { segment_len: out_len / 2 }),
            ("seg=5".into(), MergeAlgo::Segmented { segment_len: out_len / 5 }),
            ("seg=10".into(), MergeAlgo::Segmented { segment_len: out_len / 10 }),
        ];
        let mut t = Table::new(
            &format!(
                "Fig {panel} — {} (scale 1/{scale})",
                machine.name
            ),
            &["algorithm", "t=10", "t=20", "t=40"],
        );
        for (name, algo) in algos {
            let curve = speedup_curve(&machine, algo, &w, &threads);
            let mut row = vec![name];
            row.extend(curve.iter().map(|(_, s)| fmt_speedup(*s)));
            t.row(&row);
        }
        tables.push(t);
    }
    tables
}

/// HyperCore figures run at a gentler scale: the FPGA's inputs are
/// small to begin with, and at 1/64 the per-segment work would be
/// dwarfed by the (unscalable) per-segment partition searches.
fn hypercore_scale(scale: usize) -> usize {
    (scale / 8).max(1)
}

/// Figure 7: speedups on the HyperCore — (a) regular, (b) segmented.
/// Paper input sizes are small (FPGA memory); per-array sizes below.
pub fn fig7(scale: usize) -> Vec<Table> {
    let scale = hypercore_scale(scale);
    let mut spec = hypercore_fpga32();
    spec.cache_capacity = (spec.cache_capacity / scale).max(spec.line * 16);
    let sizes = [32usize << 10, 128 << 10, 512 << 10, 1 << 20];
    let cores = [2usize, 4, 8, 16, 32];
    let mut tables = Vec::new();
    for (panel, segmented) in [("7(a) regular", false), ("7(b) segmented", true)] {
        let mut t = Table::new(
            &format!("Fig {panel} — Plurality HyperCore, 32 cores (scale 1/{scale})"),
            &["size", "t=2", "t=4", "t=8", "t=16", "t=32"],
        );
        for size in sizes {
            let n = (size / scale).max(1 << 9);
            let (a, b) = workload(n);
            // §6.2: FPGA writeback latency issue → register sink.
            let w = SimWorkload { a: &a, b: &b, writeback: false, stage: Stage::Both };
            let algo = if segmented {
                let cache_elems = spec.cache_capacity / 4;
                MergeAlgo::Segmented { segment_len: (cache_elems / 3).max(64) }
            } else {
                MergeAlgo::MergePath
            };
            let curve = hypercore_speedup_curve(&spec, algo, &w, &cores);
            let mut row = vec![fmt_elems(size)];
            row.extend(curve.iter().map(|(_, s)| fmt_speedup(*s)));
            t.row(&row);
        }
        tables.push(t);
    }
    tables
}

/// Figure 8: segmented-vs-regular runtime ratio on the HyperCore
/// (values > 1 mean the segmented algorithm is faster).
pub fn fig8(scale: usize) -> Table {
    let scale = hypercore_scale(scale);
    let mut spec = hypercore_fpga32();
    spec.cache_capacity = (spec.cache_capacity / scale).max(spec.line * 16);
    let sizes = [32usize << 10, 128 << 10, 512 << 10, 1 << 20];
    let cores = [2usize, 4, 8, 16, 32];
    let mut t = Table::new(
        &format!("Fig 8 — regular/segmented cycle ratio on HyperCore (scale 1/{scale}; >1 ⇒ segmented faster)"),
        &["size", "t=2", "t=4", "t=8", "t=16", "t=32"],
    );
    for size in sizes {
        let n = (size / scale).max(1 << 9);
        let (a, b) = workload(n);
        let w = SimWorkload { a: &a, b: &b, writeback: false, stage: Stage::Both };
        let cache_elems = spec.cache_capacity / 4;
        let seg = MergeAlgo::Segmented { segment_len: (cache_elems / 3).max(64) };
        let mut row = vec![fmt_elems(size)];
        for &p in &cores {
            let r = simulate_hypercore(&spec, MergeAlgo::MergePath, &w, p).cycles;
            let s = simulate_hypercore(&spec, seg, &w, p).cycles;
            row.push(format!("{:.2}", r as f64 / s as f64));
        }
        t.row(&row);
    }
    t
}

/// Table 1: cache misses per algorithm, split into partition stage and
/// merge stage (measured L1 misses on the simulated 12-core machine).
pub fn table1(scale: usize) -> Table {
    let machine = x5670_12().scaled_caches(scale);
    let n_each = ((1usize << 20) / scale).clamp(1 << 12, 1 << 18);
    let (a, b) = workload(n_each);
    let p = 8usize;
    let l3_elems = machine.mem.l3.capacity / 4;
    let algos: Vec<(&str, MergeAlgo)> = vec![
        ("[9] Shiloach-Vishkin", MergeAlgo::ShiloachVishkin),
        ("[8] Akl-Santoro", MergeAlgo::AklSantoro),
        ("[2] & Merge Path", MergeAlgo::MergePath),
        ("Segmented Merge Path", MergeAlgo::Segmented { segment_len: (l3_elems / 3).max(64) }),
    ];
    let mut t = Table::new(
        &format!(
            "Table 1 — cache misses (measured, |A|=|B|={}, p={p}, scale 1/{scale})",
            fmt_elems(n_each)
        ),
        &["algorithm", "partition stage", "merge stage", "total", "invalidations"],
    );
    for (name, algo) in algos {
        let part = simulate_merge(
            &machine,
            algo,
            &SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Partition },
            p,
        );
        let both = simulate_merge(
            &machine,
            algo,
            &SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both },
            p,
        );
        let pm = part.mem.l1.misses();
        let tm = both.mem.l1.misses();
        t.row(&[
            name.to_string(),
            pm.to_string(),
            tm.saturating_sub(pm).to_string(),
            tm.to_string(),
            both.mem.invalidations.to_string(),
        ]);
    }
    t
}

/// Table 1 companion for the compaction hot path: cache misses of the
/// **flat k-way engine vs its segmented variant** on a cache-busting
/// shape — `k + 1` live stream lines exceeding the scaled private L1,
/// where the flat argmin's per-output head re-reads thrash while the
/// segmented engine's bounded kernel touches each element once
/// (`(k+1)·L` working set, §4.3 generalised). Partition stage is the
/// same `p − 1` rank selections for both.
pub fn table1_kway(scale: usize) -> Table {
    let machine = x5670_12().scaled_caches(scale);
    let run_len = ((1usize << 20) / scale).clamp(1 << 12, 1 << 17);
    let k = 12usize; // argmin regime; k + 1 = 13 lines > the scaled L1
    let p = 8usize;
    let runs = gen_sorted_runs(WorkloadKind::Uniform, k, run_len, SEED);
    let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
    let l3_elems = machine.mem.l3.capacity / 4;
    let auto_l = (l3_elems / (k + 1)).max(64);
    let algos: Vec<(String, KwayMergeAlgo)> = vec![
        ("flat (unsegmented)".into(), KwayMergeAlgo::Flat),
        (
            format!("segmented L=C/(k+1)={auto_l}"),
            KwayMergeAlgo::Segmented { segment_elems: auto_l },
        ),
        (
            format!("segmented L={}", auto_l * 8),
            KwayMergeAlgo::Segmented { segment_elems: auto_l * 8 },
        ),
    ];
    let mut t = Table::new(
        &format!(
            "Table 1b — k-way engine cache misses (k={k}, {} per run, p={p}, scale 1/{scale})",
            fmt_elems(run_len)
        ),
        &["engine", "partition stage", "merge stage", "total", "dram bytes"],
    );
    for (name, algo) in algos {
        let part = simulate_kway_merge(&machine, algo, &refs, true, Stage::Partition, p);
        let both = simulate_kway_merge(&machine, algo, &refs, true, Stage::Both, p);
        let pm = part.mem.l1.misses();
        let tm = both.mem.l1.misses();
        t.row(&[
            name,
            pm.to_string(),
            tm.saturating_sub(pm).to_string(),
            tm.to_string(),
            both.mem.dram_bytes().to_string(),
        ]);
    }
    t
}

/// Table 2: the systems (simulated geometries).
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — simulated systems",
        &["Proc.", "#Proc", "Cores/Proc", "Total", "L1", "L2", "L3", "Memory"],
    );
    for r in table2_rows() {
        t.row(&r);
    }
    t.row(&crate::sim::hypercore::hypercore_row(&hypercore_fpga32()));
    t
}

/// §6.1 probe: simulated partition time (cycles) as threads grow — the
/// paper's observation that intersection+sync time grows with p.
pub fn partition_probe(scale: usize) -> Table {
    let machine = e7_8870_40().scaled_caches(scale);
    let n_each = ((10usize << 20) / scale).max(1 << 12);
    let (a, b) = workload(n_each);
    let mut t = Table::new(
        &format!(
            "Partition-stage cycles vs threads (|A|=|B|={}, scale 1/{scale})",
            fmt_elems(n_each)
        ),
        &["threads", "partition cycles", "barrier cycles"],
    );
    for p in [1usize, 2, 5, 10, 20, 40] {
        let r = simulate_merge(
            &machine,
            MergeAlgo::MergePath,
            &SimWorkload { a: &a, b: &b, writeback: false, stage: Stage::Partition },
            p,
        );
        t.row(&[
            p.to_string(),
            r.makespan.to_string(),
            machine.barrier_cost(p).to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    // Figure tests run at an aggressive scale to stay fast; the bench
    // binaries use sim_scale() (default 64).
    const TEST_SCALE: usize = 1024;

    #[test]
    fn fig4_near_linear_speedup() {
        let t = fig4(TEST_SCALE);
        let r = t.render();
        assert!(r.contains("1M") && r.contains("100M"));
        // Parse the t=12 column of the largest size: expect > 6x.
        let last_line = r.lines().last().unwrap();
        let s12: f64 = last_line
            .split_whitespace()
            .last()
            .unwrap()
            .trim_end_matches('x')
            .parse()
            .unwrap();
        assert!(s12 > 6.0, "12-thread speedup {s12} too low\n{r}");
    }

    #[test]
    fn fig5_writeback_adds_latency_and_scaling_is_sublinear() {
        // The robust Fig-5 shape claims: (1) writing the output back
        // costs absolute cycles at every thread count; (2) 40-thread
        // scaling is sublinear (the paper reports ~28–32x, not 40x).
        use crate::sim::engine::{simulate_merge, MergeAlgo, SimWorkload};
        use crate::sim::machine::e7_8870_40;
        let scale = 256usize;
        let machine = e7_8870_40().scaled_caches(scale);
        let n = (50usize << 20) / scale;
        let (a, b) = workload(n);
        let wb_w = SimWorkload { a: &a, b: &b, writeback: true, stage: Stage::Both };
        let rg_w = SimWorkload { a: &a, b: &b, writeback: false, stage: Stage::Both };
        for p in [1usize, 40] {
            let wb = simulate_merge(&machine, MergeAlgo::MergePath, &wb_w, p);
            let rg = simulate_merge(&machine, MergeAlgo::MergePath, &rg_w, p);
            assert!(
                wb.cycles > rg.cycles,
                "p={p}: writeback {} should exceed register {}",
                wb.cycles,
                rg.cycles
            );
        }
        let s40 = {
            let c1 = simulate_merge(&machine, MergeAlgo::MergePath, &wb_w, 1).cycles;
            let c40 = simulate_merge(&machine, MergeAlgo::MergePath, &wb_w, 40).cycles;
            c1 as f64 / c40 as f64
        };
        assert!(s40 > 10.0, "40-thread speedup {s40:.1} unreasonably low");
        assert!(s40 < 40.0, "40-thread speedup {s40:.1} should be sublinear");
        // Table rendering smoke check.
        let tables = fig5(TEST_SCALE);
        assert_eq!(tables.len(), 4);
        assert!(tables[0].render().contains("regular"));
    }

    #[test]
    fn fig7_and_8_render() {
        let t7 = fig7(TEST_SCALE);
        assert_eq!(t7.len(), 2);
        let t8 = fig8(TEST_SCALE);
        let r = t8.render();
        assert!(r.lines().count() >= 6, "{r}");
    }

    #[test]
    fn table1_spm_not_worse_total() {
        let t = table1(64);
        let r = t.render();
        let totals: Vec<u64> = r
            .lines()
            .skip(4) // blank, title, header, rule
            .map(|l| {
                let cols: Vec<&str> = l.split_whitespace().collect();
                cols[cols.len() - 2].parse().unwrap()
            })
            .collect();
        assert_eq!(totals.len(), 4);
        // Segmented (last row) total within 1.3x of Merge Path (3rd row);
        // the paper's claim is Θ(N) for both with SPM ahead on sharing.
        assert!(
            (totals[3] as f64) <= 1.3 * totals[2] as f64,
            "SPM total {} vs MP {}\n{r}",
            totals[3],
            totals[2]
        );
    }

    #[test]
    fn table1_kway_segmented_reduces_misses() {
        // The segmented k-way acceptance claim, pinned at test scale:
        // on the cache-busting shape (k + 1 live lines > the scaled
        // private L1) the segmented engine's total simulated misses
        // must land decisively below the unsegmented flat engine's.
        let t = table1_kway(TEST_SCALE);
        let r = t.render();
        let totals: Vec<u64> = r
            .lines()
            .skip(4) // blank, title, header, rule
            .map(|l| {
                let cols: Vec<&str> = l.split_whitespace().collect();
                cols[cols.len() - 2].parse().unwrap()
            })
            .collect();
        assert_eq!(totals.len(), 3);
        assert!(
            totals[1] * 2 < totals[0],
            "segmented {} vs flat {} total misses\n{r}",
            totals[1],
            totals[0]
        );
        assert!(
            totals[2] * 2 < totals[0],
            "large-L segmented {} vs flat {}\n{r}",
            totals[2],
            totals[0]
        );
    }

    #[test]
    fn table2_has_three_systems() {
        let r = table2().render();
        assert!(r.contains("X5670"));
        assert!(r.contains("E7-8870"));
        assert!(r.contains("HyperCore"));
    }

    #[test]
    fn partition_probe_grows_with_threads() {
        let t = partition_probe(TEST_SCALE);
        let r = t.render();
        let rows: Vec<u64> = r
            .lines()
            .skip(4) // blank, title, header, rule
            .map(|l| l.split_whitespace().nth(1).unwrap().parse().unwrap())
            .collect();
        // p=1 partitions nothing to search (diag 0 only) → cheapest.
        assert!(rows[0] <= rows[rows.len() - 1], "{r}");
    }
}
