//! Workload generators for benches, examples and the simulator.
//!
//! All generators are deterministic in (kind, size, seed) so paper
//! figures can be regenerated bit-for-bit.

use crate::rng::Xoshiro256;

/// Input distribution shapes used in the evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// i.i.d. uniform keys (the paper's primary workload).
    Uniform,
    /// Skewed: 90% of keys in 10% of the range (duplicates-heavy).
    Skewed,
    /// Disjoint ranges: all of `A` below all of `B` (naive-split
    /// killer, worst case for Shiloach–Vishkin balance).
    OneSided,
    /// Perfectly interleaved: `A` holds evens, `B` odds.
    Interleaved,
    /// Long runs: alternating blocks of `A`-only / `B`-only keys
    /// (galloping-friendly; LSM-compaction shape).
    Runs,
}

impl WorkloadKind {
    /// All kinds, for sweeps.
    pub fn all() -> [WorkloadKind; 5] {
        [
            WorkloadKind::Uniform,
            WorkloadKind::Skewed,
            WorkloadKind::OneSided,
            WorkloadKind::Interleaved,
            WorkloadKind::Runs,
        ]
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "uniform" => WorkloadKind::Uniform,
            "skewed" => WorkloadKind::Skewed,
            "one-sided" | "onesided" => WorkloadKind::OneSided,
            "interleaved" => WorkloadKind::Interleaved,
            "runs" => WorkloadKind::Runs,
            _ => return None,
        })
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Uniform => "uniform",
            WorkloadKind::Skewed => "skewed",
            WorkloadKind::OneSided => "one-sided",
            WorkloadKind::Interleaved => "interleaved",
            WorkloadKind::Runs => "runs",
        }
    }
}

/// Generate a pair of sorted arrays of `na`/`nb` 32-bit keys.
pub fn gen_sorted_pair(
    kind: WorkloadKind,
    na: usize,
    nb: usize,
    seed: u64,
) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Xoshiro256::seeded(seed);
    let (mut a, mut b): (Vec<i32>, Vec<i32>) = match kind {
        WorkloadKind::Uniform => {
            let a = (0..na).map(|_| rng.next_i32()).collect();
            let b = (0..nb).map(|_| rng.next_i32()).collect();
            (a, b)
        }
        WorkloadKind::Skewed => {
            let pick = |rng: &mut Xoshiro256| -> i32 {
                if rng.chance(0.9) {
                    (rng.below(1 << 16)) as i32
                } else {
                    rng.next_i32()
                }
            };
            let a = (0..na).map(|_| pick(&mut rng)).collect();
            let b = (0..nb).map(|_| pick(&mut rng)).collect();
            (a, b)
        }
        WorkloadKind::OneSided => {
            let a = (0..na).map(|_| -(rng.below(1 << 30) as i32) - 2).collect();
            let b = (0..nb).map(|_| rng.below(1 << 30) as i32).collect();
            (a, b)
        }
        WorkloadKind::Interleaved => {
            let a = (0..na).map(|i| (i as i32) * 2).collect();
            let b = (0..nb).map(|i| (i as i32) * 2 + 1).collect();
            (a, b)
        }
        WorkloadKind::Runs => {
            // Alternate 1024-key blocks between the arrays.
            let block = 1024usize;
            let mut a = Vec::with_capacity(na);
            let mut b = Vec::with_capacity(nb);
            let mut key = 0i32;
            while a.len() < na || b.len() < nb {
                for _ in 0..block {
                    if a.len() < na {
                        a.push(key);
                        key = key.wrapping_add(1);
                    }
                }
                for _ in 0..block {
                    if b.len() < nb {
                        b.push(key);
                        key = key.wrapping_add(1);
                    }
                }
            }
            (a, b)
        }
    };
    a.sort_unstable();
    b.sort_unstable();
    (a, b)
}

/// Generate an unsorted array for the sort benches.
pub fn gen_unsorted(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| rng.next_i32()).collect()
}

/// Generate `k` distinct sorted runs of `run_len` keys each — the
/// LSM-compaction input shape used by `JobKind::Compact` and the
/// `kway_flat_vs_tree` bench. Deterministic in `(kind, k, run_len,
/// seed)`.
///
/// Random kinds (`Uniform`, `Skewed`) draw run `i` from seed
/// `seed + i`. The remaining kinds get proper k-way analogues instead
/// of the pairwise generator (which would make every run identical —
/// `Interleaved`/`Runs` ignore the seed — or lose the kind's point):
/// `OneSided` gives run `i` a private value band entirely below run
/// `i + 1`'s (the naive-split killer, k-way version); `Interleaved`
/// deals keys round-robin across runs (run `i` holds `j·k + i`); and
/// `Runs` deals 1024-key blocks round-robin (long single-run
/// stretches, the galloping-friendly compaction shape).
pub fn gen_sorted_runs(kind: WorkloadKind, k: usize, run_len: usize, seed: u64) -> Vec<Vec<i32>> {
    match kind {
        WorkloadKind::OneSided => {
            let band = (i32::MAX as usize / k.max(1)).max(1);
            (0..k)
                .map(|i| {
                    let mut rng = Xoshiro256::seeded(seed.wrapping_add(i as u64));
                    let lo = (i * band) as i64;
                    let mut v: Vec<i32> = (0..run_len)
                        .map(|_| (lo + rng.below(band as u64) as i64) as i32)
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect()
        }
        WorkloadKind::Interleaved => (0..k)
            .map(|i| (0..run_len).map(|j| (j * k + i) as i32).collect())
            .collect(),
        WorkloadKind::Runs => {
            let block = 1024usize;
            (0..k)
                .map(|i| {
                    (0..run_len)
                        .map(|j| {
                            let (blk, off) = (j / block, j % block);
                            ((blk * k + i) * block + off) as i32
                        })
                        .collect()
                })
                .collect()
        }
        _ => (0..k)
            .map(|i| gen_sorted_pair(kind, run_len, 0, seed.wrapping_add(i as u64)).0)
            .collect(),
    }
}

/// Generate `k` sorted runs of `(key, payload)` records — the typed
/// (key-value / LSM) compaction shape served by
/// `MergeService<(u64, u64)>`. Keys follow [`gen_sorted_runs`] for the
/// same `(kind, k, run_len, seed)`, shifted order-preservingly into
/// `u64` (so `Skewed` still produces dense duplicate keys); payloads
/// encode provenance (`run << 32 | offset`), which makes a *stable*
/// merge — equal keys in run-index-then-offset order — verifiable from
/// the output alone. Deterministic in all four parameters.
pub fn gen_record_runs(
    kind: WorkloadKind,
    k: usize,
    run_len: usize,
    seed: u64,
) -> Vec<Vec<(u64, u64)>> {
    gen_sorted_runs(kind, k, run_len, seed)
        .into_iter()
        .enumerate()
        .map(|(run, keys)| {
            keys.into_iter()
                .enumerate()
                .map(|(off, key)| {
                    let key = (key as i64 - i32::MIN as i64) as u64;
                    (key, ((run as u64) << 32) | off as u64)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_are_sorted_and_sized() {
        for kind in WorkloadKind::all() {
            let (a, b) = gen_sorted_pair(kind, 1000, 777, 42);
            assert_eq!(a.len(), 1000, "{kind:?}");
            assert_eq!(b.len(), 777, "{kind:?}");
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{kind:?}");
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "{kind:?}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let (a1, b1) = gen_sorted_pair(WorkloadKind::Uniform, 500, 500, 7);
        let (a2, b2) = gen_sorted_pair(WorkloadKind::Uniform, 500, 500, 7);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        let (a3, _) = gen_sorted_pair(WorkloadKind::Uniform, 500, 500, 8);
        assert_ne!(a1, a3);
    }

    #[test]
    fn one_sided_is_disjoint() {
        let (a, b) = gen_sorted_pair(WorkloadKind::OneSided, 100, 100, 1);
        assert!(a.last().unwrap() < b.first().unwrap());
    }

    #[test]
    fn skewed_has_duplicates() {
        let (a, _) = gen_sorted_pair(WorkloadKind::Skewed, 100_000, 10, 1);
        let mut uniq = a.clone();
        uniq.dedup();
        assert!(uniq.len() < a.len(), "skewed workload should repeat keys");
    }

    #[test]
    fn sorted_runs_shape_and_determinism() {
        for kind in WorkloadKind::all() {
            let runs = gen_sorted_runs(kind, 5, 300, 9);
            assert_eq!(runs.len(), 5, "{kind:?}");
            for r in &runs {
                assert_eq!(r.len(), 300, "{kind:?}");
                assert!(r.windows(2).all(|w| w[0] <= w[1]), "{kind:?}");
            }
            assert_eq!(runs, gen_sorted_runs(kind, 5, 300, 9), "{kind:?}");
            assert_ne!(runs[0], runs[1], "{kind:?}: runs must be distinct");
        }
    }

    #[test]
    fn sorted_runs_deterministic_kinds_tile_key_space() {
        // Interleaved: the k runs merge to 0..k*run_len exactly.
        let runs = gen_sorted_runs(WorkloadKind::Interleaved, 4, 100, 0);
        let mut all: Vec<i32> = runs.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..400).collect::<Vec<i32>>());
        // Runs: block-cyclic deal also tiles the key space.
        let runs = gen_sorted_runs(WorkloadKind::Runs, 2, 2048, 0);
        let mut all: Vec<i32> = runs.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..4096).collect::<Vec<i32>>());
    }

    #[test]
    fn sorted_runs_one_sided_bands_are_disjoint() {
        let runs = gen_sorted_runs(WorkloadKind::OneSided, 6, 500, 3);
        for w in runs.windows(2) {
            assert!(
                w[0].last().unwrap() < w[1].first().unwrap(),
                "run bands must be strictly increasing"
            );
        }
    }

    #[test]
    fn record_runs_carry_keys_and_provenance() {
        for kind in WorkloadKind::all() {
            let recs = gen_record_runs(kind, 4, 300, 9);
            let keys = gen_sorted_runs(kind, 4, 300, 9);
            assert_eq!(recs.len(), 4, "{kind:?}");
            for (run, (rr, kr)) in recs.iter().zip(&keys).enumerate() {
                assert_eq!(rr.len(), 300, "{kind:?}");
                for (off, (&(key, payload), &k)) in rr.iter().zip(kr).enumerate() {
                    // Order-preserving key shift: same relative order.
                    assert_eq!(key, (k as i64 - i32::MIN as i64) as u64, "{kind:?}");
                    assert_eq!(payload, ((run as u64) << 32) | off as u64, "{kind:?}");
                }
                assert!(rr.windows(2).all(|w| w[0].0 <= w[1].0), "{kind:?}");
            }
            assert_eq!(recs, gen_record_runs(kind, 4, 300, 9), "{kind:?} deterministic");
        }
        // Skewed keeps its point: dense duplicate keys survive the shift.
        let skewed = gen_record_runs(WorkloadKind::Skewed, 2, 50_000, 1);
        let mut uniq: Vec<u64> = skewed[0].iter().map(|r| r.0).collect();
        uniq.dedup();
        assert!(uniq.len() < skewed[0].len(), "skewed records should repeat keys");
    }

    #[test]
    fn parse_roundtrip() {
        for kind in WorkloadKind::all() {
            assert_eq!(WorkloadKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::parse("nope"), None);
    }
}
