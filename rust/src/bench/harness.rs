//! Micro-bench timing loop and an aligned-table printer: the in-tree
//! replacement for criterion (unavailable offline). Keeps the output a
//! stable, diff-able text format so EXPERIMENTS.md can quote it.

use crate::metrics::{fmt_ns, fmt_throughput};
use std::time::Instant;

/// Adaptive timing loop: warms up, then runs enough iterations to
/// cover a target measuring window, reporting min/mean ns per
/// iteration. Min is the headline (least noise on a busy host).
#[derive(Debug, Clone, Copy)]
pub struct BenchTimer {
    /// Target measurement window in nanoseconds.
    pub window_ns: u64,
    /// Warmup iterations.
    pub warmup: u32,
    /// Hard cap on measured iterations.
    pub max_iters: u32,
}

impl Default for BenchTimer {
    fn default() -> Self {
        Self {
            window_ns: 200_000_000, // 200 ms
            warmup: 2,
            max_iters: 1000,
        }
    }
}

/// One measurement.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Fastest observed iteration (ns).
    pub min_ns: u64,
    /// Mean over measured iterations (ns).
    pub mean_ns: u64,
    /// Iterations measured.
    pub iters: u32,
}

impl Measurement {
    /// Throughput for `elems` elements processed per iteration.
    pub fn throughput(&self, elems: u64) -> String {
        fmt_throughput(elems, self.min_ns)
    }
}

impl BenchTimer {
    /// Fast preset for CI-ish runs.
    pub fn quick() -> Self {
        Self {
            window_ns: 50_000_000,
            warmup: 1,
            max_iters: 200,
        }
    }

    /// Time `f`, which must perform one full iteration per call.
    pub fn measure<F: FnMut()>(&self, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        // Estimate single-iteration cost.
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_nanos().max(1) as u64;
        let iters = ((self.window_ns / first).clamp(1, self.max_iters as u64)) as u32;
        let mut min_ns = first;
        let mut total = first;
        let mut measured = 1u32;
        for _ in 1..iters {
            let t = Instant::now();
            f();
            let ns = t.elapsed().as_nanos().max(1) as u64;
            min_ns = min_ns.min(ns);
            total += ns;
            measured += 1;
        }
        Measurement {
            min_ns,
            mean_ns: total / measured as u64,
            iters: measured,
        }
    }
}

/// Aligned plain-text table, printed in the style the paper's tables /
/// figure series are quoted in EXPERIMENTS.md.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    /// Append a row (stringify everything up front).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience for mixed displayable cells.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a speedup cell.
pub fn fmt_speedup(s: f64) -> String {
    format!("{s:.2}x")
}

/// Format an element count the way the paper does (1M = 2^20).
pub fn fmt_elems(n: usize) -> String {
    if n >= (1 << 20) && n % (1 << 20) == 0 {
        format!("{}M", n >> 20)
    } else if n >= (1 << 10) && n % (1 << 10) == 0 {
        format!("{}K", n >> 10)
    } else {
        n.to_string()
    }
}

/// Human summary line for one measurement.
pub fn report_line(name: &str, m: &Measurement, elems: u64) -> String {
    format!(
        "{name:<40} min {:>10}  mean {:>10}  {:>12}  ({} iters)",
        fmt_ns(m.min_ns),
        fmt_ns(m.mean_ns),
        m.throughput(elems),
        m.iters
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let t = BenchTimer { window_ns: 1_000_000, warmup: 1, max_iters: 50 };
        let mut count = 0u64;
        let m = t.measure(|| {
            count += 1;
            std::hint::black_box(&count);
        });
        assert!(m.iters >= 1);
        assert!(count as u32 >= m.iters); // warmup + estimate + measured
        assert!(m.min_ns <= m.mean_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2222".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("long-name"));
        let lines: Vec<&str> = r.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_elems(1 << 20), "1M");
        assert_eq!(fmt_elems(10 << 20), "10M");
        assert_eq!(fmt_elems(2048), "2K");
        assert_eq!(fmt_elems(1000), "1000");
        assert_eq!(fmt_speedup(11.73), "11.73x");
    }
}
