//! `mergeflow` binary — leader entrypoint / CLI.

use mergeflow::bench::figures;
use mergeflow::bench::harness::report_line;
use mergeflow::bench::workload::{gen_sorted_pair, gen_unsorted, WorkloadKind};
use mergeflow::bench::BenchTimer;
use mergeflow::cli::{Cli, USAGE};
use mergeflow::config::{MergeflowConfig, RawConfig, ServerConfig, StoreConfig};
use mergeflow::coordinator::{JobKind, MergeService};
use mergeflow::mergepath::{
    cache_efficient_sort, parallel_merge, parallel_merge_sort, segmented_parallel_merge,
    CacheSortConfig, SegmentedConfig,
};
use mergeflow::metrics::{fmt_ns, fmt_throughput, Timer};
use mergeflow::record::ensure_sorted_by_key;
use mergeflow::{Error, Result};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "merge" => cmd_merge(&cli),
        "sort" => cmd_sort(&cli),
        "serve" => cmd_serve(&cli),
        "figure" => cmd_figure(&cli),
        "table" => cmd_table(&cli),
        "probe" => {
            figures::partition_probe(scale_of(&cli)).print();
            Ok(())
        }
        "artifacts" => cmd_artifacts(&cli),
        "store" => cmd_store(&cli),
        "stats" => cmd_stats(&cli),
        "kernels" => cmd_kernels(),
        "" | "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command `{other}` (try `mergeflow help`)"
        ))),
    }
}

fn scale_of(cli: &Cli) -> usize {
    cli.usize_flag("scale", figures::sim_scale()).unwrap_or(64).max(1)
}

fn cmd_merge(cli: &Cli) -> Result<()> {
    let n = cli.size_flag("n", 1 << 20)?;
    let threads = cli.usize_flag("threads", 4)?;
    let seed = cli.usize_flag("seed", 42)? as u64;
    let seg = cli.size_flag("segment-len", 0)?;
    let kind = WorkloadKind::parse(&cli.flag("kind").unwrap_or("uniform").to_string())
        .ok_or_else(|| Error::Config("unknown --kind".into()))?;
    let (a, b) = gen_sorted_pair(kind, n / 2, n / 2, seed);
    let mut out = vec![0i32; a.len() + b.len()];
    let t = Timer::start();
    if seg > 0 {
        segmented_parallel_merge(
            &a,
            &b,
            &mut out,
            SegmentedConfig { segment_len: seg, threads },
        );
    } else {
        parallel_merge(&a, &b, &mut out, threads);
    }
    let ns = t.elapsed_ns();
    assert!(out.windows(2).all(|w| w[0] <= w[1]), "output not sorted");
    println!(
        "merged {} elements ({} workload) with {} threads{} in {} ({})",
        out.len(),
        kind.name(),
        threads,
        if seg > 0 { format!(", segment_len={seg}") } else { String::new() },
        fmt_ns(ns),
        fmt_throughput(out.len() as u64, ns)
    );
    Ok(())
}

fn cmd_sort(cli: &Cli) -> Result<()> {
    let n = cli.size_flag("n", 1 << 20)?;
    let threads = cli.usize_flag("threads", 4)?;
    let seed = cli.usize_flag("seed", 42)? as u64;
    let cache_elems = cli.size_flag("cache-elems", 0)?;
    let mut data = gen_unsorted(n, seed);
    let t = Timer::start();
    if cache_elems > 0 {
        cache_efficient_sort(&mut data, CacheSortConfig { cache_elems, threads });
    } else {
        parallel_merge_sort(&mut data, threads);
    }
    let ns = t.elapsed_ns();
    assert!(data.windows(2).all(|w| w[0] <= w[1]), "output not sorted");
    println!(
        "sorted {} elements with {} threads{} in {} ({})",
        n,
        threads,
        if cache_elems > 0 { format!(", cache-efficient C={cache_elems}") } else { String::new() },
        fmt_ns(ns),
        fmt_throughput(n as u64, ns)
    );
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let (cfg, mut server_cfg, store_cfg) = match cli.flag("config") {
        Some(path) => {
            let raw = RawConfig::from_file(std::path::Path::new(path))?;
            (
                MergeflowConfig::from_raw(&raw)?,
                ServerConfig::from_raw(&raw)?,
                StoreConfig::from_raw(&raw)?,
            )
        }
        None => (
            MergeflowConfig::default(),
            ServerConfig::default(),
            StoreConfig::default(),
        ),
    };
    if cli.bool_flag("selfload") {
        return serve_selfload(cli, cfg);
    }
    if let Some(listen) = cli.flag("listen") {
        server_cfg.listen = listen.to_string();
    }
    println!("starting service: {cfg:?}");
    let svc = std::sync::Arc::new(MergeService::<i32>::start(cfg)?);
    // Optional persistent run store: spills/flushes route through the
    // attached bridge, and a background scheduler keeps levels within
    // policy. The scheduler handle lives for the whole (infinite)
    // serve loop, so it is never joined here.
    let _scheduler = if store_cfg.enabled() {
        let store =
            std::sync::Arc::new(mergeflow::store::RunStore::<i32>::open(&store_cfg)?);
        let bridge =
            mergeflow::store::StoreBridge::new(std::sync::Arc::clone(&store), svc.stats_arc());
        svc.attach_store(std::sync::Arc::new(bridge))?;
        println!(
            "store: {} (policy={}, generation={}, runs={})",
            store_cfg.dir,
            store_cfg.policy,
            store.generation(),
            store.run_count()
        );
        Some(mergeflow::store::LevelScheduler::start(
            store,
            std::sync::Arc::clone(&svc),
        ))
    } else {
        None
    };
    let handle = mergeflow::server::serve(std::sync::Arc::clone(&svc), server_cfg)?;
    println!("listening on {}", handle.local_addr());
    // Foreground server: periodic stats until the process is killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", svc.stats().snapshot());
    }
}

/// The pre-wire-server `serve` behavior, kept behind `--selfload`: the
/// service merges a self-generated stream of jobs and reports
/// throughput — a one-command smoke/load probe needing no client.
fn serve_selfload(cli: &Cli, cfg: MergeflowConfig) -> Result<()> {
    let jobs = cli.usize_flag("jobs", 64)?;
    let job_size = cli.size_flag("job-size", 64 << 10)?;
    println!("starting service: {cfg:?}");
    let svc = MergeService::start(cfg)?;
    let timer = Timer::start();
    let handles: Vec<_> = (0..jobs)
        .map(|i| {
            let (a, b) = gen_sorted_pair(
                WorkloadKind::Uniform,
                job_size / 2,
                job_size / 2,
                i as u64,
            );
            svc.submit(JobKind::Merge { a, b })
        })
        .collect::<Result<_>>()?;
    for h in handles {
        let r = h.wait()?;
        ensure_sorted_by_key("served merge output", &r.output)?;
    }
    let ns = timer.elapsed_ns();
    println!(
        "served {jobs} merge jobs x {job_size} elements in {} ({})",
        fmt_ns(ns),
        fmt_throughput((jobs * job_size) as u64, ns)
    );
    println!("{}", svc.stats().snapshot());
    svc.shutdown();
    Ok(())
}

fn cmd_figure(cli: &Cli) -> Result<()> {
    let scale = scale_of(cli);
    let which = cli.positional.first().map(|s| s.as_str()).unwrap_or("");
    match which {
        "fig4" => figures::fig4(scale).print(),
        "fig5" => figures::fig5(scale).iter().for_each(|t| t.print()),
        "fig7" => figures::fig7(scale).iter().for_each(|t| t.print()),
        "fig8" => figures::fig8(scale).print(),
        "all" => {
            figures::fig4(scale).print();
            figures::fig5(scale).iter().for_each(|t| t.print());
            figures::fig7(scale).iter().for_each(|t| t.print());
            figures::fig8(scale).print();
        }
        other => {
            return Err(Error::Config(format!(
                "unknown figure `{other}` (fig4|fig5|fig7|fig8|all)"
            )))
        }
    }
    Ok(())
}

fn cmd_table(cli: &Cli) -> Result<()> {
    let scale = scale_of(cli);
    match cli.positional.first().map(|s| s.as_str()).unwrap_or("") {
        "table1" => figures::table1(scale).print(),
        "table1b" => figures::table1_kway(scale).print(),
        "table2" => figures::table2().print(),
        other => {
            return Err(Error::Config(format!(
                "unknown table `{other}` (table1|table1b|table2)"
            )))
        }
    }
    Ok(())
}

/// `mergeflow kernels`: report the detected CPU features, whether the
/// SIMD kernels are compiled in, and what `merge.kernel = auto|simd`
/// resolve to per element type — the operator-facing view of the leaf
/// kernel dispatch (per-job usage shows up in the `serve` stats
/// snapshot under `kernels:`).
fn cmd_kernels() -> Result<()> {
    use mergeflow::mergepath::{cpu_features, LeafKernel, MergeKernel};
    let feats = cpu_features();
    println!(
        "cpu features: sse4.2={} avx2={}",
        feats.sse42, feats.avx2
    );
    println!("simd kernels compiled in: {}", cfg!(feature = "simd"));
    println!("\nkernel resolution (requested -> selected):");
    fn row<T: Ord + Copy + 'static>(name: &str) {
        let auto = LeafKernel::<T>::select(MergeKernel::Auto);
        let simd = LeafKernel::<T>::select(MergeKernel::Simd);
        println!(
            "  {name:<14} auto -> {:<10} simd -> {}",
            auto.kind().name(),
            simd.kind().name()
        );
    }
    row::<i32>("i32");
    row::<u32>("u32");
    row::<i64>("i64");
    row::<u64>("u64");
    row::<(u64, u64)>("(u64, u64)");
    Ok(())
}

/// `mergeflow store [verify] --dir DIR [--verbose]`: inspect a
/// persistent run store offline — manifest generation, per-level run
/// counts/records/bytes, and (verbose) each run's key range. The
/// `verify` action additionally re-reads every live run file end to
/// end, re-checking every block CRC against the manifest.
///
/// The record type is recovered from the manifest's wire id, so the
/// command works on any store a `mergeflow` server could have written.
fn cmd_store(cli: &Cli) -> Result<()> {
    use mergeflow::server::WireRecord;
    use mergeflow::store::{peek_wire_id, RunStore};

    let dir = cli
        .flag("dir")
        .ok_or_else(|| Error::Config("store: --dir <DIR> is required".into()))?
        .to_string();
    let verify = match cli.positional.first().map(|s| s.as_str()) {
        None => false,
        Some("verify") => true,
        Some(other) => {
            return Err(Error::Config(format!(
                "unknown store action `{other}` (expected nothing or `verify`)"
            )))
        }
    };
    let verbose = cli.bool_flag("verbose");
    let wire_id = match peek_wire_id(std::path::Path::new(&dir))? {
        Some(id) => id,
        None => {
            println!("store {dir}: empty (no manifest yet)");
            return Ok(());
        }
    };

    fn report<R: WireRecord>(dir: &str, verify: bool, verbose: bool) -> Result<()> {
        let cfg = StoreConfig { dir: dir.to_string(), ..StoreConfig::default() };
        let store = RunStore::<R>::open(&cfg)?;
        print!("{}", store.describe(verbose));
        if verify {
            let report = store.verify()?;
            println!(
                "verify: OK — {} runs, {} records, {} bytes re-checksummed",
                report.runs, report.records, report.bytes
            );
        }
        Ok(())
    }

    match wire_id {
        1 => report::<i32>(&dir, verify, verbose),
        2 => report::<u32>(&dir, verify, verbose),
        3 => report::<i64>(&dir, verify, verbose),
        4 => report::<u64>(&dir, verify, verbose),
        5 => report::<(u32, u32)>(&dir, verify, verbose),
        6 => report::<(u64, u64)>(&dir, verify, verbose),
        7 => report::<(i64, i64)>(&dir, verify, verbose),
        other => Err(Error::Config(format!(
            "store {dir}: unsupported wire id {other}"
        ))),
    }
}

/// `mergeflow stats --listen ADDR`: connect to a running server as an
/// ordinary wire client, issue `STATS` (and `STORE_STATS`), and
/// pretty-print the reply one section per line — the operator's view
/// of the per-stage latency histograms, per-shard dispatch gauges,
/// backend throughput, and the calibration report without scraping the
/// server's own periodic dump.
fn cmd_stats(cli: &Cli) -> Result<()> {
    use mergeflow::server::Client;
    let addr = cli.flag("listen").ok_or_else(|| {
        Error::Config("stats: --listen <HOST:PORT|unix:/PATH> is required".into())
    })?;
    let mut client = Client::<i32>::connect(addr, "stats-cli")?;
    let snap = client.stats()?;
    println!("service stats @ {addr}");
    let mut lines = snap.lines();
    // First line: the service snapshot, one ` | `-delimited section
    // per line. The remaining lines (tenant table) pass through as-is.
    for section in lines.next().unwrap_or("").split(" | ") {
        println!("  {section}");
    }
    for line in lines {
        println!("  {line}");
    }
    // A server without a store answers STORE_STATS with a typed error;
    // report it instead of failing the whole command.
    match client.store_stats() {
        Ok(text) => {
            println!("store stats:");
            for line in text.lines() {
                println!("  {line}");
            }
        }
        Err(e) => println!("store: unavailable ({e})"),
    }
    Ok(())
}

fn cmd_artifacts(cli: &Cli) -> Result<()> {
    let dir = cli.flag("dir").unwrap_or("artifacts");
    let rt = mergeflow::runtime::XlaRuntime::open(std::path::Path::new(dir))?;
    println!("platform: {}", rt.platform());
    for m in rt.manifest().entries() {
        println!(
            "{:<24} {:<28} op={:<6} |A|={:<8} |B|={:<8} {}",
            m.name, m.file, m.op, m.n_a, m.n_b, m.dtype
        );
    }
    // Smoke-execute the largest artifact to prove the runtime path.
    if let Some(meta) = rt.largest_merge().cloned() {
        let exe = rt.merge_executable(&meta.name)?;
        let a: Vec<i32> = (0..meta.n_a as i32).map(|x| 2 * x).collect();
        let b: Vec<i32> = (0..meta.n_b as i32).map(|x| 2 * x + 1).collect();
        let timer = BenchTimer::quick();
        let m = timer.measure(|| {
            let out = exe.merge(&a, &b).expect("merge artifact failed");
            std::hint::black_box(&out);
        });
        println!(
            "{}",
            report_line(
                &format!("xla merge {}", meta.name),
                &m,
                (meta.n_a + meta.n_b) as u64
            )
        );
    }
    Ok(())
}
