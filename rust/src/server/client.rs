//! Typed wire client: the loopback counterpart of [`serve`](super::serve)
//! used by tests, examples and the e2e harness.
//!
//! One [`Client`] is one connection (one `HELLO`, one tenant identity,
//! any number of interleaved sessions). The protocol is strictly
//! request → reply, so every method blocks until its answer frame —
//! which is exactly how server-side back-pressure reaches the caller:
//! a `FEED` into a saturated service parks the connection handler on
//! the session's blocking push, the handler stops reading, and this
//! client's write (or its reply read) stalls until admission frees up.
//!
//! Admission verdicts surface as typed errors: a `BUSY` frame (tenant
//! quota, memory budget, queue back-pressure) becomes
//! [`Error::Service`] with a `"BUSY: …"` message — test for it with
//! [`is_busy`] — and is *retryable*; the connection and its sessions
//! remain fully usable. `ERR` frames map per their code:
//! invalid-input codes to [`Error::InvalidInput`], everything else to
//! [`Error::Service`].

use super::frame::{
    self, err, tag, Cursor, FrameError, ReadOpts, WireRecord, PROTOCOL_VERSION,
};
use super::Stream;
use crate::{Error, Result};
use std::marker::PhantomData;

/// Reply-frame allocation cap. Replies carry whole merged outputs, so
/// the client's bound is intentionally far above `serve.max_frame_bytes`
/// (which guards the *server's* pre-read allocation, not ours).
const REPLY_FRAME_CAP: usize = 1 << 30;

/// True iff `e` is a fail-fast `BUSY` admission verdict (retryable;
/// nothing was admitted server-side).
pub fn is_busy(e: &Error) -> bool {
    matches!(e, Error::Service(m) if m.starts_with("BUSY"))
}

/// A connected wire client for record type `R` (checked against the
/// server's record type in the `HELLO` handshake).
pub struct Client<R: WireRecord> {
    stream: Stream,
    _record: PhantomData<R>,
}

impl<R: WireRecord> Client<R> {
    /// Dial `addr` (`host:port` or `unix:/path`) and complete the
    /// `HELLO` handshake under `tenant`'s quota identity.
    pub fn connect(addr: &str, tenant: &str) -> Result<Self> {
        let mut client =
            Self { stream: Stream::connect(addr)?, _record: PhantomData };
        let mut hello = Vec::new();
        frame::put_varint(&mut hello, PROTOCOL_VERSION);
        frame::put_varint(&mut hello, u64::from(R::WIRE_ID));
        hello.extend_from_slice(tenant.as_bytes());
        client.expect(tag::HELLO_OK, tag::HELLO, &hello)?;
        Ok(client)
    }

    /// Liveness probe — also the idiomatic lease heartbeat for a
    /// client that is alive but has no data ready.
    pub fn ping(&mut self) -> Result<()> {
        self.expect(tag::PONG, tag::PING, &[])?;
        Ok(())
    }

    /// Service stats snapshot plus the per-tenant admission lines.
    pub fn stats(&mut self) -> Result<String> {
        let payload = self.expect(tag::STATS_TEXT, tag::STATS, &[])?;
        String::from_utf8(payload)
            .map_err(|_| Error::Service("stats reply is not utf8".into()))
    }

    /// Open a streaming compaction of `runs` sorted runs; returns the
    /// session id the other session verbs address.
    pub fn open(&mut self, runs: usize) -> Result<u64> {
        let mut p = Vec::new();
        frame::put_varint(&mut p, runs as u64);
        let reply = self.expect(tag::OPENED, tag::OPEN, &p)?;
        Cursor::new(&reply).get_varint()
    }

    /// Feed one key-sorted chunk of `run` into session `session`.
    pub fn feed(&mut self, session: u64, run: usize, chunk: &[R]) -> Result<()> {
        let mut p = Vec::with_capacity(20 + chunk.len() * R::WIRE_BYTES);
        frame::put_varint(&mut p, session);
        frame::put_varint(&mut p, run as u64);
        frame::put_records(&mut p, chunk);
        self.expect(tag::OK, tag::FEED, &p)?;
        Ok(())
    }

    /// Declare that `run` of `session` will receive no more chunks.
    pub fn seal_run(&mut self, session: u64, run: usize) -> Result<()> {
        let mut p = Vec::new();
        frame::put_varint(&mut p, session);
        frame::put_varint(&mut p, run as u64);
        self.expect(tag::OK, tag::SEAL_RUN, &p)?;
        Ok(())
    }

    /// Seal `session` and block for the merged output:
    /// `(backend tag, records)`.
    pub fn seal(&mut self, session: u64) -> Result<(String, Vec<R>)> {
        let mut p = Vec::new();
        frame::put_varint(&mut p, session);
        let reply = self.expect(tag::RESULT, tag::SEAL, &p)?;
        decode_result(&reply)
    }

    /// One-shot pairwise merge of two key-sorted inputs.
    pub fn merge(&mut self, a: &[R], b: &[R]) -> Result<(String, Vec<R>)> {
        let mut p = Vec::with_capacity(20 + (a.len() + b.len()) * R::WIRE_BYTES);
        frame::put_records(&mut p, a);
        frame::put_records(&mut p, b);
        let reply = self.expect(tag::RESULT, tag::MERGE, &p)?;
        decode_result(&reply)
    }

    /// One-shot k-way compaction of key-sorted runs.
    pub fn compact(&mut self, runs: &[Vec<R>]) -> Result<(String, Vec<R>)> {
        let total: usize = runs.iter().map(|r| r.len()).sum();
        let mut p = Vec::with_capacity(20 + total * R::WIRE_BYTES);
        frame::put_varint(&mut p, runs.len() as u64);
        for run in runs {
            frame::put_records(&mut p, run);
        }
        let reply = self.expect(tag::RESULT, tag::COMPACT, &p)?;
        decode_result(&reply)
    }

    /// One-shot stable sort.
    pub fn sort(&mut self, data: &[R]) -> Result<(String, Vec<R>)> {
        let mut p = Vec::with_capacity(20 + data.len() * R::WIRE_BYTES);
        frame::put_records(&mut p, data);
        let reply = self.expect(tag::RESULT, tag::SORT, &p)?;
        decode_result(&reply)
    }

    /// Spill one key-sorted run to level 0 of the server's persistent
    /// store. The result echoes the spilled records under backend
    /// `"store-spill"`. Requires a store (`store.dir`) server-side.
    pub fn spill(&mut self, run: &[R]) -> Result<(String, Vec<R>)> {
        let mut p = Vec::with_capacity(20 + run.len() * R::WIRE_BYTES);
        frame::put_records(&mut p, run);
        let reply = self.expect(tag::RESULT, tag::FLUSH, &p)?;
        decode_result(&reply)
    }

    /// Drive the server's store compaction until every level is within
    /// policy (a `FLUSH` with no records). Blocks for as long as the
    /// compactions take; the result is empty under backend
    /// `"store-flush"`.
    pub fn flush(&mut self) -> Result<(String, Vec<R>)> {
        let mut p = Vec::new();
        frame::put_records::<R>(&mut p, &[]);
        let reply = self.expect(tag::RESULT, tag::FLUSH, &p)?;
        decode_result(&reply)
    }

    /// The store's description text (generation, per-level run
    /// counts); a typed `STATE` error when the server has no store.
    pub fn store_stats(&mut self) -> Result<String> {
        let payload = self.expect(tag::STATS_TEXT, tag::STORE_STATS, &[])?;
        String::from_utf8(payload)
            .map_err(|_| Error::Service("store stats reply is not utf8".into()))
    }

    /// Send one request frame and read its reply, demanding reply tag
    /// `want`; `ERR`/`BUSY` frames become typed errors instead.
    fn expect(&mut self, want: u8, req: u8, payload: &[u8]) -> Result<Vec<u8>> {
        frame::write_frame(&mut self.stream, req, payload)?;
        let (t, reply) =
            frame::read_frame(&mut self.stream, REPLY_FRAME_CAP, &ReadOpts::default())
                .map_err(|e| match e {
                    FrameError::Io(io) => Error::Io(io),
                    other => Error::Service(format!("wire client: {other}")),
                })?;
        if t == want {
            return Ok(reply);
        }
        Err(match t {
            tag::BUSY => Error::Service(format!(
                "BUSY: {}",
                String::from_utf8_lossy(&reply)
            )),
            tag::ERR => {
                let mut c = Cursor::new(&reply);
                let code = c.get_u8().unwrap_or(0);
                let msg = c.rest_str().unwrap_or_default();
                match code {
                    err::INVALID_INPUT => Error::InvalidInput(msg),
                    _ => Error::Service(format!("server error (code {code}): {msg}")),
                }
            }
            other => {
                Error::Service(format!("unexpected reply tag {other:#04x}"))
            }
        })
    }
}

/// Decode a `RESULT` payload: `[backend str][records]`.
fn decode_result<R: WireRecord>(payload: &[u8]) -> Result<(String, Vec<R>)> {
    let mut c = Cursor::new(payload);
    let backend = c.get_str()?;
    let records = c.get_records::<R>()?;
    Ok((backend, records))
}
