//! Admission control plane: per-tenant in-flight byte/session quotas
//! with fail-fast `BUSY` verdicts, layered on top of the service-wide
//! `merge.memory_budget`.
//!
//! A *tenant* is the name a connection declares at `HELLO`; several
//! connections may share one tenant (and therefore one quota). The
//! registry tracks, per tenant, the bytes currently held live on the
//! tenant's behalf — open-session feeds plus in-flight one-shot
//! payloads — and the number of open streaming sessions. Checks are
//! admit-then-roll-back: the gauge is raised first and lowered again
//! on a verdict of over-quota, so two connections of one tenant racing
//! the same headroom can transiently observe the sum but never both
//! keep it.

use crate::config::ServerConfig;
use crate::coordinator::ServiceStats;
use crate::metrics::{Counter, Gauge};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Live per-tenant accounting. All fields are monitoring-grade atomics
/// — readable from the `STATS` verb while connections mutate them.
#[derive(Debug, Default)]
pub struct TenantState {
    /// Bytes currently charged to the tenant (quota numerator).
    pub bytes: Gauge,
    /// Open streaming sessions.
    pub sessions: Gauge,
    /// Live connections.
    pub conns: Gauge,
    /// Fail-fast `BUSY` verdicts issued to this tenant.
    pub busy: Counter,
    /// Sessions reaped after this tenant's connections died or leased
    /// out.
    pub reaped: Counter,
}

/// The registry: tenant name → state, plus the configured limits.
#[derive(Debug)]
pub struct TenantRegistry {
    quota_bytes: u64,
    max_sessions: u64,
    stats: Arc<ServiceStats>,
    tenants: Mutex<HashMap<String, Arc<TenantState>>>,
}

impl TenantRegistry {
    /// New registry enforcing `cfg`'s per-tenant limits; `BUSY`
    /// verdicts are also counted in the service-wide
    /// [`ServiceStats::busy_rejections`].
    pub fn new(cfg: &ServerConfig, stats: Arc<ServiceStats>) -> Self {
        Self {
            quota_bytes: cfg.tenant_quota_bytes as u64,
            max_sessions: cfg.tenant_max_sessions as u64,
            stats,
            tenants: Mutex::new(HashMap::new()),
        }
    }

    /// Register a connection under `name` (created on first sight) and
    /// return the tenant's state handle.
    pub fn connect(&self, name: &str) -> Arc<TenantState> {
        let state = Arc::clone(
            self.tenants
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        );
        state.conns.add(1);
        state
    }

    /// The connection under `tenant` closed.
    pub fn disconnect(&self, tenant: &TenantState) {
        tenant.conns.sub(1);
    }

    /// Try to charge `bytes` against the tenant's quota. `Err` is the
    /// `BUSY` message; nothing stays charged on failure.
    pub fn try_charge(&self, tenant: &TenantState, bytes: u64) -> Result<(), String> {
        if self.quota_bytes == 0 {
            tenant.bytes.add(bytes);
            return Ok(());
        }
        tenant.bytes.add(bytes);
        let now = tenant.bytes.get();
        if now > self.quota_bytes {
            tenant.bytes.sub(bytes);
            self.busy(tenant);
            return Err(format!(
                "tenant quota exceeded: {bytes} B on top of {} B in flight would pass \
                 serve.tenant_quota_bytes={}",
                now - bytes,
                self.quota_bytes
            ));
        }
        Ok(())
    }

    /// Release `bytes` previously charged with
    /// [`try_charge`](Self::try_charge).
    pub fn drain(&self, tenant: &TenantState, bytes: u64) {
        tenant.bytes.sub(bytes);
    }

    /// Try to open one more streaming session for the tenant.
    pub fn try_open_session(&self, tenant: &TenantState) -> Result<(), String> {
        if self.max_sessions == 0 {
            tenant.sessions.add(1);
            return Ok(());
        }
        tenant.sessions.add(1);
        if tenant.sessions.get() > self.max_sessions {
            tenant.sessions.sub(1);
            self.busy(tenant);
            return Err(format!(
                "tenant session quota exceeded: serve.tenant_max_sessions={}",
                self.max_sessions
            ));
        }
        Ok(())
    }

    /// A session of the tenant closed (sealed or reaped).
    pub fn close_session(&self, tenant: &TenantState) {
        tenant.sessions.sub(1);
    }

    /// Count a `BUSY` verdict that was decided outside the registry
    /// (service budget / queue back-pressure surfaced over the wire).
    pub fn busy(&self, tenant: &TenantState) {
        tenant.busy.inc();
        self.stats.busy_rejections.inc();
    }

    /// Count reaped sessions for the tenant (the service-wide figure is
    /// counted by [`crate::coordinator::CompactionSession::abort`]).
    pub fn reaped(&self, tenant: &TenantState, sessions: u64) {
        tenant.reaped.add(sessions);
    }

    /// Per-tenant lines appended to the `STATS` verb's reply.
    pub fn render(&self) -> String {
        let tenants = self.tenants.lock().unwrap();
        let mut names: Vec<&String> = tenants.keys().collect();
        names.sort();
        let mut out = String::new();
        for name in names {
            let t = &tenants[name];
            out.push_str(&format!(
                "tenant {name}: conns={} bytes={} peak={} sessions={} busy={} reaped={}\n",
                t.conns.get(),
                t.bytes.get(),
                t.bytes.peak(),
                t.sessions.get(),
                t.busy.get(),
                t.reaped.get(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;

    fn registry(quota: usize, sessions: usize) -> TenantRegistry {
        let cfg = ServerConfig {
            tenant_quota_bytes: quota,
            tenant_max_sessions: sessions,
            ..Default::default()
        };
        TenantRegistry::new(&cfg, Arc::new(ServiceStats::new()))
    }

    #[test]
    fn byte_quota_admits_and_rolls_back() {
        let reg = registry(100, 0);
        let t = reg.connect("a");
        assert!(reg.try_charge(&t, 60).is_ok());
        assert!(reg.try_charge(&t, 40).is_ok());
        let err = reg.try_charge(&t, 1).unwrap_err();
        assert!(err.contains("tenant quota exceeded"), "{err}");
        assert_eq!(t.bytes.get(), 100, "failed charge fully rolled back");
        assert_eq!(t.busy.get(), 1);
        reg.drain(&t, 100);
        assert_eq!(t.bytes.get(), 0);
        assert!(reg.try_charge(&t, 100).is_ok(), "drained quota is reusable");
    }

    #[test]
    fn zero_quota_means_unlimited() {
        let reg = registry(0, 0);
        let t = reg.connect("a");
        assert!(reg.try_charge(&t, u64::MAX / 2).is_ok());
        assert!(reg.try_open_session(&t).is_ok());
        assert_eq!(t.busy.get(), 0);
    }

    #[test]
    fn session_quota_enforced_per_tenant() {
        let reg = registry(0, 2);
        let a = reg.connect("a");
        let b = reg.connect("b");
        assert!(reg.try_open_session(&a).is_ok());
        assert!(reg.try_open_session(&a).is_ok());
        assert!(reg.try_open_session(&a).is_err(), "third session busts the cap");
        assert!(reg.try_open_session(&b).is_ok(), "quotas are per tenant");
        reg.close_session(&a);
        assert!(reg.try_open_session(&a).is_ok(), "closed slot is reusable");
    }

    #[test]
    fn tenants_share_state_by_name_and_render() {
        let reg = registry(1000, 0);
        let c1 = reg.connect("shared");
        let c2 = reg.connect("shared");
        assert!(Arc::ptr_eq(&c1, &c2), "same name, same quota pool");
        assert_eq!(c1.conns.get(), 2);
        reg.try_charge(&c1, 700).unwrap();
        assert!(reg.try_charge(&c2, 700).is_err(), "shared pool is shared");
        reg.disconnect(&c2);
        assert_eq!(c1.conns.get(), 1);
        reg.reaped(&c1, 2);
        let text = reg.render();
        assert!(text.contains("tenant shared:"), "{text}");
        assert!(text.contains("bytes=700"), "{text}");
        assert!(text.contains("reaped=2"), "{text}");
    }
}
