//! Wire server: the coordinator surface served over TCP or Unix
//! sockets as a length-prefixed framed byte protocol.
//!
//! Verbs map 1:1 onto the existing in-process API — `OPEN`/`FEED`/
//! `SEAL_RUN`/`SEAL` onto
//! [`CompactionSession`](crate::coordinator::CompactionSession),
//! one-shot `MERGE`/`COMPACT`/`SORT` onto [`MergeService::submit`],
//! plus `STATS` and `PING` — so a remote client gets exactly the
//! semantics (validation,
//! stability, back-pressure) an embedded one does. Layers:
//!
//! - [`frame`] — the codec: `[tag][len varint][payload]` frames,
//!   LEB128 varints, fixed-width little-endian typed records
//!   ([`frame::WireRecord`]), allocation-capped decoding.
//! - [`conn`] (private) — one thread per connection; request → reply
//!   in order, with the session's blocking push as the back-pressure
//!   seam: while the service queue is full the handler is parked in
//!   `feed`, stops reading the socket, and the client's own writes
//!   stall.
//! - [`control`] — per-tenant in-flight byte/session quotas with
//!   fail-fast `BUSY` replies, layered on `merge.memory_budget`.
//! - [`client`] — a typed loopback [`Client`] for tests, examples and
//!   the e2e harness.
//!
//! Liveness is lease-based: `serve.lease_ms` bounds how long a
//! connection may go completely silent (no bytes arriving — any frame,
//! `PING` included, is a heartbeat; mid-frame progress counts too).
//! A connection that leases out, hangs up, or dies mid-frame has all
//! its open sessions aborted
//! ([`CompactionSession::abort`](crate::coordinator::CompactionSession::abort)):
//! the dispatcher reaps their buffered ingest (draining
//! `resident_bytes`) and the tenant's quota is restored, so a dead
//! client can never hold admission hostage.

pub mod client;
mod conn;
pub mod control;
pub mod frame;

pub use client::{is_busy, Client};
pub use frame::WireRecord;

use crate::config::ServerConfig;
use crate::coordinator::MergeService;
use crate::{Error, Result};
use control::TenantRegistry;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A listen/connect address: `host:port`, or `unix:/path` for a Unix
/// domain socket.
enum Addr {
    Tcp(String),
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

/// Parse `serve.listen` / client address syntax.
fn parse_addr(addr: &str) -> Result<Addr> {
    if let Some(path) = addr.strip_prefix("unix:") {
        #[cfg(unix)]
        {
            if path.is_empty() {
                return Err(Error::Config("empty unix socket path".into()));
            }
            return Ok(Addr::Unix(std::path::PathBuf::from(path)));
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            return Err(Error::Config(
                "unix: addresses are not supported on this platform".into(),
            ));
        }
    }
    if addr.is_empty() {
        return Err(Error::Config("empty listen address".into()));
    }
    Ok(Addr::Tcp(addr.to_string()))
}

/// One accepted or dialed connection — TCP and Unix streams behind one
/// `Read + Write` face (no `dyn`: the match compiles away).
pub(crate) enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    /// Dial `addr` (client side).
    fn connect(addr: &str) -> Result<Self> {
        match parse_addr(addr)? {
            Addr::Tcp(a) => Ok(Stream::Tcp(TcpStream::connect(a)?)),
            #[cfg(unix)]
            Addr::Unix(p) => Ok(Stream::Unix(UnixStream::connect(p)?)),
        }
    }

    fn set_read_timeout(&mut self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(dur),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(dur),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn bind(addr: &Addr) -> Result<Self> {
        match addr {
            Addr::Tcp(a) => Ok(Listener::Tcp(TcpListener::bind(a)?)),
            #[cfg(unix)]
            Addr::Unix(p) => {
                // A stale socket file from a previous run makes bind
                // fail with AddrInUse even though nobody is listening —
                // remove it first (connectable live sockets are the
                // operator's problem, like any port collision).
                if p.exists() {
                    let _ = std::fs::remove_file(p);
                }
                Ok(Listener::Unix(UnixListener::bind(p)?))
            }
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }

    /// The resolved address in the same syntax `parse_addr` accepts —
    /// for TCP this includes the kernel-assigned port when the config
    /// said `:0`, so tests can dial it back.
    fn resolved(&self) -> String {
        match self {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_default(),
            #[cfg(unix)]
            Listener::Unix(l) => {
                let path = l
                    .local_addr()
                    .ok()
                    .and_then(|a| a.as_pathname().map(|p| p.to_path_buf()))
                    .unwrap_or_default();
                format!("unix:{}", path.display())
            }
        }
    }
}

/// Handle to a running server: the resolved address and the switch to
/// stop it. Dropping the handle shuts the server down too.
pub struct ServerHandle {
    addr: String,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServerHandle {
    /// The resolved listen address, dialable by [`Client::connect`]
    /// (`host:port`, or `unix:/path`).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting, wake every parked connection handler, and join
    /// all server threads. In-flight requests finish first (a handler
    /// checks the stop flag between frames, not mid-request); open
    /// sessions of connections that never returned are aborted and
    /// reaped as if their clients had hung up.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        let Some(accept) = self.accept_thread.take() else { return };
        self.stop.store(true, Ordering::Relaxed);
        // The accept loop is parked in accept(2); a throwaway dial is
        // the portable wake-up.
        let _ = Stream::connect(&self.addr);
        let _ = accept.join();
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in conns {
            let _ = c.join();
        }
        // Leave no stale socket file behind.
        if let Some(path) = self.addr.strip_prefix("unix:") {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Start serving `svc` per `cfg` and return immediately; connections
/// are handled on their own threads until [`ServerHandle::shutdown`].
///
/// The record type is fixed per server (declared to clients via
/// [`WireRecord::WIRE_ID`] in the `HELLO` handshake); a client
/// connecting with a different record type is refused with a typed
/// error before any verb runs.
pub fn serve<R: WireRecord>(
    svc: Arc<MergeService<R>>,
    cfg: ServerConfig,
) -> Result<ServerHandle> {
    cfg.validate()?;
    let addr = parse_addr(&cfg.listen)?;
    let listener = Listener::bind(&addr)?;
    let resolved = listener.resolved();
    let stop = Arc::new(AtomicBool::new(false));
    let tenants = Arc::new(TenantRegistry::new(&cfg, svc.stats_arc()));
    let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
        Arc::new(Mutex::new(Vec::new()));

    let accept_thread = {
        let stop = Arc::clone(&stop);
        let conns = Arc::clone(&conns);
        std::thread::Builder::new()
            .name("mergeflow-accept".into())
            .spawn(move || loop {
                let stream = match listener.accept() {
                    Ok(s) => s,
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            return;
                        }
                        continue;
                    }
                };
                if stop.load(Ordering::Relaxed) {
                    return; // the shutdown wake-up dial
                }
                let svc = Arc::clone(&svc);
                let cfg = cfg.clone();
                let tenants = Arc::clone(&tenants);
                let stop2 = Arc::clone(&stop);
                let handle = std::thread::Builder::new()
                    .name("mergeflow-conn".into())
                    .spawn(move || conn::handle(stream, &svc, &cfg, &tenants, &stop2))
                    .expect("spawn connection handler");
                conns.lock().unwrap().push(handle);
            })
            .map_err(Error::Io)?
    };

    Ok(ServerHandle {
        addr: resolved,
        stop,
        accept_thread: Some(accept_thread),
        conns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_syntax_parses() {
        assert!(matches!(parse_addr("127.0.0.1:7141"), Ok(Addr::Tcp(_))));
        assert!(parse_addr("").is_err());
        #[cfg(unix)]
        {
            assert!(matches!(parse_addr("unix:/tmp/x.sock"), Ok(Addr::Unix(_))));
            assert!(parse_addr("unix:").is_err());
        }
    }
}
