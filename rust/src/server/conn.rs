//! Per-connection handler: decode frames, map verbs 1:1 onto the
//! coordinator surface, apply tenant admission, propagate blocking-push
//! back-pressure to the socket.
//!
//! One thread per connection, request → reply in order. Streaming
//! sessions are interleavable — a connection may hold any number of
//! open sessions and `FEED` them in any order — but each frame is
//! answered before the next is read, so the client's socket write
//! stalls exactly when the service's admission queue does (the
//! session's blocking push is what the server thread is parked on).
//!
//! Cleanup is unconditional: whatever ends the connection — clean
//! close, half-written frame, transport error, lease expiry, server
//! shutdown — every still-open session is aborted
//! ([`CompactionSession::abort`]), which queues it for the
//! dispatcher's reap so its ingest leaves `resident_bytes`, and every
//! charged byte leaves the tenant's quota.

use super::control::{TenantRegistry, TenantState};
use super::frame::{
    self, err, tag, Cursor, FrameError, ReadOpts, WireRecord, PROTOCOL_VERSION,
};
use super::Stream;
use crate::config::ServerConfig;
use crate::coordinator::{CompactionSession, JobKind, MergeService};
use crate::Error;
use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

/// Socket read timeout — the granularity at which a parked reader
/// notices server shutdown and checks the lease clock.
pub(super) const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// What to do after answering a frame.
enum Flow {
    /// Keep serving this connection.
    Continue,
    /// Stop serving (stream desynchronized or peer gone).
    Close,
}

/// Serve one connection to completion. Never panics on malformed
/// input; all exits run the same session/quota cleanup.
pub(super) fn handle<R: WireRecord>(
    mut stream: Stream,
    svc: &Arc<MergeService<R>>,
    cfg: &ServerConfig,
    tenants: &Arc<TenantRegistry>,
    stop: &AtomicBool,
) {
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let lease = (cfg.lease_ms > 0).then(|| Duration::from_millis(cfg.lease_ms));
    let opts = ReadOpts { idle: lease, stop: Some(stop) };

    let Some(tenant) = handshake::<R>(&mut stream, cfg, &opts, tenants) else {
        return;
    };
    let mut sessions: HashMap<u64, CompactionSession<R>> = HashMap::new();

    loop {
        match frame::read_frame(&mut stream, cfg.max_frame_bytes, &opts) {
            Ok((t, payload)) => {
                // Deterministic server-side fault injection: die at
                // this frame boundary without answering, exactly as a
                // crashed handler thread would. The tail reap below
                // must then abort every open session and drain the
                // tenant's quota — the property the fault tests pin.
                // Scoped by tenant so concurrently-running tests'
                // connections can never consume each other's kill.
                if crate::testutil::FailPoint::hit(&format!("server.conn.kill.{tenant}")) {
                    break;
                }
                match dispatch(&mut stream, t, &payload, svc, tenants, &tenant, &mut sessions)
                {
                    Flow::Continue => {}
                    Flow::Close => break,
                }
            }
            Err(FrameError::Closed) | Err(FrameError::Stopped) => break,
            Err(FrameError::TimedOut) => {
                // Lease expired: the client went silent past
                // `serve.lease_ms` (mid-frame or between frames).
                let _ = frame::write_err(
                    &mut stream,
                    err::STATE,
                    "lease expired: no bytes within serve.lease_ms",
                );
                break;
            }
            Err(e @ (FrameError::Eof | FrameError::Varint | FrameError::Io(_))) => {
                // Stream desynchronized (half-written frame, transport
                // fault): answer with a typed error if the peer can
                // still read, then close.
                let _ = frame::write_err(&mut stream, err::PROTOCOL, &e.to_string());
                break;
            }
            Err(FrameError::TooLarge(n)) => {
                let _ = frame::write_err(
                    &mut stream,
                    err::PROTOCOL,
                    &format!(
                        "declared payload of {n} bytes exceeds serve.max_frame_bytes={}",
                        cfg.max_frame_bytes
                    ),
                );
                break;
            }
        }
    }

    // Reap: any session still open when the connection ends was
    // abandoned by its client.
    let abandoned = sessions.len() as u64;
    for (_, session) in sessions.drain() {
        tenants.drain(&tenant, session.fed_bytes());
        tenants.close_session(&tenant);
        session.abort();
    }
    if abandoned > 0 {
        tenants.reaped(&tenant, abandoned);
    }
    tenants.disconnect(&tenant);
}

/// Expect and answer the `HELLO` preamble; returns the tenant handle,
/// or `None` after answering with a typed error.
fn handshake<R: WireRecord>(
    stream: &mut Stream,
    cfg: &ServerConfig,
    opts: &ReadOpts<'_>,
    tenants: &TenantRegistry,
) -> Option<Arc<TenantState>> {
    let (t, payload) = match frame::read_frame(stream, cfg.max_frame_bytes, opts) {
        Ok(f) => f,
        Err(FrameError::Closed) | Err(FrameError::Stopped) => return None,
        Err(e) => {
            let _ = frame::write_err(stream, err::PROTOCOL, &e.to_string());
            return None;
        }
    };
    if t != tag::HELLO {
        let _ = frame::write_err(stream, err::STATE, "expected HELLO before any verb");
        return None;
    }
    let parsed = (|| {
        let mut c = Cursor::new(&payload);
        let version = c.get_varint()?;
        let wire_id = c.get_varint()?;
        let tenant = c.rest_str()?;
        Ok::<_, Error>((version, wire_id, tenant))
    })();
    let (version, wire_id, tenant_name) = match parsed {
        Ok(p) => p,
        Err(e) => {
            let _ = frame::write_err(stream, err::PROTOCOL, &e.to_string());
            return None;
        }
    };
    if version != PROTOCOL_VERSION {
        let _ = frame::write_err(
            stream,
            err::UNSUPPORTED,
            &format!("protocol version {version} (server speaks {PROTOCOL_VERSION})"),
        );
        return None;
    }
    if wire_id != u64::from(R::WIRE_ID) {
        let _ = frame::write_err(
            stream,
            err::UNSUPPORTED,
            &format!("record wire id {wire_id} (server serves {})", R::WIRE_ID),
        );
        return None;
    }
    let name = if tenant_name.is_empty() { "default" } else { &tenant_name };
    let tenant = tenants.connect(name);
    let mut ok = Vec::new();
    frame::put_varint(&mut ok, PROTOCOL_VERSION);
    if frame::write_frame(stream, tag::HELLO_OK, &ok).is_err() {
        tenants.disconnect(&tenant);
        return None;
    }
    Some(tenant)
}

/// Answer one well-formed frame. Payload-level failures reply with a
/// typed error and keep the connection (the stream is still at a frame
/// boundary); only transport write failures close it.
#[allow(clippy::too_many_arguments)]
fn dispatch<R: WireRecord>(
    stream: &mut Stream,
    t: u8,
    payload: &[u8],
    svc: &Arc<MergeService<R>>,
    tenants: &TenantRegistry,
    tenant: &Arc<TenantState>,
    sessions: &mut HashMap<u64, CompactionSession<R>>,
) -> Flow {
    let reply = match t {
        tag::PING => Reply::Frame(tag::PONG, Vec::new()),
        tag::STATS => {
            let text = format!("{}\n{}", svc.stats().snapshot(), tenants.render());
            Reply::Frame(tag::STATS_TEXT, text.into_bytes())
        }
        tag::OPEN => verb_open(payload, svc, tenants, tenant, sessions),
        tag::FEED => verb_feed(payload, tenants, tenant, sessions),
        tag::SEAL_RUN => verb_seal_run(payload, sessions),
        tag::SEAL => verb_seal(payload, tenants, tenant, sessions),
        tag::MERGE => verb_one_shot(payload, svc, tenants, tenant, |c| {
            let a = c.get_records::<R>()?;
            let b = c.get_records::<R>()?;
            Ok((a.len() + b.len(), JobKind::Merge { a, b }))
        }),
        tag::COMPACT => verb_one_shot(payload, svc, tenants, tenant, |c| {
            let k = c.get_varint()? as usize;
            let mut runs = Vec::new();
            let mut total = 0usize;
            for _ in 0..k {
                let run = c.get_records::<R>()?;
                total += run.len();
                runs.push(run);
            }
            Ok((total, JobKind::Compact { runs }))
        }),
        tag::SORT => verb_one_shot(payload, svc, tenants, tenant, |c| {
            let data = c.get_records::<R>()?;
            Ok((data.len(), JobKind::Sort { data }))
        }),
        tag::FLUSH => verb_one_shot(payload, svc, tenants, tenant, |c| {
            let records = c.get_records::<R>()?;
            let elems = records.len();
            // Non-empty payload = spill this run; empty = drain the
            // store (drive compactions until within policy).
            let kind = if records.is_empty() {
                JobKind::Flush
            } else {
                JobKind::Spill { run: records }
            };
            Ok((elems, kind))
        }),
        tag::STORE_STATS => match svc.store_stats_text() {
            Some(text) => Reply::Frame(tag::STATS_TEXT, text.into_bytes()),
            None => Reply::Err(
                err::STATE,
                "no store attached (configure store.dir)".into(),
            ),
        },
        tag::HELLO => Reply::Err(err::STATE, "HELLO already completed".into()),
        other => Reply::Err(err::UNKNOWN_VERB, format!("unknown verb tag {other:#04x}")),
    };
    let written = match reply {
        Reply::Frame(t, payload) => frame::write_frame(stream, t, &payload),
        Reply::Err(code, msg) => frame::write_err(stream, code, &msg),
        Reply::Busy(msg) => frame::write_frame(stream, tag::BUSY, msg.as_bytes()),
    };
    if written.is_err() {
        Flow::Close
    } else {
        Flow::Continue
    }
}

/// A decided reply, built before anything touches the socket.
enum Reply {
    Frame(u8, Vec<u8>),
    Err(u8, String),
    Busy(String),
}

impl Reply {
    fn result<R: WireRecord>(backend: &str, output: &[R]) -> Self {
        let mut p = Vec::with_capacity(backend.len() + 12 + output.len() * R::WIRE_BYTES);
        frame::put_str(&mut p, backend);
        frame::put_records(&mut p, output);
        Reply::Frame(tag::RESULT, p)
    }

    /// Map a coordinator error: admission back-pressure (queue full,
    /// budget, shutdown) is `BUSY`; precondition violations are typed
    /// invalid-input errors.
    fn from_service_error(e: Error, tenants: &TenantRegistry, tenant: &TenantState) -> Self {
        match e {
            Error::Service(msg) => {
                tenants.busy(tenant);
                Reply::Busy(msg)
            }
            Error::InvalidInput(msg) => Reply::Err(err::INVALID_INPUT, msg),
            other => Reply::Err(err::INTERNAL, other.to_string()),
        }
    }
}

fn verb_open<R: WireRecord>(
    payload: &[u8],
    svc: &Arc<MergeService<R>>,
    tenants: &TenantRegistry,
    tenant: &Arc<TenantState>,
    sessions: &mut HashMap<u64, CompactionSession<R>>,
) -> Reply {
    let k = match Cursor::new(payload).get_varint() {
        Ok(k) => k as usize,
        Err(e) => return Reply::Err(err::PROTOCOL, e.to_string()),
    };
    if let Err(msg) = tenants.try_open_session(tenant) {
        return Reply::Busy(msg);
    }
    match svc.open_compaction(k) {
        Ok(session) => {
            let id = session.id();
            sessions.insert(id, session);
            let mut p = Vec::new();
            frame::put_varint(&mut p, id);
            Reply::Frame(tag::OPENED, p)
        }
        Err(e) => {
            tenants.close_session(tenant);
            Reply::from_service_error(e, tenants, tenant)
        }
    }
}

fn verb_feed<R: WireRecord>(
    payload: &[u8],
    tenants: &TenantRegistry,
    tenant: &Arc<TenantState>,
    sessions: &mut HashMap<u64, CompactionSession<R>>,
) -> Reply {
    let mut c = Cursor::new(payload);
    let parsed = (|| {
        let id = c.get_varint()?;
        let run = c.get_varint()? as usize;
        let chunk = c.get_records::<R>()?;
        Ok::<_, Error>((id, run, chunk))
    })();
    let (id, run, chunk) = match parsed {
        Ok(p) => p,
        Err(e) => return Reply::Err(err::PROTOCOL, e.to_string()),
    };
    let Some(session) = sessions.get_mut(&id) else {
        return Reply::Err(err::STATE, format!("no open session {id} on this connection"));
    };
    let bytes = std::mem::size_of_val(chunk.as_slice()) as u64;
    if let Err(msg) = tenants.try_charge(tenant, bytes) {
        return Reply::Busy(msg);
    }
    match session.feed(run, chunk) {
        Ok(()) => Reply::Frame(tag::OK, Vec::new()),
        Err(e) => {
            // Not admitted — the charge rolls back with it. The session
            // itself stays open and usable (feed's mid-stream
            // rejection contract).
            tenants.drain(tenant, bytes);
            Reply::from_service_error(e, tenants, tenant)
        }
    }
}

fn verb_seal_run<R: WireRecord>(
    payload: &[u8],
    sessions: &mut HashMap<u64, CompactionSession<R>>,
) -> Reply {
    let mut c = Cursor::new(payload);
    let parsed = (|| Ok::<_, Error>((c.get_varint()?, c.get_varint()? as usize)))();
    let (id, run) = match parsed {
        Ok(p) => p,
        Err(e) => return Reply::Err(err::PROTOCOL, e.to_string()),
    };
    let Some(session) = sessions.get_mut(&id) else {
        return Reply::Err(err::STATE, format!("no open session {id} on this connection"));
    };
    match session.seal_run(run) {
        Ok(()) => Reply::Frame(tag::OK, Vec::new()),
        Err(Error::InvalidInput(msg)) => Reply::Err(err::INVALID_INPUT, msg),
        Err(other) => Reply::Err(err::INTERNAL, other.to_string()),
    }
}

fn verb_seal<R: WireRecord>(
    payload: &[u8],
    tenants: &TenantRegistry,
    tenant: &Arc<TenantState>,
    sessions: &mut HashMap<u64, CompactionSession<R>>,
) -> Reply {
    let id = match Cursor::new(payload).get_varint() {
        Ok(id) => id,
        Err(e) => return Reply::Err(err::PROTOCOL, e.to_string()),
    };
    let Some(session) = sessions.remove(&id) else {
        return Reply::Err(err::STATE, format!("no open session {id} on this connection"));
    };
    let fed = session.fed_bytes();
    tenants.close_session(tenant);
    // Blocking by design: the reply to SEAL *is* the merged output, so
    // this connection thread parks on the job like any submit_blocking
    // caller. Other connections keep serving on their own threads.
    let sealed = session.seal().and_then(|handle| handle.wait());
    tenants.drain(tenant, fed);
    match sealed {
        Ok(res) => Reply::result(res.backend, &res.output),
        Err(e) => Reply::from_service_error(e, tenants, tenant),
    }
}

/// Decode + charge + submit for the one-shot verbs (`MERGE`, `COMPACT`,
/// `SORT`): `decode` yields the element count (for the quota charge)
/// and the job. The charge is held until the job completes — one-shot
/// payloads are in-flight tenant bytes exactly like session feeds.
fn verb_one_shot<'p, R, F>(
    payload: &'p [u8],
    svc: &Arc<MergeService<R>>,
    tenants: &TenantRegistry,
    tenant: &Arc<TenantState>,
    decode: F,
) -> Reply
where
    R: WireRecord,
    F: FnOnce(&mut Cursor<'p>) -> crate::Result<(usize, JobKind<R>)>,
{
    let mut c = Cursor::new(payload);
    let (elems, kind) = match decode(&mut c) {
        Ok(d) => d,
        Err(e) => return Reply::Err(err::PROTOCOL, e.to_string()),
    };
    let bytes = (elems * std::mem::size_of::<R>()) as u64;
    if let Err(msg) = tenants.try_charge(tenant, bytes) {
        return Reply::Busy(msg);
    }
    let result = svc.submit(kind).and_then(|handle| handle.wait());
    tenants.drain(tenant, bytes);
    match result {
        Ok(res) => Reply::result(res.backend, &res.output),
        Err(e) => Reply::from_service_error(e, tenants, tenant),
    }
}
