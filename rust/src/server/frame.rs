//! Wire codec: length-prefixed frames with varint lengths, verb/reply
//! tags, and typed-record payload encoding over the [`Record`] trait.
//!
//! Frame layout (both directions):
//!
//! ```text
//! ┌────────┬──────────────────┬───────────────────────────┐
//! │ tag u8 │ len varint (LEB) │ payload: len bytes        │
//! └────────┴──────────────────┴───────────────────────────┘
//! ```
//!
//! Integers inside payloads are unsigned LEB128 varints; records are
//! fixed-width little-endian via [`WireRecord`], always prefixed by
//! their count. The declared `len` is checked against the decoder's
//! configured cap (`serve.max_frame_bytes`) *before* any allocation or
//! payload read, and record counts are checked against the actual
//! remaining payload bytes before a vector is reserved — a malformed
//! or hostile frame can never make the decoder over-allocate.
//!
//! Payload-level failures (unknown verb, record count overrunning the
//! payload, unsorted chunks) leave the stream at a frame boundary, so
//! the connection answers with a typed [`tag::ERR`] frame and keeps
//! serving. Header-level failures (truncated header, varint overflow,
//! oversized declared length) desynchronize the stream: the connection
//! answers with an error frame and closes.

use crate::record::Record;
use crate::{Error, Result};
use std::io::{Read, Write};
use std::time::{Duration, Instant};

/// Protocol version carried in `HELLO` (bumped on incompatible layout
/// changes).
pub const PROTOCOL_VERSION: u64 = 1;

/// Frame tags. Requests are `0x01..=0x7f`, replies have the high bit
/// set. The numeric values are the wire contract — append, never
/// renumber.
pub mod tag {
    /// Connection preamble: `[version][wire_id][tenant utf8…]`.
    pub const HELLO: u8 = 0x01;
    /// Heartbeat / liveness probe (empty payload).
    pub const PING: u8 = 0x02;
    /// Stats snapshot request (empty payload).
    pub const STATS: u8 = 0x03;
    /// `OPEN k`: open a streaming compaction of `k` runs.
    pub const OPEN: u8 = 0x04;
    /// `FEED session run chunk`: one sorted chunk for an open session.
    pub const FEED: u8 = 0x05;
    /// `SEAL_RUN session run`: the run will receive no more chunks.
    pub const SEAL_RUN: u8 = 0x06;
    /// `SEAL session`: finish the session, reply with the merged output.
    pub const SEAL: u8 = 0x07;
    /// One-shot pairwise merge: `[a records][b records]`.
    pub const MERGE: u8 = 0x08;
    /// One-shot k-way compaction: `[k][k × records]`.
    pub const COMPACT: u8 = 0x09;
    /// One-shot sort: `[records]`.
    pub const SORT: u8 = 0x0a;
    /// Store ingest/drain: `[records]`. Non-empty spills the sorted
    /// run to level 0 of the attached store (`JobKind::Spill`; the
    /// `RESULT` echoes the records with backend `"store-spill"`);
    /// empty drives compaction passes until the store is within policy
    /// (`JobKind::Flush`; empty `RESULT`, backend `"store-flush"`).
    pub const FLUSH: u8 = 0x0b;
    /// Store description request (empty payload); answered with
    /// `STATS_TEXT`, or a `STATE` error when no store is attached.
    pub const STORE_STATS: u8 = 0x0c;

    /// `HELLO` accepted: `[version]`.
    pub const HELLO_OK: u8 = 0x81;
    /// `PING` reply (empty payload).
    pub const PONG: u8 = 0x82;
    /// Stats text (utf8).
    pub const STATS_TEXT: u8 = 0x83;
    /// Session opened: `[session id]`.
    pub const OPENED: u8 = 0x84;
    /// Generic acknowledgement (empty payload).
    pub const OK: u8 = 0x85;
    /// Merged output: `[backend utf8 (len-prefixed)][records]`.
    pub const RESULT: u8 = 0x86;
    /// Typed error: `[code u8][message utf8…]`. See [`super::err`].
    pub const ERR: u8 = 0x87;
    /// Fail-fast admission rejection (quota/budget/back-pressure):
    /// `[message utf8…]`. Not an error in the protocol sense — the
    /// connection and its sessions stay usable; retry later.
    pub const BUSY: u8 = 0x88;
}

/// Error codes carried in [`tag::ERR`] payloads.
pub mod err {
    /// Malformed frame (header or payload failed to decode). The
    /// connection closes after this when the stream desynchronized.
    pub const PROTOCOL: u8 = 1;
    /// Unknown verb tag (the frame itself was well-formed; the
    /// connection keeps serving).
    pub const UNKNOWN_VERB: u8 = 2;
    /// Input violated a documented precondition (unsorted chunk, bad
    /// run index). The session and connection stay usable.
    pub const INVALID_INPUT: u8 = 3;
    /// Protocol-state violation (verb before `HELLO`, unknown session
    /// id, sealed run).
    pub const STATE: u8 = 4;
    /// Version or record-type mismatch at `HELLO`.
    pub const UNSUPPORTED: u8 = 5;
    /// Server-side failure executing an admitted job.
    pub const INTERNAL: u8 = 6;
}

/// Fixed-width little-endian wire encoding for a record type. The
/// server and client agree on the record type at `HELLO` time via
/// [`WireRecord::WIRE_ID`]; the payload bytes then carry exactly
/// [`WireRecord::WIRE_BYTES`] per record.
///
/// Implemented for the scalar keys the engine serves plus the
/// `(key, payload)` pairs of the typed-record API. The `decode`
/// contract mirrors `encode`: `bytes` is exactly `WIRE_BYTES` long.
pub trait WireRecord: Record {
    /// Stable identifier of this encoding (part of the wire contract).
    const WIRE_ID: u32;
    /// Encoded width of one record in bytes.
    const WIRE_BYTES: usize;
    /// Append the little-endian encoding to `buf`.
    fn encode(&self, buf: &mut Vec<u8>);
    /// Decode from exactly [`Self::WIRE_BYTES`](Self::WIRE_BYTES) bytes.
    fn decode(bytes: &[u8]) -> Self;
}

macro_rules! scalar_wire {
    ($($t:ty => $id:expr),* $(,)?) => {$(
        impl WireRecord for $t {
            const WIRE_ID: u32 = $id;
            const WIRE_BYTES: usize = std::mem::size_of::<$t>();
            #[inline]
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
            #[inline]
            fn decode(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("caller sized the slice"))
            }
        }
    )*};
}

scalar_wire!(i32 => 1, u32 => 2, i64 => 3, u64 => 4);

macro_rules! pair_wire {
    ($($k:ty, $v:ty => $id:expr),* $(,)?) => {$(
        impl WireRecord for ($k, $v) {
            const WIRE_ID: u32 = $id;
            const WIRE_BYTES: usize =
                std::mem::size_of::<$k>() + std::mem::size_of::<$v>();
            #[inline]
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.0.to_le_bytes());
                buf.extend_from_slice(&self.1.to_le_bytes());
            }
            #[inline]
            fn decode(bytes: &[u8]) -> Self {
                let k = std::mem::size_of::<$k>();
                (
                    <$k>::from_le_bytes(bytes[..k].try_into().expect("sized")),
                    <$v>::from_le_bytes(bytes[k..].try_into().expect("sized")),
                )
            }
        }
    )*};
}

pair_wire!(u32, u32 => 5, u64, u64 => 6, i64, i64 => 7);

// ---------------------------------------------------------------------
// Varints and payload building.
// ---------------------------------------------------------------------

/// Append an unsigned LEB128 varint.
pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Append a count-prefixed record slice.
pub fn put_records<R: WireRecord>(buf: &mut Vec<u8>, records: &[R]) {
    put_varint(buf, records.len() as u64);
    buf.reserve(records.len() * R::WIRE_BYTES);
    for r in records {
        r.encode(buf);
    }
}

/// Append a length-prefixed utf8 string.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

/// Sequential payload reader with bounds-checked primitives. Every
/// getter fails loudly (never panics, never reads past the payload),
/// which is what lets the connection answer malformed payloads with a
/// typed error frame instead of dying.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    /// Read from the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::InvalidInput(format!(
                "payload truncated: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// One byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Unsigned LEB128 varint (≤ 10 bytes).
    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let byte = self.get_u8()?;
            v |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                // Reject non-canonical overlong encodings of the top
                // group (bits that would shift past 64).
                if shift == 63 && byte > 1 {
                    break;
                }
                return Ok(v);
            }
        }
        Err(Error::InvalidInput("varint overflows u64".into()))
    }

    /// Count-prefixed record slice. The count is validated against the
    /// bytes actually present *before* any allocation.
    pub fn get_records<R: WireRecord>(&mut self) -> Result<Vec<R>> {
        let n = self.get_varint()? as usize;
        let need = n
            .checked_mul(R::WIRE_BYTES)
            .ok_or_else(|| Error::InvalidInput("record count overflows".into()))?;
        if need > self.remaining() {
            return Err(Error::InvalidInput(format!(
                "record count {n} needs {need} bytes, payload has {}",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(R::decode(self.take(R::WIRE_BYTES)?));
        }
        Ok(out)
    }

    /// Length-prefixed utf8 string.
    pub fn get_str(&mut self) -> Result<String> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() {
            return Err(Error::InvalidInput(format!(
                "string length {n} exceeds payload ({} left)",
                self.remaining()
            )));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::InvalidInput("string is not utf8".into()))
    }

    /// Everything left, as utf8 (messages, tenant names).
    pub fn rest_str(&mut self) -> Result<String> {
        let rest = self.take(self.remaining())?;
        String::from_utf8(rest.to_vec())
            .map_err(|_| Error::InvalidInput("trailing bytes are not utf8".into()))
    }
}

// ---------------------------------------------------------------------
// Frame I/O.
// ---------------------------------------------------------------------

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// Peer closed cleanly at a frame boundary (not an error).
    Closed,
    /// Peer closed mid-frame (half-written frame then hangup).
    Eof,
    /// No bytes arrived within the idle limit (lease expiry).
    TimedOut,
    /// Cooperative stop flag was raised while waiting.
    Stopped,
    /// Varint header overflowed.
    Varint,
    /// Declared payload length exceeds the configured cap. Carries the
    /// declared length; the payload was neither allocated nor read.
    TooLarge(u64),
    /// Underlying transport error.
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Eof => write!(f, "connection closed mid-frame"),
            FrameError::TimedOut => write!(f, "no frame within the idle limit"),
            FrameError::Stopped => write!(f, "server stopping"),
            FrameError::Varint => write!(f, "frame length varint overflows"),
            FrameError::TooLarge(n) => write!(f, "declared payload of {n} bytes exceeds cap"),
            FrameError::Io(e) => write!(f, "frame i/o: {e}"),
        }
    }
}

/// Read-loop policy: how long silence may last and when to give up.
/// The underlying socket's read timeout provides the polling
/// granularity; this struct decides what a timeout *means*.
#[derive(Default)]
pub struct ReadOpts<'a> {
    /// Maximum silent gap (no bytes arriving) before the read fails
    /// with [`FrameError::TimedOut`] — the lease. `None` waits forever.
    pub idle: Option<Duration>,
    /// Checked whenever the socket read times out; `true` aborts with
    /// [`FrameError::Stopped`].
    pub stop: Option<&'a std::sync::atomic::AtomicBool>,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Fill `buf` completely, tolerating socket read timeouts up to the
/// idle limit. Progress resets the idle clock — the lease bounds
/// *silence*, not total transfer time.
fn read_full(
    r: &mut impl Read,
    buf: &mut [u8],
    opts: &ReadOpts<'_>,
    last_progress: &mut Instant,
) -> std::result::Result<(), FrameError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => return Err(FrameError::Eof),
            Ok(n) => {
                off += n;
                *last_progress = Instant::now();
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if let Some(stop) = opts.stop {
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        return Err(FrameError::Stopped);
                    }
                }
                if let Some(idle) = opts.idle {
                    if last_progress.elapsed() > idle {
                        return Err(FrameError::TimedOut);
                    }
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame: `(tag, payload)`. `cap` bounds the pre-read payload
/// allocation (`serve.max_frame_bytes`); a frame declaring more fails
/// with [`FrameError::TooLarge`] before any allocation. A clean close
/// at a frame boundary is [`FrameError::Closed`]; mid-frame close is
/// [`FrameError::Eof`].
pub fn read_frame(
    r: &mut impl Read,
    cap: usize,
    opts: &ReadOpts<'_>,
) -> std::result::Result<(u8, Vec<u8>), FrameError> {
    let mut last_progress = Instant::now();
    // Tag byte — the only read where EOF means a clean close.
    let mut tag = [0u8; 1];
    loop {
        match r.read(&mut tag) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => {
                last_progress = Instant::now();
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if let Some(stop) = opts.stop {
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        return Err(FrameError::Stopped);
                    }
                }
                if let Some(idle) = opts.idle {
                    if last_progress.elapsed() > idle {
                        return Err(FrameError::TimedOut);
                    }
                }
            }
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    // Length varint, byte by byte.
    let mut len: u64 = 0;
    let mut shift = 0u32;
    loop {
        let mut b = [0u8; 1];
        read_full(r, &mut b, opts, &mut last_progress)?;
        len |= u64::from(b[0] & 0x7f) << shift;
        if b[0] & 0x80 == 0 {
            break;
        }
        shift += 7;
        if shift >= 64 {
            return Err(FrameError::Varint);
        }
    }
    if len > cap as u64 {
        return Err(FrameError::TooLarge(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_full(r, &mut payload, opts, &mut last_progress)?;
    Ok((tag[0], payload))
}

/// Write one frame (single `write_all` of header + payload).
pub fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    let mut frame = Vec::with_capacity(1 + 10 + payload.len());
    frame.push(tag);
    put_varint(&mut frame, payload.len() as u64);
    frame.extend_from_slice(payload);
    w.write_all(&frame)?;
    w.flush()
}

/// Build and write a typed [`tag::ERR`] frame.
pub fn write_err(w: &mut impl Write, code: u8, msg: &str) -> std::io::Result<()> {
    let mut payload = Vec::with_capacity(1 + msg.len());
    payload.push(code);
    payload.extend_from_slice(msg.as_bytes());
    write_frame(w, tag::ERR, &payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_round_trip() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert!(buf.len() <= 10);
            assert_eq!(Cursor::new(&buf).get_varint().unwrap(), v, "v={v}");
        }
        // Canonical single-byte values stay single-byte.
        let mut buf = Vec::new();
        put_varint(&mut buf, 5);
        assert_eq!(buf, vec![5]);
    }

    #[test]
    fn varint_overflow_rejected() {
        // 11 continuation bytes can never terminate within u64.
        let buf = [0xffu8; 11];
        assert!(Cursor::new(&buf).get_varint().is_err());
    }

    #[test]
    fn records_round_trip_scalar_and_pair() {
        let recs = vec![-5i32, 0, 7, i32::MAX];
        let mut buf = Vec::new();
        put_records(&mut buf, &recs);
        assert_eq!(Cursor::new(&buf).get_records::<i32>().unwrap(), recs);

        let pairs = vec![(1u64, 99u64), (u64::MAX, 0)];
        let mut buf = Vec::new();
        put_records(&mut buf, &pairs);
        assert_eq!(Cursor::new(&buf).get_records::<(u64, u64)>().unwrap(), pairs);
        assert_eq!(<(u64, u64) as WireRecord>::WIRE_BYTES, 16);
        assert_ne!(<i32 as WireRecord>::WIRE_ID, <(u64, u64) as WireRecord>::WIRE_ID);
    }

    #[test]
    fn record_count_checked_before_allocation() {
        // Declares 2^40 records but carries 4 bytes: must error, not
        // reserve a terabyte.
        let mut buf = Vec::new();
        put_varint(&mut buf, 1u64 << 40);
        buf.extend_from_slice(&[0, 0, 0, 0]);
        assert!(Cursor::new(&buf).get_records::<i32>().is_err());
        // Count × width overflow is caught too.
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX);
        assert!(Cursor::new(&buf).get_records::<(u64, u64)>().is_err());
    }

    #[test]
    fn strings_round_trip() {
        let mut buf = Vec::new();
        put_str(&mut buf, "native-kway");
        let mut c = Cursor::new(&buf);
        assert_eq!(c.get_str().unwrap(), "native-kway");
        assert_eq!(c.remaining(), 0);
        // Length past the payload is rejected.
        let mut buf = Vec::new();
        put_varint(&mut buf, 100);
        buf.extend_from_slice(b"short");
        assert!(Cursor::new(&buf).get_str().is_err());
    }

    #[test]
    fn frames_round_trip_over_a_pipe() {
        let mut wire = Vec::new();
        write_frame(&mut wire, tag::OPEN, &[42]).unwrap();
        write_frame(&mut wire, tag::PING, &[]).unwrap();
        let mut r = std::io::Cursor::new(wire);
        let opts = ReadOpts::default();
        let (t, p) = read_frame(&mut r, 1 << 20, &opts).unwrap();
        assert_eq!((t, p.as_slice()), (tag::OPEN, &[42u8][..]));
        let (t, p) = read_frame(&mut r, 1 << 20, &opts).unwrap();
        assert_eq!((t, p.len()), (tag::PING, 0));
        assert!(matches!(
            read_frame(&mut r, 1 << 20, &opts),
            Err(FrameError::Closed)
        ));
    }

    #[test]
    fn oversized_declared_payload_fails_before_allocation() {
        let mut wire = Vec::new();
        wire.push(tag::FEED);
        put_varint(&mut wire, 1 << 40); // declares a terabyte
        let mut r = std::io::Cursor::new(wire);
        match read_frame(&mut r, 1 << 16, &ReadOpts::default()) {
            Err(FrameError::TooLarge(n)) => assert_eq!(n, 1 << 40),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frames_are_eof_not_closed() {
        // Tag only.
        let mut r = std::io::Cursor::new(vec![tag::MERGE]);
        assert!(matches!(
            read_frame(&mut r, 1 << 16, &ReadOpts::default()),
            Err(FrameError::Eof)
        ));
        // Header + partial payload.
        let mut wire = Vec::new();
        wire.push(tag::MERGE);
        put_varint(&mut wire, 100);
        wire.extend_from_slice(&[1, 2, 3]);
        let mut r = std::io::Cursor::new(wire);
        assert!(matches!(
            read_frame(&mut r, 1 << 16, &ReadOpts::default()),
            Err(FrameError::Eof)
        ));
    }

    #[test]
    fn length_varint_overflow_detected() {
        let mut wire = vec![tag::MERGE];
        wire.extend_from_slice(&[0xff; 11]);
        let mut r = std::io::Cursor::new(wire);
        assert!(matches!(
            read_frame(&mut r, 1 << 16, &ReadOpts::default()),
            Err(FrameError::Varint)
        ));
    }
}
