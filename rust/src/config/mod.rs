//! Configuration system: a typed config struct, a TOML-subset parser
//! (the offline image has no serde/toml crates), environment overrides
//! and validation.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (quoted), integer, float and boolean values, `#` comments. This
//! covers everything `mergeflow.toml` needs.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed key-value view of a TOML-subset document: `section.key → raw
/// value`.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section", lineno + 1)));
                }
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value`, got `{line}`",
                    lineno + 1
                )));
            };
            let key = k.trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full_key, unquote(v.trim()).to_string());
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("{key}: `{v}` is not an integer"))),
        }
    }

    /// Typed bool lookup with default.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: `{v}` is not a bool"))),
        }
    }

    /// Typed string lookup with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

pub use crate::mergepath::kernel::MergeKernel;

/// Backend used to execute merge jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native rust Merge Path.
    Native,
    /// AOT-compiled JAX/Pallas kernel via PJRT.
    Xla,
    /// Route by job size: small jobs native, fixed-size batches to XLA.
    Auto,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            "auto" => Ok(Backend::Auto),
            other => Err(Error::Config(format!("unknown backend `{other}`"))),
        }
    }
}

/// Routing policy for the block-swap in-place pairwise merge kernel
/// (`mergepath::inplace`): trades `O(n log n)` comparisons for a peak
/// extra footprint of `min(|A|, |B|)` elements instead of the
/// allocating kernel's `|A| + |B|` output buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum InplaceMode {
    /// Route in-place only when memory pressure warrants it: a
    /// [`memory_budget`](MergeflowConfig::memory_budget) is configured
    /// and the job's allocating-route footprint (~2× its data) would
    /// exceed it. With no budget set, `auto` never routes in-place.
    #[default]
    Auto,
    /// Always merge pairwise jobs in place (benchmarks, memory-bound
    /// deployments).
    Always,
    /// Never use the in-place kernel.
    Never,
}

impl std::str::FromStr for InplaceMode {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(InplaceMode::Auto),
            "always" => Ok(InplaceMode::Always),
            "never" => Ok(InplaceMode::Never),
            other => Err(Error::Config(format!("unknown inplace mode `{other}`"))),
        }
    }
}

/// Full service configuration.
#[derive(Debug, Clone)]
pub struct MergeflowConfig {
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Threads used per merge/sort job.
    pub threads_per_job: usize,
    /// Maximum queued jobs before back-pressure rejects.
    pub queue_capacity: usize,
    /// Dynamic batcher: max jobs per batch.
    pub max_batch: usize,
    /// Dynamic batcher: max wait before dispatching a partial batch (µs).
    pub batch_timeout_us: u64,
    /// Execution backend.
    pub backend: Backend,
    /// Whether the cache-efficient segmented routes (pairwise Alg 3 and
    /// the segmented flat k-way engine) are enabled at all. When
    /// `false`, [`segment_len`](Self::segment_len) and
    /// [`kway_segment_elems`](Self::kway_segment_elems) are inert and
    /// every job takes the unsegmented engines.
    ///
    /// **Migration note:** before the segmented k-way change,
    /// "segmented merging off" was spelled `segment_len = 0`; that
    /// value now means *auto-size* from the cache (unified with
    /// `kway_segment_elems` — both `*_len` knobs read `0 = auto`, off
    /// lives here), exactly the `compact_shard_min_len` →
    /// `compact_sharding` migration pattern. Old configs that relied on
    /// `segment_len = 0` to disable the segmented route must set
    /// `merge.segmented = false` instead.
    pub segmented: bool,
    /// Path-segment length `L` (elements) for the pairwise segmented
    /// merge (Alg 3): a `Merge` job routes segmented when its output
    /// has at least `2·L` elements. **0 means auto**: `C/3` per
    /// Prop. 15, with `C` the configured/detected cache size in
    /// elements (see [`cache_bytes`](Self::cache_bytes)). Disable the
    /// route with [`segmented`](Self::segmented)` = false`.
    pub segment_len: usize,
    /// Path-window length `L` (output elements) for the segmented flat
    /// k-way engine: a `Compact` job within the flat engine's range
    /// routes segmented when its output has at least `2·L` elements,
    /// and the rank-sharded / streamed sub-merges window themselves the
    /// same way. **0 means auto**: `C/(k+1)` — the k-way Prop. 15 pick,
    /// sized per job from its run count `k` — with `C` the
    /// configured/detected cache size in elements. Disable with
    /// [`segmented`](Self::segmented)` = false`.
    pub kway_segment_elems: usize,
    /// Cache capacity (bytes) the auto-sized segment lengths are
    /// derived from. **0 means detect**: the largest data/unified cache
    /// level reported by the OS (`/sys/devices/system/cpu/.../cache`),
    /// falling back to 8 MiB when detection is unavailable. The value
    /// is clamped to `[64 KiB, 1 GiB]` either way.
    pub cache_bytes: usize,
    /// Largest run count `k` served by the flat single-pass k-way merge
    /// engine (`mergepath::kway_path`) — and by the rank-sharded route,
    /// which runs the same per-shard k-way kernel; compactions with
    /// more runs fall back to the pairwise-tree engine.
    ///
    /// **0 means auto-calibrate**: at service start the
    /// [`Calibrator`](crate::coordinator::calibrate) probes the
    /// flat-vs-tree crossover on the host and pins the measured value
    /// (when [`calibrate`](Self::calibrate) is off, 0 falls back to the
    /// modeled default). Any non-zero value pins the knob.
    ///
    /// **Migration note:** before the calibration change, `0` meant
    /// "flat engine off". Spell off as `kway_flat_max_k = 1` now — the
    /// flat, sharded and eager routes all require `k ≥ 2`, so `1`
    /// routes every compaction to the pairwise tree exactly as `0` used
    /// to (the same `0 = auto` convention as `segment_len` and
    /// `compact_shard_min_len`).
    ///
    /// The default comes from the crossover *model* documented in
    /// `docs/ARCHITECTURE.md` §5, anchored by
    /// `benches/kway_flat_vs_tree.rs` runs at `k ≤ 64` (the flat
    /// engine won at every swept k; 128 sits past the sweep but well
    /// below the stream-thrash regime). Set the knob to 0 to let the
    /// calibrator re-derive it per deployment.
    pub kway_flat_max_k: usize,
    /// Whether rank-sharded compaction (`coordinator::shard`) is
    /// enabled at all.
    ///
    /// **Migration note:** before the streaming-ingest change,
    /// "sharding off" was spelled `compact_shard_min_len = 0`; that
    /// value now means *auto-tune* (see
    /// [`compact_shard_min_len`](Self::compact_shard_min_len)). Old
    /// configs that relied on `0` to disable sharding must set
    /// `merge.compact_sharding = false` instead.
    pub compact_sharding: bool,
    /// Minimum output elements per shard of a rank-sharded compaction
    /// (`coordinator::shard`). A `Compact` job whose total output is at
    /// least twice this value — and whose run count is within
    /// `kway_flat_max_k` — is split by output rank into independent
    /// `CompactShard` sub-jobs of roughly this size each (floored at
    /// `threads_per_job` shards, so sharding never reduces a job's
    /// parallelism).
    ///
    /// **0 means auto-tune**: the dispatcher picks
    /// `clamp(total / workers, AUTO_SHARD_FLOOR, u32::MAX)` per job, so
    /// a qualifying compaction splits into about one shard per pool
    /// worker while shards never drop below the measured profitability
    /// floor (`benches/sharded_vs_flat.rs` locates it per machine; the
    /// baked floor is 256 Ki elements). Use
    /// [`compact_sharding`](Self::compact_sharding)` = false` to turn
    /// sharding off entirely.
    pub compact_shard_min_len: usize,
    /// Chunk granularity (elements) used when a one-shot `Compact` job
    /// is re-expressed as a streaming session (`coordinator::session`):
    /// runs longer than this are fed to the dispatcher in chunks of
    /// this size, round-robin across runs, so ingest and eager merging
    /// overlap even for single-call submissions. Also the recommended
    /// feed size for streaming clients. 0 = never split (each run is
    /// fed as one chunk, no copies).
    pub compact_chunk_len: usize,
    /// Eager-start threshold (elements) for streaming compactions: once
    /// the session's sealed-rank frontier has advanced at least this
    /// far past what is already dispatched, the dispatcher cuts and
    /// launches an eager `StreamShard` of exactly this many output
    /// ranks *before* the session seals. 0 disables eager dispatch
    /// (all merging starts at `seal()`).
    pub compact_eager_min_len: usize,
    /// Service-wide memory budget (bytes) for admission control. When
    /// non-zero, `submit`/`feed` reject fail-fast — without poisoning
    /// the service or any open session — whenever the job's estimated
    /// peak working set plus the bytes already resident
    /// (`ServiceStats::resident_bytes`) would exceed this budget. It
    /// also feeds the [`InplaceMode::Auto`] routing decision. **0 means
    /// unlimited** (no admission check, `auto` never routes in-place).
    pub memory_budget: usize,
    /// Routing policy for the in-place pairwise merge kernel; see
    /// [`InplaceMode`]. Parsed from `merge.inplace` =
    /// `"auto"`/`"always"`/`"never"`.
    pub inplace: InplaceMode,
    /// Leaf merge kernel used under every pairwise leaf (per-segment
    /// merges, window merges, the sort's merge tree, two-run
    /// compactions); see [`MergeKernel`]. Parsed from `merge.kernel` =
    /// `"auto"`/`"scalar"`/`"branchless"`/`"hybrid"`/`"simd"`. When not
    /// `auto`, completed jobs that ran the leaf kernel report a
    /// `+<kernel>`-suffixed backend tag so the pin is visible in stats.
    pub kernel: MergeKernel,
    /// Dispatcher shards (`dispatch.shards`): independent dispatcher
    /// threads, each owning a private job queue and session-table
    /// slice; jobs and sessions are routed to a shard by id hash.
    /// **0 means auto**: one shard per ~8 hardware threads, clamped to
    /// `[1, 8]`. `1` reproduces the classic single-dispatcher control
    /// plane bit for bit.
    pub dispatch_shards: usize,
    /// Whether an idle dispatcher shard may steal queued one-shot jobs
    /// from the most loaded peer shard's queue (`dispatch.steal`).
    /// Streaming-session messages are never stolen — a session's
    /// ordering is owned by its home shard. Meaningless with one shard.
    pub dispatch_steal: bool,
    /// Whether the startup [`Calibrator`](crate::coordinator::calibrate)
    /// may resolve `0 = auto-calibrate` knobs
    /// ([`kway_flat_max_k`](Self::kway_flat_max_k),
    /// [`shard_floor`](Self::shard_floor), and the detected cache feeding
    /// [`kway_segment_elems`](Self::kway_segment_elems)) from in-process
    /// probe merges (`dispatch.calibrate`). When `false`, those knobs
    /// fall back to their modeled defaults instead. Probes run once per
    /// process and are cached.
    pub calibrate: bool,
    /// Profitability floor (elements) for auto-sized rank shards: when
    /// [`compact_shard_min_len`](Self::compact_shard_min_len)` = 0`,
    /// the per-job shard size is `clamp(total / workers, shard_floor,
    /// u32::MAX)` (`dispatch.shard_floor`). **0 means auto-calibrate**
    /// from the measured merge rate (shards below the floor would spend
    /// more time on dispatch than merging); the default pins the
    /// modeled 256 Ki-element floor that `benches/sharded_vs_flat.rs`
    /// locates per machine.
    pub shard_floor: usize,
    /// Directory holding AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for MergeflowConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            threads_per_job: 4,
            queue_capacity: 1024,
            max_batch: 32,
            batch_timeout_us: 200,
            backend: Backend::Native,
            segmented: true,
            segment_len: 0,
            kway_segment_elems: 0,
            cache_bytes: 0,
            kway_flat_max_k: 128,
            compact_sharding: true,
            compact_shard_min_len: 2 << 20,
            compact_chunk_len: 1 << 20,
            compact_eager_min_len: 1 << 20,
            memory_budget: 0,
            inplace: InplaceMode::Auto,
            kernel: MergeKernel::Auto,
            dispatch_shards: 0,
            dispatch_steal: true,
            calibrate: true,
            shard_floor: 1 << 18,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl MergeflowConfig {
    /// Build from a parsed raw config.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            workers: raw.get_usize("service.workers", d.workers)?,
            threads_per_job: raw.get_usize("service.threads_per_job", d.threads_per_job)?,
            queue_capacity: raw.get_usize("service.queue_capacity", d.queue_capacity)?,
            max_batch: raw.get_usize("batcher.max_batch", d.max_batch)?,
            batch_timeout_us: raw.get_usize("batcher.timeout_us", d.batch_timeout_us as usize)?
                as u64,
            backend: raw.get_str("service.backend", "native").parse()?,
            segmented: raw.get_bool("merge.segmented", d.segmented)?,
            segment_len: raw.get_usize("merge.segment_len", d.segment_len)?,
            kway_segment_elems: raw
                .get_usize("merge.kway_segment_elems", d.kway_segment_elems)?,
            cache_bytes: raw.get_usize("merge.cache_bytes", d.cache_bytes)?,
            kway_flat_max_k: raw.get_usize("merge.kway_flat_max_k", d.kway_flat_max_k)?,
            compact_sharding: raw.get_bool("merge.compact_sharding", d.compact_sharding)?,
            compact_shard_min_len: raw
                .get_usize("merge.compact_shard_min_len", d.compact_shard_min_len)?,
            compact_chunk_len: raw.get_usize("merge.compact_chunk_len", d.compact_chunk_len)?,
            compact_eager_min_len: raw
                .get_usize("merge.compact_eager_min_len", d.compact_eager_min_len)?,
            memory_budget: raw.get_usize("merge.memory_budget", d.memory_budget)?,
            inplace: raw.get_str("merge.inplace", "auto").parse()?,
            kernel: raw.get_str("merge.kernel", "auto").parse()?,
            dispatch_shards: raw.get_usize("dispatch.shards", d.dispatch_shards)?,
            dispatch_steal: raw.get_bool("dispatch.steal", d.dispatch_steal)?,
            calibrate: raw.get_bool("dispatch.calibrate", d.calibrate)?,
            shard_floor: raw.get_usize("dispatch.shard_floor", d.shard_floor)?,
            artifacts_dir: raw.get_str("service.artifacts_dir", &d.artifacts_dir),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a TOML file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_raw(&RawConfig::from_file(path)?)
    }

    /// Cache capacity in *elements of `elem_bytes` each* that the
    /// segmented routes size their windows from:
    /// [`cache_bytes`](Self::cache_bytes) when configured, the detected
    /// cache otherwise (see [`detected_cache_bytes`]).
    pub fn cache_elems(&self, elem_bytes: usize) -> usize {
        let bytes = if self.cache_bytes > 0 {
            self.cache_bytes.clamp(CACHE_BYTES_MIN, CACHE_BYTES_MAX)
        } else {
            detected_cache_bytes()
        };
        (bytes / elem_bytes.max(1)).max(6)
    }

    /// Effective pairwise path-segment length for records of
    /// `elem_bytes` bytes: the configured
    /// [`segment_len`](Self::segment_len), or `C/3` (Prop. 15, via
    /// [`SegmentedConfig::for_cache`](crate::mergepath::SegmentedConfig::for_cache))
    /// when auto. The pairwise engine's windows are *cooperative* — all
    /// of a job's threads work inside one window — so the whole cache
    /// budget goes to that job's one live window set; this is the
    /// paper's Prop. 15 sizing verbatim. It is a **per-job** budget:
    /// when several large segmented `Merge` jobs run concurrently their
    /// window sets compete for the same cache (the paper sizes a single
    /// merge). Operators running many concurrent large merges should
    /// lower [`cache_bytes`](Self::cache_bytes) or pin `segment_len`
    /// accordingly — the k-way auto sizing divides by the walker count
    /// instead because its walkers are *always* concurrent, even within
    /// one job. **0 means the segmented route is disabled**
    /// ([`segmented`](Self::segmented)` = false`).
    pub fn effective_segment_len(&self, elem_bytes: usize) -> usize {
        if !self.segmented {
            return 0;
        }
        if self.segment_len > 0 {
            return self.segment_len;
        }
        crate::mergepath::SegmentedConfig::for_cache(self.cache_elems(elem_bytes), 1)
            .segment_len
    }

    /// Effective k-way path-window length for a compaction of `k` runs
    /// of `elem_bytes`-byte records: the configured
    /// [`kway_segment_elems`](Self::kway_segment_elems), or — when auto
    /// — `(C/w)/(k+1)`, the k-way Prop. 15 pick (via
    /// [`KwaySegmentedConfig::for_cache`](crate::mergepath::KwaySegmentedConfig::for_cache))
    /// applied to a **per-walker share** of the cache. Unlike the
    /// pairwise engine, the segmented k-way engine windows each
    /// thread's rank segment *independently* (and rank/stream shards
    /// window concurrently on separate workers), so up to
    /// `w = max(workers, threads_per_job)` window sets are live at
    /// once; dividing `C` by `w` keeps their combined footprint within
    /// the cache instead of `w×` over it. **0 means the segmented
    /// route is disabled** ([`segmented`](Self::segmented)` = false`).
    pub fn effective_kway_segment_elems(&self, elem_bytes: usize, k: usize) -> usize {
        if !self.segmented {
            return 0;
        }
        if self.kway_segment_elems > 0 {
            return self.kway_segment_elems;
        }
        let walkers = self.workers.max(self.threads_per_job).max(1);
        crate::mergepath::KwaySegmentedConfig::for_cache(
            self.cache_elems(elem_bytes) / walkers,
            k,
            1,
        )
        .segment_elems
    }

    /// Resolved dispatcher shard count:
    /// [`dispatch_shards`](Self::dispatch_shards) when non-zero, else
    /// one shard per ~8 hardware threads (a dispatcher shard is pure
    /// control plane — it plans and hands off, so a few keep many
    /// workers fed), clamped to `[1, 8]` so small hosts get the classic
    /// single dispatcher and huge ones don't burn cores on idle pollers.
    pub fn effective_dispatch_shards(&self) -> usize {
        if self.dispatch_shards > 0 {
            return self.dispatch_shards;
        }
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        (cores / 8).clamp(1, 8)
    }

    /// Whether a pairwise merge over `total_bytes` of input should take
    /// the in-place route. `Auto` routes in-place exactly when a
    /// [`memory_budget`](Self::memory_budget) is set and the allocating
    /// route's ~2× footprint (input + full output buffer) would not fit
    /// in it — i.e. in-place is the lever that keeps the job admissible
    /// under the budget.
    pub fn inplace_route(&self, total_bytes: usize) -> bool {
        match self.inplace {
            InplaceMode::Never => false,
            InplaceMode::Always => true,
            InplaceMode::Auto => {
                self.memory_budget > 0 && 2usize.saturating_mul(total_bytes) > self.memory_budget
            }
        }
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("service.workers must be >= 1".into()));
        }
        if self.threads_per_job == 0 {
            return Err(Error::Config("service.threads_per_job must be >= 1".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("service.queue_capacity must be >= 1".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("batcher.max_batch must be >= 1".into()));
        }
        // Each shard is a live thread; 256 matches the shard::MAX_SHARDS
        // sanity bound and stops a typo'd value from spawning thousands.
        if self.dispatch_shards > 256 {
            return Err(Error::Config("dispatch.shards must be <= 256 (0 = auto)".into()));
        }
        Ok(())
    }
}

/// Wire-server configuration (`[serve]` section). Kept separate from
/// [`MergeflowConfig`] — the engine knows nothing about sockets, and
/// embedded users of the library never pay for (or spell) these knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address (`serve.listen`): `host:port` for TCP, or
    /// `unix:/path/to.sock` for a Unix domain socket. Port 0 binds an
    /// ephemeral port (tests/loopback).
    pub listen: String,
    /// Per-tenant cap (bytes) on ingest held live on the tenant's
    /// behalf — open-session feeds plus in-flight one-shot payloads
    /// (`serve.tenant_quota_bytes`). Exceeding it gets a fail-fast
    /// `BUSY` reply, layered *on top of* the service-wide
    /// `merge.memory_budget`. **0 means unlimited.**
    pub tenant_quota_bytes: usize,
    /// Per-tenant cap on concurrently open streaming sessions
    /// (`serve.tenant_max_sessions`); `OPEN` past it gets `BUSY`.
    /// **0 means unlimited.**
    pub tenant_max_sessions: usize,
    /// Connection lease (`serve.lease_ms`): the longest a client may go
    /// without delivering bytes — any frame is a heartbeat, `PING` is
    /// the no-op one — before the server reaps the connection, aborting
    /// its open sessions and draining their `resident_bytes`. **0
    /// disables lease reaping** (connections live until they close).
    pub lease_ms: u64,
    /// Largest frame payload the decoder will accept
    /// (`serve.max_frame_bytes`). This caps the decoder's pre-read
    /// allocation: a frame *declaring* more than this is answered with
    /// a typed error frame without ever allocating or reading its
    /// payload.
    pub max_frame_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7141".into(),
            tenant_quota_bytes: 0,
            tenant_max_sessions: 0,
            lease_ms: 10_000,
            max_frame_bytes: 64 << 20,
        }
    }
}

impl ServerConfig {
    /// Build from a parsed raw config (`[serve]` section).
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            listen: raw.get_str("serve.listen", &d.listen),
            tenant_quota_bytes: raw
                .get_usize("serve.tenant_quota_bytes", d.tenant_quota_bytes)?,
            tenant_max_sessions: raw
                .get_usize("serve.tenant_max_sessions", d.tenant_max_sessions)?,
            lease_ms: raw.get_usize("serve.lease_ms", d.lease_ms as usize)? as u64,
            max_frame_bytes: raw.get_usize("serve.max_frame_bytes", d.max_frame_bytes)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            return Err(Error::Config("serve.listen must not be empty".into()));
        }
        // Below this even a HELLO with a modest tenant name cannot fit,
        // and a tiny cap would make every well-formed frame "oversized".
        if self.max_frame_bytes < 64 {
            return Err(Error::Config("serve.max_frame_bytes must be >= 64".into()));
        }
        Ok(())
    }
}

/// Level-scoring policy of the persistent store's background
/// compaction scheduler (`store.policy`); see
/// [`store::scheduler`](crate::store::scheduler) for the exact
/// semantics of each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StorePolicy {
    /// Merge a whole level into one run of the next level once it
    /// holds its run threshold. Write-optimized.
    #[default]
    Tiered,
    /// Score levels against an exponentially growing run limit and
    /// merge a bounded slice of the worst level (plus the next level's
    /// overlapping runs) downward. Read-optimized.
    Leveled,
}

impl std::str::FromStr for StorePolicy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "tiered" => Ok(StorePolicy::Tiered),
            "leveled" => Ok(StorePolicy::Leveled),
            other => Err(Error::Config(format!("unknown store policy `{other}`"))),
        }
    }
}

impl std::fmt::Display for StorePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            StorePolicy::Tiered => "tiered",
            StorePolicy::Leveled => "leveled",
        })
    }
}

/// Persistent run store configuration (`[store]` section). Separate
/// from [`MergeflowConfig`] for the same reason [`ServerConfig`] is:
/// the merge engine knows nothing about disks, and embedded users who
/// never spill never spell these knobs.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Store directory (`store.dir`). **Empty means the store is
    /// disabled** — `mergeflow serve` then runs RAM-only exactly as
    /// before, and `FLUSH`/`STORE_STATS` answer with a typed `STATE`
    /// error.
    pub dir: String,
    /// Level-scoring policy (`store.policy`): `"tiered"` (default) or
    /// `"leveled"`; see [`StorePolicy`].
    pub policy: StorePolicy,
    /// Spilled (level-0) runs tolerated before the scheduler compacts
    /// (`store.level0_max_runs`). Must be ≥ 2.
    pub level0_max_runs: usize,
    /// Growth factor between level run limits, and the per-pass input
    /// fan-in of `leveled` compactions (`store.level_fanout`). Must be
    /// ≥ 2.
    pub level_fanout: usize,
    /// Payload bytes per CRC-checked block in run files
    /// (`store.block_bytes`) — also the granularity at which store
    /// readers feed compaction sessions, so it bounds per-run residency
    /// during a disk compaction. Must be ≥ 64.
    pub block_bytes: usize,
    /// Scheduler sleep between idle/rejected passes
    /// (`store.compact_backoff_ms`).
    pub compact_backoff_ms: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            dir: String::new(),
            policy: StorePolicy::Tiered,
            level0_max_runs: 4,
            level_fanout: 8,
            block_bytes: 256 << 10,
            compact_backoff_ms: 50,
        }
    }
}

impl StoreConfig {
    /// Build from a parsed raw config (`[store]` section).
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            dir: raw.get_str("store.dir", &d.dir),
            policy: raw.get_str("store.policy", "tiered").parse()?,
            level0_max_runs: raw.get_usize("store.level0_max_runs", d.level0_max_runs)?,
            level_fanout: raw.get_usize("store.level_fanout", d.level_fanout)?,
            block_bytes: raw.get_usize("store.block_bytes", d.block_bytes)?,
            compact_backoff_ms: raw
                .get_usize("store.compact_backoff_ms", d.compact_backoff_ms as usize)?
                as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Whether a store directory is configured at all.
    pub fn enabled(&self) -> bool {
        !self.dir.is_empty()
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.level0_max_runs < 2 {
            return Err(Error::Config("store.level0_max_runs must be >= 2".into()));
        }
        if self.level_fanout < 2 {
            return Err(Error::Config("store.level_fanout must be >= 2".into()));
        }
        if self.block_bytes < 64 {
            return Err(Error::Config("store.block_bytes must be >= 64".into()));
        }
        Ok(())
    }
}

/// Bounds applied to both configured and detected cache sizes, so a
/// misread sysfs entry (or an absurd knob) can never produce degenerate
/// or overflowing window lengths.
const CACHE_BYTES_MIN: usize = 64 << 10;
const CACHE_BYTES_MAX: usize = 1 << 30;
/// Assumed last-level cache when detection is unavailable (a
/// conservative modern-server L3 slice).
const CACHE_BYTES_FALLBACK: usize = 8 << 20;

/// Byte capacity of the largest data/unified cache level reported by
/// the OS (Linux sysfs), clamped to `[64 KiB, 1 GiB]`; the 8 MiB
/// fallback when nothing is readable (non-Linux, sandboxes). Detected
/// once and cached for the process — this feeds the `0 = auto` sizing
/// of [`MergeflowConfig::segment_len`] and
/// [`MergeflowConfig::kway_segment_elems`].
pub fn detected_cache_bytes() -> usize {
    use std::sync::OnceLock;
    static DETECTED: OnceLock<usize> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        sysfs_largest_cache()
            .unwrap_or(CACHE_BYTES_FALLBACK)
            .clamp(CACHE_BYTES_MIN, CACHE_BYTES_MAX)
    })
}

/// Scan `/sys/devices/system/cpu/cpu0/cache/index*` for the largest
/// `Data`/`Unified` level. Returns `None` when the tree is missing or
/// unparsable (the caller falls back).
fn sysfs_largest_cache() -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    let mut largest: Option<usize> = None;
    for entry in std::fs::read_dir(base).ok()? {
        let path = match entry {
            Ok(e) => e.path(),
            Err(_) => continue,
        };
        if !path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.starts_with("index"))
        {
            continue;
        }
        let read = |f: &str| std::fs::read_to_string(path.join(f)).ok();
        let ty = read("type").unwrap_or_default();
        if !matches!(ty.trim(), "Data" | "Unified") {
            continue;
        }
        let Some(bytes) = read("size").and_then(|s| parse_cache_size(s.trim())) else {
            continue;
        };
        largest = Some(largest.map_or(bytes, |l| l.max(bytes)));
    }
    largest
}

/// Parse sysfs cache-size spellings: `32K`, `12288K`, `8M`, plain
/// bytes.
fn parse_cache_size(s: &str) -> Option<usize> {
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1usize << 10),
        b'M' | b'm' => (&s[..s.len() - 1], 1usize << 20),
        b'G' | b'g' => (&s[..s.len() - 1], 1usize << 30),
        _ => (s, 1usize),
    };
    digits.parse::<usize>().ok().map(|v| v.saturating_mul(mult))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# mergeflow sample config
[service]
workers = 8
threads_per_job = 4
backend = "auto"   # route by size
artifacts_dir = "artifacts"

[batcher]
max_batch = 64
timeout_us = 150

[merge]
segmented = true
segment_len = 4096
kway_segment_elems = 2048
cache_bytes = 1048576
kway_flat_max_k = 32
compact_sharding = false
compact_shard_min_len = 65536
compact_chunk_len = 8192
compact_eager_min_len = 16384
memory_budget = 268435456
inplace = "always"
kernel = "branchless"

[dispatch]
shards = 2
steal = false
calibrate = false
shard_floor = 32768

[serve]
listen = "unix:/tmp/mergeflow.sock"
tenant_quota_bytes = 1048576
tenant_max_sessions = 4
lease_ms = 250
max_frame_bytes = 65536

[store]
dir = "/tmp/mergeflow-store"
policy = "leveled"
level0_max_runs = 6
level_fanout = 4
block_bytes = 131072
compact_backoff_ms = 25
"#;

    #[test]
    fn parses_sample() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("service.workers"), Some("8"));
        assert_eq!(raw.get("service.backend"), Some("auto"));
        assert_eq!(raw.get("batcher.max_batch"), Some("64"));
        let cfg = MergeflowConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.backend, Backend::Auto);
        assert!(cfg.segmented);
        assert_eq!(cfg.segment_len, 4096);
        assert_eq!(cfg.kway_segment_elems, 2048);
        assert_eq!(cfg.cache_bytes, 1 << 20);
        assert_eq!(cfg.kway_flat_max_k, 32);
        assert!(!cfg.compact_sharding);
        assert_eq!(cfg.compact_shard_min_len, 65536);
        assert_eq!(cfg.compact_chunk_len, 8192);
        assert_eq!(cfg.compact_eager_min_len, 16384);
        assert_eq!(cfg.memory_budget, 256 << 20);
        assert_eq!(cfg.inplace, InplaceMode::Always);
        assert_eq!(cfg.kernel, MergeKernel::Branchless);
        assert_eq!(cfg.batch_timeout_us, 150);
        assert_eq!(cfg.dispatch_shards, 2);
        assert!(!cfg.dispatch_steal);
        assert!(!cfg.calibrate);
        assert_eq!(cfg.shard_floor, 32768);
    }

    #[test]
    fn dispatch_defaults_and_resolution() {
        let cfg = MergeflowConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.dispatch_shards, 0, "shards default to auto");
        assert!(cfg.dispatch_steal, "stealing defaults to on");
        assert!(cfg.calibrate, "calibration defaults to on");
        assert_eq!(cfg.shard_floor, 1 << 18, "floor defaults to the modeled 256Ki");
        // Auto resolution lands in the documented [1, 8] band; a pinned
        // value passes through verbatim.
        assert!((1..=8).contains(&cfg.effective_dispatch_shards()));
        let pinned = MergeflowConfig { dispatch_shards: 3, ..Default::default() };
        assert_eq!(pinned.effective_dispatch_shards(), 3);
        // The thread-count guard rejects absurd shard counts.
        let raw = RawConfig::parse("[dispatch]\nshards = 1000\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn defaults_apply_when_missing() {
        let cfg = MergeflowConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.workers, MergeflowConfig::default().workers);
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(
            cfg.compact_shard_min_len,
            MergeflowConfig::default().compact_shard_min_len
        );
        assert!(cfg.compact_sharding, "sharding defaults to on");
        assert_eq!(cfg.compact_chunk_len, MergeflowConfig::default().compact_chunk_len);
        assert_eq!(
            cfg.compact_eager_min_len,
            MergeflowConfig::default().compact_eager_min_len
        );
        assert_eq!(cfg.memory_budget, 0, "budget defaults to unlimited");
        assert_eq!(cfg.inplace, InplaceMode::Auto);
        assert_eq!(cfg.kernel, MergeKernel::Auto);
    }

    #[test]
    fn server_config_parses_and_defaults() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let scfg = ServerConfig::from_raw(&raw).unwrap();
        assert_eq!(scfg.listen, "unix:/tmp/mergeflow.sock");
        assert_eq!(scfg.tenant_quota_bytes, 1 << 20);
        assert_eq!(scfg.tenant_max_sessions, 4);
        assert_eq!(scfg.lease_ms, 250);
        assert_eq!(scfg.max_frame_bytes, 64 << 10);
        let d = ServerConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(d.listen, ServerConfig::default().listen);
        assert_eq!(d.tenant_quota_bytes, 0, "quota defaults to unlimited");
        assert_eq!(d.tenant_max_sessions, 0);
        assert_eq!(d.lease_ms, 10_000);
        assert_eq!(d.max_frame_bytes, 64 << 20);
    }

    #[test]
    fn server_config_rejects_bad_values() {
        let raw = RawConfig::parse("[serve]\nlisten = \"\"\n").unwrap();
        assert!(ServerConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[serve]\nmax_frame_bytes = 8\n").unwrap();
        assert!(ServerConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[serve]\nlease_ms = soon\n").unwrap();
        assert!(ServerConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn store_config_parses_and_defaults() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let cfg = StoreConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.dir, "/tmp/mergeflow-store");
        assert!(cfg.enabled());
        assert_eq!(cfg.policy, StorePolicy::Leveled);
        assert_eq!(cfg.level0_max_runs, 6);
        assert_eq!(cfg.level_fanout, 4);
        assert_eq!(cfg.block_bytes, 128 << 10);
        assert_eq!(cfg.compact_backoff_ms, 25);
        let d = StoreConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert!(!d.enabled(), "store defaults to disabled");
        assert_eq!(d.policy, StorePolicy::Tiered);
        assert_eq!(d.level0_max_runs, 4);
        assert_eq!(d.level_fanout, 8);
        assert_eq!(d.block_bytes, 256 << 10);
        assert_eq!(d.compact_backoff_ms, 50);
    }

    #[test]
    fn store_config_rejects_bad_values() {
        let raw = RawConfig::parse("[store]\npolicy = \"sorted\"\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[store]\nlevel0_max_runs = 1\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[store]\nlevel_fanout = 1\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[store]\nblock_bytes = 8\n").unwrap();
        assert!(StoreConfig::from_raw(&raw).is_err());
        // Display/FromStr round-trip.
        assert_eq!(StorePolicy::Tiered.to_string(), "tiered");
        assert_eq!("leveled".parse::<StorePolicy>().unwrap(), StorePolicy::Leveled);
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("[service]\nworkers = zero\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\nworkers = 0\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\nbackend = \"gpu\"\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[merge]\ninplace = \"sometimes\"\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[merge]\nkernel = \"avx512\"\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn inplace_routing_policy() {
        // Auto without a budget never routes in-place.
        let auto = MergeflowConfig::default();
        assert!(!auto.inplace_route(usize::MAX / 4));
        // Auto with a budget routes exactly when 2× data would bust it.
        let budgeted = MergeflowConfig { memory_budget: 1 << 20, ..Default::default() };
        assert!(!budgeted.inplace_route(512 << 10), "2×512Ki fits the 1Mi budget");
        assert!(budgeted.inplace_route((512 << 10) + 1));
        assert!(budgeted.inplace_route(usize::MAX), "no mul overflow");
        // Always / Never override the budget entirely.
        let always = MergeflowConfig { inplace: InplaceMode::Always, ..Default::default() };
        assert!(always.inplace_route(16));
        let never = MergeflowConfig {
            inplace: InplaceMode::Never,
            memory_budget: 1,
            ..Default::default()
        };
        assert!(!never.inplace_route(usize::MAX));
        // FromStr spellings.
        assert_eq!("auto".parse::<InplaceMode>().unwrap(), InplaceMode::Auto);
        assert_eq!("always".parse::<InplaceMode>().unwrap(), InplaceMode::Always);
        assert_eq!("never".parse::<InplaceMode>().unwrap(), InplaceMode::Never);
        assert!("on".parse::<InplaceMode>().is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = RawConfig::parse("key_without_value\n").unwrap_err();
        assert!(err.to_string().contains("line 1"));
        let err = RawConfig::parse("[]\n").unwrap_err();
        assert!(err.to_string().contains("empty section"));
    }

    #[test]
    fn comments_and_quotes() {
        let raw = RawConfig::parse("name = \"a # not comment\" # real comment\n").unwrap();
        assert_eq!(raw.get("name"), Some("a # not comment"));
    }

    #[test]
    fn segmented_auto_sizing_and_off_switch() {
        // Explicit lengths pass through untouched.
        let cfg = MergeflowConfig {
            segment_len: 4096,
            kway_segment_elems: 512,
            ..Default::default()
        };
        assert_eq!(cfg.effective_segment_len(4), 4096);
        assert_eq!(cfg.effective_kway_segment_elems(4, 7), 512);
        // Auto: C/3 pairwise (cooperative windows, full cache budget);
        // (C/w)/(k+1) k-way (w = max(workers, threads_per_job) = 4 on
        // the default config — independent per-thread/per-shard window
        // walkers share the cache).
        let auto = MergeflowConfig { cache_bytes: 1 << 20, ..Default::default() };
        assert_eq!(auto.cache_elems(4), (1 << 20) / 4);
        assert_eq!(auto.effective_segment_len(4), (1 << 20) / 4 / 3);
        assert_eq!(auto.effective_kway_segment_elems(4, 7), (1 << 20) / 4 / 4 / 8);
        // Wider records shrink the element capacity proportionally.
        assert_eq!(auto.cache_elems(16), (1 << 20) / 16);
        // k = 0/1 degenerate divisors floored at 2.
        assert_eq!(auto.effective_kway_segment_elems(4, 0), (1 << 20) / 4 / 4 / 2);
        // More walkers shrink the per-walker window share.
        let wide = MergeflowConfig {
            cache_bytes: 1 << 20,
            workers: 8,
            threads_per_job: 2,
            ..Default::default()
        };
        assert_eq!(wide.effective_kway_segment_elems(4, 7), (1 << 20) / 4 / 8 / 8);
        // merge.segmented = false turns both routes off regardless of
        // the length knobs (the unified off switch).
        let off = MergeflowConfig {
            segmented: false,
            segment_len: 4096,
            kway_segment_elems: 512,
            ..Default::default()
        };
        assert_eq!(off.effective_segment_len(4), 0);
        assert_eq!(off.effective_kway_segment_elems(4, 7), 0);
        // Configured cache bytes are clamped to sane bounds.
        let tiny = MergeflowConfig { cache_bytes: 1, ..Default::default() };
        assert_eq!(tiny.cache_elems(4), (64 << 10) / 4);
        // Detection never reports a degenerate size (clamp + fallback).
        let d = detected_cache_bytes();
        assert!((64 << 10..=1 << 30).contains(&d), "detected {d}");
    }

    #[test]
    fn cache_size_spellings_parse() {
        assert_eq!(parse_cache_size("32K"), Some(32 << 10));
        assert_eq!(parse_cache_size("12288K"), Some(12288 << 10));
        assert_eq!(parse_cache_size("8M"), Some(8 << 20));
        assert_eq!(parse_cache_size("1G"), Some(1 << 30));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("zebra"), None);
    }

    #[test]
    fn backend_parsing() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("tpu".parse::<Backend>().is_err());
    }
}
