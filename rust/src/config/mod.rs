//! Configuration system: a typed config struct, a TOML-subset parser
//! (the offline image has no serde/toml crates), environment overrides
//! and validation.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (quoted), integer, float and boolean values, `#` comments. This
//! covers everything `mergeflow.toml` needs.

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed key-value view of a TOML-subset document: `section.key → raw
/// value`.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section", lineno + 1)));
                }
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value`, got `{line}`",
                    lineno + 1
                )));
            };
            let key = k.trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full_key, unquote(v.trim()).to_string());
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("{key}: `{v}` is not an integer"))),
        }
    }

    /// Typed bool lookup with default.
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") => Ok(true),
            Some("false") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: `{v}` is not a bool"))),
        }
    }

    /// Typed string lookup with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> &str {
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(v)
}

/// Backend used to execute merge jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native rust Merge Path.
    Native,
    /// AOT-compiled JAX/Pallas kernel via PJRT.
    Xla,
    /// Route by job size: small jobs native, fixed-size batches to XLA.
    Auto,
}

impl std::str::FromStr for Backend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "xla" => Ok(Backend::Xla),
            "auto" => Ok(Backend::Auto),
            other => Err(Error::Config(format!("unknown backend `{other}`"))),
        }
    }
}

/// Full service configuration.
#[derive(Debug, Clone)]
pub struct MergeflowConfig {
    /// Worker threads in the coordinator pool.
    pub workers: usize,
    /// Threads used per merge/sort job.
    pub threads_per_job: usize,
    /// Maximum queued jobs before back-pressure rejects.
    pub queue_capacity: usize,
    /// Dynamic batcher: max jobs per batch.
    pub max_batch: usize,
    /// Dynamic batcher: max wait before dispatching a partial batch (µs).
    pub batch_timeout_us: u64,
    /// Execution backend.
    pub backend: Backend,
    /// Segment length for cache-efficient merging (elements); 0 = off.
    pub segment_len: usize,
    /// Largest run count `k` served by the flat single-pass k-way merge
    /// engine (`mergepath::kway_path`) — and by the rank-sharded route,
    /// which runs the same per-shard k-way kernel; compactions with
    /// more runs fall back to the pairwise-tree engine. 0 disables the
    /// flat engine (and sharding with it).
    ///
    /// The default comes from the crossover *model* documented in
    /// `docs/ARCHITECTURE.md` §5, anchored by
    /// `benches/kway_flat_vs_tree.rs` runs at `k ≤ 64` (the flat
    /// engine won at every swept k; 128 sits past the sweep but well
    /// below the stream-thrash regime). Re-derive it per deployment by
    /// running the bench with larger k.
    pub kway_flat_max_k: usize,
    /// Whether rank-sharded compaction (`coordinator::shard`) is
    /// enabled at all.
    ///
    /// **Migration note:** before the streaming-ingest change,
    /// "sharding off" was spelled `compact_shard_min_len = 0`; that
    /// value now means *auto-tune* (see
    /// [`compact_shard_min_len`](Self::compact_shard_min_len)). Old
    /// configs that relied on `0` to disable sharding must set
    /// `merge.compact_sharding = false` instead.
    pub compact_sharding: bool,
    /// Minimum output elements per shard of a rank-sharded compaction
    /// (`coordinator::shard`). A `Compact` job whose total output is at
    /// least twice this value — and whose run count is within
    /// `kway_flat_max_k` — is split by output rank into independent
    /// `CompactShard` sub-jobs of roughly this size each (floored at
    /// `threads_per_job` shards, so sharding never reduces a job's
    /// parallelism).
    ///
    /// **0 means auto-tune**: the dispatcher picks
    /// `clamp(total / workers, AUTO_SHARD_FLOOR, u32::MAX)` per job, so
    /// a qualifying compaction splits into about one shard per pool
    /// worker while shards never drop below the measured profitability
    /// floor (`benches/sharded_vs_flat.rs` locates it per machine; the
    /// baked floor is 256 Ki elements). Use
    /// [`compact_sharding`](Self::compact_sharding)` = false` to turn
    /// sharding off entirely.
    pub compact_shard_min_len: usize,
    /// Chunk granularity (elements) used when a one-shot `Compact` job
    /// is re-expressed as a streaming session (`coordinator::session`):
    /// runs longer than this are fed to the dispatcher in chunks of
    /// this size, round-robin across runs, so ingest and eager merging
    /// overlap even for single-call submissions. Also the recommended
    /// feed size for streaming clients. 0 = never split (each run is
    /// fed as one chunk, no copies).
    pub compact_chunk_len: usize,
    /// Eager-start threshold (elements) for streaming compactions: once
    /// the session's sealed-rank frontier has advanced at least this
    /// far past what is already dispatched, the dispatcher cuts and
    /// launches an eager `StreamShard` of exactly this many output
    /// ranks *before* the session seals. 0 disables eager dispatch
    /// (all merging starts at `seal()`).
    pub compact_eager_min_len: usize,
    /// Directory holding AOT artifacts.
    pub artifacts_dir: String,
}

impl Default for MergeflowConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            threads_per_job: 4,
            queue_capacity: 1024,
            max_batch: 32,
            batch_timeout_us: 200,
            backend: Backend::Native,
            segment_len: 0,
            kway_flat_max_k: 128,
            compact_sharding: true,
            compact_shard_min_len: 2 << 20,
            compact_chunk_len: 1 << 20,
            compact_eager_min_len: 1 << 20,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl MergeflowConfig {
    /// Build from a parsed raw config.
    pub fn from_raw(raw: &RawConfig) -> Result<Self> {
        let d = Self::default();
        let cfg = Self {
            workers: raw.get_usize("service.workers", d.workers)?,
            threads_per_job: raw.get_usize("service.threads_per_job", d.threads_per_job)?,
            queue_capacity: raw.get_usize("service.queue_capacity", d.queue_capacity)?,
            max_batch: raw.get_usize("batcher.max_batch", d.max_batch)?,
            batch_timeout_us: raw.get_usize("batcher.timeout_us", d.batch_timeout_us as usize)?
                as u64,
            backend: raw.get_str("service.backend", "native").parse()?,
            segment_len: raw.get_usize("merge.segment_len", d.segment_len)?,
            kway_flat_max_k: raw.get_usize("merge.kway_flat_max_k", d.kway_flat_max_k)?,
            compact_sharding: raw.get_bool("merge.compact_sharding", d.compact_sharding)?,
            compact_shard_min_len: raw
                .get_usize("merge.compact_shard_min_len", d.compact_shard_min_len)?,
            compact_chunk_len: raw.get_usize("merge.compact_chunk_len", d.compact_chunk_len)?,
            compact_eager_min_len: raw
                .get_usize("merge.compact_eager_min_len", d.compact_eager_min_len)?,
            artifacts_dir: raw.get_str("service.artifacts_dir", &d.artifacts_dir),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a TOML file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_raw(&RawConfig::from_file(path)?)
    }

    /// Check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::Config("service.workers must be >= 1".into()));
        }
        if self.threads_per_job == 0 {
            return Err(Error::Config("service.threads_per_job must be >= 1".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("service.queue_capacity must be >= 1".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("batcher.max_batch must be >= 1".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# mergeflow sample config
[service]
workers = 8
threads_per_job = 4
backend = "auto"   # route by size
artifacts_dir = "artifacts"

[batcher]
max_batch = 64
timeout_us = 150

[merge]
segment_len = 4096
kway_flat_max_k = 32
compact_sharding = false
compact_shard_min_len = 65536
compact_chunk_len = 8192
compact_eager_min_len = 16384
"#;

    #[test]
    fn parses_sample() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("service.workers"), Some("8"));
        assert_eq!(raw.get("service.backend"), Some("auto"));
        assert_eq!(raw.get("batcher.max_batch"), Some("64"));
        let cfg = MergeflowConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.backend, Backend::Auto);
        assert_eq!(cfg.segment_len, 4096);
        assert_eq!(cfg.kway_flat_max_k, 32);
        assert!(!cfg.compact_sharding);
        assert_eq!(cfg.compact_shard_min_len, 65536);
        assert_eq!(cfg.compact_chunk_len, 8192);
        assert_eq!(cfg.compact_eager_min_len, 16384);
        assert_eq!(cfg.batch_timeout_us, 150);
    }

    #[test]
    fn defaults_apply_when_missing() {
        let cfg = MergeflowConfig::from_raw(&RawConfig::parse("").unwrap()).unwrap();
        assert_eq!(cfg.workers, MergeflowConfig::default().workers);
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(
            cfg.compact_shard_min_len,
            MergeflowConfig::default().compact_shard_min_len
        );
        assert!(cfg.compact_sharding, "sharding defaults to on");
        assert_eq!(cfg.compact_chunk_len, MergeflowConfig::default().compact_chunk_len);
        assert_eq!(
            cfg.compact_eager_min_len,
            MergeflowConfig::default().compact_eager_min_len
        );
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("[service]\nworkers = zero\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\nworkers = 0\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\nbackend = \"gpu\"\n").unwrap();
        assert!(MergeflowConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = RawConfig::parse("key_without_value\n").unwrap_err();
        assert!(err.to_string().contains("line 1"));
        let err = RawConfig::parse("[]\n").unwrap_err();
        assert!(err.to_string().contains("empty section"));
    }

    #[test]
    fn comments_and_quotes() {
        let raw = RawConfig::parse("name = \"a # not comment\" # real comment\n").unwrap();
        assert_eq!(raw.get("name"), Some("a # not comment"));
    }

    #[test]
    fn backend_parsing() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("xla".parse::<Backend>().unwrap(), Backend::Xla);
        assert!("tpu".parse::<Backend>().is_err());
    }
}
