//! Thread-confined XLA executor.
//!
//! The `xla` crate's PJRT client is `!Send` (`Rc` internals), so the
//! coordinator cannot share an [`super::XlaRuntime`] across its worker
//! threads. Instead, one dedicated executor thread owns the runtime
//! and serves merge requests over a channel; the [`XlaExecutor`]
//! handle is `Send + Sync` and cheap to clone. This also matches how
//! the CPU PJRT client behaves best (serialized dispatch).

use super::artifact::{ArtifactManifest, ArtifactMeta};
use crate::{Error, Result};
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

enum Req {
    Merge {
        name: String,
        a: Vec<i32>,
        b: Vec<i32>,
        reply: Sender<Result<Vec<i32>>>,
    },
    Shutdown,
}

/// Send+Sync handle to the executor thread.
pub struct XlaExecutor {
    tx: Mutex<Sender<Req>>,
    manifest: ArtifactManifest,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Names whose PJRT compilation has completed. The coordinator's
    /// router only offloads to XLA when the artifact is already warm,
    /// so background warmup never blocks the serving path (§Perf L3).
    compiled: Arc<(Mutex<HashSet<String>>, Condvar)>,
}

impl std::fmt::Debug for XlaExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaExecutor")
            .field("artifacts", &self.manifest.entries().len())
            .finish()
    }
}

impl XlaExecutor {
    /// Start the executor over an artifact directory. Fails if the
    /// manifest is missing or the PJRT client cannot start.
    pub fn start(dir: &Path) -> Result<Arc<Self>> {
        // Parse the manifest on the caller thread (pure file I/O) so
        // `find_for_sizes` never needs a round-trip.
        let manifest = ArtifactManifest::load(&dir.join("manifest.txt"))?;
        let (tx, rx) = channel::<Req>();
        let dir: PathBuf = dir.to_path_buf();
        let compiled: Arc<(Mutex<HashSet<String>>, Condvar)> =
            Arc::new((Mutex::new(HashSet::new()), Condvar::new()));
        let compiled_thread = Arc::clone(&compiled);
        // Runtime construction happens on the executor thread; report
        // startup failure back through a one-shot channel.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("mergeflow-xla".into())
            .spawn(move || {
                let mark_compiled = |name: &str| {
                    let (set, cv) = &*compiled_thread;
                    set.lock().unwrap().insert(name.to_string());
                    cv.notify_all();
                };
                let runtime = match super::XlaRuntime::open(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // Warm the compile cache eagerly, but *between* requests:
                // PJRT compilation of a Pallas-lowered module takes ~1s,
                // which must land neither on a job's latency nor block
                // jobs queued behind warmup — compile one artifact, then
                // drain any pending requests, repeat.
                let mut warm_queue: Vec<String> = runtime
                    .manifest()
                    .entries()
                    .iter()
                    .filter(|m| m.op == "merge")
                    .map(|m| m.name.clone())
                    .collect();
                loop {
                    // Serve everything pending first.
                    loop {
                        let req = if warm_queue.is_empty() {
                            // Fully warm: block on the channel.
                            match rx.recv() {
                                Ok(r) => r,
                                Err(_) => return,
                            }
                        } else {
                            match rx.try_recv() {
                                Ok(r) => r,
                                Err(std::sync::mpsc::TryRecvError::Empty) => break,
                                Err(std::sync::mpsc::TryRecvError::Disconnected) => return,
                            }
                        };
                        match req {
                            Req::Merge { name, a, b, reply } => {
                                let result = runtime
                                    .merge_executable(&name)
                                    .and_then(|exe| exe.merge(&a, &b));
                                if result.is_ok() {
                                    mark_compiled(&name);
                                }
                                let _ = reply.send(result);
                            }
                            Req::Shutdown => return,
                        }
                    }
                    // One warmup compile, then loop back to the queue.
                    if let Some(name) = warm_queue.pop() {
                        match runtime.merge_executable(&name) {
                            Ok(_) => mark_compiled(&name),
                            Err(e) => {
                                eprintln!("mergeflow: warmup compile {name} failed: {e}")
                            }
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn xla thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("xla executor died during startup".into()))??;
        Ok(Arc::new(Self {
            tx: Mutex::new(tx),
            manifest,
            join: Mutex::new(Some(join)),
            compiled,
        }))
    }

    /// Whether `name`'s PJRT compilation has completed — the router's
    /// non-blocking warm check.
    pub fn is_compiled(&self, name: &str) -> bool {
        self.compiled.0.lock().unwrap().contains(name)
    }

    /// Block until every merge artifact is compiled (or timeout).
    /// Returns `true` when fully warm.
    pub fn wait_warm(&self, timeout: Duration) -> bool {
        let total = self
            .manifest
            .entries()
            .iter()
            .filter(|m| m.op == "merge")
            .count();
        let (set, cv) = &*self.compiled;
        let deadline = std::time::Instant::now() + timeout;
        let mut guard = set.lock().unwrap();
        loop {
            if guard.len() >= total {
                return true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, res) = cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
            if res.timed_out() && guard.len() < total {
                return false;
            }
        }
    }

    /// Artifact manifest (parsed locally; no thread hop).
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Find an artifact that exactly fits the given input sizes.
    pub fn find_for_sizes(&self, n_a: usize, n_b: usize) -> Option<&ArtifactMeta> {
        self.manifest
            .entries()
            .iter()
            .find(|m| m.op == "merge" && m.n_a == n_a && m.n_b == n_b)
    }

    /// Typed-record seam for the coordinator's router: run the named
    /// artifact when `R`'s memory layout is exactly the baked `i32`
    /// keys — i.e. when [`Record::xla_seam`] yields the witness only
    /// [`KeyedI32`](crate::record::KeyedI32) types (today: `i32`) can
    /// construct. `None` means no artifact can serve this record type
    /// and the caller must route native; the gate is a compile-time
    /// property of `R`, so routing is deterministic per instantiation.
    ///
    /// [`Record::xla_seam`]: crate::record::Record::xla_seam
    pub fn merge_records<R: crate::record::Record>(
        &self,
        name: &str,
        a: &[R],
        b: &[R],
    ) -> Option<Result<Vec<R>>> {
        let seam = R::xla_seam()?;
        Some(self.merge(name, seam.view(a), seam.view(b)).map(|out| seam.back(out)))
    }

    /// Execute a merge on the executor thread (blocking rendezvous).
    ///
    /// Takes the inputs by reference so callers that may fall back to a
    /// native path never give up ownership; the one copy into the
    /// executor's channel happens here, only when the XLA route is
    /// actually taken.
    pub fn merge(&self, name: &str, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        let (reply, rx) = channel();
        // Build the request (two O(n) copies) *before* taking the tx
        // lock, so concurrent submitters only serialize on the send.
        let req = Req::Merge {
            name: name.to_string(),
            a: a.to_vec(),
            b: b.to_vec(),
            reply,
        };
        self.tx
            .lock()
            .unwrap()
            .send(req)
            .map_err(|_| Error::Runtime("xla executor stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("xla executor dropped request".into()))?
    }

    /// Stop the executor thread.
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Req::Shutdown);
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for XlaExecutor {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Req::Shutdown);
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn executor_if_built() -> Option<Arc<XlaExecutor>> {
        let dir = PathBuf::from("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        match XlaExecutor::start(&dir) {
            Ok(ex) => Some(ex),
            Err(e) => {
                // Always the case with the offline PJRT stub in the
                // build, even when artifacts exist.
                eprintln!("skipping: XLA runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn merge_through_executor_thread() {
        let Some(ex) = executor_if_built() else { return };
        let Some(meta) = ex
            .manifest()
            .entries()
            .iter()
            .find(|m| m.op == "merge")
            .cloned()
        else {
            return;
        };
        let a: Vec<i32> = (0..meta.n_a as i32).map(|x| x * 2).collect();
        let b: Vec<i32> = (0..meta.n_b as i32).map(|x| x * 2 + 1).collect();
        let got = ex.merge(&meta.name, &a, &b).unwrap();
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        assert_eq!(got, expected);
        // Callable from multiple threads.
        std::thread::scope(|s| {
            for _ in 0..4 {
                let ex = &ex;
                let meta = &meta;
                let a = &a;
                let b = &b;
                s.spawn(move || {
                    let got = ex.merge(&meta.name, a, b).unwrap();
                    assert!(got.windows(2).all(|w| w[0] <= w[1]));
                });
            }
        });
        ex.shutdown();
    }

    #[test]
    fn missing_dir_fails_fast() {
        assert!(XlaExecutor::start(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
