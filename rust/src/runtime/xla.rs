//! Offline PJRT stub with the exact API surface [`super`] consumes
//! from the real `xla` binding (PjRtClient / HloModuleProto /
//! XlaComputation / Literal / buffers).
//!
//! The build image has no crates.io access and no libxla, so this
//! module keeps the runtime layer *compiling* while making every entry
//! point fail fast at [`PjRtClient::cpu`] — `Backend::Auto` then
//! degrades to the native Merge Path and `Backend::Xla` surfaces a
//! clear startup error. Wiring a real PJRT binding back in means
//! replacing this module (same names, same signatures) with a re-export
//! of the actual crate; nothing above this layer changes.

use std::fmt;

/// Stub error: every operation reports the runtime as unavailable.
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error("PJRT/XLA runtime not available in this build (offline stub)".into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaStubError({})", self.0)
    }
}

/// Host literal (stub).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice (stub: drops the data).
    pub fn vec1<T>(_v: &[T]) -> Literal {
        Literal
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; real PJRT returns one buffer
    /// list per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// PJRT client handle (stub). [`PjRtClient::cpu`] is the single
/// fail-fast point: nothing downstream can be reached without it.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client. Always fails in the offline stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// Computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_at_client_creation() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }
}
