//! Artifact manifest: the contract between `python/compile/aot.py`
//! (writer) and the rust runtime (reader).
//!
//! `artifacts/manifest.txt` is a line-oriented text file (no serde in
//! the offline image):
//!
//! ```text
//! # name  file  op  n_a  n_b  dtype
//! merge_4096x4096  merge_4096x4096.hlo.txt  merge  4096  4096  i32
//! ```

use crate::{Error, Result};
use std::path::Path;

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Unique name (cache key).
    pub name: String,
    /// File name inside the artifact directory.
    pub file: String,
    /// Operation kind: currently `merge` (sorted-merge of two arrays)
    /// or `sort` (full sort of one array).
    pub op: String,
    /// First input length.
    pub n_a: usize,
    /// Second input length (0 for single-input ops).
    pub n_b: usize,
    /// Element dtype (only `i32` today).
    pub dtype: String,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    entries: Vec<ArtifactMeta>,
}

impl ArtifactManifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 6 {
                return Err(Error::Runtime(format!(
                    "manifest line {}: expected 6 fields, got {}",
                    lineno + 1,
                    parts.len()
                )));
            }
            let parse_n = |s: &str| -> Result<usize> {
                s.parse()
                    .map_err(|_| Error::Runtime(format!("manifest line {}: bad size `{s}`", lineno + 1)))
            };
            entries.push(ArtifactMeta {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                op: parts[2].to_string(),
                n_a: parse_n(parts[3])?,
                n_b: parse_n(parts[4])?,
                dtype: parts[5].to_string(),
            });
        }
        Ok(Self { entries })
    }

    /// Load from file.
    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    /// Lookup by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let text = "# comment\n\nmerge_4k merge_4k.hlo.txt merge 4096 4096 i32\nsort_8k sort_8k.hlo.txt sort 8192 0 i32\n";
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.get("merge_4k").unwrap();
        assert_eq!(e.n_a, 4096);
        assert_eq!(e.op, "merge");
        assert_eq!(m.get("sort_8k").unwrap().n_b, 0);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactManifest::parse("just three fields\n").is_err());
        assert!(
            ArtifactManifest::parse("n f merge not_a_number 0 i32\n").is_err()
        );
    }

    #[test]
    fn empty_manifest_ok() {
        let m = ArtifactManifest::parse("# empty\n").unwrap();
        assert!(m.entries().is_empty());
    }
}
