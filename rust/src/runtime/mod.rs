//! PJRT runtime: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`) and executes them from the rust hot path.
//!
//! Interchange is **HLO text** — the image's xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos (64-bit instruction ids), while
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//! Python never runs at serve time: after `make artifacts`, the
//! `mergeflow` binary is self-contained.

pub mod artifact;
pub mod executor;
mod xla; // offline PJRT stub — see its module docs for the real-binding seam

pub use artifact::{ArtifactManifest, ArtifactMeta};
pub use executor::XlaExecutor;

use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled merge executable: merges two fixed-size sorted `i32`
/// arrays (shape baked in at AOT time, like any XLA program).
pub struct MergeExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Expected |A|.
    pub n_a: usize,
    /// Expected |B|.
    pub n_b: usize,
    /// Artifact name.
    pub name: String,
}

impl std::fmt::Debug for MergeExecutable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MergeExecutable")
            .field("name", &self.name)
            .field("n_a", &self.n_a)
            .field("n_b", &self.n_b)
            .finish()
    }
}

impl MergeExecutable {
    /// Run the merge. Inputs must match the baked shapes exactly.
    pub fn merge(&self, a: &[i32], b: &[i32]) -> Result<Vec<i32>> {
        if a.len() != self.n_a || b.len() != self.n_b {
            return Err(Error::Runtime(format!(
                "artifact {} expects |A|={}, |B|={}; got {}, {}",
                self.name,
                self.n_a,
                self.n_b,
                a.len(),
                b.len()
            )));
        }
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let result = self
            .exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(wrap)?[0][0]
            .to_literal_sync()
            .map_err(wrap)?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1().map_err(wrap)?;
        out.to_vec::<i32>().map_err(wrap)
    }
}

fn wrap(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// The PJRT runtime: one CPU client plus a cache of compiled
/// executables keyed by artifact name.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, std::sync::Arc<MergeExecutable>>>,
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.entries().len())
            .finish()
    }
}

impl XlaRuntime {
    /// Open the runtime over an artifact directory (expects
    /// `manifest.txt` inside, written by `make artifacts`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Load + compile an artifact by name (cached).
    pub fn merge_executable(&self, name: &str) -> Result<std::sync::Arc<MergeExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named `{name}`")))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        let wrapped = std::sync::Arc::new(MergeExecutable {
            exe,
            n_a: meta.n_a,
            n_b: meta.n_b,
            name: name.to_string(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), wrapped.clone());
        Ok(wrapped)
    }

    /// Find an artifact that exactly fits the given input sizes.
    pub fn find_for_sizes(&self, n_a: usize, n_b: usize) -> Option<&ArtifactMeta> {
        self.manifest
            .entries()
            .iter()
            .find(|m| m.op == "merge" && m.n_a == n_a && m.n_b == n_b)
    }

    /// Largest merge artifact (used by the batcher to pick its bucket
    /// size).
    pub fn largest_merge(&self) -> Option<&ArtifactMeta> {
        self.manifest
            .entries()
            .iter()
            .filter(|m| m.op == "merge")
            .max_by_key(|m| m.n_a + m.n_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root.
        PathBuf::from("artifacts")
    }

    fn runtime_if_built() -> Option<XlaRuntime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        match XlaRuntime::open(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                // Always the case with the offline PJRT stub in the
                // build, even when artifacts exist.
                eprintln!("skipping: XLA runtime unavailable ({e})");
                None
            }
        }
    }

    #[test]
    fn open_and_list() {
        let Some(rt) = runtime_if_built() else { return };
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(!rt.manifest().entries().is_empty());
    }

    #[test]
    fn merge_artifact_correct_numerics() {
        let Some(rt) = runtime_if_built() else { return };
        let Some(meta) = rt.largest_merge().cloned() else { return };
        let exe = rt.merge_executable(&meta.name).unwrap();
        // Interleaved inputs of the baked size.
        let a: Vec<i32> = (0..meta.n_a as i32).map(|x| x * 2).collect();
        let b: Vec<i32> = (0..meta.n_b as i32).map(|x| x * 2 + 1).collect();
        let got = exe.merge(&a, &b).unwrap();
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        assert_eq!(got, expected);
    }

    #[test]
    fn merge_artifact_matches_native_on_random() {
        let Some(rt) = runtime_if_built() else { return };
        let Some(meta) = rt.largest_merge().cloned() else { return };
        let exe = rt.merge_executable(&meta.name).unwrap();
        let (a, b) = crate::bench::workload::gen_sorted_pair(
            crate::bench::workload::WorkloadKind::Uniform,
            meta.n_a,
            meta.n_b,
            0x1234,
        );
        let got = exe.merge(&a, &b).unwrap();
        let mut expected = vec![0i32; a.len() + b.len()];
        crate::mergepath::merge::merge_into(&a, &b, &mut expected);
        assert_eq!(got, expected);
    }

    #[test]
    fn size_mismatch_rejected() {
        let Some(rt) = runtime_if_built() else { return };
        let Some(meta) = rt.largest_merge().cloned() else { return };
        let exe = rt.merge_executable(&meta.name).unwrap();
        let err = exe.merge(&[1, 2, 3], &[4]).unwrap_err();
        assert!(err.to_string().contains("expects"));
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(rt) = runtime_if_built() else { return };
        assert!(rt.merge_executable("does-not-exist").is_err());
    }
}
