//! Hand-rolled CLI (clap is unavailable in the offline image).
//!
//! ```text
//! mergeflow merge   --n 1M --kind uniform --threads 8 [--segment-len L]
//! mergeflow sort    --n 16M --threads 8 [--cache-elems C]
//! mergeflow serve   [--config mergeflow.toml] [--listen ADDR]
//!                   [--selfload --jobs N --job-size SIZE]
//! mergeflow figure  fig4|fig5|fig7|fig8 [--scale S]
//! mergeflow table   table1|table1b|table2 [--scale S]
//! mergeflow probe   [--scale S]
//! mergeflow artifacts [--dir artifacts]
//! mergeflow store   [verify] --dir DIR [--verbose]
//! mergeflow stats   --listen ADDR
//! mergeflow kernels
//! ```

use crate::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags (`--k v` / `--k`), positional
/// arguments.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    /// First positional token (the subcommand).
    pub command: String,
    /// `--key value` pairs (bare `--flag` maps to "true").
    pub flags: BTreeMap<String, String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an argv iterator (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("empty flag `--`".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Self { command, flags, positional })
    }

    /// Flag lookup.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Size flag accepting `123`, `4K`, `16M` (binary powers, matching
    /// the paper's "1M = 2^20 elements").
    pub fn size_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => parse_size(v),
        }
    }

    /// Integer flag.
    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: `{v}` is not an integer"))),
        }
    }

    /// Boolean flag (present = true).
    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

/// Parse `123`, `64K`, `10M`, `1G` (binary suffixes).
pub fn parse_size(v: &str) -> Result<usize> {
    let v = v.trim();
    let (num, mult) = match v.chars().last() {
        Some('K') | Some('k') => (&v[..v.len() - 1], 1usize << 10),
        Some('M') | Some('m') => (&v[..v.len() - 1], 1usize << 20),
        Some('G') | Some('g') => (&v[..v.len() - 1], 1usize << 30),
        _ => (v, 1usize),
    };
    num.parse::<usize>()
        .map(|n| n * mult)
        .map_err(|_| Error::Config(format!("bad size `{v}`")))
}

/// Top-level usage text.
pub const USAGE: &str = "\
mergeflow — Merge Path parallel merging & sorting framework

USAGE:
  mergeflow merge   --n <SIZE> [--kind uniform|skewed|one-sided|interleaved|runs]
                    [--threads P] [--segment-len L] [--seed S]
  mergeflow sort    --n <SIZE> [--threads P] [--cache-elems C] [--seed S]
  mergeflow serve   [--config FILE] [--listen HOST:PORT|unix:/PATH]
                    [--selfload --jobs N --job-size SIZE]
  mergeflow figure  <fig4|fig5|fig7|fig8> [--scale S]
  mergeflow table   <table1|table1b|table2> [--scale S]
  mergeflow probe   [--scale S]
  mergeflow artifacts [--dir DIR]
  mergeflow store   [verify] --dir DIR [--verbose]
  mergeflow stats   --listen HOST:PORT|unix:/PATH
  mergeflow kernels
  mergeflow help

SIZE accepts binary suffixes: 64K, 1M, 10M (1M = 2^20 elements).
MERGEFLOW_SIM_SCALE overrides the default figure simulation scale (64).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(args: &[&str]) -> Cli {
        Cli::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let c = cli(&["figure", "fig4", "--scale", "32", "--verbose"]);
        assert_eq!(c.command, "figure");
        assert_eq!(c.positional, vec!["fig4"]);
        assert_eq!(c.flag("scale"), Some("32"));
        assert!(c.bool_flag("verbose"));
    }

    #[test]
    fn equals_style_flags() {
        let c = cli(&["merge", "--n=4M", "--threads=8"]);
        assert_eq!(c.size_flag("n", 0).unwrap(), 4 << 20);
        assert_eq!(c.usize_flag("threads", 1).unwrap(), 8);
    }

    #[test]
    fn size_parsing() {
        assert_eq!(parse_size("123").unwrap(), 123);
        assert_eq!(parse_size("64K").unwrap(), 64 << 10);
        assert_eq!(parse_size("10M").unwrap(), 10 << 20);
        assert_eq!(parse_size("1G").unwrap(), 1 << 30);
        assert!(parse_size("ten").is_err());
    }

    #[test]
    fn defaults_apply() {
        let c = cli(&["merge"]);
        assert_eq!(c.size_flag("n", 1 << 20).unwrap(), 1 << 20);
        assert_eq!(c.usize_flag("threads", 4).unwrap(), 4);
        assert!(!c.bool_flag("verbose"));
    }

    #[test]
    fn bad_values_error() {
        let c = cli(&["merge", "--threads", "many"]);
        assert!(c.usize_flag("threads", 1).is_err());
    }
}
