//! Shiloach–Vishkin parallel merge ([9], CREW PRAM).
//!
//! Partitioning: cut **each input** into `p` equal fragments and rank
//! every fragment boundary into the *other* array by binary search. The
//! union of the `2(p−1)` boundary points cuts the output into `2p − 1`
//! chunks; processor `i` is assigned chunks `2i` and `2i+1`. Each chunk
//! is bounded by `N/p` *per originating array*, so a processor can
//! receive up to `2N/p` output elements — the load imbalance the paper
//! (§5) contrasts with Merge Path's exact `N/p`: "such a load imbalance
//! can cause a 2X increase in latency".
//!
//! Time `O(N/p + log N)`; correct for CREW (fragment ranks are read
//! concurrently, writes are disjoint).

use crate::exec::fork_join;
use crate::mergepath::merge::merge_into;
use crate::mergepath::parallel::SliceParts;

/// A work item: merge `a[a0..a1]` with `b[b0..b1]` into the output at
/// `out0` (lengths always agree by construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SvChunk {
    /// `A` sub-range.
    pub a0: usize,
    /// End of the `A` sub-range.
    pub a1: usize,
    /// `B` sub-range.
    pub b0: usize,
    /// End of the `B` sub-range.
    pub b1: usize,
    /// Output offset.
    pub out0: usize,
}

/// Compute the Shiloach–Vishkin chunk decomposition (exposed for the
/// cache simulator and the load-imbalance bench).
pub fn sv_chunks<T: Ord>(a: &[T], b: &[T], p: usize) -> Vec<SvChunk> {
    assert!(p > 0);
    // Split points as (a_idx, b_idx) pairs on the merge path, from both
    // arrays' fragment boundaries. A-boundary i: (i·|A|/p, rank of
    // A-bound in B with A-priority ties); symmetrically for B.
    let mut points: Vec<(usize, usize)> = Vec::with_capacity(2 * p);
    points.push((0, 0));
    for i in 1..p {
        let ai = i * a.len() / p;
        if ai > 0 && ai < a.len() {
            // B elements strictly below A[ai] are consumed before it
            // (ties in B lose to A ⇒ strictly-less rank).
            let bi = lower_bound(b, &a[ai]);
            points.push((ai, bi));
        }
        let bj = i * b.len() / p;
        if bj > 0 && bj < b.len() {
            // A elements ≤ B[bj] precede it (A wins ties) ⇒ upper rank.
            let aj = upper_bound(a, &b[bj]);
            points.push((aj, bj));
        }
    }
    points.push((a.len(), b.len()));
    // Both coordinates are monotone along the merge path; sorting by the
    // pair orders points by their position on the path.
    points.sort_unstable();
    points.dedup();
    let mut chunks = Vec::with_capacity(points.len() - 1);
    let mut out0 = 0usize;
    for w in points.windows(2) {
        let (a0, b0) = w[0];
        let (a1, b1) = w[1];
        chunks.push(SvChunk { a0, a1, b0, b1, out0 });
        out0 += (a1 - a0) + (b1 - b0);
    }
    debug_assert_eq!(out0, a.len() + b.len());
    chunks
}

/// Chunk-to-processor assignment: the historical algorithm hands each
/// processor **two consecutive** chunks (there are at most `2p`), so a
/// processor can receive up to `2N/p` output elements — the paper's §5
/// load-imbalance criticism. (A smarter deal would rebalance, but that
/// is precisely what [9] does not do.)
#[inline]
pub fn sv_owner(chunk_idx: usize, p: usize) -> usize {
    (chunk_idx / 2) % p
}

/// Merge `a` and `b` into `out` with the Shiloach–Vishkin decomposition
/// on `p` threads (blocked two-chunks-per-processor assignment, see
/// [`sv_owner`]).
pub fn shiloach_vishkin_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let chunks = sv_chunks(a, b, p);
    let shared = SliceParts::new(out);
    fork_join(p, |tid| {
        for (i, c) in chunks.iter().enumerate() {
            if sv_owner(i, p) != tid {
                continue;
            }
            let len = (c.a1 - c.a0) + (c.b1 - c.b0);
            if len > 0 {
                // SAFETY: chunk output ranges are disjoint by construction.
                let dst = unsafe { shared.slice_mut(c.out0, len) };
                merge_into(&a[c.a0..c.a1], &b[c.b0..c.b1], dst);
            }
        }
    });
}

/// Max output elements assigned to any one thread under the blocked
/// deal — the load-imbalance metric reported by the comparison bench.
pub fn sv_max_load<T: Ord>(a: &[T], b: &[T], p: usize) -> usize {
    let chunks = sv_chunks(a, b, p);
    let mut loads = vec![0usize; p];
    for (i, c) in chunks.iter().enumerate() {
        loads[sv_owner(i, p)] += (c.a1 - c.a0) + (c.b1 - c.b0);
    }
    loads.into_iter().max().unwrap_or(0)
}

/// First index with `xs[i] >= key` (strict rank).
fn lower_bound<T: Ord>(xs: &[T], key: &T) -> usize {
    let (mut lo, mut hi) = (0, xs.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if xs[mid] < *key {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// First index with `xs[i] > key`.
fn upper_bound<T: Ord>(xs: &[T], key: &T) -> usize {
    let (mut lo, mut hi) = (0, xs.len());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if xs[mid] <= *key {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn matches_oracle_random() {
        let mut rng = Xoshiro256::seeded(0x5111);
        for _ in 0..30 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 100);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 100);
            let expected = oracle(&a, &b);
            for p in [1, 2, 4, 7, 16] {
                let mut out = vec![0i64; a.len() + b.len()];
                shiloach_vishkin_merge(&a, &b, &mut out, p);
                assert_eq!(out, expected, "p={p}");
            }
        }
    }

    #[test]
    fn chunks_tile_output() {
        let mut rng = Xoshiro256::seeded(0x5112);
        let a = random_sorted(&mut rng, 200, 50);
        let b = random_sorted(&mut rng, 150, 50);
        let chunks = sv_chunks(&a, &b, 8);
        let mut expect = 0usize;
        for c in &chunks {
            assert_eq!(c.out0, expect);
            expect += (c.a1 - c.a0) + (c.b1 - c.b0);
        }
        assert_eq!(expect, 350);
    }

    #[test]
    fn imbalance_witness() {
        // Skewed data forces imbalance: all of B falls inside A's first
        // fragment, so the chunks around that region are much larger
        // than the rest — one processor ends up with well over the
        // average load (the paper's §5 criticism of [9]), while Merge
        // Path is exact by construction.
        let n = 1 << 12;
        let p = 8;
        let a: Vec<i64> = (0..n as i64).collect();
        let b: Vec<i64> = vec![100i64; n]; // inside A-fragment 0
        let max = sv_max_load(&a, &b, p);
        let avg = (2 * n) / p;
        assert!(
            max as f64 >= 1.25 * avg as f64,
            "skewed imbalance should exceed average (got {max}, avg {avg})"
        );
        // Merge Path's partition of the same input is exactly equisized.
        let segs = crate::mergepath::partition_merge_path(&a, &b, p);
        let mp_max = segs.iter().map(|s| s.len()).max().unwrap();
        assert_eq!(mp_max, avg);
    }

    #[test]
    fn all_equal_keys() {
        let a = vec![3i64; 97];
        let b = vec![3i64; 103];
        let mut out = vec![0i64; 200];
        shiloach_vishkin_merge(&a, &b, &mut out, 6);
        assert!(out.iter().all(|&x| x == 3));
    }

    #[test]
    fn empty_sides() {
        let e: Vec<i64> = vec![];
        let a: Vec<i64> = (0..50).collect();
        let mut out = vec![0i64; 50];
        shiloach_vishkin_merge(&a, &e, &mut out, 4);
        assert_eq!(out, a);
        shiloach_vishkin_merge(&e, &a, &mut out, 4);
        assert_eq!(out, a);
    }

    #[test]
    fn bounds_helpers() {
        let xs = [1i64, 3, 3, 5];
        assert_eq!(lower_bound(&xs, &3), 1);
        assert_eq!(upper_bound(&xs, &3), 3);
        assert_eq!(lower_bound(&xs, &0), 0);
        assert_eq!(upper_bound(&xs, &9), 4);
    }
}
