//! Baseline parallel-merge algorithms from the paper's related work (§5)
//! plus the naive strawman of §1.
//!
//! These exist so the benchmark harness can regenerate Table 1 (cache
//! misses per algorithm) and provide speedup comparisons with identical
//! workloads and the same execution substrate:
//!
//! - [`naive`] — equal split of both inputs (incorrect; kept as the §1
//!   counter-example and as a teaching aid).
//! - [`shiloach_vishkin`] — [9]: fragment-boundary ranking, load
//!   imbalance up to `2N/p`.
//! - [`akl_santoro`] — [8]: recursive median bisection, `log p` rounds,
//!   EREW-friendly, `O(N/p + log N·log p)`.
//! - [`deo_sarkar`] — [2]: equispaced k-th smallest selection,
//!   `O(N/p + log N)` — the algorithm Merge Path is equivalent to, with
//!   a different (non-geometric) derivation.
//! - [`bitonic`] — [7]: Batcher's bitonic merge/sort networks.

pub mod akl_santoro;
pub mod bitonic;
pub mod deo_sarkar;
pub mod naive;
pub mod shiloach_vishkin;

pub use akl_santoro::akl_santoro_merge;
pub use bitonic::{bitonic_merge, bitonic_sort};
pub use deo_sarkar::{deo_sarkar_merge, kth_of_union};
pub use naive::{concat_sort_merge, naive_equal_split_merge};
pub use shiloach_vishkin::shiloach_vishkin_merge;
