//! Deo–Sarkar parallel merge ([2], CREW).
//!
//! For each `k ∈ {N/p, 2N/p, …}` find the pair `(i, j)` such that the
//! `k`-th smallest element of `A ∪ B` splits the arrays at `(i, j)` —
//! the classic two-array selection, done here with the textbook
//! `O(log min(|A|,|B|))` bisection on *one* array's contribution
//! (a genuinely different code path from the cross-diagonal search,
//! kept separate on purpose: the paper's point is that Merge Path
//! computes the same partition with a more intuitive derivation).
//!
//! Time `O(N/p + log N)` — the same bound as Merge Path (§5).

use crate::exec::fork_join;
use crate::mergepath::merge::merge_bounded;
use crate::mergepath::parallel::SliceParts;

/// Two-array selection: how many elements of `a` (and of `b`) belong to
/// the first `k` outputs of the stable A-priority merge. Returns
/// `(i, j)` with `i + j == k`.
///
/// Implemented as a binary search on `i` (the contribution of `a`),
/// validating against the neighbouring elements of `b` — the Deo–Sarkar
/// "find the k-th smallest in the union" routine.
pub fn kth_of_union<T: Ord>(a: &[T], b: &[T], k: usize) -> (usize, usize) {
    debug_assert!(k <= a.len() + b.len());
    let mut lo = k.saturating_sub(b.len());
    let mut hi = k.min(a.len());
    while lo < hi {
        let i = lo + (hi - lo) / 2;
        let j = k - i;
        // Too few from A if A[i] should have been inside the first k:
        // A[i] < B[j-1] means A[i] is definitely among the first k
        // (even against ties, A-priority strengthens this).
        if j > 0 && a.get(i).is_some() && a[i] <= b[j - 1] {
            lo = i + 1;
        } else if i > 0 && j < b.len() && a[i - 1] > b[j] {
            // Too many from A: the last chosen A element exceeds a B
            // element that should have been taken first.
            hi = i - 1 + 1; // hi = i, but keep the derivation explicit
        } else {
            return (i, j);
        }
    }
    (lo, k - lo)
}

/// Merge `a` and `b` into `out` with the Deo–Sarkar equispaced-selection
/// partition on `p` threads.
pub fn deo_sarkar_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let n = out.len();
    if p == 1 || n < 2 * p {
        merge_bounded(a, b, out, n);
        return;
    }
    let shared = SliceParts::new(out);
    fork_join(p, |tid| {
        let k0 = tid * n / p;
        let k1 = (tid + 1) * n / p;
        if k0 == k1 {
            return;
        }
        let (i, j) = kth_of_union(a, b, k0);
        // SAFETY: [k0, k1) disjoint across tids.
        let dst = unsafe { shared.slice_mut(k0, k1 - k0) };
        merge_bounded(&a[i..], &b[j..], dst, k1 - k0);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mergepath::diagonal::diagonal_intersection;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn selection_agrees_with_merge_path() {
        // Thm: Deo–Sarkar's selection and the cross-diagonal intersection
        // compute the same split — the paper's equivalence claim (§5).
        let mut rng = Xoshiro256::seeded(0xDE0);
        for _ in 0..40 {
            let n_a = rng.range(0, 60);
            let a = random_sorted(&mut rng, n_a, 25);
            let n_b = rng.range(0, 60);
            let b = random_sorted(&mut rng, n_b, 25);
            for k in 0..=(a.len() + b.len()) {
                let (i, j) = kth_of_union(&a, &b, k);
                let pt = diagonal_intersection(&a, &b, k);
                assert_eq!((i, j), (pt.a, pt.b), "k={k} a={a:?} b={b:?}");
            }
        }
    }

    #[test]
    fn matches_oracle_random() {
        let mut rng = Xoshiro256::seeded(0xDE1);
        for _ in 0..30 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 100);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 100);
            let expected = oracle(&a, &b);
            for p in [1, 2, 5, 8, 32] {
                let mut out = vec![0i64; a.len() + b.len()];
                deo_sarkar_merge(&a, &b, &mut out, p);
                assert_eq!(out, expected, "p={p}");
            }
        }
    }

    #[test]
    fn selection_extremes() {
        let a = [1i64, 5, 9];
        let b = [2i64, 6];
        assert_eq!(kth_of_union(&a, &b, 0), (0, 0));
        assert_eq!(kth_of_union(&a, &b, 5), (3, 2));
        // k = 2 → outputs {1, 2} → one from each.
        assert_eq!(kth_of_union(&a, &b, 2), (1, 1));
    }

    #[test]
    fn empty_arrays() {
        let e: [i64; 0] = [];
        let b = [4i64, 8];
        assert_eq!(kth_of_union(&e, &b, 1), (0, 1));
        assert_eq!(kth_of_union(&b, &e, 1), (1, 0));
        assert_eq!(kth_of_union(&e, &e, 0), (0, 0));
    }
}
