//! Batcher's bitonic merging and sorting networks ([7], §5).
//!
//! The paper cites bitonic sort as the classic example of the
//! "problem-size-dependent processor count" category: `N/2` comparators
//! per stage, `O(log² N)` stages. Here the network runs on `p` real
//! threads by chunking each stage's independent compare-exchanges —
//! every stage is a perfectly parallel loop, but total work is
//! `O(N log² N)`, which is what the comparison benches show against the
//! `O(N)` Merge Path.
//!
//! Arbitrary (non-power-of-two) lengths are handled by virtually
//! padding with `+∞` (`None`-as-greatest in a scratch buffer of
//! `Option<T>`).

use crate::exec::fork_join;
use crate::mergepath::parallel::SliceParts;

/// `Option<T>` ordered with `None` as `+∞` (padding element).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Padded<T>(Option<T>);

impl<T: Ord> PartialOrd for Padded<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<T: Ord> Ord for Padded<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        match (&self.0, &other.0) {
            (Some(a), Some(b)) => a.cmp(b),
            (Some(_), None) => std::cmp::Ordering::Less,
            (None, Some(_)) => std::cmp::Ordering::Greater,
            (None, None) => std::cmp::Ordering::Equal,
        }
    }
}

/// One ascending bitonic-network pass over `v` (length must be a power
/// of two): for stride `k`, compare-exchange pairs `(i, i|k)`.
fn stage<T: Ord + Copy + Send + Sync>(v: &mut [Padded<T>], k: usize, p: usize) {
    let n = v.len();
    let shared = SliceParts::new(v);
    let pairs = n / 2;
    let workers = p.min(pairs.max(1));
    fork_join(workers, |tid| {
        // Enumerate pair indices i with bit k clear, chunked by thread.
        let lo = tid * pairs / workers;
        let hi = (tid + 1) * pairs / workers;
        for t in lo..hi {
            // t-th index with bit k clear: insert a 0 at bit position of k.
            let below = t & (k - 1);
            let above = (t & !(k - 1)) << 1;
            let i = above | below;
            let j = i | k;
            // SAFETY: each (i, j) pair is touched by exactly one thread.
            unsafe {
                let a = shared.slice_mut(i, 1);
                let b = shared.slice_mut(j, 1);
                if a[0] > b[0] {
                    std::mem::swap(&mut a[0], &mut b[0]);
                }
            }
        }
    });
}

/// Bitonic *merge* of a bitonic sequence held in `v` (power-of-two
/// length): the classic `log n` halving stages.
fn bitonic_merge_network<T: Ord + Copy + Send + Sync>(v: &mut [Padded<T>], p: usize) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut k = n / 2;
    while k >= 1 {
        stage(v, k, p);
        k /= 2;
    }
}

/// Merge two sorted arrays with the bitonic merging network on `p`
/// threads. `O(N log N)` work, `O(log N)` depth.
pub fn bitonic_merge<T: Ord + Copy + Send + Sync>(a: &[T], b: &[T], out: &mut [T], p: usize) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let n = (a.len() + b.len()).next_power_of_two().max(1);
    if a.len() + b.len() == 0 {
        return;
    }
    // ascending ++ descending = bitonic. Padding must go *between* the
    // ascending run and the reversed `b`: [a…, +∞…, b-reversed…] is
    // non-decreasing then non-increasing, i.e. still bitonic, whereas
    // appending +∞ after the descent would not be.
    let pad = n - (a.len() + b.len());
    let mut v: Vec<Padded<T>> = Vec::with_capacity(n);
    v.extend(a.iter().map(|&x| Padded(Some(x))));
    v.extend(std::iter::repeat(Padded(None)).take(pad));
    v.extend(b.iter().rev().map(|&x| Padded(Some(x))));
    debug_assert_eq!(v.len(), n);
    bitonic_merge_network(&mut v, p);
    for (o, x) in out.iter_mut().zip(v.into_iter()) {
        *o = x.0.expect("padding sorted past payload");
    }
}

/// Full bitonic sort on `p` threads. `O(N log² N)` work.
pub fn bitonic_sort<T: Ord + Copy + Send + Sync>(data: &mut [T], p: usize) {
    assert!(p > 0);
    let len = data.len();
    if len <= 1 {
        return;
    }
    let n = len.next_power_of_two();
    let mut v: Vec<Padded<T>> = Vec::with_capacity(n);
    v.extend(data.iter().map(|&x| Padded(Some(x))));
    v.extend(std::iter::repeat(Padded(None)).take(n - len));
    // Standard iterative bitonic sorter (ascending), padding = +∞.
    let mut k = 2usize;
    while k <= n {
        let mut j = k / 2;
        while j >= 1 {
            bitonic_sort_stage(&mut v, j, k, p);
            j /= 2;
        }
        k *= 2;
    }
    for (o, x) in data.iter_mut().zip(v.into_iter()) {
        *o = x.0.expect("padding sorted past payload");
    }
}

/// One stage of the full sorter: direction depends on bit `k` of `i`.
fn bitonic_sort_stage<T: Ord + Copy + Send + Sync>(
    v: &mut [Padded<T>],
    j: usize,
    k: usize,
    p: usize,
) {
    let n = v.len();
    let shared = SliceParts::new(v);
    let pairs = n / 2;
    let workers = p.min(pairs.max(1));
    fork_join(workers, |tid| {
        let lo = tid * pairs / workers;
        let hi = (tid + 1) * pairs / workers;
        for t in lo..hi {
            let below = t & (j - 1);
            let above = (t & !(j - 1)) << 1;
            let i = above | below;
            let l = i | j;
            let ascending = i & k == 0;
            // SAFETY: disjoint pairs per thread.
            unsafe {
                let a = shared.slice_mut(i, 1);
                let b = shared.slice_mut(l, 1);
                if (a[0] > b[0]) == ascending {
                    std::mem::swap(&mut a[0], &mut b[0]);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn merge_matches_oracle() {
        let mut rng = Xoshiro256::seeded(0xB170);
        for _ in 0..25 {
            let n_a = rng.range(0, 200);
            let a = random_sorted(&mut rng, n_a, 64);
            let n_b = rng.range(0, 200);
            let b = random_sorted(&mut rng, n_b, 64);
            let expected = oracle(&a, &b);
            for p in [1, 2, 4] {
                let mut out = vec![0i64; a.len() + b.len()];
                bitonic_merge(&a, &b, &mut out, p);
                assert_eq!(out, expected, "p={p}");
            }
        }
    }

    #[test]
    fn merge_power_of_two_exact() {
        let a: Vec<i64> = (0..64).map(|x| x * 2).collect();
        let b: Vec<i64> = (0..64).map(|x| x * 2 + 1).collect();
        let mut out = vec![0i64; 128];
        bitonic_merge(&a, &b, &mut out, 4);
        assert_eq!(out, (0..128).collect::<Vec<i64>>());
    }

    #[test]
    fn sort_matches_std() {
        let mut rng = Xoshiro256::seeded(0xB171);
        for _ in 0..15 {
            let n = rng.range(0, 500);
            let v: Vec<i64> = (0..n).map(|_| rng.next_i32() as i64).collect();
            let mut expected = v.clone();
            expected.sort();
            for p in [1, 3, 8] {
                let mut got = v.clone();
                bitonic_sort(&mut got, p);
                assert_eq!(got, expected, "n={n} p={p}");
            }
        }
    }

    #[test]
    fn sort_edge_cases() {
        let mut v: Vec<i64> = vec![];
        bitonic_sort(&mut v, 4);
        let mut v = vec![1i64];
        bitonic_sort(&mut v, 4);
        assert_eq!(v, vec![1]);
        let mut v = vec![3i64, 1, 2]; // non-power-of-two
        bitonic_sort(&mut v, 2);
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn merge_empty_sides() {
        let e: Vec<i64> = vec![];
        let a: Vec<i64> = (0..37).collect();
        let mut out = vec![0i64; 37];
        bitonic_merge(&a, &e, &mut out, 3);
        assert_eq!(out, a);
        bitonic_merge(&e, &a, &mut out, 3);
        assert_eq!(out, a);
    }
}
