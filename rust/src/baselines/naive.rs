//! The naive strawmen of §1.
//!
//! [`naive_equal_split_merge`] partitions *each input* into `p`
//! equal-length contiguous sub-arrays, pairs them up positionally,
//! merges each pair, and concatenates — which is **incorrect** in
//! general (take all of `A` greater than all of `B`). It is retained
//! because the paper opens with it as motivation; tests assert both the
//! cases where it happens to work and a witness where it fails.
//!
//! [`concat_sort_merge`] is the trivially correct (but `O(N log N)`)
//! fallback: concatenate and sort. It serves as the throughput floor in
//! the hot-path benches.

use crate::exec::fork_join;
use crate::mergepath::merge::merge_into;
use crate::mergepath::parallel::SliceParts;

/// The incorrect naive parallel "merge": split `a` and `b` into `p`
/// positional pairs, merge pairwise, concatenate. Returned so callers
/// can inspect (and tests can falsify) the result.
pub fn naive_equal_split_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    p: usize,
) -> Vec<T> {
    assert!(p > 0);
    let n = a.len() + b.len();
    let mut out = vec![];
    out.reserve_exact(n);
    // Build per-pair outputs, then concatenate in pair order.
    let mut pieces: Vec<Vec<T>> = Vec::with_capacity(p);
    for i in 0..p {
        let (a0, a1) = (i * a.len() / p, (i + 1) * a.len() / p);
        let (b0, b1) = (i * b.len() / p, (i + 1) * b.len() / p);
        let mut piece = vec![];
        piece.resize(a1 - a0 + (b1 - b0), a.first().copied().unwrap_or_else(|| b[0]));
        merge_into(&a[a0..a1], &b[b0..b1], &mut piece);
        pieces.push(piece);
    }
    for piece in pieces {
        out.extend_from_slice(&piece);
    }
    out
}

/// Correct-but-slow baseline: copy both inputs into `out` and sort.
/// `O(N log N)` work; used as the floor in `merge_hotpath` benches.
pub fn concat_sort_merge<T: Ord + Copy + Send + Sync>(a: &[T], b: &[T], out: &mut [T]) {
    assert_eq!(out.len(), a.len() + b.len());
    out[..a.len()].copy_from_slice(a);
    out[a.len()..].copy_from_slice(b);
    out.sort();
}

/// Parallel copy helper used by several baselines: copy `src` into
/// `dst` with `p` threads (bandwidth-bound stage of [9]'s description).
pub fn parallel_copy<T: Copy + Send + Sync>(src: &[T], dst: &mut [T], p: usize) {
    assert_eq!(src.len(), dst.len());
    assert!(p > 0);
    let n = src.len();
    if n == 0 {
        return;
    }
    let shared = SliceParts::new(dst);
    fork_join(p.min(n), |tid| {
        let p = p.min(n);
        let (s, e) = (tid * n / p, (tid + 1) * n / p);
        if e > s {
            // SAFETY: ranges disjoint across tids.
            let chunk = unsafe { shared.slice_mut(s, e - s) };
            chunk.copy_from_slice(&src[s..e]);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_split_fails_on_one_sided_input() {
        // §1's counter-example: all of A greater than all of B.
        let a = [10i64, 20, 30, 40];
        let b = [1i64, 2, 3, 4];
        let got = naive_equal_split_merge(&a, &b, 2);
        let mut expected: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        expected.sort();
        assert_ne!(got, expected, "naive split should be wrong here");
        // ... and the output is not even sorted:
        assert!(got.windows(2).any(|w| w[0] > w[1]));
    }

    #[test]
    fn naive_split_happens_to_work_when_interleaved_evenly() {
        // Perfectly interleaved inputs make the naive split correct —
        // the trap that makes the bug easy to miss.
        let a = [0i64, 2, 4, 6];
        let b = [1i64, 3, 5, 7];
        let got = naive_equal_split_merge(&a, &b, 2);
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn concat_sort_is_correct() {
        let a = [5i64, 9, 12];
        let b = [1i64, 9, 30, 31];
        let mut out = [0i64; 7];
        concat_sort_merge(&a, &b, &mut out);
        assert_eq!(out, [1, 5, 9, 9, 12, 30, 31]);
    }

    #[test]
    fn parallel_copy_matches() {
        let src: Vec<u32> = (0..1000).collect();
        let mut dst = vec![0u32; 1000];
        parallel_copy(&src, &mut dst, 7);
        assert_eq!(src, dst);
        // degenerate: empty, p > n
        let e: Vec<u32> = vec![];
        let mut de: Vec<u32> = vec![];
        parallel_copy(&e, &mut de, 4);
        let one = vec![9u32];
        let mut done = vec![0u32];
        parallel_copy(&one, &mut done, 16);
        assert_eq!(done, vec![9]);
    }
}
