//! Akl–Santoro parallel merge ([8], EREW, memory-conflict free).
//!
//! The algorithm repeatedly bisects: find the pair `(i, j)` with
//! `i + j = (|A|+|B|)/2` such that the first `i` elements of `A` and
//! first `j` of `B` are exactly the lower half of the output (the
//! "median split"), then recurse on both halves until `p` partitions
//! exist — `⌈log₂ p⌉` rounds of `O(log N)` searches. The partitions are
//! then merged sequentially and concurrently.
//!
//! Total time `O(N/p + log N·log p)` — the extra `log p` factor is the
//! price of total memory-conflict elimination (§5). Note the partition
//! produced is *identical* to Merge Path's when `p` is a power of two;
//! the difference is the number of dependent search rounds, which the
//! virtual-time simulator charges.

use crate::exec::fork_join;
use crate::mergepath::diagonal::diagonal_intersection;
use crate::mergepath::merge::merge_into;
use crate::mergepath::parallel::SliceParts;

/// A partition produced by the recursive bisection: merge `a[a0..a1]`
/// with `b[b0..b1]` into output offset `out0`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AsPart {
    /// `A` range start.
    pub a0: usize,
    /// `A` range end.
    pub a1: usize,
    /// `B` range start.
    pub b0: usize,
    /// `B` range end.
    pub b1: usize,
    /// Output offset.
    pub out0: usize,
}

/// Recursive median bisection into `p` parts. Returns the parts in
/// output order, and the number of *dependent* bisection rounds
/// performed (`⌈log₂ p⌉`), which the simulator charges as serial steps.
pub fn as_partitions<T: Ord>(a: &[T], b: &[T], p: usize) -> (Vec<AsPart>, usize) {
    assert!(p > 0);
    let mut parts = vec![AsPart {
        a0: 0,
        a1: a.len(),
        b0: 0,
        b1: b.len(),
        out0: 0,
    }];
    let mut rounds = 0usize;
    while parts.len() < p {
        rounds += 1;
        let mut next = Vec::with_capacity(parts.len() * 2);
        for part in &parts {
            // Leaves that can no longer split stay as-is.
            let len = (part.a1 - part.a0) + (part.b1 - part.b0);
            if parts.len() + next.len() >= p || len <= 1 {
                // Keep unsplit if we already have enough parts budget;
                // handled below by the split-count check.
            }
            let half = len / 2;
            if half == 0 || len == 0 {
                next.push(*part);
                continue;
            }
            // Median split of this part = merge-path intersection with
            // the part-local middle diagonal (the [8] median-finding
            // procedure computes the same point).
            let pa = &a[part.a0..part.a1];
            let pb = &b[part.b0..part.b1];
            let m = diagonal_intersection(pa, pb, half);
            next.push(AsPart {
                a0: part.a0,
                a1: part.a0 + m.a,
                b0: part.b0,
                b1: part.b0 + m.b,
                out0: part.out0,
            });
            next.push(AsPart {
                a0: part.a0 + m.a,
                a1: part.a1,
                b0: part.b0 + m.b,
                b1: part.b1,
                out0: part.out0 + half,
            });
        }
        if next.len() == parts.len() {
            break; // nothing splittable left
        }
        parts = next;
    }
    (parts, rounds)
}

/// Merge `a` and `b` into `out` with the Akl–Santoro partition on `p`
/// threads (part `i` → thread `i % p`).
pub fn akl_santoro_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let (parts, _rounds) = as_partitions(a, b, p);
    let shared = SliceParts::new(out);
    fork_join(p, |tid| {
        let mut i = tid;
        while i < parts.len() {
            let pt = parts[i];
            let len = (pt.a1 - pt.a0) + (pt.b1 - pt.b0);
            if len > 0 {
                // SAFETY: part output ranges are disjoint by construction.
                let dst = unsafe { shared.slice_mut(pt.out0, len) };
                merge_into(&a[pt.a0..pt.a1], &b[pt.b0..pt.b1], dst);
            }
            i += p;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn matches_oracle_random() {
        let mut rng = Xoshiro256::seeded(0xA5A5);
        for _ in 0..30 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 100);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 100);
            let expected = oracle(&a, &b);
            for p in [1, 2, 3, 4, 8, 16] {
                let mut out = vec![0i64; a.len() + b.len()];
                akl_santoro_merge(&a, &b, &mut out, p);
                assert_eq!(out, expected, "p={p}");
            }
        }
    }

    #[test]
    fn rounds_is_log_p() {
        let a: Vec<i64> = (0..1024).collect();
        let b: Vec<i64> = (0..1024).collect();
        for (p, want) in [(1, 0), (2, 1), (4, 2), (8, 3), (16, 4)] {
            let (parts, rounds) = as_partitions(&a, &b, p);
            assert_eq!(rounds, want, "p={p}");
            assert!(parts.len() >= p.min(2048));
        }
        // Non-power-of-two: ceil(log2 p) rounds.
        let (_, rounds) = as_partitions(&a, &b, 5);
        assert_eq!(rounds, 3);
    }

    #[test]
    fn partitions_are_balanced_halves() {
        let a: Vec<i64> = (0..100).map(|x| x * 3).collect();
        let b: Vec<i64> = (0..100).map(|x| x * 3 + 1).collect();
        let (parts, _) = as_partitions(&a, &b, 4);
        let lens: Vec<usize> = parts
            .iter()
            .map(|p| (p.a1 - p.a0) + (p.b1 - p.b0))
            .collect();
        assert_eq!(lens.iter().sum::<usize>(), 200);
        // Median bisection gives exactly equal halves (len divisible).
        assert!(lens.iter().all(|&l| l == 50), "{lens:?}");
    }

    #[test]
    fn one_sided_and_tiny() {
        let e: Vec<i64> = vec![];
        let a: Vec<i64> = (0..33).collect();
        let mut out = vec![0i64; 33];
        akl_santoro_merge(&a, &e, &mut out, 8);
        assert_eq!(out, a);
        let mut out1 = vec![0i64; 1];
        akl_santoro_merge(&[7i64], &e, &mut out1, 4);
        assert_eq!(out1, vec![7]);
    }

    #[test]
    fn duplicate_keys() {
        let a = vec![1i64; 128];
        let b = vec![1i64; 128];
        let mut out = vec![0i64; 256];
        akl_santoro_merge(&a, &b, &mut out, 8);
        assert!(out.iter().all(|&x| x == 1));
    }
}
