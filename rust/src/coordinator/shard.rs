//! Rank-sharded compaction: split one giant `Compact` job into
//! independent `CompactShard` sub-jobs by **output rank**.
//!
//! Merge Path's core property — any output rank induces a unique,
//! synchronization-free cut of the inputs (Alg 1/2 of the paper,
//! generalised to `k` runs after Siebert & Träff) — means a compaction
//! does not have to execute as one monolithic job: cutting every run
//! once per shard boundary with
//! [`partition_kway_merge_path`](crate::mergepath::partition_kway_merge_path)
//! yields `S` equisized shards that merge disjoint windows of the
//! output with **zero inter-shard coordination**. The dispatcher
//! expands a qualifying `Compact` job into `S` [`JobKind::CompactShard`]
//! sub-jobs *before* dispatch, so each shard is scheduled on the
//! persistent worker pool like any other job (own back-pressure slot,
//! own queue accounting) and no worker ever sits blocked waiting for
//! sibling shards.
//!
//! Everything here is generic over keyed records ([`Record`]): shards
//! carry `Vec<R>` runs and merge through the key-only [`ByKey`]
//! adapter, so the stable tie order (run index, then offset) is
//! preserved for payload-carrying records exactly as for scalars.
//!
//! ## Lifecycle
//!
//! ```text
//! Compact{runs}           dispatcher: plan S cuts (kway_rank_split
//!      │                  per boundary), build one ShardGroup
//!      ▼
//! ShardGroup ── Arc ──┬── CompactShard #0 ──▶ worker: merge window 0 ─┐
//!   runs (shared)     ├── CompactShard #1 ──▶ worker: merge window 1 ─┤
//!   output buffer     └── CompactShard #S−1 ▶ worker: merge window S−1┤
//!   remaining = S                                                     │
//!                  last shard to finish (remaining → 0) ◀─────────────┘
//!                  takes the stitched buffer, records the completion
//!                  (backend "native-kway-sharded") and replies to the
//!                  client's original handle
//! ```
//!
//! Shards write through disjoint, statically-known windows of a single
//! shared output buffer (the tiling + equisize ±1 invariants of the
//! k-way partition), so "stitching in rank order" is free — the windows
//! *are* the final layout. Stability is inherited: each shard runs the
//! same stable loser-tree kernel over its slices, and concatenating
//! stable per-rank-range merges is exactly the stable k-way merge.
//!
//! The whole path runs on the coordinator's persistent
//! [`WorkerPool`](crate::exec::WorkerPool) — no scoped-thread spawning
//! anywhere.

use super::job::{Job, JobKind, JobResult};
use super::stats::ServiceStats;
use crate::config::MergeflowConfig;
use crate::mergepath::kernel::{LeafKernel, MergeKernel};
use crate::mergepath::kway::loser_tree_merge_segmented_with;
use crate::mergepath::kway_path::{partition_kway_merge_path, KwaySegment};
use crate::record::{self, ByKey, Record};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Backend tag reported for compactions executed as rank shards.
pub const BACKEND_SHARDED: &str = "native-kway-sharded";

/// Hard ceiling on shards per compaction, independent of configuration
/// — bounds dispatcher-side planning cost and per-job bookkeeping.
/// Shared with the streaming remainder planner ([`super::session`]).
pub(crate) const MAX_SHARDS: usize = 256;

/// Model fallback for the smallest shard length the auto-tuner will
/// pick (`merge.compact_shard_min_len = 0`). Below this, per-shard
/// dispatch and planning overhead eat the scheduling win —
/// `benches/sharded_vs_flat.rs` locates the boundary per machine; 256
/// Ki elements sits above it on every shape the bench has swept. The
/// runtime floor is `dispatch.shard_floor`, which defaults to this
/// constant and can be re-derived per machine at service start by
/// [`super::calibrate`] (`dispatch.shard_floor = 0`).
pub(crate) const AUTO_SHARD_FLOOR: usize = 1 << 18;

/// Resolve the configured shard length for a job of `total` output
/// elements. A configured `compact_shard_min_len` is used as-is;
/// **0 means auto**: one shard per pool worker
/// (`total / workers`), clamped to `[shard_floor, u32::MAX]` so shards
/// never drop below the profitability floor (configured or calibrated
/// — the service resolves `dispatch.shard_floor = 0` through
/// [`super::calibrate`] before any job is planned, so the model
/// fallback here only covers configs used without a service) and the
/// arithmetic stays sane for absurd totals.
pub(crate) fn effective_shard_min_len(cfg: &MergeflowConfig, total: usize) -> usize {
    if cfg.compact_shard_min_len != 0 {
        return cfg.compact_shard_min_len;
    }
    let floor = if cfg.shard_floor > 0 { cfg.shard_floor } else { AUTO_SHARD_FLOOR };
    (total / cfg.workers.max(1)).clamp(floor, u32::MAX as usize)
}

/// Output buffer shared by concurrent writers of one merge group.
/// Writers go through disjoint windows off the cached `base` pointer
/// (partition tiling invariant), which is what makes the unsynchronized
/// access sound. While writers run, no `&mut` to the `Vec` itself is
/// ever materialized (two live `&mut` would alias even if the written
/// windows are disjoint). Used by the rank shards here and by the
/// streamed remainder shards in [`super::session`].
pub(crate) struct SharedOut<T> {
    buf: UnsafeCell<Vec<T>>,
    /// Heap base of `buf`, captured before the group is shared. Stays
    /// valid when the `Vec` moves: only its header moves, not the heap
    /// allocation, and writers never grow/shrink the buffer.
    base: *mut T,
}

impl<T> SharedOut<T> {
    pub(crate) fn new(mut buf: Vec<T>) -> Self {
        let base = buf.as_mut_ptr();
        Self { buf: UnsafeCell::new(buf), base }
    }

    /// The cached heap base. Callers carve disjoint windows out of it
    /// with `from_raw_parts_mut`; every window must be fully written
    /// before [`SharedOut::take`] (the buffer may be uninitialized —
    /// see [`crate::uninit_vec`]).
    pub(crate) fn base(&self) -> *mut T {
        self.base
    }

    /// Move the buffer out.
    ///
    /// # Safety
    /// All writers must have finished, with a happens-before edge to
    /// this call (countdown with AcqRel, or a shared mutex).
    pub(crate) unsafe fn take(&self) -> Vec<T> {
        std::mem::take(&mut *self.buf.get())
    }
}

// SAFETY: concurrent access is only through `base` with disjoint
// windows; the buffer itself is touched again only after all writers
// finished (completion countdown / mutex in the owning group).
unsafe impl<T: Send> Send for SharedOut<T> {}
unsafe impl<T: Send> Sync for SharedOut<T> {}

impl<T> std::fmt::Debug for SharedOut<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The Vec must not be inspected while writers may be live.
        f.debug_struct("SharedOut").finish_non_exhaustive()
    }
}

/// Shared state of one sharded compaction: the run buffers (shared by
/// all shards via `Arc`), the planned per-shard cuts, the output
/// buffer, and the completion countdown.
pub struct ShardGroup<R: Record = i32> {
    runs: Vec<Vec<R>>,
    segments: Vec<KwaySegment>,
    out: SharedOut<R>,
    /// Shards still running; the shard that decrements this to zero
    /// stitches and replies.
    remaining: AtomicUsize,
    /// Parent job id (every shard reports it; the client sees one job).
    parent_id: u64,
    /// Parent admission time — end-to-end latency covers queue wait,
    /// planning, and the slowest shard.
    enqueued_at: Instant,
    /// Queue wait of the parent (admission → expansion), in ns.
    queue_wait_ns: u64,
    /// Total output elements across all shards.
    total: usize,
    /// Path-window length for the per-shard merges (`0` = unwindowed):
    /// resolved at plan time from `merge.kway_segment_elems` (auto =
    /// `C/(k+1)`), so every shard merges its rank window in
    /// `(k+1)·L`-bounded segments like the flat segmented engine.
    seg_elems: usize,
    /// Requested leaf kernel (`merge.kernel`), resolved per shard at
    /// execute time so two-run shards hit the same pairwise leaf
    /// kernels as the in-process engines.
    kernel: MergeKernel,
}

impl<R: Record> std::fmt::Debug for ShardGroup<R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardGroup")
            .field("parent_id", &self.parent_id)
            .field("shards", &self.segments.len())
            .field("total", &self.total)
            .finish()
    }
}

/// One shard's handle into its [`ShardGroup`]: which segment of the
/// plan this sub-job executes. Carried by [`JobKind::CompactShard`];
/// constructed only by the dispatcher's shard expansion (clients
/// cannot submit shards directly).
#[derive(Debug, Clone)]
pub struct ShardTask<R: Record = i32> {
    group: Arc<ShardGroup<R>>,
    index: usize,
}

impl<R: Record> ShardTask<R> {
    /// Output elements this shard produces (its window length).
    pub fn len(&self) -> usize {
        self.group.segments[self.index].out_range.len()
    }

    /// True iff the shard's output window is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total shards in this shard's group.
    pub fn shard_count(&self) -> usize {
        self.group.segments.len()
    }
}

/// How many shards a compaction of `total` output elements with
/// `live_runs` non-empty runs should execute as. `1` means "do not
/// shard" (the flat/tree engines handle it in-process).
///
/// The sharded route shares the flat engine's run-count cap
/// (`kway_flat_max_k`): each shard performs the same k-way loser-tree
/// merge the knob governs, and the cap also bounds the dispatcher-side
/// planning cost (each boundary search is `O(k²·log²(max run))`), so a
/// compaction with thousands of runs cannot stall dispatch while being
/// planned — it falls to the pairwise tree on a worker instead.
///
/// Qualifying jobs get at least `threads_per_job` shards: each shard
/// merges *sequentially*, so fewer concurrent shards than the flat
/// engine's thread count would reduce the job's parallelism on a
/// borderline total (shards then run somewhat smaller than
/// `compact_shard_min_len`, never smaller than `2·min_len/threads`).
pub(crate) fn shard_count(cfg: &MergeflowConfig, live_runs: usize, total: usize) -> usize {
    if !cfg.compact_sharding || live_runs < 2 || live_runs > cfg.kway_flat_max_k {
        return 1;
    }
    let s = total / effective_shard_min_len(cfg, total);
    if s < 2 {
        return 1;
    }
    s.max(cfg.threads_per_job).min(MAX_SHARDS)
}

/// Expand a qualifying `Compact` job into one sub-job per shard; any
/// other job (including compactions below the sharding threshold) is
/// returned unchanged. Called by the dispatcher before dispatch, so
/// every returned job flows through the normal in-flight accounting.
///
/// Planning cost is one [`kway_rank_split`] per interior shard
/// boundary — `O(S·k²·log²(max run))` comparisons, vanishing against
/// the `Θ(total)` merge the shards then perform in parallel. Planning
/// runs *sequentially on the dispatcher thread* on purpose: routing
/// the searches through the pool would make the dispatcher's scoped
/// wait help-steal whole queued job closures (FIFO ahead of the
/// microsecond-scale searches) and stall all dispatch behind them —
/// the pooled partition is for the merge engines, which already own a
/// worker (see
/// [`partition_kway_merge_path_with_pool`](crate::mergepath::partition_kway_merge_path_with_pool)).
/// The stall this can cost other traffic is bounded by the caps: at
/// the extreme (`k = kway_flat_max_k` runs, [`MAX_SHARDS`] shards —
/// i.e. a multi-gigabyte compaction) planning is on the order of a
/// second, against the tens of seconds that job spends merging;
/// operators who care more about dispatch latency than giant-job
/// throughput raise `compact_shard_min_len`.
///
/// [`MAX_SHARDS`]: self::MAX_SHARDS
/// [`kway_rank_split`]: crate::mergepath::kway_rank_split
pub(crate) fn maybe_expand<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    job: Job<R>,
) -> Vec<Job<R>> {
    let Job { id, kind, enqueued_at, reply } = job;
    let runs = match kind {
        JobKind::Compact { runs } => runs,
        other => return vec![Job { id, kind: other, enqueued_at, reply }],
    };
    let total: usize = runs.iter().map(|r| r.len()).sum();
    let live_runs = runs.iter().filter(|r| !r.is_empty()).count();
    let shards = shard_count(cfg, live_runs, total);
    if shards < 2 {
        return vec![Job { id, kind: JobKind::Compact { runs }, enqueued_at, reply }];
    }
    let segments = {
        let refs: Vec<&[ByKey<R>]> = runs.iter().map(|r| record::as_keyed(r)).collect();
        partition_kway_merge_path(&refs, shards)
    };
    let queue_wait_ns =
        u64::try_from(enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    let group = Arc::new(ShardGroup {
        seg_elems: cfg
            .effective_kway_segment_elems(std::mem::size_of::<R>(), runs.len()),
        kernel: cfg.kernel,
        runs,
        segments,
        // Fully tiled by the shard windows — every slot written exactly
        // once before the stitched read (see crate::uninit_vec).
        out: SharedOut::new(crate::uninit_vec(total)),
        remaining: AtomicUsize::new(shards),
        parent_id: id,
        enqueued_at,
        queue_wait_ns,
        total,
    });
    stats.compact_shards.add(shards as u64);
    (0..shards)
        .map(|index| Job {
            id,
            kind: JobKind::CompactShard {
                shard: ShardTask { group: Arc::clone(&group), index },
            },
            enqueued_at,
            // Every shard carries a clone; only the last-finishing
            // shard actually sends through it.
            reply: reply.clone(),
        })
        .collect()
}

/// Execute one shard: stable loser-tree merge of its per-run slices
/// into its exclusive output window — in `(k+1)·L`-bounded path
/// windows when the group was planned with segmented merging (see
/// [`ShardGroup::seg_elems`]; bit-identical either way). The shard
/// that completes the group stitches (takes the fully-tiled buffer)
/// and replies on the parent's channel with backend
/// [`BACKEND_SHARDED`].
pub(crate) fn execute_shard<R: Record>(
    shard: ShardTask<R>,
    reply: &std::sync::mpsc::Sender<JobResult<R>>,
    stats: &ServiceStats,
) {
    let group = &*shard.group;
    let seg = &group.segments[shard.index];
    if !seg.is_empty() {
        let parts: Vec<&[ByKey<R>]> = seg
            .run_ranges
            .iter()
            .zip(&group.runs)
            .map(|(r, run)| record::as_keyed(&run[r.clone()]))
            .collect();
        // SAFETY: shard windows are disjoint and tile [0, total) (k-way
        // partition invariants), so this shard has exclusive access to
        // its window for the lifetime of the borrow; `base` was cached
        // before the group was shared, so no `&mut Vec` aliases here.
        let window = unsafe {
            std::slice::from_raw_parts_mut(
                group.out.base().add(seg.out_range.start),
                seg.out_range.len(),
            )
        };
        if group.seg_elems > 0 {
            stats.segmented_shard_merges.inc();
        }
        loser_tree_merge_segmented_with(
            &parts,
            record::as_keyed_mut(window),
            group.seg_elems,
            LeafKernel::select(group.kernel),
        );
    }
    stats.compact_shards_completed.inc();
    // AcqRel: our window writes happen-before the final shard's read of
    // the whole buffer.
    if group.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // SAFETY: all shards have finished writing (we observed the
        // counter reach zero with Acquire), so we are the only thread
        // touching the buffer.
        let output = unsafe { group.out.take() };
        let latency_ns =
            u64::try_from(group.enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
        stats.record_completion(
            BACKEND_SHARDED,
            group.total as u64,
            latency_ns,
            group.queue_wait_ns,
        );
        // Receiver may have been dropped (client gave up) — fine.
        let _ = reply.send(JobResult {
            id: group.parent_id,
            output,
            backend: BACKEND_SHARDED,
            latency_ns,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{gen_record_runs, gen_sorted_runs, WorkloadKind};
    use crate::mergepath::kway::loser_tree_merge;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    fn cfg_with(min_len: usize) -> MergeflowConfig {
        MergeflowConfig {
            compact_shard_min_len: min_len,
            // threads_per_job = 2 keeps S = total/min_len exact in the
            // expectations below (no threads floor kicking in).
            threads_per_job: 2,
            ..Default::default()
        }
    }

    #[test]
    fn shard_count_thresholds() {
        let cfg = cfg_with(1000);
        assert_eq!(shard_count(&cfg, 4, 999), 1, "below one shard of data");
        assert_eq!(shard_count(&cfg, 4, 1999), 1, "below two shards");
        assert_eq!(shard_count(&cfg, 4, 2000), 2, "exactly two shards");
        assert_eq!(shard_count(&cfg, 4, 10_500), 10);
        assert_eq!(shard_count(&cfg, 1, 10_500), 1, "single live run never shards");
        assert_eq!(shard_count(&cfg, 0, 0), 1);
        let mut off = cfg_with(1000);
        off.compact_sharding = false;
        assert_eq!(shard_count(&off, 8, 1 << 30), 1, "bool knob disables sharding");
        assert_eq!(shard_count(&cfg_with(1), 2, 1 << 30), MAX_SHARDS, "capped");
        // The sharded route inherits the flat engine's k cap: beyond it
        // (or with the flat engine disabled) the tree handles the job.
        let k_cap = cfg.kway_flat_max_k;
        assert_eq!(shard_count(&cfg, k_cap, 1 << 30), MAX_SHARDS);
        assert_eq!(shard_count(&cfg, k_cap + 1, 1 << 30), 1, "k over flat cap");
        // `kway_flat_max_k = 1` is the off spelling (0 now means
        // auto-calibrate at service start; k ≥ 2 everywhere makes 1
        // unreachable, i.e. off).
        let mut flat_off = cfg_with(1000);
        flat_off.kway_flat_max_k = 1;
        assert_eq!(shard_count(&flat_off, 4, 1 << 30), 1, "flat engine off");
        // Threads floor: a qualifying job never gets fewer shards than
        // threads_per_job (sharding must not reduce parallelism), but
        // the floor never forces sharding below the 2·min_len bar.
        let mut four = cfg_with(1000);
        four.threads_per_job = 4;
        assert_eq!(shard_count(&four, 4, 1999), 1, "below the 2-shard bar");
        assert_eq!(shard_count(&four, 4, 2000), 4, "floored at threads_per_job");
        assert_eq!(shard_count(&four, 4, 10_500), 10, "floor inactive past it");
    }

    #[test]
    fn auto_shard_len_tracks_workers() {
        // min_len = 0 → auto: total/workers clamped to the measured
        // floor, so a qualifying job splits into ~workers shards.
        let mut auto = cfg_with(0);
        auto.workers = 4;
        assert_eq!(
            effective_shard_min_len(&auto, 8 * AUTO_SHARD_FLOOR),
            2 * AUTO_SHARD_FLOOR
        );
        assert_eq!(shard_count(&auto, 8, 8 * AUTO_SHARD_FLOOR), 4, "~one per worker");
        // Below the floor, auto never shrinks shards further...
        assert_eq!(effective_shard_min_len(&auto, AUTO_SHARD_FLOOR), AUTO_SHARD_FLOOR);
        // ...so borderline totals do not shard at all (< 2 shards).
        assert_eq!(shard_count(&auto, 8, AUTO_SHARD_FLOOR + 1), 1);
        assert_eq!(shard_count(&auto, 8, 2 * AUTO_SHARD_FLOOR), 2);
        // An explicit min_len is used as-is.
        assert_eq!(effective_shard_min_len(&cfg_with(1000), 1 << 30), 1000);
        // A lowered dispatch.shard_floor (pinned or calibrated) moves
        // the clamp: totals the model floor would leave unsharded now
        // split.
        let mut low = cfg_with(0);
        low.workers = 4;
        low.shard_floor = 1 << 15;
        assert_eq!(effective_shard_min_len(&low, 1 << 16), 1 << 15);
        assert_eq!(shard_count(&low, 8, 1 << 16), 2);
        // The u32 clamp guards absurd totals on huge worker counts.
        let mut one = cfg_with(0);
        one.workers = 1;
        assert_eq!(
            effective_shard_min_len(&one, usize::MAX),
            u32::MAX as usize,
            "auto shard length is clamped to u32::MAX"
        );
    }

    #[test]
    fn expand_leaves_small_jobs_alone() {
        let cfg = cfg_with(1 << 20);
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let job = Job {
            id: 7,
            kind: JobKind::Compact { runs: vec![vec![1, 3], vec![2, 4]] },
            enqueued_at: Instant::now(),
            reply: tx,
        };
        let out = maybe_expand(&cfg, &stats, job);
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0].kind, JobKind::Compact { .. }));
        assert_eq!(stats.compact_shards.get(), 0);
    }

    #[test]
    fn expand_and_execute_stitches_bit_identical() {
        // Drive the shard path directly (no service): expand, execute
        // every sub-job in arbitrary order, check the stitched reply.
        let cfg = cfg_with(512);
        let stats = ServiceStats::new();
        let runs = gen_sorted_runs(WorkloadKind::Skewed, 6, 700, 11);
        let mut expected = vec![0i32; 4200];
        {
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            loser_tree_merge(&refs, &mut expected);
        }
        let (tx, rx) = channel();
        let job = Job {
            id: 42,
            kind: JobKind::Compact { runs },
            enqueued_at: Instant::now(),
            reply: tx,
        };
        let subs = maybe_expand(&cfg, &stats, job);
        assert_eq!(subs.len(), 4200 / 512); // 8 shards
        assert_eq!(stats.compact_shards.get(), subs.len() as u64);
        // Execute out of order: completion must not depend on ordering.
        for sub in subs.into_iter().rev() {
            match sub.kind {
                JobKind::CompactShard { shard } => {
                    assert!(shard.shard_count() >= 2);
                    execute_shard(shard, &sub.reply, &stats);
                }
                _ => unreachable!("expansion must yield only shards"),
            }
        }
        let res = rx.try_recv().expect("last shard must reply exactly once");
        assert!(rx.try_recv().is_err(), "only one reply for the group");
        assert_eq!(res.id, 42);
        assert_eq!(res.backend, BACKEND_SHARDED);
        assert_eq!(res.output, expected);
        assert_eq!(stats.compact_shards_completed.get(), 8);
        assert_eq!(stats.sharded_jobs.get(), 1);
        assert_eq!(stats.completed.get(), 1);
    }

    #[test]
    fn segmented_shard_merges_are_bit_identical_and_counted() {
        // Tiny explicit window: every shard merges through many bounded
        // windows; the stitched result must not change by a bit.
        let mut cfg = cfg_with(512);
        cfg.segmented = true;
        cfg.kway_segment_elems = 64;
        let stats = ServiceStats::new();
        let runs = gen_sorted_runs(WorkloadKind::Skewed, 6, 700, 11);
        let mut expected = vec![0i32; 4200];
        {
            let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
            loser_tree_merge(&refs, &mut expected);
        }
        let (tx, rx) = channel();
        let job = Job {
            id: 43,
            kind: JobKind::Compact { runs },
            enqueued_at: Instant::now(),
            reply: tx,
        };
        let subs = maybe_expand(&cfg, &stats, job);
        let n_shards = subs.len();
        assert!(n_shards >= 2);
        for sub in subs {
            match sub.kind {
                JobKind::CompactShard { shard } => execute_shard(shard, &sub.reply, &stats),
                _ => unreachable!(),
            }
        }
        assert_eq!(rx.try_recv().unwrap().output, expected);
        assert_eq!(stats.segmented_shard_merges.get(), n_shards as u64);
        // With segmented merging off the counter stays put.
        let mut off = cfg_with(512);
        off.segmented = false;
        let runs = gen_sorted_runs(WorkloadKind::Uniform, 4, 600, 12);
        let (tx, rx) = channel();
        let job =
            Job { id: 44, kind: JobKind::Compact { runs }, enqueued_at: Instant::now(), reply: tx };
        for sub in maybe_expand(&off, &stats, job) {
            match sub.kind {
                JobKind::CompactShard { shard } => execute_shard(shard, &sub.reply, &stats),
                _ => unreachable!(),
            }
        }
        let _ = rx.try_recv().unwrap();
        assert_eq!(stats.segmented_shard_merges.get(), n_shards as u64);
    }

    #[test]
    fn expand_handles_empty_runs_in_the_mix() {
        let cfg = cfg_with(64);
        let stats = ServiceStats::new();
        let mut runs = gen_sorted_runs(WorkloadKind::Uniform, 3, 200, 5);
        runs.insert(1, vec![]);
        runs.push(vec![]);
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let (tx, rx) = channel();
        let job =
            Job { id: 1, kind: JobKind::Compact { runs }, enqueued_at: Instant::now(), reply: tx };
        let subs = maybe_expand(&cfg, &stats, job);
        assert!(subs.len() >= 2);
        for sub in subs {
            match sub.kind {
                JobKind::CompactShard { shard } => execute_shard(shard, &sub.reply, &stats),
                _ => unreachable!(),
            }
        }
        assert_eq!(rx.try_recv().unwrap().output, expected);
    }

    #[test]
    fn sharded_records_keep_stable_tie_order() {
        // Payload-carrying records with dense duplicate keys: the
        // stitched shard output must equal the stable oracle (flatten
        // in run order, stable-sort by key) bit for bit.
        let cfg = cfg_with(256);
        let stats = ServiceStats::new();
        let runs = gen_record_runs(WorkloadKind::Skewed, 5, 600, 21);
        let mut expected: Vec<(u64, u64)> = runs.iter().flatten().copied().collect();
        expected.sort_by_key(|r| r.0); // stable: ties keep run/offset order
        let (tx, rx) = channel();
        let job =
            Job { id: 9, kind: JobKind::Compact { runs }, enqueued_at: Instant::now(), reply: tx };
        let subs = maybe_expand(&cfg, &stats, job);
        assert!(subs.len() >= 2, "record job must shard");
        for sub in subs.into_iter().rev() {
            match sub.kind {
                JobKind::CompactShard { shard } => execute_shard(shard, &sub.reply, &stats),
                _ => unreachable!(),
            }
        }
        assert_eq!(rx.try_recv().unwrap().output, expected);
    }
}
