//! Bounded MPMC admission queue with back-pressure.
//!
//! This is the *client-facing* half of the coordinator's flow control,
//! with two admission modes:
//!
//! - [`BoundedQueue::try_push`] rejects when full, so overload surfaces
//!   at `submit` instead of growing unbounded memory (fail-fast mode,
//!   used for whole jobs — and for the *first* message of the one-shot
//!   `Compact` wrapper, which is its admission decision);
//! - [`BoundedQueue::push`] blocks until space frees, used for the
//!   chunk messages of admitted streaming compaction sessions
//!   ([`super::session`]): the session is the admitted unit, and from
//!   then on a full queue *pauses the feeder* instead of failing it —
//!   ingest back-pressure without forcing clients to implement retry
//!   (and without a big job spuriously rejecting itself on its own
//!   queued chunks).
//!
//! The second half is the dispatcher's in-flight semaphore, which stops
//! dispatch from outrunning the workers — note that a `Compact` job may
//! expand into several `CompactShard` sub-jobs *after* popping (see
//! [`super::shard`]), and a session message may unlock eager
//! `StreamShard`s, each taking its own in-flight slot, so one queue
//! entry can represent several units of pool work.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Queue at capacity (reject-mode push).
    Full,
    /// Queue closed for shutdown.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> std::fmt::Debug for BoundedQueue<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BoundedQueue")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .finish()
    }
}

impl<T> BoundedQueue<T> {
    /// Queue with the given capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Current length.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the queue is at capacity right now. A racy snapshot by
    /// nature — used as the fail-fast admission gate for one-shot
    /// compactions, whose chunk messages then use blocking [`push`]
    /// for flow control (see the module docs).
    ///
    /// [`push`]: Self::push
    pub fn is_full(&self) -> bool {
        self.inner.lock().unwrap().items.len() >= self.capacity
    }

    /// Reject-mode push: fails fast when full (service back-pressure).
    pub fn try_push(&self, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for space (or closure).
    pub fn push(&self, item: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pop one item, waiting up to `timeout`. `None` on timeout or when
    /// closed-and-drained.
    ///
    /// The deadline is computed once up front and each condvar wait only
    /// covers the *remaining* time — a spurious wakeup (or a racing
    /// consumer winning the item) must not re-arm the full timeout, or
    /// total blocking time would be unbounded under contention.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        // `Instant + Duration` panics on overflow, so a huge timeout
        // (e.g. `Duration::MAX` as block-forever) maps to "no deadline".
        let deadline = Instant::now().checked_add(timeout);
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return None;
                    }
                    let (guard, _res) = self.not_empty.wait_timeout(g, d - now).unwrap();
                    // Loop re-checks the queue first, so a wakeup that
                    // races the deadline still gets one final pop.
                    g = guard;
                }
                None => g = self.not_empty.wait(g).unwrap(),
            }
        }
    }

    /// Drain up to `max` items without blocking (batch assembly).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = g.items.len().min(max);
        let out: Vec<T> = g.items.drain(..take).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Steal up to `max` items from the *front* while `stealable`
    /// approves each (work stealing between dispatcher shards). The
    /// front-only discipline stops at the first refused item, so the
    /// relative order of everything left behind — in particular a
    /// streaming session's ordered message sequence — is untouched, and
    /// a session message never migrates off its owning shard.
    pub fn steal_front(&self, max: usize, stealable: impl Fn(&T) -> bool) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < max {
            match g.items.front() {
                Some(item) if stealable(item) => {
                    out.push(g.items.pop_front().expect("front was Some"));
                }
                _ => break,
            }
        }
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Close the queue: pending items remain poppable, new pushes fail.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether `close` was called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(10);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(i));
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn try_push_rejects_when_full() {
        let q = BoundedQueue::new(2);
        assert!(!q.is_full());
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(q.is_full());
        assert_eq!(q.try_push(3), Err(PushError::Full));
        q.pop_timeout(Duration::from_millis(1));
        assert!(!q.is_full());
        q.try_push(3).unwrap();
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(PushError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(7));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn blocking_push_unblocks_on_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push(2));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(1));
        h.join().unwrap().unwrap();
        assert_eq!(q.pop_timeout(Duration::from_millis(100)), Some(2));
    }

    #[test]
    fn drain_up_to_takes_at_most_max() {
        let q = BoundedQueue::new(10);
        for i in 0..7 {
            q.try_push(i).unwrap();
        }
        let batch = q.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 3);
        assert!(q.drain_up_to(0).is_empty());
    }

    #[test]
    fn steal_front_stops_at_first_refusal() {
        let q = BoundedQueue::new(10);
        for i in 0..6 {
            q.try_push(i).unwrap();
        }
        // Odd items are "session messages": 0 is taken, 1 blocks the
        // scan even though 2 and 4 would qualify.
        let stolen = q.steal_front(10, |x| x % 2 == 0);
        assert_eq!(stolen, vec![0]);
        assert_eq!(q.len(), 5);
        // The remaining order is untouched.
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        let stolen = q.steal_front(2, |x| x % 2 == 0);
        assert_eq!(stolen, vec![2], "3 refuses before the max of 2 is reached");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(3));
        let stolen = q.steal_front(1, |_| true);
        assert_eq!(stolen, vec![4], "max = 1 takes exactly one even when more qualify");
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(5));
        assert!(q.steal_front(4, |_| true).is_empty());
    }

    #[test]
    fn pop_timeout_respects_deadline() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(50)), None);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(40), "returned early: {waited:?}");
        assert!(waited < Duration::from_millis(2000), "deadline overshot: {waited:?}");
    }

    #[test]
    fn producer_consumer_threads() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 200;
        let qp = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..total {
                qp.push(i).unwrap();
            }
            qp.close();
        });
        let mut got = vec![];
        while let Some(x) = q.pop_timeout(Duration::from_millis(200)) {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..total).collect::<Vec<_>>());
    }
}
