//! The serving layer (L3): a merge/sort/compaction job service in the
//! style of an inference-serving router — bounded admission queue,
//! dynamic batcher, size-aware backend router (native Merge Path vs
//! AOT XLA executable), persistent worker pool, and service metrics.
//!
//! The paper's contribution (Merge Path partitioning) is the *kernel*
//! this service schedules: every merge job is executed with perfectly
//! load-balanced segments across `threads_per_job` threads, and large
//! jobs can use the cache-efficient segmented variant (§4.3) by
//! setting `merge.segment_len`. Large compactions are additionally
//! split by output rank into independent [`shard`] sub-jobs — the
//! paper's equipartition property applied at the job level.
//!
//! See `docs/ARCHITECTURE.md` for the full job flow
//! (`submit → queue → execute_job → shard / flat / tree`).

pub mod job;
pub mod queue;
pub mod service;
pub mod shard;
pub mod stats;

pub use job::{Job, JobHandle, JobKind, JobResult};
pub use queue::{BoundedQueue, PushError};
pub use service::MergeService;
pub use shard::ShardTask;
pub use stats::ServiceStats;
