//! The serving layer (L3): a merge/sort/compaction job service in the
//! style of an inference-serving router — bounded admission queue,
//! dynamic batcher, size-aware backend router (native Merge Path vs
//! AOT XLA executable), persistent worker pool, and service metrics.
//!
//! The whole layer is **generic over keyed records**
//! ([`crate::record::Record`]): `MergeService<R>`, `JobKind<R>`,
//! `JobResult<R>`, sessions and shards all carry `Vec<R>` payloads and
//! merge by key with a guaranteed-stable tie order (equal keys keep
//! run-index-then-offset order). The default parameter `R = i32` keeps
//! the classic scalar spelling source-compatible; key-value compaction
//! is `MergeService<(K, V)>` — see the [`crate::record`] docs for the
//! contract and the quickstart.
//!
//! The paper's contribution (Merge Path partitioning) is the *kernel*
//! this service schedules: every merge job is executed with perfectly
//! load-balanced segments across `threads_per_job` threads, and large
//! jobs can use the cache-efficient segmented variant (§4.3) by
//! setting `merge.segment_len`. Large compactions are additionally
//! split by output rank into independent [`shard`] sub-jobs — the
//! paper's equipartition property applied at the job level — and can
//! be *streamed in*: a [`session::CompactionSession`] feeds runs chunk
//! by chunk while the dispatcher eagerly merges the already-settled
//! output prefix, overlapping ingest and merge end to end.
//!
//! The control plane itself is sharded (`dispatch.shards`): each
//! dispatcher shard owns a private admission queue and session-table
//! slice keyed by id hash, idle shards work-steal one-shot jobs from
//! loaded peers, and the `0 = auto-calibrate` tuning knobs are
//! resolved at startup by [`calibrate`]'s in-process probe merges.
//!
//! See `docs/ARCHITECTURE.md` for the full job flow
//! (`submit → queue → execute_job → shard / flat / tree`) and the
//! streaming session protocol.

pub mod calibrate;
pub mod job;
pub mod queue;
pub mod service;
pub mod session;
pub mod shard;
pub mod stats;

pub use calibrate::CalibrationReport;
pub use job::{Job, JobHandle, JobKind, JobResult};
pub use queue::{BoundedQueue, PushError};
pub use service::{I32MergeService, MergeService, StoreSink};
pub use session::CompactionSession;
pub use shard::ShardTask;
pub use stats::{DispatchShardStats, ServiceStats};
