//! The coordinator service: admission → dynamic batching → shard
//! expansion → routing → execution → reply.
//!
//! The whole service is generic over keyed records ([`Record`]) — the
//! default parameter `i32` keeps the classic scalar surface spelling
//! (`MergeService`, `JobKind`, ...) source-compatible. All merging is
//! stable: equal keys keep run-index-then-offset order (pairwise, all
//! of A's ties precede B's; sorts are stable by key) — see
//! [`crate::record`].
//!
//! The control plane is sharded (`dispatch.shards`, default auto from
//! the core count): each dispatcher shard owns a private admission
//! queue and session-table slice, keyed by job/session id hash, and
//! assembles batches from its own queue (dispatch on `max_batch` or
//! `batch_timeout_us`, whichever first), expands oversized compactions
//! into rank shards ([`super::shard`]), and hands jobs to the shared
//! worker pool behind one shared in-flight semaphore. Idle shards
//! steal one-shot jobs from the front of loaded peers' queues
//! (`dispatch.steal`); streaming-session messages are never stolen, so
//! a session's ordered message sequence is always absorbed by its
//! owning shard. With `dispatch.shards = 1` the control plane is
//! exactly the historical single dispatcher. The router sends a merge
//! job to
//! the XLA backend when an AOT artifact with the exact baked shape
//! exists (`Backend::Xla`/`Auto`) **and** the record type is the baked
//! `i32` (see [`crate::record::KeyedI32`] — any other instantiation
//! deterministically routes native), to the segmented native path when
//! `segment_len` is configured and the job is large, and to the plain
//! native Merge Path otherwise. Compactions route by shape — see
//! `run_compaction` below — and always execute on the coordinator's
//! persistent pool (merge engines receive the pool handle; nested
//! fork-join from inside a worker is deadlock-free because the pool's
//! scoped wait is helping, see [`WorkerPool::run_scoped`]).

use super::calibrate;
use super::job::{Job, JobHandle, JobKind, JobResult};
use super::queue::{BoundedQueue, PushError};
use super::session::{self, CompactionSession, SessionTable};
use super::shard;
use super::stats::{DispatchShardStats, ServiceStats};
use crate::config::{Backend, MergeflowConfig};
use crate::exec::WorkerPool;
use crate::mergepath::kernel::{tagged_backend, KernelKind, LeafKernel, MergeKernel};
use crate::mergepath::{
    concat_for_inplace, parallel_inplace_merge_with_pool, parallel_kway_merge_with,
    parallel_merge_sort_with_pool_kernel, parallel_merge_with_pool_kernel,
    segmented_kway_merge_with, segmented_parallel_merge_with_pool_kernel,
    KwaySegmentedConfig, SegmentedConfig,
};
use crate::record::{self, ByKey, Record};
use crate::runtime::XlaExecutor;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Durability hooks a persistent store plugs into the service (the
/// concrete implementation is [`crate::store::StoreBridge`]; the trait
/// lives here so the coordinator stays ignorant of file formats).
/// Attached once via [`MergeService::attach_store`]; `JobKind::Spill`
/// jobs call [`StoreSink::spill`] from pool workers, and the
/// synchronous `JobKind::Flush` path calls [`StoreSink::flush`] on the
/// submitting thread — deliberately *not* on a pool worker, since a
/// flush drives whole compactions through the service and must never
/// occupy the workers those compactions need.
pub trait StoreSink<R: Record>: Send + Sync {
    /// Persist one sealed, sorted run to level 0. Returns the bytes
    /// written.
    fn spill(&self, run: &[R]) -> Result<u64>;
    /// Run compaction passes against `svc` until the store is within
    /// policy. Returns the number of compactions installed.
    fn flush(&self, svc: &MergeService<R>) -> Result<u64>;
    /// Human-readable store description (the `STORE_STATS` wire verb).
    fn stats_text(&self) -> String;
}

/// The attach-once slot a service and its dispatcher share. The
/// dispatcher thread captures the slot at `start()` — before any store
/// exists — so attachment is a later, lock-free publication rather
/// than a service restart.
type StoreSlot<R> = Arc<OnceLock<Arc<dyn StoreSink<R>>>>;

/// Counting semaphore bounding in-flight (dispatched, not yet
/// completed) jobs — this is what propagates back-pressure from slow
/// workers to the admission queue.
#[derive(Debug)]
struct InFlight {
    limit: usize,
    count: Mutex<usize>,
    cv: Condvar,
}

impl InFlight {
    fn new(limit: usize) -> Self {
        Self { limit: limit.max(1), count: Mutex::new(0), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c >= self.limit {
            c = self.cv.wait(c).unwrap();
        }
        *c += 1;
    }

    fn release(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        // notify_all: both acquire-waiters (dispatch loop) and the
        // drain-waiter (dispatcher shutdown) share this condvar.
        self.cv.notify_all();
    }

    /// Block until no job is in flight (dispatcher shutdown barrier).
    fn wait_idle(&self) {
        let mut c = self.count.lock().unwrap();
        while *c > 0 {
            c = self.cv.wait(c).unwrap();
        }
    }
}

/// Releases one in-flight slot when dropped — *after* dropping its
/// pool handle. Job closures must not complete still owning an
/// `Arc<WorkerPool>`: the dispatcher treats "in-flight reached zero"
/// as "I hold the last pool handle" before it exits and joins the
/// workers, and a worker that dropped the final `Arc` itself would
/// run `WorkerPool::drop` on a pool thread and self-join (hang).
/// Dropping on unwind also keeps a panicking job from leaking its
/// slot, which would wedge both dispatch and shutdown.
///
/// The guard also carries the job's plan-time working-set estimate:
/// the dispatcher charges it to [`ServiceStats::resident_bytes`] at
/// dispatch, and the drop releases it — on unwind too, so a panicking
/// job cannot permanently inflate the figure budget admission checks
/// against.
struct SlotGuard {
    pool: Option<Arc<WorkerPool>>,
    in_flight: Arc<InFlight>,
    stats: Arc<ServiceStats>,
    est_bytes: u64,
}

impl Drop for SlotGuard {
    fn drop(&mut self) {
        self.pool.take();
        self.stats.resident_bytes.sub(self.est_bytes);
        self.in_flight.release();
    }
}

/// One dispatcher shard's control-plane slice: a private admission
/// queue and session table, owned by one dispatcher thread. Jobs and
/// sessions land on a shard by id hash ([`shard_index`]); with
/// `dispatch.shards = 1` everything routes to shard 0 and the control
/// plane behaves exactly like the historical single dispatcher.
struct DispatchShard<R: Record> {
    queue: Arc<BoundedQueue<Job<R>>>,
    table: Arc<SessionTable<R>>,
}

/// Route a job/session id onto a dispatcher shard. Ids are sequential,
/// so the Fibonacci multiplicative hash is what spreads consecutive
/// ids across shards; a single shard degenerates to the identity
/// (always 0), keeping that configuration bit-identical to the
/// historical dispatcher.
fn shard_index(id: u64, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    (id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % shards
}

/// A running merge/sort service over records of type `R` (default:
/// the classic `i32` scalar workload). See [`crate::record`] for the
/// typed API and its stability contract.
pub struct MergeService<R: Record = i32> {
    cfg: MergeflowConfig,
    shards: Vec<DispatchShard<R>>,
    stats: Arc<ServiceStats>,
    runtime: Option<Arc<XlaExecutor>>,
    store: StoreSlot<R>,
    next_id: AtomicU64,
    dispatchers: Vec<std::thread::JoinHandle<()>>,
}

/// The classic `i32`-keyed service, spelled explicitly.
/// `MergeService`'s default record parameter means the bare name still
/// denotes this same type in type positions. (The pre-typed-API
/// `LegacyMergeService` shim has been removed; this alias is the
/// supported spelling.)
pub type I32MergeService = MergeService<i32>;

impl<R: Record> std::fmt::Debug for MergeService<R> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MergeService")
            .field("workers", &self.cfg.workers)
            .field("backend", &self.cfg.backend)
            .finish()
    }
}

impl<R: Record> MergeService<R> {
    /// Start the service. If the configured backend wants XLA, the
    /// artifact directory is opened now (fail fast); `Auto` degrades to
    /// native silently when artifacts are missing. (Whether merge jobs
    /// can actually offload additionally depends on `R` — only
    /// [`KeyedI32`](crate::record::KeyedI32) records fit the baked
    /// artifacts; everything else routes native deterministically.)
    pub fn start(cfg: MergeflowConfig) -> Result<Self> {
        let mut cfg = cfg;
        cfg.validate()?;
        // Resolve the `0 = auto-calibrate` knobs before anything reads
        // them (routing gates, shard planning, the session planner):
        // past this point the dispatchers and workers only ever see
        // concrete values. Which knobs were actually calibrated (vs
        // pinned by config) is captured first so the stats report 0
        // for pinned ones.
        let wanted_flat = cfg.kway_flat_max_k == 0;
        let wanted_floor = cfg.shard_floor == 0;
        let wanted_cache = cfg.segmented
            && cfg.kway_segment_elems == 0
            && cfg.segment_len == 0
            && cfg.cache_bytes == 0;
        let report = calibrate::apply(&mut cfg);
        let runtime = match cfg.backend {
            Backend::Native => None,
            Backend::Xla => {
                Some(XlaExecutor::start(std::path::Path::new(&cfg.artifacts_dir))?)
            }
            Backend::Auto => {
                XlaExecutor::start(std::path::Path::new(&cfg.artifacts_dir)).ok()
            }
        };
        let stats = Arc::new(ServiceStats::new());
        if let Some(report) = report {
            stats.record_calibration(
                if wanted_flat { cfg.kway_flat_max_k as u64 } else { 0 },
                if wanted_floor { cfg.shard_floor as u64 } else { 0 },
                if wanted_cache { cfg.cache_bytes as u64 } else { 0 },
                report.probe_ns,
            );
            eprintln!(
                "mergeflow: calibration ({}, ~{}K elems/ms) resolved \
                 kway_flat_max_k={} shard_floor={} cache_bytes={}",
                crate::metrics::fmt_ns(report.probe_ns),
                report.merge_elems_per_ms / 1000,
                cfg.kway_flat_max_k,
                cfg.shard_floor,
                cfg.cache_bytes,
            );
        }
        let pool = Arc::new(WorkerPool::new(cfg.workers));
        let store: StoreSlot<R> = Arc::new(OnceLock::new());
        let n = cfg.effective_dispatch_shards();
        let shard_stats = stats.init_dispatch_shards(n);
        let shards: Vec<DispatchShard<R>> = (0..n)
            .map(|_| DispatchShard {
                queue: Arc::new(BoundedQueue::<Job<R>>::new(cfg.queue_capacity)),
                table: Arc::new(SessionTable::<R>::default()),
            })
            .collect();
        // Every dispatcher sees every queue (for stealing) but only its
        // own session table — session messages route by id hash to
        // their owning shard and are never stolen, so no other shard
        // ever needs another's table.
        let queues: Vec<Arc<BoundedQueue<Job<R>>>> =
            shards.iter().map(|s| Arc::clone(&s.queue)).collect();
        let in_flight = Arc::new(InFlight::new(cfg.workers * 2));
        let dispatchers = (0..n)
            .map(|i| {
                let ctx = DispatcherCtx {
                    shard_idx: i,
                    cfg: cfg.clone(),
                    queues: queues.clone(),
                    table: Arc::clone(&shards[i].table),
                    pool: Arc::clone(&pool),
                    runtime: runtime.clone(),
                    stats: Arc::clone(&stats),
                    store: Arc::clone(&store),
                    in_flight: Arc::clone(&in_flight),
                    shard_stats: Arc::clone(&shard_stats[i]),
                };
                std::thread::Builder::new()
                    .name(format!("mergeflow-dispatcher-{i}"))
                    .spawn(move || dispatcher_loop(ctx))
                    .expect("spawn dispatcher")
            })
            .collect();

        Ok(Self {
            cfg,
            shards,
            stats,
            runtime,
            store,
            next_id: AtomicU64::new(1),
            dispatchers,
        })
    }

    /// The dispatcher shard owning `id` (jobs and sessions alike).
    fn shard_for(&self, id: u64) -> &DispatchShard<R> {
        &self.shards[shard_index(id, self.shards.len())]
    }

    /// Attach the persistent store's sink. At most one store per
    /// service lifetime; a second attach is an error. Jobs submitted
    /// before attachment that need the store (`Spill`, `Flush`) fail
    /// fast with a typed error rather than queueing.
    pub fn attach_store(&self, sink: Arc<dyn StoreSink<R>>) -> Result<()> {
        self.store
            .set(sink)
            .map_err(|_| Error::Service("a store is already attached".into()))
    }

    /// Whether a store sink is attached.
    pub fn has_store(&self) -> bool {
        self.store.get().is_some()
    }

    /// The attached store's description text (`STORE_STATS`), or
    /// `None` when no store is attached.
    pub fn store_stats_text(&self) -> Option<String> {
        self.store.get().map(|s| s.stats_text())
    }

    /// Whether an XLA runtime actually started for this service.
    /// `false` under `Backend::Native`, when `Backend::Auto` degraded
    /// (artifacts missing or the PJRT binding is the offline stub) —
    /// lets tests distinguish "no runtime" from "runtime still cold".
    pub fn xla_available(&self) -> bool {
        self.runtime.is_some()
    }

    /// Block until the XLA backend has compiled all artifacts (no-op /
    /// `false` when no XLA backend is configured). Useful before
    /// latency-sensitive load or in tests asserting the XLA route.
    pub fn wait_xla_warm(&self, timeout: Duration) -> bool {
        self.runtime
            .as_ref()
            .is_some_and(|rt| rt.wait_warm(timeout))
    }

    /// Service configuration.
    pub fn config(&self) -> &MergeflowConfig {
        &self.cfg
    }

    /// Live statistics.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Owning handle to the live statistics — for threads that must
    /// outlive any borrow of the service (the wire server's admission
    /// control and connection handlers count `BUSY` replies and reaps
    /// from their own threads).
    pub fn stats_arc(&self) -> Arc<ServiceStats> {
        Arc::clone(&self.stats)
    }

    /// Submit a job; fails fast with back-pressure when the queue is
    /// full or the input violates preconditions.
    ///
    /// `Compact` jobs are re-expressed as a streaming session
    /// ([`CompactionSession`]) — open, chunked feeds, seal — so the
    /// one-shot and streaming paths share one code path: sortedness is
    /// validated chunk by chunk (bounded work per call instead of one
    /// O(total) walk), and runs longer than
    /// `merge.compact_chunk_len` are fed round-robin so the dispatcher
    /// can start merging settled low ranks while later chunks are
    /// still being admitted.
    pub fn submit(&self, kind: JobKind<R>) -> Result<JobHandle<R>> {
        let kind = match kind {
            JobKind::Compact { runs } => return self.submit_compact(runs),
            JobKind::Flush => return self.submit_flush(),
            other => other,
        };
        // Per-input admission validation (the compact analogue is the
        // per-chunk check on the session feed path): each merge input
        // is checked independently, so the error names the offending
        // input and the walk is bounded by that input alone.
        if let JobKind::Merge { a, b } = &kind {
            for (name, input) in [("A", a.as_slice()), ("B", b.as_slice())] {
                if !record::is_sorted_by_key(input) {
                    self.stats.rejected.inc();
                    return Err(Error::InvalidInput(format!(
                        "merge input {name} is not sorted by key"
                    )));
                }
            }
        }
        // Spill preconditions, all fail-fast at admission: a store to
        // spill into, a non-empty run (a run file must have a key
        // range), and sortedness — a store run file *is* a sorted run,
        // and the worker-side writer rejecting it later could only
        // surface as a dropped reply channel.
        if let JobKind::Spill { run } = &kind {
            if self.store.get().is_none() {
                self.stats.rejected.inc();
                return Err(Error::Service(
                    "no store attached (configure store.dir and attach a StoreBridge)".into(),
                ));
            }
            if run.is_empty() {
                self.stats.rejected.inc();
                return Err(Error::InvalidInput("refusing to spill an empty run".into()));
            }
            if !record::is_sorted_by_key(run) {
                self.stats.rejected.inc();
                return Err(Error::InvalidInput("spill run is not sorted by key".into()));
            }
        }
        self.check_budget(estimated_job_bytes(&self.cfg, &kind))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let job = Job { id, kind, enqueued_at: Instant::now(), reply: tx };
        match self.shard_for(id).queue.try_push(job) {
            Ok(()) => {
                self.stats.submitted.inc();
                Ok(JobHandle::new(id, rx))
            }
            Err(PushError::Full) => {
                self.stats.rejected.inc();
                Err(Error::Service("queue full (back-pressure)".into()))
            }
            Err(PushError::Closed) => {
                self.stats.rejected.inc();
                Err(Error::Service("service shut down".into()))
            }
        }
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, kind: JobKind<R>) -> Result<JobResult<R>> {
        self.submit(kind)?.wait()
    }

    /// The synchronous `Flush` path: drive the attached store's
    /// compaction scheduler on the *caller's* thread until every level
    /// is within policy, then hand back a pre-completed handle. Runs
    /// here rather than on the pool because the compactions a flush
    /// drives are themselves pool jobs — a flush parked on a worker
    /// could deadlock a one-worker pool against its own work.
    fn submit_flush(&self) -> Result<JobHandle<R>> {
        let Some(sink) = self.store.get() else {
            self.stats.rejected.inc();
            return Err(Error::Service(
                "no store attached (configure store.dir and attach a StoreBridge)".into(),
            ));
        };
        let sink = Arc::clone(sink);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.stats.submitted.inc();
        let t0 = Instant::now();
        match sink.flush(self) {
            Ok(_installed) => {
                let latency_ns =
                    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
                self.stats.record_completion("store-flush", 0, latency_ns, 0);
                let (tx, rx) = channel();
                let _ = tx.send(JobResult {
                    id,
                    output: Vec::new(),
                    backend: "store-flush",
                    latency_ns,
                });
                Ok(JobHandle::new(id, rx))
            }
            Err(e) => {
                self.stats.rejected.inc();
                Err(e)
            }
        }
    }

    /// Open a streaming compaction of `runs` sorted runs: feed chunks
    /// through the returned [`CompactionSession`] as they become
    /// available, seal runs as they end, then `seal()` the session for
    /// a [`JobHandle`] to the merged output. The dispatcher plans and
    /// launches eager merge shards over the settled output prefix
    /// *while later chunks are still arriving* (see
    /// [`super::session`]); the run count is fixed up front because a
    /// surprise run could insert keys below already-merged ranks.
    pub fn open_compaction(&self, runs: usize) -> Result<CompactionSession<R>> {
        // Streaming clients get blocking (flow-control) feeds and
        // eager pre-seal planning.
        self.open_session(runs, true, true)
    }

    fn open_session(
        &self,
        runs: usize,
        blocking: bool,
        eager: bool,
    ) -> Result<CompactionSession<R>> {
        if self.shards[0].queue.is_closed() {
            return Err(Error::Service("service shut down".into()));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // `submitted` is counted at seal() — a session only becomes an
        // admitted job once its ingest completes, so the old invariant
        // (submitted = completed + rejected + in-flight) still holds
        // for sessions that are aborted or rejected mid-feed.
        self.stats.streamed_sessions.inc();
        // Session affinity: the whole session — every chunk, seal, and
        // abort reap — lives on the shard owning its id. Its ordered
        // message sequence is absorbed by that one dispatcher (steals
        // never take session messages), which is what preserves the
        // single-dispatcher session semantics per shard.
        let shard = self.shard_for(id);
        Ok(session::open(
            Arc::clone(&shard.queue),
            Arc::clone(&shard.table),
            Arc::clone(&self.stats),
            id,
            runs,
            blocking,
            eager,
            self.cfg.memory_budget as u64,
        ))
    }

    /// Budget admission: with `merge.memory_budget` configured, reject
    /// fast when `estimate` on top of what the service already holds
    /// resident would exceed it. Non-poisoning by construction —
    /// nothing was enqueued and no state changed, so the service keeps
    /// serving and the client may resubmit once completions (or
    /// streaming reclamation) bring the resident figure back down.
    fn check_budget(&self, estimate: u64) -> Result<()> {
        let budget = self.cfg.memory_budget as u64;
        if budget == 0 {
            return Ok(());
        }
        let resident = self.stats.resident_bytes.get();
        if estimate.saturating_add(resident) > budget {
            self.stats.rejected.inc();
            return Err(Error::Service(format!(
                "memory budget exceeded: job estimated at {estimate} B on top of \
                 {resident} B resident would pass merge.memory_budget={budget} B"
            )));
        }
        Ok(())
    }

    /// The one-shot compaction wrapper over the session protocol. The
    /// session runs in reject mode, so `submit`'s fail-fast contract is
    /// preserved: a full queue surfaces as an immediate back-pressure
    /// error (at whichever feed hits it) instead of blocking the caller.
    fn submit_compact(&self, runs: Vec<Vec<R>>) -> Result<JobHandle<R>> {
        // Cheap early-out before opening a session the queue clearly
        // has no room to carry (racy snapshot — probe the shard the
        // next allocated id would land on; the session's reject-mode
        // first push is the authoritative check).
        if self.shard_for(self.next_id.load(Ordering::Relaxed)).queue.is_full() {
            self.stats.rejected.inc();
            return Err(Error::Service("queue full (back-pressure)".into()));
        }
        // Budget admission for the whole compaction up front (the
        // session's own per-chunk budget checks are skipped in
        // reject mode — its ingest is this job's already-admitted
        // working set, and re-checking per chunk would self-reject).
        self.check_budget(compact_estimate(&self.cfg, &runs))?;
        // Chunked feeding only buys overlap when the dispatcher could
        // actually dispatch eager shards for this job (same gates as
        // the session planner); otherwise feed whole runs by move —
        // zero copies, fewer queue messages. And if no run is long
        // enough to chunk, ingest completes in one breath: register
        // the session with eager planning off, so the job
        // deterministically takes the classic routing instead of
        // paying eager copies that cannot buy overlap.
        let eager_possible = self.cfg.compact_eager_min_len > 0
            && runs.len() >= 2
            && runs.len() <= self.cfg.kway_flat_max_k;
        let chunk_len = if eager_possible { self.cfg.compact_chunk_len } else { 0 };
        let will_chunk = chunk_len > 0 && runs.iter().any(|r| r.len() > chunk_len);
        let mut session = self.open_session(runs.len(), false, will_chunk)?;
        let fed = feed_round_robin(&mut session, runs, chunk_len);
        match fed {
            Ok(()) => session.seal(), // seal does its own stats accounting
            Err(e) => {
                // Invalid chunk or full-queue admission failure: the
                // dropped session aborts and its buffered chunks are
                // reaped; count the rejection here (the session never
                // counted an admission).
                self.stats.rejected.inc();
                Err(e)
            }
        }
    }

    /// Drain and stop. Pending jobs are completed first: every shard
    /// queue is closed up front (so no shard can keep admitting while
    /// another drains), then each dispatcher drains its own queue,
    /// waits on the shared in-flight barrier, and exits — the last one
    /// out provably holds the final pool handle and joins the workers.
    pub fn shutdown(mut self) {
        for s in &self.shards {
            s.queue.close();
        }
        for h in self.dispatchers.drain(..) {
            let _ = h.join();
        }
    }
}

impl<R: Record> Drop for MergeService<R> {
    fn drop(&mut self) {
        for s in &self.shards {
            s.queue.close();
        }
        for h in self.dispatchers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Feed a one-shot compaction's runs through a session. Runs at most
/// `chunk_len` long are fed whole *by move* (no copy — identical
/// ingest cost to the old by-value `Compact` message); longer runs are
/// sliced into `chunk_len` chunks and fed round-robin across runs, so
/// the sealed-rank frontier advances during ingest and the dispatcher
/// can overlap merging with the remaining feeds. `chunk_len == 0`
/// means never split.
fn feed_round_robin<R: Record>(
    session: &mut CompactionSession<R>,
    mut runs: Vec<Vec<R>>,
    chunk_len: usize,
) -> Result<()> {
    let chunk_len = if chunk_len == 0 { usize::MAX } else { chunk_len };
    let k = runs.len();
    let mut offs = vec![0usize; k];
    let mut done = vec![false; k];
    let mut remaining = k;
    while remaining > 0 {
        for i in 0..k {
            if done[i] {
                continue;
            }
            let len = runs[i].len();
            if offs[i] == 0 && len <= chunk_len {
                session.feed(i, std::mem::take(&mut runs[i]))?;
            } else {
                let end = offs[i].saturating_add(chunk_len).min(len);
                session.feed(i, runs[i][offs[i]..end].to_vec())?;
                offs[i] = end;
                if end < len {
                    continue;
                }
            }
            session.seal_run(i)?;
            done[i] = true;
            remaining -= 1;
        }
    }
    Ok(())
}

/// Plan-time estimate of a pairwise merge's peak working set in bytes:
/// inputs plus a full output buffer on the allocating routes, inputs
/// plus the *smaller* run on the in-place route (the only transient
/// [`concat_for_inplace`] pays). This asymmetry is the point of the
/// in-place kernel — under a tight `merge.memory_budget` it is what
/// keeps large merges admissible at all.
fn pairwise_estimate<R: Record>(cfg: &MergeflowConfig, a_len: usize, b_len: usize) -> u64 {
    let elem = std::mem::size_of::<R>() as u64;
    let total = a_len as u64 + b_len as u64;
    let extra = if cfg.inplace_route((a_len + b_len).saturating_mul(std::mem::size_of::<R>()))
    {
        a_len.min(b_len) as u64
    } else {
        total
    };
    (total + extra) * elem
}

/// Plan-time estimate of a compaction's peak working set: inputs plus
/// output for the k-way engines; the pairwise figure (which may route
/// in place) when exactly two runs survive.
fn compact_estimate<R: Record>(cfg: &MergeflowConfig, runs: &[Vec<R>]) -> u64 {
    if runs.len() == 2 {
        return pairwise_estimate::<R>(cfg, runs[0].len(), runs[1].len());
    }
    let elem = std::mem::size_of::<R>() as u64;
    let total: u64 = runs.iter().map(|r| r.len() as u64).sum();
    2 * total * elem
}

/// Plan-time working-set estimate for one dispatched job, charged to
/// [`ServiceStats::resident_bytes`] for the job's in-flight lifetime
/// (released by its [`SlotGuard`]). Session protocol messages estimate
/// zero — their ingest is accounted exactly, per chunk, by the session
/// layer.
fn estimated_job_bytes<R: Record>(cfg: &MergeflowConfig, kind: &JobKind<R>) -> u64 {
    let elem = std::mem::size_of::<R>() as u64;
    match kind {
        JobKind::Merge { a, b } => pairwise_estimate::<R>(cfg, a.len(), b.len()),
        JobKind::Sort { data } => 2 * data.len() as u64 * elem,
        JobKind::Compact { runs } => compact_estimate(cfg, runs),
        JobKind::CompactShard { shard } => 2 * shard.len() as u64 * elem,
        JobKind::StreamShard { shard } => 2 * shard.len() as u64 * elem,
        // A spill holds its run resident until the writer finishes;
        // the write path itself buffers O(block_bytes) on top, which
        // is noise at plan granularity. A flush never reaches the
        // dispatcher (intercepted at submit).
        JobKind::Spill { run } => run.len() as u64 * elem,
        JobKind::Flush => 0,
        JobKind::CompactChunk { .. }
        | JobKind::CompactSealRun { .. }
        | JobKind::CompactSeal { .. } => 0,
    }
}

/// Everything one dispatcher shard's loop needs, bundled so the spawn
/// site stays readable. `queues[shard_idx]` is this shard's own queue;
/// the rest are peers it may steal from.
struct DispatcherCtx<R: Record> {
    shard_idx: usize,
    cfg: MergeflowConfig,
    queues: Vec<Arc<BoundedQueue<Job<R>>>>,
    table: Arc<SessionTable<R>>,
    pool: Arc<WorkerPool>,
    runtime: Option<Arc<XlaExecutor>>,
    stats: Arc<ServiceStats>,
    store: StoreSlot<R>,
    in_flight: Arc<InFlight>,
    shard_stats: Arc<DispatchShardStats>,
}

fn dispatcher_loop<R: Record>(ctx: DispatcherCtx<R>) {
    let DispatcherCtx {
        shard_idx,
        cfg,
        queues,
        table,
        pool,
        runtime,
        stats,
        store,
        in_flight,
        shard_stats,
    } = ctx;
    let queue = &queues[shard_idx];
    let timeout = Duration::from_micros(cfg.batch_timeout_us.max(1));
    loop {
        // Free the buffered ingest of any sessions aborted since the
        // last iteration (runs on idle ticks too, so an abort on a
        // quiet service is still reclaimed within one poll interval).
        table.reap_aborted(&stats);
        shard_stats.depth.set(queue.len() as u64);
        // Block for the first job of a batch.
        let batch = match queue.pop_timeout(Duration::from_millis(50)) {
            Some(first) => {
                // Assemble the rest of the batch: wait at most
                // `timeout` for stragglers, cap at max_batch.
                let mut batch = vec![first];
                let deadline = Instant::now() + timeout;
                while batch.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match queue.pop_timeout(deadline - now) {
                        Some(j) => batch.push(j),
                        None => break,
                    }
                }
                batch
            }
            None => {
                if queue.is_closed() && queue.is_empty() {
                    // Admission is drained; now wait for execution
                    // across *all* shards (the semaphore is shared).
                    // Only once no job is in flight does the exiting
                    // dispatcher provably hold a final Arc<WorkerPool>,
                    // so the last shard out drops the last handle and
                    // joins the workers from its own thread — and
                    // shutdown() really does complete pending jobs
                    // first. Peers' leftover queues are their owners'
                    // to drain; every queue was closed before any join.
                    in_flight.wait_idle();
                    return;
                }
                // Idle tick: steal a batch from the deepest peer's
                // queue front. Only non-session jobs move — the scan
                // stops at the first session message, so a session's
                // ordered sequence never leaves its owning shard.
                if !cfg.dispatch_steal || queues.len() < 2 {
                    continue;
                }
                let victim = queues
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != shard_idx)
                    .map(|(_, q)| q)
                    .max_by_key(|q| q.len());
                let stolen = match victim {
                    Some(v) => v
                        .steal_front(cfg.max_batch, |j| {
                            !session::is_session_message(&j.kind)
                        }),
                    None => Vec::new(),
                };
                if stolen.is_empty() {
                    continue;
                }
                shard_stats.stolen_batches.inc();
                shard_stats.stolen_jobs.add(stolen.len() as u64);
                stolen
            }
        };
        stats.batches.inc();
        // Per-stage observability: how long each job of this batch sat
        // in admission before planning, and how stale the oldest one
        // was (the shard's queue-age gauge).
        let mut oldest_ns = 0u64;
        for job in &batch {
            let age_ns =
                u64::try_from(job.enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
            stats.stage_admission.record(age_ns.max(1));
            oldest_ns = oldest_ns.max(age_ns);
        }
        shard_stats.oldest_age_us.set(oldest_ns / 1_000);

        // Execute the batch on the pool: jobs own their data, so they
        // can be moved into 'static closures; a latch in run_scoped
        // style is unnecessary (each job replies on its own channel).
        // The in-flight semaphore keeps dispatch from outrunning the
        // workers, so a full admission queue means the system really is
        // saturated (back-pressure reaches the client).
        //
        // Session messages (streaming compaction ingest) are absorbed
        // here on the dispatcher: chunks and run-seals mutate session
        // state, a seal plans the remainder (or falls back to the
        // classic Compact routing). Eager planning runs once per
        // drained batch, over the sessions the batch touched — so a
        // session whose seal landed in the same batch skips straight
        // to the seal's zero-copy plan. Whatever jobs come out are
        // dispatched like any others.
        //
        // Oversized compactions are expanded here into rank shards:
        // each shard takes its own in-flight slot, so a giant
        // compaction saturates the pool shard by shard instead of
        // parking one worker on a monolithic job (and back-pressure
        // sees its true width).
        let mut touched = Vec::new();
        let dispatch = |job: Job<R>| {
            for sub in shard::maybe_expand(&cfg, &stats, job) {
                in_flight.acquire();
                // Charge the job's working-set estimate for its
                // in-flight lifetime; the guard releases it (on panic
                // too). This is what budget admission and the
                // peak-resident high-water mark observe.
                let est_bytes = estimated_job_bytes(&cfg, &sub.kind);
                let cfg = cfg.clone();
                let runtime = runtime.clone();
                let stats = Arc::clone(&stats);
                let store = Arc::clone(&store);
                stats.resident_bytes.add(est_bytes);
                shard_stats.dispatched.inc();
                let guard = SlotGuard {
                    pool: Some(Arc::clone(&pool)),
                    in_flight: Arc::clone(&in_flight),
                    stats: Arc::clone(&stats),
                    est_bytes,
                };
                let planned_at = Instant::now();
                pool.submit(move || {
                    // Stage: planning → a worker actually starting
                    // (slot acquire above happened before `planned_at`,
                    // so this is pure pool queueing).
                    stats.stage_dispatch.record(
                        u64::try_from(planned_at.elapsed().as_nanos())
                            .unwrap_or(u64::MAX)
                            .max(1),
                    );
                    let pool = guard.pool.as_deref().expect("guard holds the pool");
                    let t0 = Instant::now();
                    execute_job(&cfg, runtime.as_deref(), &stats, pool, sub, &store);
                    // Stage: pure execution (reply send included).
                    stats.stage_exec.record(
                        u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX).max(1),
                    );
                    // `guard` drops here: pool handle first, then
                    // the in-flight slot — on unwind too.
                });
            }
        };
        for job in batch {
            let unlocked = if session::is_session_message(&job.kind) {
                shard_stats.session_msgs.inc();
                session::handle_message(&cfg, &stats, &table, job, &mut touched)
            } else {
                vec![job]
            };
            for job in unlocked {
                dispatch(job);
            }
        }
        for job in session::plan_eager(&cfg, &stats, &table, &mut touched) {
            dispatch(job);
        }
    }
}

/// Run one job to completion and reply. Runs on a pool worker; `pool`
/// is the same pool, handed to the merge engines so per-job parallelism
/// reuses the persistent workers instead of spawning scoped threads.
fn execute_job<R: Record>(
    cfg: &MergeflowConfig,
    runtime: Option<&XlaExecutor>,
    stats: &ServiceStats,
    pool: &WorkerPool,
    job: Job<R>,
    store: &OnceLock<Arc<dyn StoreSink<R>>>,
) {
    let wait_ns =
        u64::try_from(job.enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    let t0 = Instant::now();
    let elements = job.kind.input_len() as u64;
    let (output, backend) = match job.kind {
        JobKind::Merge { a, b } => run_merge(cfg, runtime, stats, a, b, pool),
        JobKind::Sort { mut data } => {
            // Sorts run on the persistent pool like the compaction
            // engines (we are already on one of its workers; the
            // helping scoped wait makes the nested fork-join sound) —
            // no scoped-thread spawning anywhere in execute_job. The
            // key-only ordering keeps the sort stable for records.
            let kernel = LeafKernel::<ByKey<R>>::select(cfg.kernel);
            parallel_merge_sort_with_pool_kernel(
                pool,
                record::as_keyed_mut(&mut data),
                cfg.threads_per_job,
                kernel,
            );
            stats.record_kernel(kernel.kind());
            (data, kernel_tag(cfg, "native", kernel.kind()))
        }
        JobKind::Compact { runs } => run_compaction(cfg, stats, runs, pool),
        JobKind::CompactShard { shard: task } => {
            // Shards reply through the group (only the last one sends);
            // per-shard and parent-completion accounting live in
            // execute_shard, so the common tail below must not run.
            shard::execute_shard(task, &job.reply, stats);
            return;
        }
        JobKind::StreamShard { shard: task } => {
            // Same pattern: completion accounting and the (last-shard)
            // reply live in the session's shared exec state.
            session::execute_stream_shard(task, stats);
            return;
        }
        JobKind::Spill { run } => {
            // Admission verified a sink is attached, and the slot is
            // write-once — `get()` cannot fail here except by a
            // harness bug, which the error path below still reports.
            let spilled = match store.get() {
                Some(sink) => sink.spill(&run),
                None => Err(Error::Service("store detached mid-flight".into())),
            };
            match spilled {
                Ok(_bytes) => (run, "store-spill"),
                Err(e) => {
                    // No typed error channel on jobs: report, count
                    // the failure (submitted = completed + rejected +
                    // in-flight stays balanced), and drop the reply
                    // sender so the client's `wait()` observes
                    // `job N dropped by service`.
                    eprintln!("mergeflow: spill job {} failed: {e}", job.id);
                    stats.rejected.inc();
                    return;
                }
            }
        }
        JobKind::Flush => {
            unreachable!("flush is intercepted at submit and runs on the caller")
        }
        JobKind::CompactChunk { .. }
        | JobKind::CompactSealRun { .. }
        | JobKind::CompactSeal { .. } => {
            unreachable!("session messages are absorbed on the dispatcher")
        }
    };
    let latency_ns = wait_ns
        + u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX);
    stats.record_completion(backend, elements, latency_ns, wait_ns);
    // Receiver may have been dropped (client gave up) — that's fine.
    let _ = job.reply.send(JobResult { id: job.id, output, backend, latency_ns });
}

/// Route and run a merge. The inputs stay owned here so the native
/// paths merge straight out of them — no clones on the hot path; the
/// XLA route copies once, inside [`XlaExecutor::merge`], and only when
/// it is actually taken. Non-`i32` record types can never take the XLA
/// route ([`XlaExecutor::merge_records`] returns `None` for them), so
/// typed traffic routes native deterministically.
///
/// Both native routes run on the coordinator's persistent `pool` (we
/// are already on one of its workers; the helping scoped wait makes
/// the nested fork-joins sound) — the segmented route in particular
/// fork-joins once **per path segment**, so the pool is what keeps an
/// `N/L`-segment job from spawning `N/L·(p−1)` scoped threads.
fn run_merge<R: Record>(
    cfg: &MergeflowConfig,
    runtime: Option<&XlaExecutor>,
    stats: &ServiceStats,
    a: Vec<R>,
    b: Vec<R>,
    pool: &WorkerPool,
) -> (Vec<R>, &'static str) {
    // XLA route: exact-shape artifact required (XLA shapes are static).
    if matches!(cfg.backend, Backend::Xla | Backend::Auto) {
        if let Some(rt) = runtime {
            // Route to XLA only when the executable is already warm —
            // a cold compile (~1s) must never land on a job's latency.
            if let Some(meta) = rt.find_for_sizes(a.len(), b.len()) {
                if rt.is_compiled(&meta.name) {
                    let name = meta.name.clone();
                    match rt.merge_records(&name, &a, &b) {
                        Some(Ok(out)) => return (out, "xla"),
                        Some(Err(e)) => {
                            eprintln!("mergeflow: xla merge failed, falling back: {e}")
                        }
                        // Record type is not i32-keyed: the baked
                        // artifact cannot serve it — native by design.
                        None => {}
                    }
                }
            }
            if cfg.backend == Backend::Xla {
                // Explicit XLA mode with no fitting warm artifact (or a
                // non-i32 record type): still serve (degrade to native)
                // but tag it, so operators can see the misconfiguration
                // in stats.
                eprintln!(
                    "mergeflow: no XLA artifact serves sizes ({}, {}) for this record type; \
                     falling back to native",
                    a.len(),
                    b.len()
                );
            }
        }
    }
    // In-place route: when the memory budget makes an allocating
    // merge's 2× footprint unaffordable (`merge.inplace = auto` with a
    // budget, or `always`), concatenate the runs — growing the larger
    // buffer by the smaller, the only transient this route pays — and
    // run the block-swap kernel under the same Merge Path partition.
    // Stable and bit-identical to the allocating routes.
    let total_bytes = (a.len() + b.len()).saturating_mul(std::mem::size_of::<R>());
    if cfg.inplace_route(total_bytes) {
        let (mut buf, mid) = concat_for_inplace(a, b);
        parallel_inplace_merge_with_pool(
            pool,
            record::as_keyed_mut(&mut buf),
            mid,
            cfg.threads_per_job,
        );
        return (buf, "native-inplace");
    }
    // Fully tiled by the merge below (see crate::uninit_vec).
    let mut out: Vec<ByKey<R>> = crate::uninit_vec(a.len() + b.len());
    let (ka, kb) = (record::as_keyed(&a), record::as_keyed(&b));
    let kernel = LeafKernel::<ByKey<R>>::select(cfg.kernel);
    let seg = cfg.effective_segment_len(std::mem::size_of::<R>());
    if seg > 0 && out.len() >= 2 * seg {
        segmented_parallel_merge_with_pool_kernel(
            pool,
            ka,
            kb,
            &mut out,
            SegmentedConfig { segment_len: seg, threads: cfg.threads_per_job },
            kernel,
        );
        stats.record_kernel(kernel.kind());
        (record::into_records(out), kernel_tag(cfg, "native-segmented", kernel.kind()))
    } else {
        parallel_merge_with_pool_kernel(pool, ka, kb, &mut out, cfg.threads_per_job, kernel);
        stats.record_kernel(kernel.kind());
        (record::into_records(out), kernel_tag(cfg, "native", kernel.kind()))
    }
}

/// Backend tag for a kernel-dispatched route: the plain base tag under
/// the default `merge.kernel = auto` (so existing exact-tag consumers
/// see no change), or `base+<kernel>` when a kernel was forced via the
/// knob. [`ServiceStats::record_completion`] strips the suffix again,
/// so per-backend counters stay comparable across kernel settings.
fn kernel_tag(
    cfg: &MergeflowConfig,
    base: &'static str,
    kind: KernelKind,
) -> &'static str {
    if cfg.kernel == MergeKernel::Auto {
        base
    } else {
        tagged_backend(base, kind)
    }
}

/// Compaction router for jobs *below* the sharding threshold (larger
/// ones were already expanded into rank shards by the dispatcher, see
/// [`super::shard`]). In preference order:
///
/// 1. sequential loser tree for small jobs or `threads_per_job == 1`
///    (one pass, no parallel setup cost) — backend `"native"`;
/// 2. within the flat engine's range (`2 ≤ k ≤ kway_flat_max_k`), the
///    **segmented** flat k-way engine
///    ([`segmented_kway_merge`](crate::mergepath::segmented_kway_merge))
///    when segmented merging is enabled and the job spans at least two
///    path windows (`merge.kway_segment_elems`, `0 =` auto per-walker
///    `C/(k+1)`) — same single pass, `(k+1)·L`-bounded working set,
///    backend `"native-kway-segmented"`. The in-simulator miss win is
///    specific to the argmin regime (`k ≤ 16`, whose head re-reads
///    thrash small caches); for larger `k` both kernels touch each
///    element once and the windowing is neutral in-model (bounded
///    working set only, a few per-mille of state-refill overhead);
/// 3. otherwise the flat single-pass k-way engine
///    ([`mergepath::kway_path`](crate::mergepath::kway_path)) — one
///    pass over memory instead of the tree's `⌈log₂ k⌉`, backend
///    `"native-kway"` (scalar records) or `"native-kway-typed"`
///    (payload-carrying records, so typed traffic is visible in the
///    stats);
/// 4. the pairwise Merge-Path tree beyond the flat engine's configured
///    range — backend `"native"`.
///
/// Both parallel engines run on the coordinator's persistent `pool`
/// (we are already on one of its workers; the pool's helping scoped
/// wait makes that sound) — no scoped-thread spawning per job. Every
/// route merges through the key-only [`ByKey`] order, so the output is
/// stable for records exactly as for scalars.
fn run_compaction<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    mut runs: Vec<Vec<R>>,
    pool: &WorkerPool,
) -> (Vec<R>, &'static str) {
    runs.retain(|r| !r.is_empty());
    if runs.is_empty() {
        return (vec![], "native");
    }
    if runs.len() == 1 {
        // Single surviving run: already sorted, return it by move.
        return (runs.pop().unwrap(), "native");
    }
    let total: usize = runs.iter().map(|r| r.len()).sum();
    // Two surviving runs under a memory budget (or `inplace = always`)
    // take the pairwise in-place route: same stable cut, no full
    // second output buffer — mirrored by `compact_estimate` at
    // admission, so this is the route that keeps budgeted two-run
    // compactions admissible.
    if runs.len() == 2
        && cfg.inplace_route(total.saturating_mul(std::mem::size_of::<R>()))
    {
        let b = runs.pop().expect("two runs");
        let a = runs.pop().expect("two runs");
        let (mut buf, mid) = concat_for_inplace(a, b);
        parallel_inplace_merge_with_pool(
            pool,
            record::as_keyed_mut(&mut buf),
            mid,
            cfg.threads_per_job,
        );
        return (buf, "native-inplace");
    }
    let kernel = LeafKernel::<ByKey<R>>::select(cfg.kernel);
    let refs: Vec<&[ByKey<R>]> = runs.iter().map(|r| record::as_keyed(r)).collect();
    if total < 4096 || cfg.threads_per_job == 1 {
        // Small compactions: one sequential k-way pass beats any
        // parallel setup cost (two runs short-circuit to the pairwise
        // leaf kernel inside `loser_tree_merge_with`).
        let mut out: Vec<ByKey<R>> = crate::uninit_vec(total);
        crate::mergepath::kway::loser_tree_merge_with(&refs, &mut out, kernel);
        stats.record_kernel(kernel.kind());
        return (record::into_records(out), kernel_tag(cfg, "native", kernel.kind()));
    }
    if cfg.kway_flat_max_k > 0 && refs.len() <= cfg.kway_flat_max_k {
        // Flat engine's segments tile [0, total): every slot written.
        let mut out: Vec<ByKey<R>> = crate::uninit_vec(total);
        let seg =
            cfg.effective_kway_segment_elems(std::mem::size_of::<R>(), refs.len());
        if seg > 0 && total >= 2 * seg {
            // Segmented variant: same stable single pass, but each
            // thread walks its rank segment in (k+1)·L-bounded path
            // windows so the live windows stay cache-resident. The
            // scalar/typed tag split mirrors the flat route, so typed
            // traffic stays visible in per-job results here too.
            segmented_kway_merge_with(
                &refs,
                &mut out,
                KwaySegmentedConfig { segment_elems: seg, threads: cfg.threads_per_job },
                Some(pool),
                kernel,
            );
            let tag = if R::IS_SCALAR {
                "native-kway-segmented"
            } else {
                "native-kway-segmented-typed"
            };
            stats.record_kernel(kernel.kind());
            return (record::into_records(out), kernel_tag(cfg, tag, kernel.kind()));
        }
        parallel_kway_merge_with(&refs, &mut out, cfg.threads_per_job, Some(pool), kernel);
        let tag = if R::IS_SCALAR { "native-kway" } else { "native-kway-typed" };
        stats.record_kernel(kernel.kind());
        return (record::into_records(out), kernel_tag(cfg, tag, kernel.kind()));
    }
    // The job owns `runs`, so hand them to the consuming tree variant:
    // it frees each run buffer as its first-round merge completes,
    // keeping peak memory lower than merging out of borrows.
    drop(refs);
    let keyed: Vec<Vec<ByKey<R>>> = runs.into_iter().map(record::into_keyed).collect();
    let merged = crate::mergepath::kway::parallel_tree_merge_kernel(
        keyed,
        cfg.threads_per_job,
        Some(pool),
        kernel,
    );
    stats.record_kernel(kernel.kind());
    (record::into_records(merged), kernel_tag(cfg, "native", kernel.kind()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{gen_sorted_pair, gen_unsorted, WorkloadKind};
    use crate::config::InplaceMode;

    fn test_config() -> MergeflowConfig {
        MergeflowConfig {
            workers: 2,
            threads_per_job: 2,
            queue_capacity: 64,
            max_batch: 8,
            batch_timeout_us: 100,
            backend: Backend::Native,
            // Segmented routes are off by default in unit tests so each
            // test opts in explicitly (the length knobs stay on auto
            // but are inert while disabled) — like sharding below.
            segmented: false,
            segment_len: 0,
            kway_segment_elems: 0,
            cache_bytes: 0,
            kway_flat_max_k: 64,
            // Sharding and eager streaming are off by default in unit
            // tests so each test opts into those paths explicitly
            // (min_len stays on auto but is inert while disabled).
            compact_sharding: false,
            compact_shard_min_len: 0,
            compact_chunk_len: 0,
            compact_eager_min_len: 0,
            // No budget → Auto never routes in place; tests opt in via
            // `inplace = Always` or an explicit budget.
            memory_budget: 0,
            inplace: InplaceMode::Auto,
            kernel: MergeKernel::Auto,
            // One dispatcher shard, probes off: unit tests exercise
            // the historical single-dispatcher control plane with
            // deterministic knob values; multi-shard tests opt in.
            dispatch_shards: 1,
            dispatch_steal: true,
            calibrate: false,
            shard_floor: 1 << 18,
            artifacts_dir: "artifacts".into(),
        }
    }

    #[test]
    fn merge_job_end_to_end() {
        let svc = MergeService::start(test_config()).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 1000, 900, 1);
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.output, expected);
        assert_eq!(res.backend, "native");
        assert_eq!(svc.stats().completed.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn sort_job_end_to_end() {
        let svc = MergeService::start(test_config()).unwrap();
        let data = gen_unsorted(5000, 2);
        let mut expected = data.clone();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Sort { data }).unwrap();
        assert_eq!(res.output, expected);
        svc.shutdown();
    }

    #[test]
    fn compaction_job_merges_runs() {
        let svc = MergeService::start(test_config()).unwrap();
        let runs: Vec<Vec<i32>> = (0..5)
            .map(|i| {
                let (r, _) = gen_sorted_pair(WorkloadKind::Uniform, 200 + i * 13, 1, i as u64);
                r
            })
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.output, expected);
        // Small compaction (< 4096 keys): sequential loser-tree path.
        assert_eq!(res.backend, "native");
        svc.shutdown();
    }

    #[test]
    fn large_compaction_uses_flat_kway_engine() {
        let svc = MergeService::start(test_config()).unwrap();
        let runs: Vec<Vec<i32>> = (0..8u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 2000, 1, 100 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway");
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().kway_jobs.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn typed_record_compaction_is_stable_and_tagged() {
        // (key, payload) records through the flat engine: the backend
        // tag flips to "native-kway-typed" and equal keys keep
        // run-index-then-offset order — checked against the stable
        // oracle (flatten in run order, stable-sort by key).
        let svc = MergeService::<(u32, u32)>::start(test_config()).unwrap();
        let runs: Vec<Vec<(u32, u32)>> = (0..6u32)
            .map(|run| (0..2000u32).map(|off| (off / 50, run * 10_000 + off)).collect())
            .collect();
        let mut expected: Vec<(u32, u32)> = runs.iter().flatten().copied().collect();
        expected.sort_by_key(|r| r.0);
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-typed");
        assert_eq!(res.output, expected, "ties must keep run-then-offset order");
        assert_eq!(svc.stats().kway_jobs.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn large_compaction_shards_by_rank() {
        let mut cfg = test_config();
        cfg.compact_sharding = true;
        cfg.compact_shard_min_len = 2048;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..6u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 3000, 1, 300 + i).0)
            .collect();
        // Oracle: the unsharded flat engine over the same runs.
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut expected = vec![0i32; 18_000];
        parallel_kway_merge(&refs, &mut expected, 4, None);
        drop(refs);
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-sharded");
        assert_eq!(res.output, expected, "sharded output must be bit-identical");
        let stats = svc.stats();
        assert_eq!(stats.sharded_jobs.get(), 1);
        assert_eq!(stats.compact_shards.get(), 18_000 / 2048); // 8 shards
        assert_eq!(stats.compact_shards_completed.get(), stats.compact_shards.get());
        assert_eq!(stats.completed.get(), 1, "client sees one job");
        assert_eq!(stats.kway_jobs.get(), 0);
        svc.shutdown();
    }

    #[test]
    fn sharding_disabled_keeps_flat_route() {
        // Same workload as above with sharding off: flat engine, same
        // bits.
        let svc = MergeService::start(test_config()).unwrap();
        let runs: Vec<Vec<i32>> = (0..6u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 3000, 1, 300 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway");
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().compact_shards.get(), 0);
        svc.shutdown();
    }

    #[test]
    fn oversized_k_falls_back_to_tree() {
        let mut cfg = test_config();
        cfg.kway_flat_max_k = 4;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..6u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 1500, 1, 200 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native");
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().kway_jobs.get(), 0);
        svc.shutdown();
    }

    #[test]
    fn unsorted_merge_rejected_at_admission() {
        let svc = MergeService::start(test_config()).unwrap();
        let err = svc
            .submit(JobKind::Merge { a: vec![3, 1], b: vec![] })
            .unwrap_err();
        assert!(matches!(err, Error::InvalidInput(_)));
        assert_eq!(svc.stats().rejected.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn segmented_route_for_large_jobs() {
        let mut cfg = test_config();
        cfg.segmented = true;
        cfg.segment_len = 256;
        let svc = MergeService::start(cfg).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 4000, 4000, 3);
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native-segmented");
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().segmented_jobs.get(), 1);
        // Small job still takes the plain path.
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 50, 50, 4);
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native");
        svc.shutdown();
    }

    #[test]
    fn segmented_off_switch_disables_both_routes() {
        // merge.segmented = false makes the length knobs inert: large
        // jobs take the unsegmented engines.
        let mut cfg = test_config();
        cfg.segmented = false;
        cfg.segment_len = 256;
        cfg.kway_segment_elems = 256;
        let svc = MergeService::start(cfg).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 4000, 4000, 3);
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native");
        let runs: Vec<Vec<i32>> = (0..6u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 2000, 1, 500 + i).0)
            .collect();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway");
        svc.shutdown();
    }

    #[test]
    fn segmented_kway_route_for_large_compactions() {
        let mut cfg = test_config();
        cfg.segmented = true;
        cfg.kway_segment_elems = 512;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..8u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 2000, 1, 600 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-segmented");
        assert_eq!(res.output, expected);
        let stats = svc.stats();
        assert_eq!(stats.kway_segmented_jobs.get(), 1);
        assert_eq!(stats.kway_jobs.get(), 0, "segmented is its own counter");
        // Small totals take the sequential route before any windowing.
        let runs: Vec<Vec<i32>> =
            (0..2u64).map(|i| gen_sorted_pair(WorkloadKind::Uniform, 300, 1, 800 + i).0).collect();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native");
        svc.shutdown();
        // A job spanning less than two windows stays on the unsegmented
        // flat engine (needs L > total/2 while total ≥ 4096).
        let mut cfg = test_config();
        cfg.segmented = true;
        cfg.kway_segment_elems = 4096;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..4u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 1250, 1, 700 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway", "5000 < 2·4096 → one window, flat");
        assert_eq!(res.output, expected);
        svc.shutdown();
    }

    #[test]
    fn segmented_kway_auto_sizing_routes_by_cache() {
        // Auto (kway_segment_elems = 0) with a configured 64 KiB cache:
        // C = 16K i32 elems over w = 2 walkers, k = 7 →
        // L = 16Ki/2/8 = 1024; a 21K-element job spans ≥ 2 windows and
        // routes segmented.
        let mut cfg = test_config();
        cfg.segmented = true;
        cfg.cache_bytes = 64 << 10;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..7u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 3000, 1, 900 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.backend, "native-kway-segmented");
        assert_eq!(res.output, expected);
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_jobs() {
        let svc = MergeService::start(test_config()).unwrap();
        let handles: Vec<_> = (0..40)
            .map(|i| {
                let (a, b) =
                    gen_sorted_pair(WorkloadKind::Uniform, 100 + i, 80 + i, i as u64);
                svc.submit(JobKind::Merge { a, b }).unwrap()
            })
            .collect();
        for h in handles {
            let res = h.wait().unwrap();
            assert!(res.output.windows(2).all(|w| w[0] <= w[1]));
        }
        assert_eq!(svc.stats().completed.get(), 40);
        assert!(svc.stats().batches.get() >= 1);
        svc.shutdown();
    }

    #[test]
    fn sharded_control_plane_completes_and_reports() {
        let mut cfg = test_config();
        cfg.dispatch_shards = 4;
        let svc = MergeService::start(cfg).unwrap();
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 200 + i, 150, i as u64);
                svc.submit(JobKind::Merge { a, b }).unwrap()
            })
            .collect();
        for h in handles {
            let res = h.wait().unwrap();
            assert!(res.output.windows(2).all(|w| w[0] <= w[1]));
        }
        let stats = svc.stats();
        assert_eq!(stats.completed.get(), 32);
        assert_eq!(stats.dispatch_shard_count(), 4);
        let per_shard: Vec<u64> = (0..4)
            .map(|i| {
                let sh = stats.dispatch_shard(i).unwrap();
                // Jobs either dispatched from their home shard or were
                // stolen by an idle peer; the sum must cover them all.
                sh.dispatched.get()
            })
            .collect();
        assert_eq!(per_shard.iter().sum::<u64>(), 32, "{per_shard:?}");
        assert!(
            per_shard.iter().filter(|&&n| n > 0).count() >= 2,
            "sequential ids must hash across shards: {per_shard:?}"
        );
        let snap = stats.snapshot();
        assert!(snap.contains("dispatch: shards=4"), "{snap}");
        assert!(snap.contains("stages: admit[p50="), "{snap}");
        svc.shutdown();
    }

    #[test]
    fn single_shard_control_plane_matches_legacy_routing() {
        // dispatch.shards = 1: every id hashes to shard 0 and the
        // shard's counters account for the whole service.
        let svc = MergeService::start(test_config()).unwrap();
        for i in 0..8u64 {
            let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 300, 200, i);
            svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        }
        let stats = svc.stats();
        assert_eq!(stats.dispatch_shard_count(), 1);
        let sh = stats.dispatch_shard(0).unwrap();
        assert_eq!(sh.dispatched.get(), 8);
        assert_eq!(sh.stolen_jobs.get(), 0, "one shard has no peers to steal from");
        svc.shutdown();
    }

    #[test]
    fn calibrate_off_substitutes_model_defaults_for_auto_knobs() {
        let mut cfg = test_config();
        cfg.kway_flat_max_k = 0; // auto, but calibrate=false in tests
        cfg.shard_floor = 0;
        let svc = MergeService::<i32>::start(cfg).unwrap();
        assert_eq!(svc.config().kway_flat_max_k, calibrate::MODEL_FLAT_MAX_K);
        assert_eq!(svc.config().shard_floor, calibrate::MODEL_SHARD_FLOOR);
        let snap = svc.stats().snapshot();
        assert!(
            snap.contains("calibration: flat-max-k=0 shard-floor=0"),
            "model fallback is not a calibration: {snap}"
        );
        svc.shutdown();
    }

    #[test]
    fn calibration_resolves_auto_knobs_and_reports() {
        let mut cfg = test_config();
        cfg.calibrate = true;
        cfg.kway_flat_max_k = 0;
        cfg.shard_floor = 0;
        let svc = MergeService::<i32>::start(cfg).unwrap();
        let resolved = svc.config();
        assert!((8..=512).contains(&resolved.kway_flat_max_k), "{resolved:?}");
        assert!(
            (1 << 15..=1 << 21).contains(&resolved.shard_floor),
            "{resolved:?}"
        );
        let stats = svc.stats();
        assert_eq!(stats.calibrated_flat_max_k.get(), resolved.kway_flat_max_k as u64);
        assert_eq!(stats.calibrated_shard_floor.get(), resolved.shard_floor as u64);
        assert!(stats.calibration_probe_ns.get() > 0);
        // cache_bytes stays pinned: segmented is off in the test base.
        assert_eq!(stats.calibrated_cache_bytes.get(), 0);
        // Calibrated knobs serve real traffic.
        let runs: Vec<Vec<i32>> = (0..6u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 2000, 1, 70 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs }).unwrap();
        assert_eq!(res.output, expected);
        svc.shutdown();
    }

    #[test]
    fn shutdown_completes_pending() {
        let svc = MergeService::start(test_config()).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 2000, 2000, 9);
        let h = svc.submit(JobKind::Merge { a, b }).unwrap();
        svc.shutdown(); // drains the queue first
        assert!(h.wait().is_ok());
    }

    #[test]
    fn shutdown_waits_for_dispatched_jobs() {
        // A job already handed to a worker (in-flight, no longer
        // queued) must also complete before shutdown returns — the
        // dispatcher drains the in-flight count, which is equally what
        // guarantees it holds the last pool handle when it exits.
        let svc = MergeService::start(test_config()).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 400_000, 400_000, 5);
        let h = svc.submit(JobKind::Merge { a, b }).unwrap();
        // Let the dispatcher hand the job to a worker before closing.
        std::thread::sleep(Duration::from_millis(20));
        svc.shutdown();
        assert!(
            h.try_wait().is_some(),
            "job must be complete by the time shutdown returns"
        );
    }

    #[test]
    fn empty_compaction() {
        // No data anywhere pins nothing for inference — spell the
        // record type (the only call site that ever needs to).
        let svc = MergeService::<i32>::start(test_config()).unwrap();
        let res = svc
            .submit_blocking(JobKind::Compact { runs: vec![vec![], vec![]] })
            .unwrap();
        assert!(res.output.is_empty());
        svc.shutdown();
    }

    #[test]
    fn i32_alias_names_the_service() {
        // The explicit alias for the classic scalar service (the
        // supported spelling now that the deprecated
        // `LegacyMergeService` shim is gone) names the same type as
        // the bare default-parameter name.
        let svc: I32MergeService = MergeService::start(test_config()).unwrap();
        let res = svc
            .submit_blocking(JobKind::Compact { runs: vec![vec![1, 3], vec![2]] })
            .unwrap();
        assert_eq!(res.output, vec![1, 2, 3]);
        svc.shutdown();
    }

    #[test]
    fn inplace_route_tags_and_matches() {
        let mut cfg = test_config();
        cfg.inplace = InplaceMode::Always;
        let svc = MergeService::start(cfg).unwrap();
        // Pairwise merge through the block-swap kernel: tagged, and
        // bit-identical to the allocating route's stable output.
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 5000, 3000, 11);
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native-inplace");
        assert_eq!(res.output, expected);
        // Two-run compactions ride the same kernel.
        let (c, d) = gen_sorted_pair(WorkloadKind::Uniform, 4000, 2500, 12);
        let mut expected: Vec<i32> = c.iter().chain(d.iter()).copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Compact { runs: vec![c, d] }).unwrap();
        assert_eq!(res.backend, "native-inplace");
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().inplace_jobs.get(), 2);
        svc.shutdown();
    }

    #[test]
    fn over_budget_jobs_reject_without_poisoning() {
        let mut cfg = test_config();
        cfg.memory_budget = 64 << 10; // 64 KiB
        let svc = MergeService::start(cfg).unwrap();
        // 16K + 16K i32 is 128 KiB of input alone — over budget on any
        // route. Fail-fast Service error, rejection counted, nothing
        // admitted.
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 16_384, 16_384, 21);
        let err = svc
            .submit(JobKind::Merge { a: a.clone(), b: b.clone() })
            .unwrap_err();
        assert!(matches!(err, Error::Service(_)));
        assert_eq!(svc.stats().rejected.get(), 1);
        assert_eq!(svc.stats().submitted.get(), 0);
        // Non-poisoning: in-budget work keeps flowing afterwards.
        let res = svc
            .submit_blocking(JobKind::Merge { a: vec![1, 3], b: vec![2] })
            .unwrap();
        assert_eq!(res.output, vec![1, 2, 3]);
        assert_eq!(svc.stats().completed.get(), 1);
        // Over-budget compactions reject through the same gate.
        let err = svc.submit(JobKind::Compact { runs: vec![a, b] }).unwrap_err();
        assert!(matches!(err, Error::Service(_)));
        assert_eq!(svc.stats().rejected.get(), 2);
        svc.shutdown();
    }

    #[test]
    fn inplace_keeps_budgeted_jobs_admissible() {
        // The budget lever the in-place kernel exists for: 512 KiB of
        // input under a 768 KiB budget. The allocating route would
        // estimate inputs + full output = 1 MiB (rejected); the
        // in-place route's transient is only the smaller run, so the
        // same job admits — and `Auto` picks that route precisely
        // because 2× input exceeds the budget.
        let mut cfg = test_config();
        cfg.memory_budget = 768 << 10;
        let svc = MergeService::start(cfg).unwrap();
        let (a, b) = gen_sorted_pair(WorkloadKind::Uniform, 98_304, 32_768, 22);
        let mut expected: Vec<i32> = a.iter().chain(b.iter()).copied().collect();
        expected.sort_unstable();
        let res = svc.submit_blocking(JobKind::Merge { a, b }).unwrap();
        assert_eq!(res.backend, "native-inplace");
        assert_eq!(res.output, expected);
        assert!(svc.stats().peak_resident_bytes() > 0);
        svc.shutdown();
    }

    #[test]
    fn unsorted_compact_rejected_at_submit() {
        // Compact validation moved from JobKind::validate's O(total)
        // walk to the per-chunk feed path — the submit-facing contract
        // (unsorted input → InvalidInput, rejection counted) must hold
        // unchanged.
        let svc = MergeService::start(test_config()).unwrap();
        let err = svc
            .submit(JobKind::Compact { runs: vec![vec![1, 2], vec![3, 1]] })
            .unwrap_err();
        assert!(matches!(err, Error::InvalidInput(_)));
        assert!(svc.stats().rejected.get() >= 1);
        assert_eq!(
            svc.stats().submitted.get(),
            0,
            "a rejected compaction was never admitted"
        );
        // The aborted session must not wedge later traffic.
        let res = svc
            .submit_blocking(JobKind::Compact { runs: vec![vec![1, 3], vec![2, 4]] })
            .unwrap();
        assert_eq!(res.output, vec![1, 2, 3, 4]);
        assert_eq!(svc.stats().submitted.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn streaming_session_end_to_end() {
        let mut cfg = test_config();
        cfg.compact_eager_min_len = 256;
        let svc = MergeService::start(cfg).unwrap();
        let runs: Vec<Vec<i32>> = (0..3u64)
            .map(|i| gen_sorted_pair(WorkloadKind::Uniform, 1200, 1, 40 + i).0)
            .collect();
        let mut expected: Vec<i32> = runs.iter().flatten().copied().collect();
        expected.sort_unstable();
        let mut session = svc.open_compaction(runs.len()).unwrap();
        // Interleave feeds across runs in 300-element chunks.
        for start in (0..1200).step_by(300) {
            for (i, run) in runs.iter().enumerate() {
                session.feed(i, run[start..start + 300].to_vec()).unwrap();
            }
        }
        for i in 0..runs.len() {
            session.seal_run(i).unwrap();
        }
        let res = session.seal().unwrap().wait().unwrap();
        assert_eq!(res.output, expected);
        assert_eq!(svc.stats().streamed_sessions.get(), 1);
        assert_eq!(svc.stats().streamed_chunks.get(), 12);
        assert_eq!(svc.stats().completed.get(), 1);
        svc.shutdown();
    }

    #[test]
    fn streaming_feed_validation_bounds() {
        let svc = MergeService::start(test_config()).unwrap();
        let mut session = svc.open_compaction(2).unwrap();
        assert_eq!(session.run_count(), 2);
        // Out-of-range run.
        assert!(session.feed(2, vec![1]).is_err());
        // Unsorted chunk rejected, session stays usable.
        assert!(session.feed(0, vec![3, 1]).is_err());
        session.feed(0, vec![1, 5]).unwrap();
        // Boundary violation against the run's last element.
        assert!(session.feed(0, vec![4]).is_err());
        session.feed(0, vec![5, 9]).unwrap();
        session.feed(1, vec![2]).unwrap();
        // Sealed run refuses more data.
        session.seal_run(1).unwrap();
        assert!(session.feed(1, vec![7]).is_err());
        let res = session.seal().unwrap().wait().unwrap();
        assert_eq!(res.output, vec![1, 2, 5, 5, 9]);
        svc.shutdown();
    }

    #[test]
    fn dropped_session_aborts_cleanly() {
        let svc = MergeService::start(test_config()).unwrap();
        {
            let mut session = svc.open_compaction(2).unwrap();
            session.feed(0, vec![1, 2, 3]).unwrap();
            // Dropped without seal: buffered data must be discarded.
        }
        // Service still serves.
        let res = svc
            .submit_blocking(JobKind::Compact { runs: vec![vec![2], vec![1]] })
            .unwrap();
        assert_eq!(res.output, vec![1, 2]);
        assert_eq!(svc.stats().completed.get(), 1);
        svc.shutdown();
    }
}
