//! Streaming compaction ingest: the chunked run protocol behind
//! [`MergeService::open_compaction`](super::MergeService::open_compaction).
//!
//! A classic `JobKind::Compact` carries every run by value in one queue
//! message, so a multi-gigabyte compaction pays full ingest latency and
//! peak memory before the first cut is computed. Merge Path's core
//! property makes that wait unnecessary: **any output rank induces a
//! unique, synchronization-free cut of the inputs**, and the cut at
//! rank `r` only inspects elements that can still land in the first
//! `r` outputs. So a compaction whose low ranks are already *settled*
//! can start merging them while high-rank data is still arriving.
//!
//! ## Protocol
//!
//! ```text
//! client                       dispatcher                         pool
//! ─────────────────────────────────────────────────────────────────────
//! open_compaction(k) ─ registers a session (k runs, all open)
//! feed(run, chunk) ──▶ CompactChunk ─▶ append to run buffer,
//!   (validated           │             advance the sealed-rank
//!    per chunk,          │             frontier; if it moved ≥
//!    O(chunk) on         │             compact_eager_min_len past the
//!    the caller)         │             planned rank: cut + dispatch
//!                        │             eager StreamShard(s) ─────▶ merge
//! seal_run(run) ───▶ CompactSealRun ─▶ run leaves the frontier min
//! seal() ──────────▶ CompactSeal ───▶ plan the remaining rank range
//!                                     as zero-copy StreamShards ─▶ merge
//!                                     (or, if nothing was dispatched
//!                                     eagerly, fall back to the classic
//!                                     Compact routing — one code path,
//!                                     same backends as before)
//! last StreamShard to finish concatenates the per-shard outputs in
//! rank order and replies on the session's handle
//! ("native-kway-streamed")
//! ```
//!
//! ## The sealed-rank frontier
//!
//! Let `F` be the minimum, over all *open* (unsealed) runs, of the last
//! key fed to that run — undefined (no rank is safe) while any open run
//! is still empty, and `+∞` once every run is sealed. Per-chunk
//! admission validation guarantees each run's future elements are `≥`
//! its current last key, hence `≥ F`. Every already-fed element with
//! key `< F` therefore precedes all future elements in the stable merge
//! (strict inequality: a tie at `F` from a lower-indexed run would
//! still sort *before* an existing element — only strictly smaller keys
//! are settled). The frontier rank
//!
//! ```text
//! safe = Σ_j |{ x ∈ fed(run j) : x < F }|
//! ```
//!
//! is exactly the length of the settled output prefix, and for any rank
//! `r ≤ safe` the stable cut computed over the *fed prefixes*
//! ([`kway_rank_split`]) equals the cut over the final, complete runs:
//! the first `safe` outputs of both merges are the same elements in the
//! same `(key, run, index)` order. Eager shards cut on live data are
//! therefore bit-identical to shards cut after seal.
//!
//! ## Memory & cost model
//!
//! Eager shards copy their per-run windows out of the live ingest
//! buffers (the buffers keep growing and may reallocate, so running
//! workers must not borrow them); the remainder planned at `seal()`
//! borrows the by-then frozen buffers through an `Arc` with no copy.
//! Each shard merges into its own output vector and the last one
//! concatenates — one extra `memcpy` pass over the output versus the
//! in-place sharded path, bought back (and then some, on ingest-bound
//! workloads) by overlapping merge work with ingest end to end. The
//! per-chunk admission checks replace `JobKind::validate`'s former
//! O(total) walk of every compaction on the submit path: validation
//! cost is now amortized and bounded by the chunk size per call.

use super::job::{Job, JobHandle, JobKind, JobResult};
use super::queue::{BoundedQueue, PushError};
use super::shard;
use super::stats::ServiceStats;
use crate::config::MergeflowConfig;
use crate::mergepath::kway::loser_tree_merge;
use crate::mergepath::kway_path::kway_rank_split;
use crate::{Error, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Backend tag reported for compactions that overlapped ingest with
/// eager merging (at least one pre-seal shard dispatched).
pub const BACKEND_STREAMED: &str = "native-kway-streamed";

/// Hard ceiling on *eager* shards per session, independent of
/// configuration — bounds dispatcher-side planning/copy cost. The
/// remainder planned at seal is separately capped by
/// [`shard::MAX_SHARDS`].
const MAX_EAGER_SHARDS: usize = shard::MAX_SHARDS;

// ---------------------------------------------------------------------
// Queue message payloads. Fields are private to this module, so clients
// cannot construct (and `submit` cannot receive) session messages
// directly — the same opacity trick as `shard::ShardTask`.
// ---------------------------------------------------------------------

/// Payload of [`JobKind::CompactChunk`]: one validated chunk of one run.
#[derive(Debug, Clone)]
pub struct ChunkMsg {
    session: u64,
    run: usize,
    data: Vec<i32>,
}

impl ChunkMsg {
    /// Elements in this chunk (for job accounting).
    pub(super) fn len(&self) -> usize {
        self.data.len()
    }
}

/// Payload of [`JobKind::CompactSealRun`]: a run will receive no more
/// chunks (it leaves the frontier minimum).
#[derive(Debug, Clone)]
pub struct RunSealMsg {
    session: u64,
    run: usize,
}

/// Payload of [`JobKind::CompactSeal`]: no more feeds at all; plan the
/// remaining rank range and arrange the reply.
#[derive(Debug, Clone)]
pub struct SealMsg {
    session: u64,
}

// ---------------------------------------------------------------------
// Shared execution state (session ↔ stream-shard jobs on the pool).
// ---------------------------------------------------------------------

/// One shard of a streamed compaction: merge `k` per-run windows into
/// an owned output vector, then hand it to the session's shared
/// execution state. Carried by [`JobKind::StreamShard`]; constructed
/// only by the dispatcher's session planner.
#[derive(Debug, Clone)]
pub struct StreamShard {
    exec: Arc<StreamExec>,
    input: ShardInput,
    /// Slot in the session's output list; slots are allocated in rank
    /// order, so concatenating by slot index reassembles the output.
    idx: usize,
}

#[derive(Debug, Clone)]
enum ShardInput {
    /// Eager (pre-seal) shard: windows copied out of the live ingest
    /// buffers, which keep growing (and may reallocate) underneath.
    Owned(Vec<Vec<i32>>),
    /// Remainder shard planned at seal: borrows the frozen run buffers.
    Shared {
        runs: Arc<Vec<Vec<i32>>>,
        ranges: Vec<Range<usize>>,
    },
}

impl StreamShard {
    /// Output elements this shard produces.
    pub fn len(&self) -> usize {
        match &self.input {
            ShardInput::Owned(windows) => windows.iter().map(|w| w.len()).sum(),
            ShardInput::Shared { ranges, .. } => ranges.iter().map(|r| r.len()).sum(),
        }
    }

    /// True iff the shard produces no output.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Completion state shared by all stream shards of one session.
#[derive(Debug, Default)]
struct StreamExec {
    state: Mutex<ExecState>,
}

#[derive(Debug, Default)]
struct ExecState {
    /// Per-shard outputs, indexed by rank-ordered slot.
    outputs: Vec<Option<Vec<i32>>>,
    /// Shards completed so far.
    done: usize,
    /// Set when the session seals: from then on the shard count is
    /// final and the last completion assembles + replies.
    sealed: Option<SealInfo>,
}

#[derive(Debug)]
struct SealInfo {
    /// Total shard count (eager + remainder).
    expected: usize,
    /// Total output elements.
    total: usize,
    reply: Sender<JobResult>,
    parent_id: u64,
    /// Session open time — end-to-end latency covers the whole ingest.
    enqueued_at: Instant,
    /// Ingest duration (open → seal processed), reported as queue wait.
    queue_wait_ns: u64,
}

impl StreamExec {
    /// Allocate the next rank-ordered output slot.
    fn push_slot(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.outputs.push(None);
        st.outputs.len() - 1
    }
}

/// Record one shard's output; the completion that brings the sealed
/// group to full strength assembles the final buffer and replies.
fn complete_shard(exec: &StreamExec, idx: usize, out: Vec<i32>, stats: &ServiceStats) {
    let mut st = exec.state.lock().unwrap();
    debug_assert!(st.outputs[idx].is_none(), "shard slot filled twice");
    st.outputs[idx] = Some(out);
    st.done += 1;
    stats.stream_shards_completed.inc();
    maybe_finish(&mut st, stats);
}

/// If the session is sealed and every shard has reported, concatenate
/// the rank-ordered outputs and reply on the session handle.
fn maybe_finish(st: &mut ExecState, stats: &ServiceStats) {
    let Some(info) = &st.sealed else { return };
    if st.done < info.expected {
        return;
    }
    let mut output = Vec::with_capacity(info.total);
    for slot in st.outputs.iter_mut() {
        output.append(&mut slot.take().expect("sealed group complete but a slot is empty"));
    }
    let latency_ns =
        u64::try_from(info.enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    stats.record_completion(
        BACKEND_STREAMED,
        info.total as u64,
        latency_ns,
        info.queue_wait_ns,
    );
    // Receiver may have been dropped (client gave up) — that's fine.
    let _ = info.reply.send(JobResult {
        id: info.parent_id,
        output,
        backend: BACKEND_STREAMED,
        latency_ns,
    });
    // Drop the sender so an aborted/forgotten receiver unblocks.
    st.sealed = None;
}

/// Execute one stream shard on a pool worker: stable loser-tree merge
/// of its per-run windows into an owned buffer, then report completion
/// (the last shard of a sealed session assembles and replies).
pub(crate) fn execute_stream_shard(shard: StreamShard, stats: &ServiceStats) {
    let out = match &shard.input {
        ShardInput::Owned(windows) => {
            let parts: Vec<&[i32]> = windows.iter().map(|w| w.as_slice()).collect();
            merge_parts(&parts)
        }
        ShardInput::Shared { runs, ranges } => {
            let parts: Vec<&[i32]> = ranges
                .iter()
                .zip(runs.iter())
                .map(|(r, run)| &run[r.clone()])
                .collect();
            merge_parts(&parts)
        }
    };
    complete_shard(&shard.exec, shard.idx, out, stats);
}

fn merge_parts(parts: &[&[i32]]) -> Vec<i32> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    // Fully tiled by the loser-tree merge (see crate::uninit_vec).
    let mut out = crate::uninit_vec(total);
    loser_tree_merge(parts, &mut out);
    out
}

// ---------------------------------------------------------------------
// Dispatcher-side session state.
// ---------------------------------------------------------------------

/// All live streaming sessions, shared between the service front end
/// (open / abort) and the dispatcher (everything else). The dispatcher
/// is the only mutator of per-session ingest state; clients only insert
/// new sessions and flip the abort flag, so one mutex over the map is
/// uncontended in practice.
#[derive(Debug, Default)]
pub(super) struct SessionTable {
    sessions: Mutex<HashMap<u64, SessionState>>,
    /// Ids of aborted sessions awaiting reclamation. Dropping a session
    /// records its id here (an in-memory list — unlike a queue message
    /// it cannot fail under back-pressure), and the dispatcher reaps on
    /// every loop iteration, so an aborted session's buffered ingest is
    /// freed promptly instead of leaking until service shutdown.
    aborted: Mutex<Vec<u64>>,
}

impl SessionTable {
    fn insert(&self, id: u64, state: SessionState) {
        self.sessions.lock().unwrap().insert(id, state);
    }

    fn mark_aborted(&self, id: u64) {
        if let Some(s) = self.sessions.lock().unwrap().get_mut(&id) {
            s.aborted = true;
        }
        self.aborted.lock().unwrap().push(id);
    }

    /// Drop the state of every aborted session. Called by the
    /// dispatcher once per loop iteration; in-flight messages that
    /// still reference a reaped id just find no entry and are ignored.
    pub(super) fn reap_aborted(&self) {
        let ids: Vec<u64> = std::mem::take(&mut *self.aborted.lock().unwrap());
        if ids.is_empty() {
            return;
        }
        let mut map = self.sessions.lock().unwrap();
        for id in ids {
            map.remove(&id);
        }
    }
}

#[derive(Debug)]
struct SessionState {
    runs: Vec<RunIngest>,
    /// Absolute per-run cut positions already dispatched to eager
    /// shards (componentwise nondecreasing; sums to `planned_rank`).
    planned: Vec<usize>,
    /// Output ranks `[0, planned_rank)` are covered by eager shards.
    planned_rank: usize,
    exec: Arc<StreamExec>,
    /// Session reply sender; every emitted shard job carries a clone.
    reply: Sender<JobResult>,
    enqueued_at: Instant,
    /// Whether eager (pre-seal) planning is enabled for this session.
    /// The one-shot wrapper disables it when it fed every run as one
    /// whole-moved chunk: ingest completes in the same breath, so
    /// eager window copies could never buy overlap — and the route the
    /// job takes stays deterministic (classic fallback) instead of
    /// depending on where batch boundaries happen to fall.
    eager: bool,
    eager_count: usize,
    aborted: bool,
}

#[derive(Debug, Default)]
struct RunIngest {
    buf: Vec<i32>,
    sealed: bool,
}

/// Settled output prefix length under the sealed-rank frontier (module
/// docs): elements strictly below the minimum last-fed key of any open
/// run; everything once all runs are sealed; nothing while an open run
/// is still empty.
fn safe_rank(runs: &[RunIngest]) -> usize {
    let mut frontier: Option<i32> = None;
    let mut all_sealed = true;
    for r in runs {
        if !r.sealed {
            all_sealed = false;
            match r.buf.last() {
                None => return 0,
                Some(&v) => frontier = Some(frontier.map_or(v, |f| f.min(v))),
            }
        }
    }
    if all_sealed {
        return runs.iter().map(|r| r.buf.len()).sum();
    }
    let f = frontier.expect("an open run with data exists");
    runs.iter().map(|r| r.buf.partition_point(|x| *x < f)).sum()
}

/// True iff `kind` is a session protocol message (handled on the
/// dispatcher, never dispatched to a worker).
pub(super) fn is_session_message(kind: &JobKind) -> bool {
    matches!(
        kind,
        JobKind::CompactChunk { .. } | JobKind::CompactSealRun { .. } | JobKind::CompactSeal { .. }
    )
}

/// Process one session message on the dispatcher thread. Ingest
/// messages (chunk / run-seal) only mutate session state and record the
/// touched session in `touched`; eager planning runs once per drained
/// batch via [`plan_eager`], so a session whose seal is absorbed in the
/// same batch never pays for eager window copies the seal's zero-copy
/// remainder planner would make redundant. A seal returns the jobs it
/// unlocked (the remainder plan or the classic-fallback `Compact`); the
/// caller dispatches them through the normal expansion + in-flight
/// accounting.
pub(super) fn handle_message(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    table: &SessionTable,
    job: Job,
    touched: &mut Vec<u64>,
) -> Vec<Job> {
    let Job { id, kind, enqueued_at, reply } = job;
    let mut map = table.sessions.lock().unwrap();
    match kind {
        JobKind::CompactChunk { msg } => {
            let Some(state) = map.get_mut(&msg.session) else { return Vec::new() };
            if state.aborted {
                map.remove(&msg.session);
                return Vec::new();
            }
            let r = &mut state.runs[msg.run];
            debug_assert!(!r.sealed, "chunk for a sealed run passed admission");
            if r.buf.is_empty() {
                // First chunk of a run lands by move — the whole-run
                // feeds of the one-shot wrapper never copy.
                r.buf = msg.data;
            } else {
                r.buf.extend_from_slice(&msg.data);
            }
            touched.push(msg.session);
            Vec::new()
        }
        JobKind::CompactSealRun { msg } => {
            let Some(state) = map.get_mut(&msg.session) else { return Vec::new() };
            if state.aborted {
                map.remove(&msg.session);
                return Vec::new();
            }
            state.runs[msg.run].sealed = true;
            touched.push(msg.session);
            Vec::new()
        }
        JobKind::CompactSeal { msg } => {
            let Some(state) = map.remove(&msg.session) else { return Vec::new() };
            if state.aborted {
                return Vec::new();
            }
            // `state` is owned now — release the table lock so client
            // threads (open_compaction, session drops) are not stalled
            // behind the remainder planning below.
            drop(map);
            finalize(cfg, stats, state, id, reply)
        }
        other => vec![Job { id, kind: other, enqueued_at, reply }],
    }
}

/// Batch-level eager planning: for every session touched by the just
/// drained batch that is still live (not sealed in that same batch, not
/// aborted), dispatch eager shards over its newly settled ranks. Called
/// by the dispatcher after each batch; `touched` is drained.
pub(super) fn plan_eager(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    table: &SessionTable,
    touched: &mut Vec<u64>,
) -> Vec<Job> {
    if touched.is_empty() {
        return Vec::new();
    }
    touched.sort_unstable();
    touched.dedup();
    let mut jobs = Vec::new();
    let mut map = table.sessions.lock().unwrap();
    for id in touched.drain(..) {
        let Some(state) = map.get_mut(&id) else { continue };
        if state.aborted {
            continue; // the reap frees it
        }
        jobs.extend(maybe_plan_eager(cfg, stats, state, id));
    }
    jobs
}

/// Dispatch eager shards while the sealed-rank frontier is at least
/// `compact_eager_min_len` ahead of the planned rank. Each shard covers
/// exactly that many output ranks; the cut is computed over the fed
/// prefixes, which for ranks within the frontier equals the cut over
/// the final runs (module docs). Skipped entirely once every run is
/// sealed: the seal message is imminent and its remainder planner
/// merges the tail zero-copy, so eager window copies would be waste.
fn maybe_plan_eager(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    state: &mut SessionState,
    id: u64,
) -> Vec<Job> {
    let eager_len = cfg.compact_eager_min_len;
    if eager_len == 0 || !state.eager {
        return Vec::new();
    }
    let k = state.runs.len();
    // Eager shards run the flat engine's per-shard kernel; share its k
    // cap (which also bounds per-cut planning cost, like shard.rs).
    if k < 2 || k > cfg.kway_flat_max_k {
        return Vec::new();
    }
    if state.runs.iter().all(|r| r.sealed) {
        return Vec::new();
    }
    let safe = safe_rank(&state.runs);
    let mut jobs = Vec::new();
    while safe.saturating_sub(state.planned_rank) >= eager_len
        && state.eager_count < MAX_EAGER_SHARDS
    {
        let target = state.planned_rank + eager_len;
        let (cut, windows) = {
            let prefixes: Vec<&[i32]> =
                state.runs.iter().map(|r| r.buf.as_slice()).collect();
            let cut = kway_rank_split(&prefixes, target);
            let windows: Vec<Vec<i32>> = prefixes
                .iter()
                .zip(cut.iter().zip(state.planned.iter()))
                .map(|(p, (&e, &s))| p[s..e].to_vec())
                .collect();
            (cut, windows)
        };
        state.planned = cut;
        state.planned_rank = target;
        state.eager_count += 1;
        stats.eager_shards.inc();
        let idx = state.exec.push_slot();
        jobs.push(Job {
            id,
            kind: JobKind::StreamShard {
                shard: StreamShard {
                    exec: Arc::clone(&state.exec),
                    input: ShardInput::Owned(windows),
                    idx,
                },
            },
            // Session open time: latency accounting covers the ingest.
            enqueued_at: state.enqueued_at,
            reply: state.reply.clone(),
        });
    }
    jobs
}

/// Seal processing. With no eager work done the session degrades to the
/// classic one-shot routing (`shard::maybe_expand` → sharded / flat /
/// tree, identical backends) — streaming is purely additive for
/// sessions that never overlapped. Otherwise the remaining rank range
/// is planned as zero-copy `StreamShard`s over the frozen buffers and
/// the group is armed to assemble + reply on its last completion.
fn finalize(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    mut state: SessionState,
    id: u64,
    reply: Sender<JobResult>,
) -> Vec<Job> {
    for r in &mut state.runs {
        r.sealed = true;
    }
    // Latency accounting runs from session open, so the reported
    // end-to-end figure covers the whole ingest (and "queue wait" is
    // the open→seal ingest duration).
    let opened_at = state.enqueued_at;
    let total: usize = state.runs.iter().map(|r| r.buf.len()).sum();
    if state.eager_count == 0 {
        let runs: Vec<Vec<i32>> = state.runs.into_iter().map(|r| r.buf).collect();
        return vec![Job {
            id,
            kind: JobKind::Compact { runs },
            enqueued_at: opened_at,
            reply,
        }];
    }
    let queue_wait_ns =
        u64::try_from(opened_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    let remainder = total - state.planned_rank;
    let runs: Arc<Vec<Vec<i32>>> =
        Arc::new(state.runs.into_iter().map(|r| r.buf).collect());
    let mut jobs = Vec::new();
    if remainder > 0 {
        // Same sizing policy as the sharded route: ~min_len elements
        // per shard (auto-tuned when configured so), floored at
        // threads_per_job so the tail never has less parallelism than
        // a one-shot job would, capped at MAX_SHARDS, and never more
        // shards than elements. `merge.compact_sharding = false` is
        // honored here too: the tail then merges as a single shard.
        let n = if cfg.compact_sharding {
            let min_len = shard::effective_shard_min_len(cfg, remainder).max(1);
            (remainder / min_len)
                .max(1)
                .max(cfg.threads_per_job)
                .min(shard::MAX_SHARDS)
                .min(remainder)
        } else {
            1
        };
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut prev = state.planned.clone();
        for i in 1..=n {
            let cut: Vec<usize> = if i == n {
                refs.iter().map(|r| r.len()).collect()
            } else {
                kway_rank_split(&refs, state.planned_rank + i * remainder / n)
            };
            let ranges: Vec<Range<usize>> =
                prev.iter().zip(cut.iter()).map(|(&s, &e)| s..e).collect();
            let idx = state.exec.push_slot();
            jobs.push(Job {
                id,
                kind: JobKind::StreamShard {
                    shard: StreamShard {
                        exec: Arc::clone(&state.exec),
                        input: ShardInput::Shared { runs: Arc::clone(&runs), ranges },
                        idx,
                    },
                },
                enqueued_at: opened_at,
                reply: reply.clone(),
            });
            prev = cut;
        }
    }
    let mut st = state.exec.state.lock().unwrap();
    st.sealed = Some(SealInfo {
        expected: st.outputs.len(),
        total,
        reply,
        parent_id: id,
        enqueued_at: opened_at,
        queue_wait_ns,
    });
    // All eager shards may already be done (and the remainder empty):
    // assemble right here on the dispatcher.
    maybe_finish(&mut st, stats);
    drop(st);
    jobs
}

// ---------------------------------------------------------------------
// Client handle.
// ---------------------------------------------------------------------

/// Client handle to a streaming compaction: feed sorted chunks run by
/// run, seal runs as they end, then [`seal`](Self::seal) the session
/// for a [`JobHandle`] to the merged output.
///
/// Every chunk is validated at admission — sortedness within the chunk
/// plus the boundary against the run's previous chunk — in O(chunk) on
/// the calling thread, so a violation is rejected *mid-stream* with the
/// session intact (the offending chunk is simply not admitted; the
/// client may correct and continue). Feeds apply back-pressure by
/// blocking while the service queue is full.
///
/// Dropping an unsealed session aborts it: buffered data is discarded
/// and no reply is ever delivered.
#[derive(Debug)]
pub struct CompactionSession {
    queue: Arc<BoundedQueue<Job>>,
    table: Arc<SessionTable>,
    stats: Arc<ServiceStats>,
    id: u64,
    tx: Sender<JobResult>,
    rx: Option<Receiver<JobResult>>,
    runs: Vec<ClientRun>,
    sealed: bool,
    /// Back-pressure mode: `true` (streaming clients) blocks feeds
    /// while the queue is full; `false` (the one-shot `submit` wrapper)
    /// rejects the *first* message instead — preserving `submit`'s
    /// fail-fast admission — and switches to blocking once admitted,
    /// so a large job cannot spuriously reject itself mid-feed by
    /// outrunning the dispatcher with its own chunk messages.
    blocking: bool,
    /// Set after the first successful push (see `blocking`).
    admitted: bool,
}

#[derive(Debug, Default)]
struct ClientRun {
    last: Option<i32>,
    sealed: bool,
}

/// Open a session: register dispatcher-side state and build the client
/// handle. Called by `MergeService::open_compaction` (which allocates
/// the id); `submitted` is counted later, at [`CompactionSession::seal`].
pub(super) fn open(
    queue: Arc<BoundedQueue<Job>>,
    table: Arc<SessionTable>,
    stats: Arc<ServiceStats>,
    id: u64,
    run_count: usize,
    blocking: bool,
    eager: bool,
) -> CompactionSession {
    let (tx, rx) = channel();
    table.insert(
        id,
        SessionState {
            runs: (0..run_count).map(|_| RunIngest::default()).collect(),
            planned: vec![0; run_count],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx.clone(),
            enqueued_at: Instant::now(),
            eager,
            eager_count: 0,
            aborted: false,
        },
    );
    CompactionSession {
        queue,
        table,
        stats,
        id,
        tx,
        rx: Some(rx),
        runs: (0..run_count).map(|_| ClientRun::default()).collect(),
        sealed: false,
        blocking,
        admitted: false,
    }
}

impl CompactionSession {
    /// Session id (the job id the eventual [`JobResult`] reports).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Number of runs declared at open.
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    fn check_open(&self, run: usize) -> Result<()> {
        if self.sealed {
            return Err(Error::InvalidInput("session already sealed".into()));
        }
        if run >= self.runs.len() {
            return Err(Error::InvalidInput(format!(
                "run {run} out of range (session has {} runs)",
                self.runs.len()
            )));
        }
        if self.runs[run].sealed {
            return Err(Error::InvalidInput(format!("run {run} already sealed")));
        }
        Ok(())
    }

    fn push(&mut self, kind: JobKind) -> Result<()> {
        let job = Job {
            id: self.id,
            kind,
            enqueued_at: Instant::now(),
            reply: self.tx.clone(),
        };
        // Streaming clients get flow control (block while full). The
        // one-shot wrapper fail-fast-rejects only its *first* message —
        // the admission decision, matching the old by-value `Compact` —
        // and then blocks like any admitted ingest: its own chunk
        // messages filling the queue must pause it, not reject it.
        let result = if self.blocking || self.admitted {
            self.queue.push(job)
        } else {
            self.queue.try_push(job)
        };
        match result {
            Ok(()) => {
                self.admitted = true;
                Ok(())
            }
            Err(PushError::Closed) => Err(Error::Service("service shut down".into())),
            Err(PushError::Full) => {
                debug_assert!(!self.blocking, "blocking push never reports Full");
                Err(Error::Service("queue full (back-pressure)".into()))
            }
        }
    }

    /// Feed one sorted chunk of `run`. Validation is per chunk and
    /// bounded by its length: the chunk itself must be sorted and its
    /// first element must not precede the run's last fed element. An
    /// empty chunk is a no-op. Blocks while the service queue is full.
    pub fn feed(&mut self, run: usize, chunk: Vec<i32>) -> Result<()> {
        self.check_open(run)?;
        if chunk.is_empty() {
            return Ok(());
        }
        if !chunk.windows(2).all(|w| w[0] <= w[1]) {
            return Err(Error::InvalidInput(format!(
                "chunk for run {run} is not sorted"
            )));
        }
        if let Some(last) = self.runs[run].last {
            if chunk[0] < last {
                return Err(Error::InvalidInput(format!(
                    "chunk for run {run} starts at {} before the run's last element {last}",
                    chunk[0]
                )));
            }
        }
        // Client-side state and the admission counters advance only
        // after the push succeeds: a rejected push (full queue in
        // reject mode, or shutdown) must leave the session exactly as
        // it was, so the same chunk can be retried.
        let last = chunk.last().copied();
        let bytes = (chunk.len() * std::mem::size_of::<i32>()) as u64;
        self.push(JobKind::CompactChunk {
            msg: ChunkMsg { session: self.id, run, data: chunk },
        })?;
        self.runs[run].last = last;
        self.stats.streamed_chunks.inc();
        self.stats.streamed_bytes.add(bytes);
        Ok(())
    }

    /// Declare that `run` will receive no more chunks. Sealing a run
    /// removes it from the frontier minimum, which is what lets the
    /// dispatcher advance past the run's last key.
    pub fn seal_run(&mut self, run: usize) -> Result<()> {
        self.check_open(run)?;
        self.push(JobKind::CompactSealRun {
            msg: RunSealMsg { session: self.id, run },
        })?;
        self.runs[run].sealed = true;
        Ok(())
    }

    /// Seal the session (any still-open runs are sealed implicitly) and
    /// return the handle to the merged output. Consumes the session; on
    /// error (full queue in reject mode, or shutdown) the session is
    /// dropped and therefore aborted — its buffered ingest is reaped —
    /// and the admission converts into a rejection in the stats.
    pub fn seal(mut self) -> Result<JobHandle> {
        // Count the admission *before* the push: the dispatcher may
        // absorb the seal and complete the job before this thread
        // resumes, and a snapshot must never observe
        // completed > submitted. A failed push converts the admission
        // into a rejection (submitted = completed + rejected +
        // in-flight stays balanced); aborted-without-seal sessions
        // never touch either counter.
        self.stats.submitted.inc();
        if let Err(e) = self.push(JobKind::CompactSeal { msg: SealMsg { session: self.id } })
        {
            self.stats.rejected.inc();
            return Err(e);
        }
        self.sealed = true; // the seal is in: Drop must not abort now
        let rx = self.rx.take().expect("receiver taken only here");
        Ok(JobHandle::new(self.id, rx))
    }
}

impl Drop for CompactionSession {
    fn drop(&mut self) {
        if self.sealed {
            return;
        }
        // Abort: flag the session (stops eager planning even before the
        // reap) and queue its id for reclamation — the dispatcher reaps
        // on its next loop iteration, so the buffered ingest is freed
        // promptly and without depending on queue capacity.
        self.table.mark_aborted(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ingest(pairs: &[(&[i32], bool)]) -> Vec<RunIngest> {
        pairs
            .iter()
            .map(|(buf, sealed)| RunIngest { buf: buf.to_vec(), sealed: *sealed })
            .collect()
    }

    #[test]
    fn safe_rank_frontier_cases() {
        // No runs: vacuously all sealed, nothing to settle.
        assert_eq!(safe_rank(&[]), 0);
        // An open empty run pins the frontier at "nothing settled".
        assert_eq!(safe_rank(&ingest(&[(&[1, 2, 3], false), (&[], false)])), 0);
        // All sealed: everything is settled.
        assert_eq!(safe_rank(&ingest(&[(&[1, 2], true), (&[0], true)])), 3);
        // Frontier = the open run's last key (5); only strictly-below
        // counts: {2, 3} from the open run and {1} from the sealed one.
        // The ties at 5 are unsettled — a future element of the open
        // run could equal 5 and sort between them.
        assert_eq!(
            safe_rank(&ingest(&[(&[2, 3, 5], false), (&[1, 5, 9], true)])),
            3
        );
        // Two open runs: frontier is the smaller last element.
        assert_eq!(
            safe_rank(&ingest(&[(&[1, 4, 8], false), (&[2, 6], false)])),
            3, // {1, 4} and {2} are < 6
        );
        // Duplicate-heavy: nothing strictly below the frontier.
        assert_eq!(safe_rank(&ingest(&[(&[5, 5], false), (&[5, 5, 5], false)])), 0);
    }

    #[test]
    fn stream_shard_len_both_inputs() {
        let exec = Arc::new(StreamExec::default());
        let owned = StreamShard {
            exec: Arc::clone(&exec),
            input: ShardInput::Owned(vec![vec![1, 2], vec![3]]),
            idx: 0,
        };
        assert_eq!(owned.len(), 3);
        assert!(!owned.is_empty());
        let shared = StreamShard {
            exec,
            input: ShardInput::Shared {
                runs: Arc::new(vec![vec![1, 2, 3, 4], vec![5, 6]]),
                ranges: vec![1..3, 0..2],
            },
            idx: 1,
        };
        assert_eq!(shared.len(), 4);
    }

    #[test]
    fn exec_assembles_in_rank_order_after_seal() {
        let stats = ServiceStats::new();
        let exec = StreamExec::default();
        let a = exec.push_slot();
        let b = exec.push_slot();
        let (tx, rx) = channel();
        // Complete out of order, seal in between: reply fires only when
        // both the seal info and the last output are in.
        complete_shard(&exec, b, vec![30, 40], &stats);
        {
            let mut st = exec.state.lock().unwrap();
            st.sealed = Some(SealInfo {
                expected: 2,
                total: 4,
                reply: tx,
                parent_id: 9,
                enqueued_at: Instant::now(),
                queue_wait_ns: 1,
            });
            maybe_finish(&mut st, &stats);
        }
        assert!(rx.try_recv().is_err(), "must wait for the first shard");
        complete_shard(&exec, a, vec![10, 20], &stats);
        let res = rx.try_recv().expect("group complete");
        assert_eq!(res.output, vec![10, 20, 30, 40]);
        assert_eq!(res.backend, BACKEND_STREAMED);
        assert_eq!(res.id, 9);
        assert_eq!(stats.streamed_jobs.get(), 1);
        assert_eq!(stats.stream_shards_completed.get(), 2);
    }

    #[test]
    fn eager_plan_respects_threshold_and_seal_skip() {
        let cfg =
            MergeflowConfig { compact_eager_min_len: 4, ..MergeflowConfig::default() };
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[1, 2, 3, 4, 50], false), (&[1, 2, 3, 4, 60], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            aborted: false,
        };
        // Frontier = 50 → 8 settled ranks → two eager shards of 4.
        let jobs = maybe_plan_eager(&cfg, &stats, &mut state, 1);
        assert_eq!(jobs.len(), 2);
        assert_eq!(state.planned_rank, 8);
        assert_eq!(state.planned, vec![4, 4]);
        assert_eq!(stats.eager_shards.get(), 2);
        // Nothing new settled → no further shards.
        assert!(maybe_plan_eager(&cfg, &stats, &mut state, 1).is_empty());
        // All runs sealed → the seal will handle the tail zero-copy.
        for r in &mut state.runs {
            r.sealed = true;
        }
        assert!(maybe_plan_eager(&cfg, &stats, &mut state, 1).is_empty());
        // The planned shards merge the settled prefix bit-identically.
        for job in jobs {
            match job.kind {
                JobKind::StreamShard { shard } => {
                    assert_eq!(shard.len(), 4);
                    execute_stream_shard(shard, &stats);
                }
                _ => unreachable!("eager planning emits stream shards"),
            }
        }
        let st = state.exec.state.lock().unwrap();
        let merged: Vec<i32> = st
            .outputs
            .iter()
            .flat_map(|o| o.clone().unwrap())
            .collect();
        assert_eq!(merged, vec![1, 1, 2, 2, 3, 3, 4, 4]);
    }

    #[test]
    fn reap_frees_aborted_sessions() {
        let table = SessionTable::default();
        let (tx, _rx) = channel();
        table.insert(
            7,
            SessionState {
                runs: ingest(&[(&[1, 2, 3], false)]),
                planned: vec![0],
                planned_rank: 0,
                exec: Arc::new(StreamExec::default()),
                reply: tx,
                enqueued_at: Instant::now(),
                eager: true,
                eager_count: 0,
                aborted: false,
            },
        );
        table.mark_aborted(7);
        assert!(!table.sessions.lock().unwrap().is_empty(), "reap is deferred");
        table.reap_aborted();
        assert!(table.sessions.lock().unwrap().is_empty(), "buffers freed");
        // Aborting an id with no entry (already reaped) is a no-op.
        table.mark_aborted(99);
        table.reap_aborted();
    }

    #[test]
    fn eager_plan_disabled_cases() {
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[1, 2, 3, 4], false), (&[1, 2, 3, 9], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            aborted: false,
        };
        let off =
            MergeflowConfig { compact_eager_min_len: 0, ..MergeflowConfig::default() };
        assert!(maybe_plan_eager(&off, &stats, &mut state, 1).is_empty());
        let k_cap = MergeflowConfig {
            compact_eager_min_len: 1,
            kway_flat_max_k: 1,
            ..MergeflowConfig::default()
        };
        assert!(maybe_plan_eager(&k_cap, &stats, &mut state, 1).is_empty());
        assert_eq!(stats.eager_shards.get(), 0);
    }
}
