//! Streaming compaction ingest: the chunked run protocol behind
//! [`MergeService::open_compaction`](super::MergeService::open_compaction).
//!
//! A classic `JobKind::Compact` carries every run by value in one queue
//! message, so a multi-gigabyte compaction pays full ingest latency and
//! peak memory before the first cut is computed. Merge Path's core
//! property makes that wait unnecessary: **any output rank induces a
//! unique, synchronization-free cut of the inputs**, and the cut at
//! rank `r` only inspects elements that can still land in the first
//! `r` outputs. So a compaction whose low ranks are already *settled*
//! can start merging them while high-rank data is still arriving.
//!
//! The protocol is generic over keyed records ([`Record`]): chunks are
//! `Vec<R>`, validation and the frontier compare keys only, and every
//! merge is stable (equal keys keep run-index-then-offset order).
//!
//! ## Protocol
//!
//! ```text
//! client                       dispatcher                         pool
//! ─────────────────────────────────────────────────────────────────────
//! open_compaction(k) ─ registers a session (k runs, all open)
//! feed(run, chunk) ──▶ CompactChunk ─▶ append to run buffer,
//!   (validated           │             advance the sealed-rank
//!    per chunk,          │             frontier; if it moved ≥
//!    O(chunk) on         │             compact_eager_min_len past the
//!    the caller)         │             planned rank: cut + dispatch
//!                        │             eager StreamShard(s) ─────▶ merge
//! seal_run(run) ───▶ CompactSealRun ─▶ run leaves the frontier min
//! seal() ──────────▶ CompactSeal ───▶ allocate the final buffer; plan
//!                                     the remaining rank range as
//!                                     zero-copy StreamShards that merge
//!                                     straight into their disjoint
//!                                     windows of it ──────────────▶ merge
//!                                     (or, if nothing was dispatched
//!                                     eagerly, fall back to the classic
//!                                     Compact routing — one code path,
//!                                     same backends as before)
//! eager outputs are memcpy'd into their windows by a pool-worker
//! install task at seal (or by the shard's own completion after seal);
//! the completion that brings the sealed group to full strength takes
//! the fully-tiled buffer and replies ("native-kway-streamed") — there
//! is no concatenation pass.
//! ```
//!
//! ## The sealed-rank frontier (tie-aware)
//!
//! Let `F` be the minimum, over all *open* (unsealed) runs, of the last
//! key fed to that run — undefined (no rank is safe) while any open run
//! is still empty, and `+∞` once every run is sealed. Per-chunk
//! admission validation guarantees each run's future elements have keys
//! `≥` the run's current last key, hence `≥ F`. A fed element
//! `(key, run j, offset)` is **settled** — no future element can
//! precede it in the stable `(key, run, offset)` order — iff
//!
//! - `key < F` (every future key is `≥ F`), or
//! - `key == F` and `j ≤ m`, where `m` is the lowest-indexed *open* run
//!   whose last key equals `F`: open runs below `m` have last key
//!   `> F` (their ties at `F` are complete), run `m`'s own future ties
//!   land at later offsets (which never precede its fed ones), and
//!   every other open run that can still produce a tie at `F` has index
//!   `> m ≥ j`. Runs above `m` must wait — run `m` may yet feed a tie
//!   that sorts before theirs.
//!
//! The settled elements are a prefix of the stable merge of the *fed
//! prefixes* and of the *final runs* alike, so for any rank `r ≤ safe`
//! (`safe` = settled count, computed with one `partition_point` pair
//! per run) the cut over the fed prefixes ([`kway_rank_split`]) equals
//! the cut over the complete runs: eager shards cut on live data are
//! bit-identical to shards cut after seal. Tracking the `(key, run)`
//! tie owner — not just bare keys — is what keeps heavy-duplicate
//! sessions streaming: with `k` identical runs the bare-key frontier
//! settles nothing (no key is strictly below `F`), while the tie-aware
//! frontier settles all of run 0's duplicates.
//!
//! ## Memory & cost model
//!
//! Eager shards copy their per-run windows out of the live ingest
//! buffers (the buffers keep growing and may reallocate, so running
//! workers must not borrow them) and merge into owned vectors — the
//! final buffer does not exist yet. At `seal()` the final buffer is
//! allocated once and the remainder is planned zero-copy (Arc'd frozen
//! run buffers): remainder shards merge **in place** through disjoint
//! windows of the shared buffer (the `SharedOut` pattern from
//! [`super::shard`]), and only the eager outputs are memcpy'd in —
//! removing the former whole-output concatenation pass. The per-chunk
//! admission checks replace `JobKind::validate`'s former O(total) walk
//! of every compaction on the submit path: validation cost is now
//! amortized and bounded by the chunk size per call.
//!
//! ## Frontier-driven run reclamation
//!
//! Once an eager shard has copied its windows out, the covered run
//! prefixes can never be read again: every later cut is at a higher
//! rank, and stable cuts are nested (the prefix of the merge at rank
//! `r₁ < r₂` is componentwise a prefix of the cut at `r₂`). So after
//! each planning round the dispatcher **drops the planned prefixes
//! from the live buffers** ([`RunIngest::base`] records how much was
//! dropped; `base + buf.len()` is the run's fed length). A long-lived
//! streamed session therefore holds O(unsettled) bytes — the data
//! between the planned rank and the ingest tip — instead of O(total).
//! All rank arithmetic stays absolute at the interfaces; cuts over the
//! live tails use `rank − Σ base`, which equals the absolute cut minus
//! the per-run bases precisely because stable cuts are nested.
//!
//! Reclaimed bytes are counted in
//! [`ServiceStats::reclaimed_bytes`], and every session's live ingest
//! is tracked in the [`ServiceStats::resident_bytes`] gauge (added per
//! chunk, subtracted on reclaim / abort / seal hand-off), which is
//! what `merge.memory_budget` admission is checked against.

use super::job::{Job, JobHandle, JobKind, JobResult};
use super::queue::{BoundedQueue, PushError};
use super::shard::{self, SharedOut};
use super::stats::ServiceStats;
use crate::config::MergeflowConfig;
use crate::mergepath::kernel::{LeafKernel, MergeKernel};
use crate::mergepath::kway::loser_tree_merge_segmented_with;
use crate::mergepath::kway_path::kway_rank_split;
use crate::record::{self, ByKey, Record};
use crate::{Error, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Backend tag reported for compactions that overlapped ingest with
/// eager merging (at least one pre-seal shard dispatched).
pub const BACKEND_STREAMED: &str = "native-kway-streamed";

/// Hard ceiling on *eager* shards per session, independent of
/// configuration — bounds dispatcher-side planning/copy cost. The
/// remainder planned at seal is separately capped by
/// [`shard::MAX_SHARDS`].
const MAX_EAGER_SHARDS: usize = shard::MAX_SHARDS;

// ---------------------------------------------------------------------
// Queue message payloads. Fields are private to this module, so clients
// cannot construct (and `submit` cannot receive) session messages
// directly — the same opacity trick as `shard::ShardTask`.
// ---------------------------------------------------------------------

/// Payload of [`JobKind::CompactChunk`]: one validated chunk of one run.
#[derive(Debug, Clone)]
pub struct ChunkMsg<R: Record = i32> {
    session: u64,
    run: usize,
    data: Vec<R>,
}

impl<R: Record> ChunkMsg<R> {
    /// Elements in this chunk (for job accounting).
    pub(super) fn len(&self) -> usize {
        self.data.len()
    }
}

/// Payload of [`JobKind::CompactSealRun`]: a run will receive no more
/// chunks (it leaves the frontier minimum).
#[derive(Debug, Clone)]
pub struct RunSealMsg {
    session: u64,
    run: usize,
}

/// Payload of [`JobKind::CompactSeal`]: no more feeds at all; plan the
/// remaining rank range and arrange the reply.
#[derive(Debug, Clone)]
pub struct SealMsg {
    session: u64,
}

// ---------------------------------------------------------------------
// Shared execution state (session ↔ stream-shard jobs on the pool).
// ---------------------------------------------------------------------

/// One shard of a streamed compaction. Eager (pre-seal) shards carry
/// owned window copies and merge into an owned vector (the final
/// buffer does not exist yet); remainder shards planned at `seal()`
/// borrow the frozen run buffers and merge **in place** into their
/// disjoint window of the final output buffer. Carried by
/// [`JobKind::StreamShard`]; constructed only by the dispatcher's
/// session planner.
#[derive(Debug, Clone)]
pub struct StreamShard<R: Record = i32> {
    exec: Arc<StreamExec<R>>,
    /// Slot in the session's rank-ordered window list.
    idx: usize,
    input: ShardInput<R>,
    /// Path-window length for this shard's merge (`0` = unwindowed):
    /// resolved at plan time from `merge.kway_segment_elems` (auto =
    /// `C/(k+1)`), mirroring the rank-sharded route.
    seg_elems: usize,
    /// Requested leaf kernel (`merge.kernel`), resolved at execute
    /// time so two-run shards hit the same pairwise leaf kernels as
    /// the in-process engines. Install tasks are memcpy-only and carry
    /// the inert `Auto`.
    kernel: MergeKernel,
}

#[derive(Debug, Clone)]
enum ShardInput<R: Record> {
    /// Eager (pre-seal) shard: windows copied out of the live ingest
    /// buffers, which keep growing (and may reallocate) underneath.
    Owned(Vec<Vec<R>>),
    /// Remainder shard planned at seal: borrows the frozen run buffers
    /// and writes its `window` of the shared output buffer directly.
    Windowed {
        runs: Arc<Vec<Vec<R>>>,
        ranges: Vec<Range<usize>>,
        out: Arc<SharedOut<R>>,
        window: Range<usize>,
    },
    /// Post-seal install task: memcpy the outputs of eager shards that
    /// completed *before* the seal into their (disjoint) windows of
    /// the final buffer — on a pool worker, so the dispatcher's seal
    /// handling stays at planning cost. Counted via `ExecState::extra`
    /// (it is not a shard).
    Install {
        items: Vec<(Range<usize>, Vec<R>)>,
        out: Arc<SharedOut<R>>,
    },
}

impl<R: Record> StreamShard<R> {
    /// Output elements this shard produces.
    pub fn len(&self) -> usize {
        match &self.input {
            ShardInput::Owned(windows) => windows.iter().map(|w| w.len()).sum(),
            ShardInput::Windowed { window, .. } => window.len(),
            ShardInput::Install { items, .. } => items.iter().map(|(w, _)| w.len()).sum(),
        }
    }

    /// True iff the shard produces no output.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Completion state shared by all stream shards of one session.
#[derive(Debug)]
struct StreamExec<R: Record> {
    state: Mutex<ExecState<R>>,
}

impl<R: Record> Default for StreamExec<R> {
    fn default() -> Self {
        Self { state: Mutex::new(ExecState::default()) }
    }
}

#[derive(Debug)]
struct ExecState<R: Record> {
    /// Disjoint output windows, one per shard, in rank order (slot `i`
    /// covers output ranks `slots[i]`). Eager slots tile
    /// `[0, planned_rank)`; remainder slots tile the rest at seal.
    slots: Vec<Range<usize>>,
    /// Eager outputs completed *before* the seal allocated the final
    /// buffer, parked here and memcpy'd into their windows at seal.
    parked: Vec<Option<Vec<R>>>,
    /// The final output buffer, allocated at seal. Remainder shards
    /// write their windows directly; eager completions after seal copy
    /// themselves in.
    out: Option<Arc<SharedOut<R>>>,
    /// Shards completed so far.
    done: usize,
    /// Pending auxiliary work that must also finish before the reply —
    /// the install task carrying pre-seal eager outputs (0 or 1).
    extra: usize,
    /// Set when the session seals: from then on the shard count is
    /// final and the completion that reaches full strength replies.
    sealed: Option<SealInfo<R>>,
}

impl<R: Record> Default for ExecState<R> {
    fn default() -> Self {
        Self {
            slots: Vec::new(),
            parked: Vec::new(),
            out: None,
            done: 0,
            extra: 0,
            sealed: None,
        }
    }
}

#[derive(Debug)]
struct SealInfo<R: Record> {
    /// Total output elements.
    total: usize,
    reply: Sender<JobResult<R>>,
    parent_id: u64,
    /// Session open time — end-to-end latency covers the whole ingest.
    enqueued_at: Instant,
    /// Ingest duration (open → seal processed), reported as queue wait.
    queue_wait_ns: u64,
}

impl<R: Record> StreamExec<R> {
    /// Allocate the next rank-ordered shard slot covering `window`.
    fn push_slot(&self, window: Range<usize>) -> usize {
        let mut st = self.state.lock().unwrap();
        st.slots.push(window);
        st.parked.push(None);
        st.slots.len() - 1
    }
}

/// Record one *eager* shard's owned output: parked until the seal
/// allocates the final buffer, copied straight into the shard's window
/// once it exists. The completion that brings the sealed group to full
/// strength replies.
fn complete_eager<R: Record>(
    exec: &StreamExec<R>,
    idx: usize,
    out: Vec<R>,
    stats: &ServiceStats,
) {
    let mut guard = exec.state.lock().unwrap();
    let st = &mut *guard;
    debug_assert!(st.parked[idx].is_none(), "shard slot filled twice");
    match &st.out {
        Some(buf) => {
            let w = st.slots[idx].clone();
            debug_assert_eq!(w.len(), out.len(), "shard output must fill its window");
            // SAFETY: slot windows are disjoint and this completion is
            // its window's only writer; concurrent remainder shards
            // write other windows of the same buffer.
            unsafe { std::slice::from_raw_parts_mut(buf.base().add(w.start), w.len()) }
                .copy_from_slice(&out);
        }
        None => st.parked[idx] = Some(out),
    }
    st.done += 1;
    stats.stream_shards_completed.inc();
    maybe_finish(st, stats);
}

/// Record a windowed (remainder) shard completion — its output is
/// already in place in the final buffer.
fn complete_windowed<R: Record>(exec: &StreamExec<R>, stats: &ServiceStats) {
    let mut guard = exec.state.lock().unwrap();
    let st = &mut *guard;
    st.done += 1;
    stats.stream_shards_completed.inc();
    maybe_finish(st, stats);
}

/// Arm a sealed session's exec state: install the final output buffer
/// and the seal info, and *steal* any parked eager outputs — they are
/// returned for installation by a pool-worker task (counted in
/// `extra`), so the dispatcher's seal handling stays at planning cost
/// instead of memcpying the whole eager prefix under the exec lock.
/// Fires the reply immediately when nothing is parked and every shard
/// already completed.
fn arm_sealed<R: Record>(
    exec: &StreamExec<R>,
    out: &Arc<SharedOut<R>>,
    info: SealInfo<R>,
    stats: &ServiceStats,
) -> Vec<(Range<usize>, Vec<R>)> {
    let mut guard = exec.state.lock().unwrap();
    let st = &mut *guard;
    let mut items = Vec::new();
    for (idx, slot) in st.parked.iter_mut().enumerate() {
        if let Some(v) = slot.take() {
            let w = st.slots[idx].clone();
            debug_assert_eq!(w.len(), v.len(), "shard output must fill its window");
            items.push((w, v));
        }
    }
    st.extra = usize::from(!items.is_empty());
    st.out = Some(Arc::clone(out));
    st.sealed = Some(info);
    maybe_finish(st, stats);
    items
}

/// If the session is sealed and every shard (plus the install task, if
/// any) has reported, take the fully-tiled output buffer and reply on
/// the session handle.
fn maybe_finish<R: Record>(st: &mut ExecState<R>, stats: &ServiceStats) {
    let Some(info) = &st.sealed else { return };
    if st.done < st.slots.len() || st.extra > 0 {
        return;
    }
    let buf = st.out.take().expect("sealed group has an output buffer");
    // SAFETY: the slot windows tile the buffer and every shard has
    // completed (done == slots, observed under the state mutex, which
    // every completion passed through — happens-before established),
    // so the buffer is fully written and no writer can touch it again.
    let output = unsafe { buf.take() };
    let latency_ns =
        u64::try_from(info.enqueued_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    stats.record_completion(
        BACKEND_STREAMED,
        info.total as u64,
        latency_ns,
        info.queue_wait_ns,
    );
    // Receiver may have been dropped (client gave up) — that's fine.
    let _ = info.reply.send(JobResult {
        id: info.parent_id,
        output,
        backend: BACKEND_STREAMED,
        latency_ns,
    });
    // Drop the sender so an aborted/forgotten receiver unblocks.
    st.sealed = None;
}

/// Execute one stream shard on a pool worker: stable loser-tree merge
/// of its per-run windows (key-only order via [`ByKey`]) — in
/// `(k+1)·L`-bounded path windows when planned with segmented merging
/// (`seg_elems > 0`; bit-identical either way) — then report
/// completion. Eager shards merge into an owned buffer; remainder
/// shards merge straight into their window of the final buffer; the
/// install task memcpys pre-seal eager outputs into theirs.
pub(crate) fn execute_stream_shard<R: Record>(shard: StreamShard<R>, stats: &ServiceStats) {
    // Install tasks are memcpy-only and always carry seg_elems == 0.
    if shard.seg_elems > 0 {
        stats.segmented_shard_merges.inc();
    }
    match &shard.input {
        ShardInput::Owned(windows) => {
            let parts: Vec<&[ByKey<R>]> =
                windows.iter().map(|w| record::as_keyed(w)).collect();
            let total: usize = parts.iter().map(|p| p.len()).sum();
            // Fully tiled by the loser-tree merge (see crate::uninit_vec).
            let mut out: Vec<ByKey<R>> = crate::uninit_vec(total);
            loser_tree_merge_segmented_with(
                &parts,
                &mut out,
                shard.seg_elems,
                LeafKernel::select(shard.kernel),
            );
            complete_eager(&shard.exec, shard.idx, record::into_records(out), stats);
        }
        ShardInput::Windowed { runs, ranges, out, window } => {
            let parts: Vec<&[ByKey<R>]> = ranges
                .iter()
                .zip(runs.iter())
                .map(|(r, run)| record::as_keyed(&run[r.clone()]))
                .collect();
            // SAFETY: remainder windows are disjoint (nested rank cuts)
            // and disjoint from every eager window; the buffer is read
            // only after all shards completed (state mutex ordering).
            let dst = unsafe {
                std::slice::from_raw_parts_mut(out.base().add(window.start), window.len())
            };
            loser_tree_merge_segmented_with(
                &parts,
                record::as_keyed_mut(dst),
                shard.seg_elems,
                LeafKernel::select(shard.kernel),
            );
            complete_windowed(&shard.exec, stats);
        }
        ShardInput::Install { items, out } => {
            for (w, v) in items {
                // SAFETY: eager windows are disjoint from each other
                // and from every remainder window, and their producing
                // shards have completed — this task is each window's
                // only writer.
                unsafe {
                    std::slice::from_raw_parts_mut(out.base().add(w.start), w.len())
                }
                .copy_from_slice(v);
            }
            let mut guard = shard.exec.state.lock().unwrap();
            let st = &mut *guard;
            st.extra -= 1;
            maybe_finish(st, stats);
        }
    }
}

// ---------------------------------------------------------------------
// Dispatcher-side session state.
// ---------------------------------------------------------------------

/// All live streaming sessions, shared between the service front end
/// (open / abort) and the dispatcher (everything else). The dispatcher
/// is the only mutator of per-session ingest state; clients only insert
/// new sessions and flip the abort flag, so one mutex over the map is
/// uncontended in practice.
#[derive(Debug)]
pub(super) struct SessionTable<R: Record> {
    sessions: Mutex<HashMap<u64, SessionState<R>>>,
    /// Ids of aborted sessions awaiting reclamation. Dropping a session
    /// records its id here (an in-memory list — unlike a queue message
    /// it cannot fail under back-pressure), and the dispatcher reaps on
    /// every loop iteration, so an aborted session's buffered ingest is
    /// freed promptly instead of leaking until service shutdown.
    aborted: Mutex<Vec<u64>>,
}

impl<R: Record> Default for SessionTable<R> {
    fn default() -> Self {
        Self { sessions: Mutex::new(HashMap::new()), aborted: Mutex::new(Vec::new()) }
    }
}

impl<R: Record> SessionTable<R> {
    fn insert(&self, id: u64, state: SessionState<R>) {
        self.sessions.lock().unwrap().insert(id, state);
    }

    fn mark_aborted(&self, id: u64) {
        if let Some(s) = self.sessions.lock().unwrap().get_mut(&id) {
            s.aborted = true;
        }
        self.aborted.lock().unwrap().push(id);
    }

    /// Drop the state of every aborted session. Called by the
    /// dispatcher once per loop iteration; in-flight messages that
    /// still reference a reaped id just find no entry and are ignored.
    /// Releases the reaped sessions' live ingest from the resident
    /// gauge — an abort mid-reclaim must leave the accounting at zero,
    /// not leak the unreclaimed tail.
    pub(super) fn reap_aborted(&self, stats: &ServiceStats) {
        let ids: Vec<u64> = std::mem::take(&mut *self.aborted.lock().unwrap());
        if ids.is_empty() {
            return;
        }
        let mut map = self.sessions.lock().unwrap();
        for id in ids {
            if let Some(state) = map.remove(&id) {
                stats.resident_bytes.sub(state.ingest_bytes);
            }
        }
    }
}

#[derive(Debug)]
struct SessionState<R: Record> {
    runs: Vec<RunIngest<R>>,
    /// Absolute per-run cut positions already dispatched to eager
    /// shards (componentwise nondecreasing; sums to `planned_rank`).
    planned: Vec<usize>,
    /// Output ranks `[0, planned_rank)` are covered by eager shards.
    planned_rank: usize,
    exec: Arc<StreamExec<R>>,
    /// Session reply sender; every emitted shard job carries a clone.
    reply: Sender<JobResult<R>>,
    enqueued_at: Instant,
    /// Whether eager (pre-seal) planning is enabled for this session.
    /// The one-shot wrapper disables it when it fed every run as one
    /// whole-moved chunk: ingest completes in the same breath, so
    /// eager window copies could never buy overlap — and the route the
    /// job takes stays deterministic (classic fallback) instead of
    /// depending on where batch boundaries happen to fall.
    eager: bool,
    eager_count: usize,
    /// Bytes of live (unreclaimed) ingest currently buffered across the
    /// session's runs — the amount held in
    /// [`ServiceStats::resident_bytes`] on this session's behalf.
    ingest_bytes: u64,
    aborted: bool,
}

#[derive(Debug)]
struct RunIngest<R: Record> {
    /// Live (unreclaimed) tail of the run's fed prefix.
    buf: Vec<R>,
    /// Elements already reclaimed from the front of the run — settled
    /// prefixes copied into eager shards and then dropped.
    /// `base + buf.len()` is the run's total fed length; all ranks at
    /// the planner interfaces stay absolute.
    base: usize,
    /// Last record fed to the run. The frontier needs it even after
    /// reclamation drains the live buffer to empty.
    last: Option<R>,
    sealed: bool,
}

impl<R: Record> Default for RunIngest<R> {
    fn default() -> Self {
        Self { buf: Vec::new(), base: 0, last: None, sealed: false }
    }
}

impl<R: Record> RunIngest<R> {
    /// Total elements fed to this run (reclaimed prefix + live tail).
    fn fed_len(&self) -> usize {
        self.base + self.buf.len()
    }
}

/// Settled output prefix length under the tie-aware sealed-rank
/// frontier (module docs): keys strictly below the minimum last-fed
/// key `F` of any open run always settle; ties *at* `F` settle for
/// every run up to (and including) the lowest-indexed open run whose
/// last key is `F` — later runs must wait for that run's possible
/// future ties. Everything once all runs are sealed; nothing while an
/// open run has never been fed.
///
/// Reclamation-aware: the frontier reads each run's `last` fed record
/// (which survives draining the live buffer), and each run counts its
/// reclaimed `base` in full — reclaimed elements were settled when
/// dropped and settledness is monotone (the frontier never retreats,
/// and the tie owner never moves below a run whose ties it admitted).
fn safe_rank<R: Record>(runs: &[RunIngest<R>]) -> usize {
    let mut frontier: Option<&R::Key> = None;
    let mut all_sealed = true;
    for r in runs {
        if !r.sealed {
            all_sealed = false;
            match &r.last {
                None => return 0,
                Some(v) => {
                    let k = v.key();
                    frontier = Some(match frontier {
                        Some(f) if f <= k => f,
                        _ => k,
                    });
                }
            }
        }
    }
    if all_sealed {
        return runs.iter().map(|r| r.fed_len()).sum();
    }
    let f = frontier.expect("an open run with data exists");
    // The tie owner: lowest-indexed open run whose last fed key is F.
    let owner = runs
        .iter()
        .position(|r| !r.sealed && r.last.as_ref().map(|v| v.key()) == Some(f))
        .expect("the frontier came from some open run");
    runs.iter()
        .enumerate()
        .map(|(j, r)| {
            let below = r.buf.partition_point(|x| x.key() < f);
            r.base
                + if j <= owner {
                    below + r.buf[below..].partition_point(|x| x.key() == f)
                } else {
                    below
                }
        })
        .sum()
}

/// True iff `kind` is a session protocol message (handled on the
/// dispatcher, never dispatched to a worker).
pub(super) fn is_session_message<R: Record>(kind: &JobKind<R>) -> bool {
    matches!(
        kind,
        JobKind::CompactChunk { .. } | JobKind::CompactSealRun { .. } | JobKind::CompactSeal { .. }
    )
}

/// Process one session message on the dispatcher thread. Ingest
/// messages (chunk / run-seal) only mutate session state and record the
/// touched session in `touched`; eager planning runs once per drained
/// batch via [`plan_eager`], so a session whose seal is absorbed in the
/// same batch never pays for eager window copies the seal's zero-copy
/// remainder planner would make redundant. A seal returns the jobs it
/// unlocked (the remainder plan or the classic-fallback `Compact`); the
/// caller dispatches them through the normal expansion + in-flight
/// accounting.
pub(super) fn handle_message<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    table: &SessionTable<R>,
    job: Job<R>,
    touched: &mut Vec<u64>,
) -> Vec<Job<R>> {
    let Job { id, kind, enqueued_at, reply } = job;
    let mut map = table.sessions.lock().unwrap();
    match kind {
        JobKind::CompactChunk { msg } => {
            let Some(state) = map.get_mut(&msg.session) else { return Vec::new() };
            if state.aborted {
                let st = map.remove(&msg.session).expect("entry just found");
                stats.resident_bytes.sub(st.ingest_bytes);
                return Vec::new();
            }
            let bytes = std::mem::size_of_val(msg.data.as_slice()) as u64;
            let r = &mut state.runs[msg.run];
            debug_assert!(!r.sealed, "chunk for a sealed run passed admission");
            if let Some(v) = msg.data.last() {
                r.last = Some(*v);
            }
            if r.buf.is_empty() {
                // First chunk of a run lands by move — the whole-run
                // feeds of the one-shot wrapper never copy.
                r.buf = msg.data;
            } else {
                r.buf.extend_from_slice(&msg.data);
            }
            state.ingest_bytes += bytes;
            stats.resident_bytes.add(bytes);
            touched.push(msg.session);
            Vec::new()
        }
        JobKind::CompactSealRun { msg } => {
            let Some(state) = map.get_mut(&msg.session) else { return Vec::new() };
            if state.aborted {
                let st = map.remove(&msg.session).expect("entry just found");
                stats.resident_bytes.sub(st.ingest_bytes);
                return Vec::new();
            }
            state.runs[msg.run].sealed = true;
            touched.push(msg.session);
            Vec::new()
        }
        JobKind::CompactSeal { msg } => {
            let Some(state) = map.remove(&msg.session) else { return Vec::new() };
            if state.aborted {
                stats.resident_bytes.sub(state.ingest_bytes);
                return Vec::new();
            }
            // `state` is owned now — release the table lock so client
            // threads (open_compaction, session drops) are not stalled
            // behind the remainder planning below.
            drop(map);
            finalize(cfg, stats, state, id, reply)
        }
        other => vec![Job { id, kind: other, enqueued_at, reply }],
    }
}

/// Batch-level eager planning: for every session touched by the just
/// drained batch that is still live (not sealed in that same batch, not
/// aborted), dispatch eager shards over its newly settled ranks. Called
/// by the dispatcher after each batch; `touched` is drained.
pub(super) fn plan_eager<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    table: &SessionTable<R>,
    touched: &mut Vec<u64>,
) -> Vec<Job<R>> {
    if touched.is_empty() {
        return Vec::new();
    }
    touched.sort_unstable();
    touched.dedup();
    let mut jobs = Vec::new();
    let mut map = table.sessions.lock().unwrap();
    for id in touched.drain(..) {
        let Some(state) = map.get_mut(&id) else { continue };
        if state.aborted {
            continue; // the reap frees it
        }
        jobs.extend(maybe_plan_eager(cfg, stats, state, id));
    }
    jobs
}

/// Dispatch eager shards while the sealed-rank frontier is at least
/// `compact_eager_min_len` ahead of the planned rank. Each shard covers
/// exactly that many output ranks; the cut is computed over the fed
/// prefixes, which for ranks within the frontier equals the cut over
/// the final runs (module docs). Skipped entirely once every run is
/// sealed: the seal message is imminent and its remainder planner
/// merges the tail zero-copy and in place, so eager window copies would
/// be waste.
fn maybe_plan_eager<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    state: &mut SessionState<R>,
    id: u64,
) -> Vec<Job<R>> {
    let eager_len = cfg.compact_eager_min_len;
    if eager_len == 0 || !state.eager {
        return Vec::new();
    }
    let k = state.runs.len();
    // Eager shards run the flat engine's per-shard kernel; share its k
    // cap (which also bounds per-cut planning cost, like shard.rs).
    if k < 2 || k > cfg.kway_flat_max_k {
        return Vec::new();
    }
    if state.runs.iter().all(|r| r.sealed) {
        return Vec::new();
    }
    let safe = safe_rank(&state.runs);
    let seg_elems = cfg.effective_kway_segment_elems(std::mem::size_of::<R>(), k);
    let mut jobs = Vec::new();
    while safe.saturating_sub(state.planned_rank) >= eager_len
        && state.eager_count < MAX_EAGER_SHARDS
    {
        let target = state.planned_rank + eager_len;
        // The cut over the live tails at `target − Σ base` equals the
        // absolute cut at `target` minus the per-run bases: stable
        // cuts are nested, and every base is a previously planned cut.
        let base_sum: usize = state.runs.iter().map(|r| r.base).sum();
        let cut = {
            let prefixes: Vec<&[ByKey<R>]> =
                state.runs.iter().map(|r| record::as_keyed(&r.buf)).collect();
            kway_rank_split(&prefixes, target - base_sum)
        };
        let windows: Vec<Vec<R>> = state
            .runs
            .iter()
            .zip(cut.iter().zip(state.planned.iter()))
            .map(|(r, (&e_rel, &s_abs))| r.buf[s_abs - r.base..e_rel].to_vec())
            .collect();
        let idx = state.exec.push_slot(state.planned_rank..target);
        state.planned = state
            .runs
            .iter()
            .zip(cut.iter())
            .map(|(r, &e_rel)| r.base + e_rel)
            .collect();
        state.planned_rank = target;
        state.eager_count += 1;
        stats.eager_shards.inc();
        jobs.push(Job {
            id,
            kind: JobKind::StreamShard {
                shard: StreamShard {
                    exec: Arc::clone(&state.exec),
                    idx,
                    input: ShardInput::Owned(windows),
                    seg_elems,
                    kernel: cfg.kernel,
                },
            },
            // Session open time: latency accounting covers the ingest.
            enqueued_at: state.enqueued_at,
            reply: state.reply.clone(),
        });
    }
    if !jobs.is_empty() {
        reclaim_planned(stats, state);
    }
    jobs
}

/// Frontier-driven run reclamation: drop the planned prefixes from the
/// live ingest buffers. Everything below `planned[j]` has been copied
/// into eager shard windows and — stable cuts being nested — can never
/// be read by a later cut, so a long-lived streamed session holds
/// O(unsettled) bytes instead of O(total). Buffers whose live tail
/// shrank below half their capacity are reallocated down so the freed
/// memory actually returns to the allocator.
fn reclaim_planned<R: Record>(stats: &ServiceStats, state: &mut SessionState<R>) {
    for (r, &p) in state.runs.iter_mut().zip(state.planned.iter()) {
        let rel = p - r.base;
        if rel == 0 {
            continue;
        }
        let bytes = (rel * std::mem::size_of::<R>()) as u64;
        r.buf.drain(..rel);
        if r.buf.capacity() / 2 > r.buf.len() {
            r.buf.shrink_to_fit();
        }
        r.base = p;
        state.ingest_bytes -= bytes;
        stats.resident_bytes.sub(bytes);
        stats.reclaimed_bytes.add(bytes);
    }
}

/// Seal processing. With no eager work done the session degrades to the
/// classic one-shot routing (`shard::maybe_expand` → sharded / flat /
/// tree, identical backends) — streaming is purely additive for
/// sessions that never overlapped. Otherwise the final output buffer is
/// allocated here, the remaining rank range is planned as zero-copy
/// `StreamShard`s that merge straight into their disjoint windows of
/// it, parked eager outputs are handed to a pool-worker install task
/// (the dispatcher pays planning cost only), and the group is armed to
/// reply on its last completion.
fn finalize<R: Record>(
    cfg: &MergeflowConfig,
    stats: &ServiceStats,
    mut state: SessionState<R>,
    id: u64,
    reply: Sender<JobResult<R>>,
) -> Vec<Job<R>> {
    for r in &mut state.runs {
        r.sealed = true;
    }
    // The buffers leave session ownership here — as a classic Compact
    // payload or as Arc'd frozen shard inputs, both re-estimated at
    // dispatch — so the session's share of the resident gauge drops.
    stats.resident_bytes.sub(state.ingest_bytes);
    state.ingest_bytes = 0;
    // Latency accounting runs from session open, so the reported
    // end-to-end figure covers the whole ingest (and "queue wait" is
    // the open→seal ingest duration).
    let opened_at = state.enqueued_at;
    let total: usize = state.runs.iter().map(|r| r.fed_len()).sum();
    if state.eager_count == 0 {
        // No eager shards means no reclamation ran: the live buffers
        // are the complete runs and move into the classic route whole.
        debug_assert!(state.runs.iter().all(|r| r.base == 0));
        let runs: Vec<Vec<R>> = state.runs.into_iter().map(|r| r.buf).collect();
        return vec![Job {
            id,
            kind: JobKind::Compact { runs },
            enqueued_at: opened_at,
            reply,
        }];
    }
    let queue_wait_ns =
        u64::try_from(opened_at.elapsed().as_nanos()).unwrap_or(u64::MAX);
    let remainder = total - state.planned_rank;
    // Remainder planning works on the live tails; `bases` converts the
    // absolute planner state (`planned`, output ranks) into positions
    // relative to them. The `Windowed` ranges index the frozen live
    // buffers; output windows stay absolute.
    let bases: Vec<usize> = state.runs.iter().map(|r| r.base).collect();
    let base_sum: usize = bases.iter().sum();
    let runs: Arc<Vec<Vec<R>>> =
        Arc::new(state.runs.into_iter().map(|r| r.buf).collect());
    // The final output buffer, allocated exactly once. Eager windows
    // tile [0, planned_rank), remainder windows tile the rest; every
    // slot is fully written before the buffer is read (uninit_vec
    // contract).
    let out: Arc<SharedOut<R>> = Arc::new(SharedOut::new(crate::uninit_vec(total)));
    let seg_elems =
        cfg.effective_kway_segment_elems(std::mem::size_of::<R>(), runs.len());
    let mut jobs = Vec::new();
    if remainder > 0 {
        // Same sizing policy as the sharded route: ~min_len elements
        // per shard (auto-tuned when configured so), floored at
        // threads_per_job so the tail never has less parallelism than
        // a one-shot job would, capped at MAX_SHARDS, and never more
        // shards than elements. `merge.compact_sharding = false` is
        // honored here too: the tail then merges as a single shard.
        let n = if cfg.compact_sharding {
            let min_len = shard::effective_shard_min_len(cfg, remainder).max(1);
            (remainder / min_len)
                .max(1)
                .max(cfg.threads_per_job)
                .min(shard::MAX_SHARDS)
                .min(remainder)
        } else {
            1
        };
        let refs: Vec<&[ByKey<R>]> =
            runs.iter().map(|r| record::as_keyed(r)).collect();
        let mut prev: Vec<usize> = state
            .planned
            .iter()
            .zip(bases.iter())
            .map(|(&p, &b)| p - b)
            .collect();
        let mut prev_rank = state.planned_rank;
        for i in 1..=n {
            let (cut, rank): (Vec<usize>, usize) = if i == n {
                (refs.iter().map(|r| r.len()).collect(), total)
            } else {
                let rank = state.planned_rank + i * remainder / n;
                (kway_rank_split(&refs, rank - base_sum), rank)
            };
            let ranges: Vec<Range<usize>> =
                prev.iter().zip(cut.iter()).map(|(&s, &e)| s..e).collect();
            let idx = state.exec.push_slot(prev_rank..rank);
            jobs.push(Job {
                id,
                kind: JobKind::StreamShard {
                    shard: StreamShard {
                        exec: Arc::clone(&state.exec),
                        idx,
                        input: ShardInput::Windowed {
                            runs: Arc::clone(&runs),
                            ranges,
                            out: Arc::clone(&out),
                            window: prev_rank..rank,
                        },
                        seg_elems,
                        kernel: cfg.kernel,
                    },
                },
                enqueued_at: opened_at,
                reply: reply.clone(),
            });
            prev = cut;
            prev_rank = rank;
        }
    }
    // Arm the group. Parked eager outputs are stolen here and installed
    // by a pool-worker task below — the dispatcher never pays the
    // memcpy. With nothing parked and no remainder, arm_sealed
    // assembles right here (the buffer is already fully tiled).
    let installs = arm_sealed(
        &state.exec,
        &out,
        SealInfo { total, reply, parent_id: id, enqueued_at: opened_at, queue_wait_ns },
        stats,
    );
    if !installs.is_empty() {
        jobs.push(Job {
            id,
            kind: JobKind::StreamShard {
                shard: StreamShard {
                    exec: Arc::clone(&state.exec),
                    idx: 0, // unused: installs have no slot of their own
                    input: ShardInput::Install { items: installs, out },
                    seg_elems: 0, // memcpy only, nothing to window
                    kernel: MergeKernel::Auto, // memcpy only, no leaf merges
                },
            },
            enqueued_at: opened_at,
            reply: state.reply.clone(),
        });
    }
    jobs
}

// ---------------------------------------------------------------------
// Client handle.
// ---------------------------------------------------------------------

/// Client handle to a streaming compaction: feed sorted record chunks
/// run by run, seal runs as they end, then [`seal`](Self::seal) the
/// session for a [`JobHandle`] to the merged output.
///
/// Every chunk is validated at admission — sortedness *by key* within
/// the chunk plus the key boundary against the run's previous chunk —
/// in O(chunk) on the calling thread, so a violation is rejected
/// *mid-stream* with the session intact (the offending chunk is simply
/// not admitted; the client may correct and continue). Feeds apply
/// back-pressure by blocking while the service queue is full.
///
/// Dropping an unsealed session aborts it: buffered data is discarded
/// and no reply is ever delivered.
#[derive(Debug)]
pub struct CompactionSession<R: Record = i32> {
    queue: Arc<BoundedQueue<Job<R>>>,
    table: Arc<SessionTable<R>>,
    stats: Arc<ServiceStats>,
    id: u64,
    tx: Sender<JobResult<R>>,
    rx: Option<Receiver<JobResult<R>>>,
    runs: Vec<ClientRun<R>>,
    sealed: bool,
    /// Back-pressure mode: `true` (streaming clients) blocks feeds
    /// while the queue is full; `false` (the one-shot `submit` wrapper)
    /// rejects the *first* message instead — preserving `submit`'s
    /// fail-fast admission — and switches to blocking once admitted,
    /// so a large job cannot spuriously reject itself mid-feed by
    /// outrunning the dispatcher with its own chunk messages.
    blocking: bool,
    /// Set after the first successful push (see `blocking`).
    admitted: bool,
    /// `merge.memory_budget` in bytes (`0` = unlimited). Streaming
    /// feeds are budget-checked per chunk; the one-shot wrapper is
    /// checked once at submit instead (its own ingest is already
    /// resident, so per-chunk checks would self-reject).
    budget: u64,
    /// Total bytes admitted through [`feed`](Self::feed) — the wire
    /// server's per-tenant quota accounting reads this instead of
    /// keeping a parallel ledger.
    fed_bytes: u64,
}

#[derive(Debug)]
struct ClientRun<R: Record> {
    /// Last record fed to the run (its key bounds the next chunk).
    last: Option<R>,
    sealed: bool,
}

/// Open a session: register dispatcher-side state and build the client
/// handle. Called by `MergeService::open_compaction` (which allocates
/// the id); `submitted` is counted later, at [`CompactionSession::seal`].
pub(super) fn open<R: Record>(
    queue: Arc<BoundedQueue<Job<R>>>,
    table: Arc<SessionTable<R>>,
    stats: Arc<ServiceStats>,
    id: u64,
    run_count: usize,
    blocking: bool,
    eager: bool,
    budget: u64,
) -> CompactionSession<R> {
    let (tx, rx) = channel();
    table.insert(
        id,
        SessionState {
            runs: (0..run_count).map(|_| RunIngest::default()).collect(),
            planned: vec![0; run_count],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx.clone(),
            enqueued_at: Instant::now(),
            eager,
            eager_count: 0,
            ingest_bytes: 0,
            aborted: false,
        },
    );
    CompactionSession {
        queue,
        table,
        stats,
        id,
        tx,
        rx: Some(rx),
        runs: (0..run_count).map(|_| ClientRun { last: None, sealed: false }).collect(),
        sealed: false,
        blocking,
        admitted: false,
        budget,
        fed_bytes: 0,
    }
}

impl<R: Record> CompactionSession<R> {
    /// Session id (the job id the eventual [`JobResult`] reports).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Number of runs declared at open.
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    fn check_open(&self, run: usize) -> Result<()> {
        if self.sealed {
            return Err(Error::InvalidInput("session already sealed".into()));
        }
        if run >= self.runs.len() {
            return Err(Error::InvalidInput(format!(
                "run {run} out of range (session has {} runs)",
                self.runs.len()
            )));
        }
        if self.runs[run].sealed {
            return Err(Error::InvalidInput(format!("run {run} already sealed")));
        }
        Ok(())
    }

    fn push(&mut self, kind: JobKind<R>) -> Result<()> {
        let job = Job {
            id: self.id,
            kind,
            enqueued_at: Instant::now(),
            reply: self.tx.clone(),
        };
        // Streaming clients get flow control (block while full). The
        // one-shot wrapper fail-fast-rejects only its *first* message —
        // the admission decision, matching the old by-value `Compact` —
        // and then blocks like any admitted ingest: its own chunk
        // messages filling the queue must pause it, not reject it.
        let result = if self.blocking || self.admitted {
            self.queue.push(job)
        } else {
            self.queue.try_push(job)
        };
        match result {
            Ok(()) => {
                self.admitted = true;
                Ok(())
            }
            Err(PushError::Closed) => Err(Error::Service("service shut down".into())),
            Err(PushError::Full) => {
                debug_assert!(!self.blocking, "blocking push never reports Full");
                Err(Error::Service("queue full (back-pressure)".into()))
            }
        }
    }

    /// Feed one key-sorted chunk of `run`. Validation is per chunk and
    /// bounded by its length: the chunk itself must be sorted by key
    /// and its first key must not precede the run's last fed key. An
    /// empty chunk is a no-op. Blocks while the service queue is full.
    pub fn feed(&mut self, run: usize, chunk: Vec<R>) -> Result<()> {
        self.check_open(run)?;
        if chunk.is_empty() {
            return Ok(());
        }
        if !record::is_sorted_by_key(&chunk) {
            return Err(Error::InvalidInput(format!(
                "chunk for run {run} is not sorted by key"
            )));
        }
        if let Some(last) = &self.runs[run].last {
            if chunk[0].key() < last.key() {
                return Err(Error::InvalidInput(format!(
                    "chunk for run {run} starts at key {:?} before the run's last key {:?}",
                    chunk[0].key(),
                    last.key()
                )));
            }
        }
        let bytes = std::mem::size_of_val(chunk.as_slice()) as u64;
        // Budget admission (streaming clients only; the one-shot
        // wrapper was budget-checked at submit): fail fast without
        // poisoning the session — the chunk is simply not admitted,
        // and the client may retry once reclamation or completions
        // bring the resident figure back under budget.
        if self.blocking
            && self.budget > 0
            && self.stats.resident_bytes.get().saturating_add(bytes) > self.budget
        {
            return Err(Error::Service(format!(
                "memory budget exceeded: chunk of {bytes} bytes would push resident \
                 {} past merge.memory_budget={}",
                self.stats.resident_bytes.get(),
                self.budget
            )));
        }
        // Client-side state and the admission counters advance only
        // after the push succeeds: a rejected push (full queue in
        // reject mode, or shutdown) must leave the session exactly as
        // it was, so the same chunk can be retried.
        let last = chunk.last().copied();
        self.push(JobKind::CompactChunk {
            msg: ChunkMsg { session: self.id, run, data: chunk },
        })?;
        self.runs[run].last = last;
        self.fed_bytes += bytes;
        self.stats.streamed_chunks.inc();
        self.stats.streamed_bytes.add(bytes);
        Ok(())
    }

    /// Total bytes admitted through [`feed`](Self::feed) so far — what
    /// the session holds resident on the client's behalf at most (the
    /// dispatcher may already have reclaimed settled prefixes). The
    /// wire server drains exactly this figure from a tenant's quota
    /// when the session seals or is reaped.
    pub fn fed_bytes(&self) -> u64 {
        self.fed_bytes
    }

    /// Declare that `run` will receive no more chunks. Sealing a run
    /// removes it from the frontier minimum, which is what lets the
    /// dispatcher advance past the run's last key.
    pub fn seal_run(&mut self, run: usize) -> Result<()> {
        self.check_open(run)?;
        self.push(JobKind::CompactSealRun {
            msg: RunSealMsg { session: self.id, run },
        })?;
        self.runs[run].sealed = true;
        Ok(())
    }

    /// Seal the session (any still-open runs are sealed implicitly) and
    /// return the handle to the merged output. Consumes the session; on
    /// error (full queue in reject mode, or shutdown) the session is
    /// dropped and therefore aborted — its buffered ingest is reaped —
    /// and the admission converts into a rejection in the stats.
    pub fn seal(mut self) -> Result<JobHandle<R>> {
        // Count the admission *before* the push: the dispatcher may
        // absorb the seal and complete the job before this thread
        // resumes, and a snapshot must never observe
        // completed > submitted. A failed push converts the admission
        // into a rejection (submitted = completed + rejected +
        // in-flight stays balanced); aborted-without-seal sessions
        // never touch either counter.
        self.stats.submitted.inc();
        if let Err(e) = self.push(JobKind::CompactSeal { msg: SealMsg { session: self.id } })
        {
            self.stats.rejected.inc();
            return Err(e);
        }
        self.sealed = true; // the seal is in: Drop must not abort now
        let rx = self.rx.take().expect("receiver taken only here");
        Ok(JobHandle::new(self.id, rx))
    }

    /// Explicitly abort the session: buffered ingest is reaped by the
    /// dispatcher (its bytes leave [`ServiceStats::resident_bytes`] on
    /// the next loop iteration) and no reply is ever delivered —
    /// exactly what dropping an unsealed session does, plus a count in
    /// [`ServiceStats::sessions_reaped`]. This is the wire server's
    /// reap hook for dead clients (disconnect mid-feed, half-written
    /// frame, lease expiry); plain drops stay uncounted so one-shot
    /// error paths don't read as reaps.
    pub fn abort(self) {
        self.stats.sessions_reaped.inc();
        // Drop performs the actual mark_aborted.
    }
}

impl<R: Record> Drop for CompactionSession<R> {
    fn drop(&mut self) {
        if self.sealed {
            return;
        }
        // Abort: flag the session (stops eager planning even before the
        // reap) and queue its id for reclamation — the dispatcher reaps
        // on its next loop iteration, so the buffered ingest is freed
        // promptly and without depending on queue capacity.
        self.table.mark_aborted(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ingest(pairs: &[(&[i32], bool)]) -> Vec<RunIngest<i32>> {
        pairs
            .iter()
            .map(|(buf, sealed)| RunIngest {
                buf: buf.to_vec(),
                base: 0,
                last: buf.last().copied(),
                sealed: *sealed,
            })
            .collect()
    }

    #[test]
    fn safe_rank_frontier_cases() {
        // No runs: vacuously all sealed, nothing to settle.
        assert_eq!(safe_rank::<i32>(&[]), 0);
        // An open empty run pins the frontier at "nothing settled".
        assert_eq!(safe_rank(&ingest(&[(&[1, 2, 3], false), (&[], false)])), 0);
        // All sealed: everything is settled.
        assert_eq!(safe_rank(&ingest(&[(&[1, 2], true), (&[0], true)])), 3);
        // Frontier = the open run 0's last key (5); {2, 3} and {1} are
        // strictly below, and run 0 *owns* the tie at 5 (its future
        // fives land at later offsets, which never precede the fed
        // one), so it settles too. Run 1's 5 must wait even though run
        // 1 is sealed: run 0 may still feed a 5, which sorts before it
        // (run 0 < run 1).
        assert_eq!(
            safe_rank(&ingest(&[(&[2, 3, 5], false), (&[1, 5, 9], true)])),
            4
        );
        // Two open runs: frontier is the smaller last key (6), owned by
        // run 1 — so run 1's fed 6 settles ({1, 4}, {2}, and the 6).
        assert_eq!(
            safe_rank(&ingest(&[(&[1, 4, 8], false), (&[2, 6], false)])),
            4
        );
        // Duplicate-heavy: nothing is strictly below the frontier, but
        // the tie owner (run 0, the lowest-indexed open run at F = 5)
        // settles its fed duplicates — run 1's must wait for run 0's
        // possible future fives.
        assert_eq!(safe_rank(&ingest(&[(&[5, 5], false), (&[5, 5, 5], false)])), 2);
        // Owner below a tying sealed run: runs 0/1 open with last keys
        // 9/5 → F = 5 owned by run 1; run 0 contributes {1}, run 1 its
        // two fives (own future ties are later offsets).
        assert_eq!(safe_rank(&ingest(&[(&[1, 9], false), (&[5, 5], false)])), 3);
        // A sealed lower-indexed run's ties always settle: F = 6 owned
        // by run 1; the three 5s settle everywhere, run 1's 6 settles,
        // run 2's nothing beyond its 5.
        assert_eq!(
            safe_rank(&ingest(&[(&[5], true), (&[5, 6], false), (&[5, 7], false)])),
            4
        );
    }

    #[test]
    fn stream_shard_len_both_inputs() {
        let exec: Arc<StreamExec<i32>> = Arc::new(StreamExec::default());
        let owned = StreamShard {
            exec: Arc::clone(&exec),
            idx: 0,
            input: ShardInput::Owned(vec![vec![1, 2], vec![3]]),
            seg_elems: 0,
            kernel: MergeKernel::Auto,
        };
        assert_eq!(owned.len(), 3);
        assert!(!owned.is_empty());
        let windowed = StreamShard {
            exec,
            idx: 1,
            input: ShardInput::Windowed {
                runs: Arc::new(vec![vec![1, 2, 3, 4], vec![5, 6]]),
                ranges: vec![1..3, 0..2],
                out: Arc::new(SharedOut::new(vec![0i32; 6])),
                window: 2..6,
            },
            seg_elems: 2,
            kernel: MergeKernel::Auto,
        };
        assert_eq!(windowed.len(), 4);
    }

    #[test]
    fn exec_writes_in_place_and_replies_after_seal() {
        let stats = ServiceStats::new();
        let exec: Arc<StreamExec<i32>> = Arc::new(StreamExec::default());
        let a = exec.push_slot(0..2);
        let b = exec.push_slot(2..4);
        let (tx, rx) = channel();
        // Shard b completes *before* the seal: its output parks.
        complete_eager(&exec, b, vec![30, 40], &stats);
        assert!(
            exec.state.lock().unwrap().parked[b].is_some(),
            "pre-seal output must park (no buffer yet)"
        );
        // Seal: buffer installed; the parked output is stolen for a
        // pool-worker install task instead of being copied here.
        let out = Arc::new(SharedOut::new(vec![0i32; 4]));
        let installs = arm_sealed(
            &exec,
            &out,
            SealInfo {
                total: 4,
                reply: tx,
                parent_id: 9,
                enqueued_at: Instant::now(),
                queue_wait_ns: 1,
            },
            &stats,
        );
        assert_eq!(installs.len(), 1, "parked output stolen for install");
        assert!(rx.try_recv().is_err(), "must wait for install + shard a");
        // The install task runs like any stream shard.
        execute_stream_shard(
            StreamShard {
                exec: Arc::clone(&exec),
                idx: 0,
                input: ShardInput::Install { items: installs, out },
                seg_elems: 0,
                kernel: MergeKernel::Auto,
            },
            &stats,
        );
        assert!(rx.try_recv().is_err(), "shard a still outstanding");
        // Shard a completes after the seal: copied straight in, group
        // reaches full strength, reply fires with the tiled buffer.
        complete_eager(&exec, a, vec![10, 20], &stats);
        let res = rx.try_recv().expect("group complete");
        assert_eq!(res.output, vec![10, 20, 30, 40]);
        assert_eq!(res.backend, BACKEND_STREAMED);
        assert_eq!(res.id, 9);
        assert_eq!(stats.streamed_jobs.get(), 1);
        assert_eq!(
            stats.stream_shards_completed.get(),
            2,
            "the install task is not a shard"
        );
    }

    #[test]
    fn eager_plan_respects_threshold_and_seal_skip() {
        let cfg =
            MergeflowConfig { compact_eager_min_len: 4, ..MergeflowConfig::default() };
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[1, 2, 3, 4, 50], false), (&[1, 2, 3, 4, 60], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            ingest_bytes: 40, // 10 × i32, as if fed through chunks
            aborted: false,
        };
        // Frontier = 50 → 8 settled ranks → two eager shards of 4.
        let jobs = maybe_plan_eager(&cfg, &stats, &mut state, 1);
        assert_eq!(jobs.len(), 2);
        assert_eq!(state.planned_rank, 8);
        assert_eq!(state.planned, vec![4, 4]);
        assert_eq!(stats.eager_shards.get(), 2);
        // Reclamation dropped the planned prefixes: only the two
        // unsettled tails stay live, and the accounting says so.
        assert_eq!(state.runs[0].buf, vec![50]);
        assert_eq!(state.runs[1].buf, vec![60]);
        assert_eq!(state.runs[0].base, 4);
        assert_eq!(state.runs[1].base, 4);
        assert_eq!(state.ingest_bytes, 8);
        assert_eq!(stats.reclaimed_bytes.get(), 32);
        // Nothing new settled → no further shards.
        assert!(maybe_plan_eager(&cfg, &stats, &mut state, 1).is_empty());
        // All runs sealed → the seal will handle the tail zero-copy.
        for r in &mut state.runs {
            r.sealed = true;
        }
        assert!(maybe_plan_eager(&cfg, &stats, &mut state, 1).is_empty());
        // The planned shards merge the settled prefix bit-identically;
        // pre-seal their outputs park in rank-ordered slots.
        for job in jobs {
            match job.kind {
                JobKind::StreamShard { shard } => {
                    assert_eq!(shard.len(), 4);
                    execute_stream_shard(shard, &stats);
                }
                _ => unreachable!("eager planning emits stream shards"),
            }
        }
        let st = state.exec.state.lock().unwrap();
        assert_eq!(st.slots, vec![0..4, 4..8]);
        let merged: Vec<i32> = st
            .parked
            .iter()
            .flat_map(|o| o.clone().unwrap())
            .collect();
        assert_eq!(merged, vec![1, 1, 2, 2, 3, 3, 4, 4]);
    }

    #[test]
    fn duplicate_heavy_runs_settle_for_the_tie_owner() {
        // All-identical keys: the bare-key frontier would settle
        // nothing; the tie-aware frontier settles run 0's fed
        // duplicates, so eager shards still launch.
        let cfg =
            MergeflowConfig { compact_eager_min_len: 2, ..MergeflowConfig::default() };
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[7, 7, 7, 7], false), (&[7, 7, 7], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            ingest_bytes: 28, // 7 × i32
            aborted: false,
        };
        let jobs = maybe_plan_eager(&cfg, &stats, &mut state, 1);
        assert_eq!(jobs.len(), 2, "4 settled ranks / eager_len 2");
        assert_eq!(state.planned_rank, 4);
        assert_eq!(state.planned, vec![4, 0], "all shards cut from the tie owner");
        // The tie owner's settled duplicates reclaim; the waiting run
        // keeps everything.
        assert!(state.runs[0].buf.is_empty());
        assert_eq!(state.runs[0].base, 4);
        assert_eq!(state.runs[1].buf.len(), 3);
        assert_eq!(stats.reclaimed_bytes.get(), 16);
    }

    #[test]
    fn safe_rank_counts_reclaimed_bases() {
        // A run fully drained by reclamation still anchors the frontier
        // through `last`, and its base counts as settled in full.
        let runs = vec![
            RunIngest { buf: vec![], base: 4, last: Some(6), sealed: false },
            RunIngest { buf: vec![5, 8, 9], base: 2, last: Some(9), sealed: false },
        ];
        // Frontier = min(6, 9) = 6, owned by run 0 (its future ties
        // land later). Run 0: base 4 + its tie at 6 already reclaimed.
        // Run 1: base 2 + one live element below 6 (the 5).
        assert_eq!(safe_rank(&runs), 4 + 2 + 1);
        // All sealed: everything fed settles, bases included.
        let sealed = vec![
            RunIngest { buf: vec![], base: 4, last: Some(6), sealed: true },
            RunIngest { buf: vec![5, 8, 9], base: 2, last: Some(9), sealed: true },
        ];
        assert_eq!(safe_rank(&sealed), 9);
    }

    #[test]
    fn eager_plan_continues_after_reclamation() {
        // Cuts after a reclamation use live-relative ranks; the planned
        // state stays absolute and the windows line up bit-identically
        // with what an unreclaimed session would have cut.
        let cfg =
            MergeflowConfig { compact_eager_min_len: 2, ..MergeflowConfig::default() };
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[1, 3, 5, 7], false), (&[2, 4, 6, 8], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            ingest_bytes: 32,
            aborted: false,
        };
        // Frontier = 7 → 7 settled ranks → three shards of 2; then the
        // planned prefixes reclaim.
        let first = maybe_plan_eager(&cfg, &stats, &mut state, 1);
        assert_eq!(first.len(), 3);
        assert_eq!(state.planned_rank, 6);
        assert_eq!(state.planned, vec![3, 3]);
        assert!(state.runs.iter().all(|r| r.base == 3 && r.buf.len() == 1));
        // More data arrives on the drained buffers; planning resumes
        // across the reclaimed boundary.
        for (r, tail) in state.runs.iter_mut().zip([[9i32, 11], [10, 12]]) {
            r.buf.extend_from_slice(&tail);
            r.last = Some(tail[1]);
            state.ingest_bytes += 8;
        }
        let second = maybe_plan_eager(&cfg, &stats, &mut state, 1);
        assert_eq!(second.len(), 2, "ranks 6..10 settle under frontier 11");
        assert_eq!(state.planned_rank, 10);
        assert_eq!(state.planned, vec![5, 5]);
        // Execute everything; the rank-ordered slots must tile the
        // stable merge of the fed prefixes exactly.
        for job in first.into_iter().chain(second) {
            match job.kind {
                JobKind::StreamShard { shard } => execute_stream_shard(shard, &stats),
                _ => unreachable!("eager planning emits stream shards"),
            }
        }
        let st = state.exec.state.lock().unwrap();
        let merged: Vec<i32> = st.parked.iter().flat_map(|o| o.clone().unwrap()).collect();
        assert_eq!(merged, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
    }

    #[test]
    fn reap_frees_aborted_sessions() {
        let stats = ServiceStats::new();
        let table: SessionTable<i32> = SessionTable::default();
        let (tx, _rx) = channel();
        stats.resident_bytes.add(12);
        table.insert(
            7,
            SessionState {
                runs: ingest(&[(&[1, 2, 3], false)]),
                planned: vec![0],
                planned_rank: 0,
                exec: Arc::new(StreamExec::default()),
                reply: tx,
                enqueued_at: Instant::now(),
                eager: true,
                eager_count: 0,
                ingest_bytes: 12,
                aborted: false,
            },
        );
        table.mark_aborted(7);
        assert!(!table.sessions.lock().unwrap().is_empty(), "reap is deferred");
        table.reap_aborted(&stats);
        assert!(table.sessions.lock().unwrap().is_empty(), "buffers freed");
        assert_eq!(
            stats.resident_bytes.get(),
            0,
            "aborted ingest must leave the resident gauge"
        );
        // Aborting an id with no entry (already reaped) is a no-op.
        table.mark_aborted(99);
        table.reap_aborted(&stats);
    }

    #[test]
    fn eager_plan_disabled_cases() {
        let stats = ServiceStats::new();
        let (tx, _rx) = channel();
        let mut state = SessionState {
            runs: ingest(&[(&[1, 2, 3, 4], false), (&[1, 2, 3, 9], false)]),
            planned: vec![0, 0],
            planned_rank: 0,
            exec: Arc::new(StreamExec::default()),
            reply: tx,
            enqueued_at: Instant::now(),
            eager: true,
            eager_count: 0,
            ingest_bytes: 0,
            aborted: false,
        };
        let off =
            MergeflowConfig { compact_eager_min_len: 0, ..MergeflowConfig::default() };
        assert!(maybe_plan_eager(&off, &stats, &mut state, 1).is_empty());
        let k_cap = MergeflowConfig {
            compact_eager_min_len: 1,
            kway_flat_max_k: 1,
            ..MergeflowConfig::default()
        };
        assert!(maybe_plan_eager(&k_cap, &stats, &mut state, 1).is_empty());
        assert_eq!(stats.eager_shards.get(), 0);
    }
}
