//! Online knob calibration: short in-process probe merges that
//! re-derive the `0 = auto-calibrate` tuning knobs from *measured*
//! crossovers instead of the documented hand models.
//!
//! Three knobs resolve through here (see `docs/ARCHITECTURE.md` §11):
//!
//! - `merge.kway_flat_max_k = 0` — the flat-vs-tree engine crossover:
//!   the largest run count `k` at which the single-pass loser-tree
//!   walk still beats a pairwise merge tree over the same data.
//! - `dispatch.shard_floor = 0` — the rank-shard profitability floor:
//!   how many elements a shard must merge for the merge work to
//!   dominate its dispatch overhead, derived from the measured
//!   sequential merge rate.
//! - `merge.cache_bytes` feeding `kway_segment_elems = 0` — the
//!   streaming working-set cliff: the largest merge footprint whose
//!   per-element cost stays near the in-cache optimum. Only probed
//!   when the segmented route is on with every window knob left auto.
//!
//! Probes are machine properties, not service properties: they run at
//! most once per process (`OnceLock`) and the whole suite is budgeted
//! at a few milliseconds of sequential work, so a service (or a test
//! spinning up hundreds of services) pays essentially nothing.
//! [`MergeService::start`](super::service::MergeService::start) applies
//! the report by rewriting its own config copy — a non-zero config
//! value always pins the knob, and `dispatch.calibrate = false` swaps
//! the probes for the modeled defaults.

use crate::config::MergeflowConfig;
use crate::mergepath::{loser_tree_merge, merge_into};
use std::sync::OnceLock;
use std::time::Instant;

/// Modeled flat-engine crossover (ARCHITECTURE §5) used when
/// calibration is disabled but `kway_flat_max_k = 0` asks for auto.
pub const MODEL_FLAT_MAX_K: usize = 128;
/// Modeled shard profitability floor (256 Ki elements,
/// `benches/sharded_vs_flat.rs`) used when calibration is disabled but
/// `dispatch.shard_floor = 0` asks for auto.
pub const MODEL_SHARD_FLOOR: usize = 1 << 18;

/// Bounds on the calibrated flat crossover: below 8 the probe is
/// noise-dominated, above 512 the loser tree's log-k compare chain is
/// provably past any modern cache's stream budget.
const FLAT_K_MIN: usize = 8;
const FLAT_K_MAX: usize = 512;
/// Bounds on the calibrated shard floor (elements).
const SHARD_FLOOR_MIN: usize = 1 << 15;
const SHARD_FLOOR_MAX: usize = 1 << 21;
/// Bounds on the calibrated cache estimate (bytes) — the same band the
/// config layer clamps configured/detected cache sizes to.
const CACHE_MIN: usize = 64 << 10;
const CACHE_MAX: usize = 1 << 30;

/// What the probes measured. All values are already clamped to their
/// documented bands; `probe_ns` is the wall cost of the whole suite.
#[derive(Debug, Clone, Copy)]
pub struct CalibrationReport {
    /// Measured flat-vs-tree crossover `k`.
    pub flat_max_k: usize,
    /// Measured shard profitability floor (elements).
    pub shard_floor: usize,
    /// Measured streaming working-set cliff (bytes).
    pub cache_bytes: usize,
    /// Sequential merge rate the floor was derived from (elements/ms).
    pub merge_elems_per_ms: u64,
    /// Wall time the probe suite took (ns).
    pub probe_ns: u64,
}

/// Run (or reuse) the process-wide probe suite.
pub fn calibration() -> &'static CalibrationReport {
    static REPORT: OnceLock<CalibrationReport> = OnceLock::new();
    REPORT.get_or_init(run_probes)
}

/// Resolve every `0 = auto-calibrate` knob in `cfg` in place. Returns
/// the report when probes were consulted, `None` when nothing needed
/// them (all knobs pinned, or calibration disabled — the latter still
/// substitutes the modeled defaults so downstream code never sees 0).
pub fn apply(cfg: &mut MergeflowConfig) -> Option<&'static CalibrationReport> {
    let wants_flat_k = cfg.kway_flat_max_k == 0;
    let wants_floor = cfg.shard_floor == 0;
    let wants_cache = cfg.segmented
        && cfg.kway_segment_elems == 0
        && cfg.segment_len == 0
        && cfg.cache_bytes == 0;
    if !cfg.calibrate {
        if wants_flat_k {
            cfg.kway_flat_max_k = MODEL_FLAT_MAX_K;
        }
        if wants_floor {
            cfg.shard_floor = MODEL_SHARD_FLOOR;
        }
        return None;
    }
    if !(wants_flat_k || wants_floor || wants_cache) {
        return None;
    }
    let report = calibration();
    if wants_flat_k {
        cfg.kway_flat_max_k = report.flat_max_k;
    }
    if wants_floor {
        cfg.shard_floor = report.shard_floor;
    }
    if wants_cache {
        cfg.cache_bytes = report.cache_bytes;
    }
    Some(report)
}

fn run_probes() -> CalibrationReport {
    let t0 = Instant::now();
    let (merge_elems_per_ms, cache_bytes) = probe_merge_rate_and_cache();
    let flat_max_k = probe_flat_crossover();
    let shard_floor = floor_from_rate(merge_elems_per_ms);
    CalibrationReport {
        flat_max_k,
        shard_floor,
        cache_bytes,
        merge_elems_per_ms,
        probe_ns: u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX),
    }
}

/// Deterministic sorted run: strictly increasing with pseudo-random
/// gaps so adjacent probes never degenerate into all-ties or pure
/// interleave (both have atypical branch behavior).
fn probe_run(len: usize, seed: u64) -> Vec<i32> {
    let mut x = seed | 1;
    let mut v = 0i32;
    (0..len)
        .map(|_| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            v = v.wrapping_add(((x >> 33) % 7) as i32 + 1);
            v
        })
        .collect()
}

/// Time one closure, best of `reps` (best-of filters scheduler noise
/// without needing long runs).
fn best_ns(reps: usize, mut f: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        f();
        best = best.min(u64::try_from(t.elapsed().as_nanos()).unwrap_or(u64::MAX));
    }
    best
}

/// Sweep pairwise merges over doubling footprints: the smallest sizes
/// give the in-cache merge rate (→ shard floor), and the largest
/// footprint whose per-element cost stays within 25% of the best one
/// locates the working-set cliff (→ cache estimate). The probe's live
/// footprint is `2·S` bytes (inputs + output), so the cache estimate
/// is twice the last good input size.
fn probe_merge_rate_and_cache() -> (u64, usize) {
    // Input sizes S in bytes; footprint is 2S. Capped at 4 MiB so the
    // whole sweep stays in the low single-digit milliseconds.
    const SIZES: [usize; 4] = [64 << 10, 256 << 10, 1 << 20, 4 << 20];
    let mut per_elem = [0u64; SIZES.len()];
    let mut best_rate = 0u64;
    for (i, &bytes) in SIZES.iter().enumerate() {
        let n = bytes / std::mem::size_of::<i32>() / 2;
        let a = probe_run(n, 0x9E37_79B9 + i as u64);
        let b = probe_run(n, 0x85EB_CA6B + i as u64);
        let mut out = vec![0i32; 2 * n];
        let ns = best_ns(2, || {
            merge_into(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        let elems = (2 * n) as u64;
        // Scaled ns-per-1024-elements keeps integer math meaningful.
        per_elem[i] = ns.saturating_mul(1024) / elems.max(1);
        best_rate = best_rate.max(elems.saturating_mul(1_000_000) / ns.max(1));
    }
    let best = per_elem.iter().copied().min().unwrap_or(u64::MAX).max(1);
    let mut cache = CACHE_MIN;
    for (i, &bytes) in SIZES.iter().enumerate() {
        if per_elem[i] <= best.saturating_mul(5) / 4 {
            cache = 2 * bytes;
        }
    }
    (best_rate, cache.clamp(CACHE_MIN, CACHE_MAX))
}

/// Sweep run counts and time the flat single-pass loser tree against a
/// sequential pairwise merge tree over the same 64 Ki elements. The
/// calibrated `kway_flat_max_k` is the largest swept `k` where the
/// flat walk stays within 10% of the tree (one memory pass at log k
/// compares, vs log k passes at one compare each — the crossover is
/// where compare cost overtakes the saved memory traffic).
fn probe_flat_crossover() -> usize {
    const TOTAL: usize = 64 << 10;
    let mut winner = FLAT_K_MIN;
    for &k in &[8usize, 16, 32, 64, 128, 256] {
        let run_len = TOTAL / k;
        let runs: Vec<Vec<i32>> = (0..k).map(|i| probe_run(run_len, 0xC0FF_EE00 + i as u64)).collect();
        let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
        let total = run_len * k;
        let mut out = vec![0i32; total];
        let flat_ns = best_ns(2, || {
            loser_tree_merge(&refs, &mut out);
            std::hint::black_box(&out);
        });
        let tree_ns = best_ns(2, || {
            std::hint::black_box(tree_merge_seq(&runs));
        });
        if flat_ns <= tree_ns.saturating_mul(11) / 10 {
            winner = k;
        } else {
            break;
        }
    }
    winner.clamp(FLAT_K_MIN, FLAT_K_MAX)
}

/// Sequential pairwise merge tree (the fallback engine's cost shape
/// without its thread fan-out — probes compare engine *work*, not
/// scheduling).
fn tree_merge_seq(runs: &[Vec<i32>]) -> Vec<i32> {
    let mut level: Vec<Vec<i32>> = runs.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut iter = level.chunks(2);
        for pair in &mut iter {
            match pair {
                [a, b] => {
                    let mut out = vec![0i32; a.len() + b.len()];
                    merge_into(a, b, &mut out);
                    next.push(out);
                }
                [a] => next.push(a.clone()),
                _ => unreachable!("chunks(2) yields 1- or 2-slices"),
            }
        }
        level = next;
    }
    level.pop().unwrap_or_default()
}

/// A shard is profitable once its merge work comfortably dominates the
/// fixed dispatch cost (queue hop, slot acquire, stitch bookkeeping —
/// modeled at ~50µs of budget amortized to 2% overhead): floor at the
/// elements merged in ~2.5ms of sequential work, rounded down to a
/// power of two to keep shard cuts aligned, clamped to the documented
/// band.
fn floor_from_rate(elems_per_ms: u64) -> usize {
    let raw = usize::try_from(elems_per_ms.saturating_mul(5) / 2).unwrap_or(SHARD_FLOOR_MAX);
    let pow2 = if raw <= 1 { 1 } else { 1usize << (usize::BITS - 1 - raw.leading_zeros()) };
    pow2.clamp(SHARD_FLOOR_MIN, SHARD_FLOOR_MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_lands_in_documented_bands() {
        let r = calibration();
        assert!((FLAT_K_MIN..=FLAT_K_MAX).contains(&r.flat_max_k), "{r:?}");
        assert!((SHARD_FLOOR_MIN..=SHARD_FLOOR_MAX).contains(&r.shard_floor), "{r:?}");
        assert!(r.shard_floor.is_power_of_two(), "{r:?}");
        assert!((CACHE_MIN..=CACHE_MAX).contains(&r.cache_bytes), "{r:?}");
        assert!(r.merge_elems_per_ms > 0, "{r:?}");
        assert!(r.probe_ns > 0, "{r:?}");
        // Cached: the second call must reuse the same report.
        assert_eq!(calibration().probe_ns, r.probe_ns);
    }

    #[test]
    fn apply_pins_and_calibrates() {
        // All knobs pinned: apply is a no-op and consults no probes.
        let mut pinned = MergeflowConfig::default();
        let before = pinned.clone();
        assert!(apply(&mut pinned).is_none());
        assert_eq!(pinned.kway_flat_max_k, before.kway_flat_max_k);
        assert_eq!(pinned.shard_floor, before.shard_floor);
        assert_eq!(pinned.cache_bytes, before.cache_bytes);

        // calibrate = false substitutes the modeled defaults for 0.
        let mut modeled = MergeflowConfig {
            calibrate: false,
            kway_flat_max_k: 0,
            shard_floor: 0,
            ..Default::default()
        };
        assert!(apply(&mut modeled).is_none());
        assert_eq!(modeled.kway_flat_max_k, MODEL_FLAT_MAX_K);
        assert_eq!(modeled.shard_floor, MODEL_SHARD_FLOOR);

        // calibrate = true resolves 0 from the measured report and
        // leaves non-zero knobs alone.
        let mut auto = MergeflowConfig {
            kway_flat_max_k: 0,
            shard_floor: 0,
            kway_segment_elems: 0,
            segment_len: 0,
            cache_bytes: 0,
            ..Default::default()
        };
        let r = apply(&mut auto).expect("probes consulted");
        assert_eq!(auto.kway_flat_max_k, r.flat_max_k);
        assert_eq!(auto.shard_floor, r.shard_floor);
        assert_eq!(auto.cache_bytes, r.cache_bytes, "auto windows get the measured cache");
        let mut window_pinned = MergeflowConfig {
            kway_flat_max_k: 0,
            kway_segment_elems: 2048,
            ..Default::default()
        };
        apply(&mut window_pinned);
        assert_eq!(window_pinned.cache_bytes, 0, "pinned window leaves cache detection alone");
    }

    #[test]
    fn floor_rounds_to_power_of_two_in_band() {
        assert_eq!(floor_from_rate(0), SHARD_FLOOR_MIN);
        assert_eq!(floor_from_rate(u64::MAX), SHARD_FLOOR_MAX);
        let mid = floor_from_rate(100_000); // 250k elems → 2^17
        assert_eq!(mid, 1 << 17);
    }
}
