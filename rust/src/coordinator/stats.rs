//! Service-level metrics.

use crate::mergepath::kernel::KernelKind;
use crate::metrics::{fmt_ns, Counter, Gauge, Histogram};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex, OnceLock};

/// Per-dispatcher-shard control-plane metrics: one block per
/// `dispatch.shards` thread, initialized by the service at start
/// ([`ServiceStats::init_dispatch_shards`]) and rendered in the
/// `dispatch:` section of [`ServiceStats::snapshot`].
#[derive(Debug, Default)]
pub struct DispatchShardStats {
    /// Queue depth sampled at every batch-assembly pass (`peak()` is
    /// the shard's high-water backlog).
    pub depth: Gauge,
    /// Age (µs) of the oldest job in the most recent batch — how stale
    /// the head of this shard's queue was when the dispatcher got to
    /// it.
    pub oldest_age_us: Gauge,
    /// Jobs this shard dispatched to the pool (including jobs it stole
    /// and shard-expansion sub-jobs).
    pub dispatched: Counter,
    /// Jobs stolen *by* this shard from peers' queues.
    pub stolen_jobs: Counter,
    /// Steal passes by this shard that took at least one job.
    pub stolen_batches: Counter,
    /// Streaming-session messages absorbed by this shard (always on the
    /// session's owning shard — messages are never stolen).
    pub session_msgs: Counter,
}

/// Counters + latency histogram for the running service.
#[derive(Debug, Default)]
pub struct ServiceStats {
    /// Jobs admitted.
    pub submitted: Counter,
    /// Jobs completed.
    pub completed: Counter,
    /// Jobs rejected at admission (queue full / invalid input).
    pub rejected: Counter,
    /// Jobs executed on the native backend.
    pub native_jobs: Counter,
    /// Jobs executed on the segmented native backend.
    pub segmented_jobs: Counter,
    /// Compactions executed on the flat single-pass k-way engine —
    /// both the scalar tag ("native-kway") and the typed-record tag
    /// ("native-kway-typed"): same engine, the tag only distinguishes
    /// payload-carrying records in per-job results.
    pub kway_jobs: Counter,
    /// Compactions executed on the *segmented* flat k-way engine —
    /// both the scalar tag ("native-kway-segmented") and the
    /// typed-record tag ("native-kway-segmented-typed"): the same
    /// single stable pass, walked in `(k+1)·L`-bounded path windows so
    /// the live windows stay cache-resident
    /// (`merge.kway_segment_elems`).
    pub kway_segmented_jobs: Counter,
    /// Rank-shard / stream-shard sub-merges executed in bounded path
    /// windows (the per-shard analogue of the segmented engine; the
    /// parent jobs still count under their own backends).
    pub segmented_shard_merges: Counter,
    /// Compactions executed as rank shards (backend
    /// "native-kway-sharded"); one count per *parent* compaction.
    pub sharded_jobs: Counter,
    /// Shard sub-jobs planned by the dispatcher's shard expansion.
    pub compact_shards: Counter,
    /// Shard sub-jobs completed. Equals [`ServiceStats::compact_shards`]
    /// when no sharded compaction is in flight.
    pub compact_shards_completed: Counter,
    /// Compactions that overlapped ingest with eager merging (backend
    /// "native-kway-streamed"); one count per session. Sessions that
    /// never dispatched an eager shard fall back to the classic routing
    /// and are counted under that backend instead.
    pub streamed_jobs: Counter,
    /// Streaming compaction sessions opened (every one-shot `Compact`
    /// opens one — the one-shot path is a wrapper over the session
    /// protocol).
    pub streamed_sessions: Counter,
    /// Non-empty chunks admitted across all sessions.
    pub streamed_chunks: Counter,
    /// Bytes admitted through session feeds.
    pub streamed_bytes: Counter,
    /// Eager `StreamShard`s dispatched *before* their session's final
    /// seal — the overlap the streaming protocol exists to create.
    pub eager_shards: Counter,
    /// Stream shards completed (eager + remainder).
    pub stream_shards_completed: Counter,
    /// Pairwise merges executed on the block-swap in-place kernel
    /// (backend "native-inplace") — the route that skips the full
    /// output buffer when the memory budget makes 2× footprint
    /// unaffordable (`merge.inplace`, `merge.memory_budget`).
    pub inplace_jobs: Counter,
    /// Jobs executed on the XLA backend.
    pub xla_jobs: Counter,
    /// Jobs whose leaf merges ran on the plain scalar kernel
    /// (`merge.kernel = scalar`).
    pub kernel_scalar_jobs: Counter,
    /// Jobs whose leaf merges ran on the branchless kernel
    /// (`merge.kernel = branchless`, or a `simd` request degraded on an
    /// unsupported CPU / non-scalar record).
    pub kernel_branchless_jobs: Counter,
    /// Jobs whose leaf merges ran on the hybrid branchless+gallop
    /// kernel (the `auto` default when SIMD is unavailable).
    pub kernel_hybrid_jobs: Counter,
    /// Jobs whose leaf merges ran on the SIMD bitonic-network kernel.
    pub kernel_simd_jobs: Counter,
    /// Elements processed in total.
    pub elements: Counter,
    /// Batches dispatched.
    pub batches: Counter,
    /// End-to-end job latency (ns).
    pub latency: Histogram,
    /// Queue wait latency (ns).
    pub queue_wait: Histogram,
    /// Bytes the service currently holds live on behalf of jobs:
    /// session ingest buffers plus plan-time estimates of dispatched
    /// jobs' working sets. `peak()` is the service-wide high-water mark
    /// — the number a `merge.memory_budget` is sized against.
    pub resident_bytes: Gauge,
    /// Bytes released early by frontier-driven run reclamation —
    /// settled run prefixes dropped *before* session seal. Zero means
    /// streamed sessions held O(total); anything above proves
    /// O(unsettled).
    pub reclaimed_bytes: Counter,
    /// Fail-fast `BUSY` replies sent by the wire server's admission
    /// control (tenant byte/session quotas and budget rejections
    /// surfaced over the socket). A `BUSY` is *not* a rejection in the
    /// `submitted = completed + rejected` ledger — nothing was admitted
    /// — which is exactly why it gets its own counter.
    pub busy_rejections: Counter,
    /// Sessions explicitly reaped
    /// ([`super::CompactionSession::abort`]) — a wire client dropped
    /// mid-stream, hung up on a half-written frame, or went silent past
    /// its lease, and the server aborted its sessions so the dispatcher
    /// could drain their ingest from [`ServiceStats::resident_bytes`].
    /// Plain drops of unsealed sessions (one-shot error paths) abort
    /// too but are not counted here.
    pub sessions_reaped: Counter,
    /// `Spill` jobs completed (runs persisted to level 0 of the
    /// attached store).
    pub store_spills: Counter,
    /// `Flush` requests served (each drives compaction passes until
    /// the store is within policy).
    pub store_flushes: Counter,
    /// Bytes written to the store by spills (run file bytes, including
    /// framing/CRC overhead).
    pub store_spilled_bytes: Counter,
    /// Compactions installed into the store (background scheduler and
    /// synchronous flush passes alike).
    pub store_compactions: Counter,
    /// Input bytes consumed by installed store compactions.
    pub store_compacted_bytes: Counter,
    /// Live run files in the store right now (seeded from the
    /// recovered manifest at attach; +1 per spill, −(k−1) per k-input
    /// compaction).
    pub store_runs: Gauge,
    /// Manifest generations committed, seeded from the recovered
    /// generation at attach — monotone, so restarts never appear to
    /// rewind it.
    pub store_generation: Counter,
    /// Scheduler passes that installed a compaction.
    pub scheduler_passes: Counter,
    /// Scheduler passes that found every level within policy.
    pub scheduler_skips: Counter,
    /// Scheduler passes rejected by the service (BUSY / budget) and
    /// retried after backoff.
    pub scheduler_backoffs: Counter,
    /// Stage latency: admission → the dispatcher picking the job into a
    /// batch (queue residency before planning).
    pub stage_admission: Histogram,
    /// Stage latency: batch planning → a pool worker picking the job up
    /// (dispatch/slot-acquire overhead plus pool queueing).
    pub stage_dispatch: Histogram,
    /// Stage latency: worker start → reply sent (pure execution).
    pub stage_exec: Histogram,
    /// Per-shard control-plane metrics, sized once at service start.
    dispatch: OnceLock<Vec<Arc<DispatchShardStats>>>,
    /// Elements completed per backend base tag (throughput counters;
    /// kernel suffixes are stripped like the per-backend job counters).
    backend_elements: Mutex<BTreeMap<String, u64>>,
    /// Calibrated `kway_flat_max_k` in effect (0 = knob pinned by
    /// config, calibration not consulted).
    pub calibrated_flat_max_k: Gauge,
    /// Calibrated shard floor in effect (elements; 0 = pinned).
    pub calibrated_shard_floor: Gauge,
    /// Calibrated cache estimate in effect (bytes; 0 = pinned/detected).
    pub calibrated_cache_bytes: Gauge,
    /// Wall cost of the calibration probe suite (ns; 0 = never ran).
    pub calibration_probe_ns: Gauge,
}

impl ServiceStats {
    /// New zeroed stats block.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a completed job.
    ///
    /// Backends tagged with a leaf-kernel suffix (e.g.
    /// `"native-segmented+simd"`, produced by
    /// [`tagged_backend`](crate::mergepath::kernel::tagged_backend)
    /// when `merge.kernel` is forced away from `auto`) are stripped
    /// back to their base tag here, so the per-backend counters stay
    /// comparable across kernel settings. Kernel usage is counted
    /// separately via [`ServiceStats::record_kernel`].
    pub fn record_completion(&self, backend: &str, elements: u64, latency_ns: u64, wait_ns: u64) {
        self.completed.inc();
        self.elements.add(elements);
        self.latency.record(latency_ns.max(1));
        self.queue_wait.record(wait_ns.max(1));
        let backend = backend.split_once('+').map_or(backend, |(base, _)| base);
        if let Ok(mut per) = self.backend_elements.lock() {
            *per.entry(backend.to_string()).or_insert(0) += elements;
        }
        match backend {
            "xla" => self.xla_jobs.inc(),
            "native-segmented" => self.segmented_jobs.inc(),
            "native-kway" | "native-kway-typed" => self.kway_jobs.inc(),
            "native-kway-segmented" | "native-kway-segmented-typed" => {
                self.kway_segmented_jobs.inc()
            }
            "native-kway-sharded" => self.sharded_jobs.inc(),
            "native-kway-streamed" => self.streamed_jobs.inc(),
            "native-inplace" => self.inplace_jobs.inc(),
            "store-spill" => self.store_spills.inc(),
            "store-flush" => self.store_flushes.inc(),
            _ => self.native_jobs.inc(),
        }
    }

    /// Service-wide peak resident bytes (high-water mark of
    /// [`ServiceStats::resident_bytes`]).
    pub fn peak_resident_bytes(&self) -> u64 {
        self.resident_bytes.peak()
    }

    /// Size the per-shard metric blocks (idempotent — the first caller
    /// wins, matching the service's one-time shard layout) and hand
    /// back clones of the per-shard handles for the dispatcher threads.
    pub fn init_dispatch_shards(&self, n: usize) -> Vec<Arc<DispatchShardStats>> {
        self.dispatch
            .get_or_init(|| (0..n.max(1)).map(|_| Arc::new(DispatchShardStats::default())).collect())
            .clone()
    }

    /// Metrics block of dispatcher shard `i` (`None` before the service
    /// initialized the layout, or past the shard count).
    pub fn dispatch_shard(&self, i: usize) -> Option<&Arc<DispatchShardStats>> {
        self.dispatch.get().and_then(|v| v.get(i))
    }

    /// Number of dispatcher shards the metrics were sized for (0 before
    /// service start).
    pub fn dispatch_shard_count(&self) -> usize {
        self.dispatch.get().map_or(0, |v| v.len())
    }

    /// Elements completed under a backend base tag (0 if never seen).
    pub fn backend_elements(&self, tag: &str) -> u64 {
        self.backend_elements.lock().map_or(0, |per| per.get(tag).copied().unwrap_or(0))
    }

    /// Record the calibration outcome the service start resolved
    /// (values of 0 mean the corresponding knob was pinned by config).
    pub fn record_calibration(
        &self,
        flat_max_k: u64,
        shard_floor: u64,
        cache_bytes: u64,
        probe_ns: u64,
    ) {
        self.calibrated_flat_max_k.set(flat_max_k);
        self.calibrated_shard_floor.set(shard_floor);
        self.calibrated_cache_bytes.set(cache_bytes);
        self.calibration_probe_ns.set(probe_ns);
    }

    /// Record which leaf kernel a job's pairwise merges ran on.
    ///
    /// Called once per job that routed through a
    /// [`LeafKernel`](crate::mergepath::kernel::LeafKernel)-dispatched
    /// engine; memcpy-only and XLA routes do not count.
    pub fn record_kernel(&self, kind: KernelKind) {
        match kind {
            KernelKind::Scalar => self.kernel_scalar_jobs.inc(),
            KernelKind::Branchless => self.kernel_branchless_jobs.inc(),
            KernelKind::Hybrid => self.kernel_hybrid_jobs.inc(),
            KernelKind::Simd => self.kernel_simd_jobs.inc(),
        }
    }

    /// Human-readable snapshot (the `serve` CLI's stats dump and the
    /// wire `STATS` verb's payload). Fixed counter sections first, then
    /// the variable-width sections: per-stage latency histograms,
    /// per-shard dispatch gauges, per-backend element throughput, and
    /// the calibration report in effect.
    pub fn snapshot(&self) -> String {
        let mut out = self.snapshot_fixed();
        let stage = |h: &Histogram| {
            format!("p50={} p99={} n={}", fmt_ns(h.quantile(0.5)), fmt_ns(h.quantile(0.99)), h.count())
        };
        let _ = write!(
            out,
            " | stages: admit[{}] plan[{}] exec[{}]",
            stage(&self.stage_admission),
            stage(&self.stage_dispatch),
            stage(&self.stage_exec),
        );
        if let Some(shards) = self.dispatch.get() {
            let _ = write!(out, " | dispatch: shards={}", shards.len());
            for (i, sh) in shards.iter().enumerate() {
                let _ = write!(
                    out,
                    " s{i}[depth={}/{} age={}µs disp={} stole={}/{} sess={}]",
                    sh.depth.get(),
                    sh.depth.peak(),
                    sh.oldest_age_us.get(),
                    sh.dispatched.get(),
                    sh.stolen_jobs.get(),
                    sh.stolen_batches.get(),
                    sh.session_msgs.get(),
                );
            }
        }
        if let Ok(per) = self.backend_elements.lock() {
            if !per.is_empty() {
                out.push_str(" | throughput:");
                for (tag, n) in per.iter() {
                    let _ = write!(out, " {tag}={n}e");
                }
            }
        }
        let _ = write!(
            out,
            " | calibration: flat-max-k={} shard-floor={} cache-bytes={} probe={}",
            self.calibrated_flat_max_k.get(),
            self.calibrated_shard_floor.get(),
            self.calibrated_cache_bytes.get(),
            fmt_ns(self.calibration_probe_ns.get()),
        );
        out
    }

    /// The fixed-width counter sections of [`snapshot`](Self::snapshot).
    fn snapshot_fixed(&self) -> String {
        format!(
            "jobs: submitted={} completed={} rejected={} | backends: native={} segmented={} kway={} kway-seg={} sharded={} streamed={} inplace={} xla={} | \
             kernels: scalar={} branchless={} hybrid={} simd={} | \
             shards: planned={} done={} seg-merges={} | \
             streaming: sessions={} chunks={} bytes={} eager={} stream-done={} | \
             mem: resident={} peak={} reclaimed={} | \
             server: busy={} reaped={} | \
             store: spills={} flushes={} spilled={} compactions={} compacted={} runs={} gen={} | \
             scheduler: passes={} skips={} backoffs={} | \
             batches={} elements={} | latency p50={} p95={} p99={} max={} | queue-wait p50={}",
            self.submitted.get(),
            self.completed.get(),
            self.rejected.get(),
            self.native_jobs.get(),
            self.segmented_jobs.get(),
            self.kway_jobs.get(),
            self.kway_segmented_jobs.get(),
            self.sharded_jobs.get(),
            self.streamed_jobs.get(),
            self.inplace_jobs.get(),
            self.xla_jobs.get(),
            self.kernel_scalar_jobs.get(),
            self.kernel_branchless_jobs.get(),
            self.kernel_hybrid_jobs.get(),
            self.kernel_simd_jobs.get(),
            self.compact_shards.get(),
            self.compact_shards_completed.get(),
            self.segmented_shard_merges.get(),
            self.streamed_sessions.get(),
            self.streamed_chunks.get(),
            self.streamed_bytes.get(),
            self.eager_shards.get(),
            self.stream_shards_completed.get(),
            self.resident_bytes.get(),
            self.resident_bytes.peak(),
            self.reclaimed_bytes.get(),
            self.busy_rejections.get(),
            self.sessions_reaped.get(),
            self.store_spills.get(),
            self.store_flushes.get(),
            self.store_spilled_bytes.get(),
            self.store_compactions.get(),
            self.store_compacted_bytes.get(),
            self.store_runs.get(),
            self.store_generation.get(),
            self.scheduler_passes.get(),
            self.scheduler_skips.get(),
            self.scheduler_backoffs.get(),
            self.batches.get(),
            self.elements.get(),
            fmt_ns(self.latency.quantile(0.5)),
            fmt_ns(self.latency.quantile(0.95)),
            fmt_ns(self.latency.quantile(0.99)),
            fmt_ns(self.latency.max()),
            fmt_ns(self.queue_wait.quantile(0.5)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_routing() {
        let s = ServiceStats::new();
        s.record_completion("native", 100, 1000, 10);
        s.record_completion("xla", 200, 2000, 20);
        s.record_completion("native-segmented", 300, 3000, 30);
        s.record_completion("native-kway", 400, 4000, 40);
        s.record_completion("native-kway-typed", 450, 4500, 45);
        s.record_completion("native-kway-segmented", 480, 4800, 48);
        s.record_completion("native-kway-segmented-typed", 470, 4700, 47);
        s.record_completion("native-kway-sharded", 500, 5000, 50);
        s.record_completion("native-kway-streamed", 600, 6000, 60);
        s.record_completion("native-inplace", 700, 7000, 70);
        assert_eq!(s.completed.get(), 10);
        assert_eq!(s.native_jobs.get(), 1);
        assert_eq!(s.xla_jobs.get(), 1);
        assert_eq!(s.segmented_jobs.get(), 1);
        assert_eq!(s.kway_jobs.get(), 2, "typed tag counts as the same engine");
        assert_eq!(s.kway_segmented_jobs.get(), 2, "typed segmented tag too");
        assert_eq!(s.sharded_jobs.get(), 1);
        assert_eq!(s.streamed_jobs.get(), 1);
        assert_eq!(s.inplace_jobs.get(), 1);
        assert_eq!(s.elements.get(), 4200);
        let snap = s.snapshot();
        assert!(snap.contains("completed=10"));
        assert!(snap.contains("kway=2"));
        assert!(snap.contains("kway-seg=2"));
        assert!(snap.contains("sharded=1"));
        assert!(snap.contains("streamed=1"));
        assert!(snap.contains("inplace=1"));
        assert!(snap.contains("xla=1"));
    }

    #[test]
    fn kernel_suffixed_tags_route_to_base_backend() {
        let s = ServiceStats::new();
        s.record_completion("native+branchless", 10, 100, 1);
        s.record_completion("native-segmented+simd", 20, 200, 2);
        s.record_completion("native-kway-typed+scalar", 30, 300, 3);
        assert_eq!(s.native_jobs.get(), 1);
        assert_eq!(s.segmented_jobs.get(), 1);
        assert_eq!(s.kway_jobs.get(), 1);
        assert_eq!(s.completed.get(), 3);
    }

    #[test]
    fn kernel_counters_in_snapshot() {
        let s = ServiceStats::new();
        s.record_kernel(KernelKind::Scalar);
        s.record_kernel(KernelKind::Branchless);
        s.record_kernel(KernelKind::Branchless);
        s.record_kernel(KernelKind::Hybrid);
        s.record_kernel(KernelKind::Simd);
        assert_eq!(s.kernel_scalar_jobs.get(), 1);
        assert_eq!(s.kernel_branchless_jobs.get(), 2);
        assert_eq!(s.kernel_hybrid_jobs.get(), 1);
        assert_eq!(s.kernel_simd_jobs.get(), 1);
        let snap = s.snapshot();
        assert!(snap.contains("scalar=1"));
        assert!(snap.contains("branchless=2"));
        assert!(snap.contains("hybrid=1"));
        assert!(snap.contains("simd=1"));
        assert_eq!(s.completed.get(), 0, "kernel counts are not completions");
    }

    #[test]
    fn streaming_counters_in_snapshot() {
        let s = ServiceStats::new();
        s.streamed_sessions.inc();
        s.streamed_chunks.add(12);
        s.streamed_bytes.add(4096);
        s.eager_shards.add(3);
        s.stream_shards_completed.add(5);
        let snap = s.snapshot();
        assert!(snap.contains("sessions=1"));
        assert!(snap.contains("chunks=12"));
        assert!(snap.contains("bytes=4096"));
        assert!(snap.contains("eager=3"));
        assert!(snap.contains("stream-done=5"));
        assert_eq!(s.completed.get(), 0, "ingest counters are not completions");
    }

    #[test]
    fn shard_counters_are_independent_of_completions() {
        let s = ServiceStats::new();
        s.compact_shards.add(8);
        for _ in 0..8 {
            s.compact_shards_completed.inc();
        }
        assert_eq!(s.compact_shards.get(), s.compact_shards_completed.get());
        assert_eq!(s.completed.get(), 0, "shards are not client-visible jobs");
        s.segmented_shard_merges.add(3);
        let snap = s.snapshot();
        assert!(snap.contains("planned=8"));
        assert!(snap.contains("seg-merges=3"));
    }

    #[test]
    fn memory_counters_in_snapshot() {
        let s = ServiceStats::new();
        s.resident_bytes.add(8192);
        s.resident_bytes.sub(4096);
        s.reclaimed_bytes.add(4096);
        assert_eq!(s.peak_resident_bytes(), 8192);
        let snap = s.snapshot();
        assert!(snap.contains("resident=4096"));
        assert!(snap.contains("peak=8192"));
        assert!(snap.contains("reclaimed=4096"));
        assert_eq!(s.completed.get(), 0, "memory accounting is not a completion");
    }

    #[test]
    fn store_counters_in_snapshot() {
        let s = ServiceStats::new();
        // Spill/flush completions route to their own counters, not the
        // native fallback.
        s.record_completion("store-spill", 1000, 500, 5);
        s.record_completion("store-flush", 0, 900, 0);
        assert_eq!(s.store_spills.get(), 1);
        assert_eq!(s.store_flushes.get(), 1);
        assert_eq!(s.native_jobs.get(), 0, "store tags must not count as native");
        assert_eq!(s.completed.get(), 2);
        s.store_spilled_bytes.add(4096);
        s.store_compactions.inc();
        s.store_compacted_bytes.add(8192);
        s.store_runs.add(3);
        s.store_generation.add(4);
        s.scheduler_passes.inc();
        s.scheduler_skips.add(2);
        s.scheduler_backoffs.add(5);
        let snap = s.snapshot();
        assert!(snap.contains("spills=1"));
        assert!(snap.contains("flushes=1"));
        assert!(snap.contains("spilled=4096"));
        assert!(snap.contains("compactions=1"));
        assert!(snap.contains("compacted=8192"));
        assert!(snap.contains("runs=3"));
        assert!(snap.contains("gen=4"));
        assert!(snap.contains("passes=1"));
        assert!(snap.contains("skips=2"));
        assert!(snap.contains("backoffs=5"));
    }

    #[test]
    fn stage_histograms_in_snapshot() {
        let s = ServiceStats::new();
        s.stage_admission.record(1_000);
        s.stage_dispatch.record(2_000);
        s.stage_exec.record(500_000);
        let snap = s.snapshot();
        assert!(snap.contains("stages: admit[p50="), "{snap}");
        assert!(snap.contains("plan[p50="), "{snap}");
        assert!(snap.contains("exec[p50="), "{snap}");
        assert!(snap.contains("n=1]"), "{snap}");
    }

    #[test]
    fn dispatch_shard_stats_sized_once_and_rendered() {
        let s = ServiceStats::new();
        assert_eq!(s.dispatch_shard_count(), 0, "unsized before service start");
        assert!(!s.snapshot().contains("dispatch:"), "section hidden until sized");
        let shards = s.init_dispatch_shards(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(s.dispatch_shard_count(), 2);
        // Idempotent: a second init keeps the first layout.
        assert_eq!(s.init_dispatch_shards(8).len(), 2);
        shards[0].depth.set(3);
        shards[0].oldest_age_us.set(250);
        shards[0].dispatched.add(7);
        shards[1].stolen_jobs.add(4);
        shards[1].stolen_batches.inc();
        shards[1].session_msgs.add(2);
        let snap = s.snapshot();
        assert!(snap.contains("dispatch: shards=2"), "{snap}");
        assert!(snap.contains("s0[depth=3/3 age=250µs disp=7 stole=0/0 sess=0]"), "{snap}");
        assert!(snap.contains("s1[depth=0/0 age=0µs disp=0 stole=4/1 sess=2]"), "{snap}");
        assert!(s.dispatch_shard(1).is_some());
        assert!(s.dispatch_shard(2).is_none());
    }

    #[test]
    fn backend_element_throughput_in_snapshot() {
        let s = ServiceStats::new();
        s.record_completion("native", 100, 1000, 10);
        s.record_completion("native", 150, 1000, 10);
        s.record_completion("native-kway+simd", 300, 1000, 10);
        assert_eq!(s.backend_elements("native"), 250);
        assert_eq!(s.backend_elements("native-kway"), 300, "kernel suffix stripped");
        assert_eq!(s.backend_elements("xla"), 0);
        let snap = s.snapshot();
        assert!(snap.contains("throughput:"), "{snap}");
        assert!(snap.contains("native=250e"), "{snap}");
        assert!(snap.contains("native-kway=300e"), "{snap}");
    }

    #[test]
    fn calibration_report_in_snapshot() {
        let s = ServiceStats::new();
        let snap = s.snapshot();
        assert!(snap.contains("calibration: flat-max-k=0 shard-floor=0 cache-bytes=0"), "{snap}");
        s.record_calibration(64, 1 << 17, 2 << 20, 1_500_000);
        let snap = s.snapshot();
        assert!(snap.contains("flat-max-k=64"), "{snap}");
        assert!(snap.contains("shard-floor=131072"), "{snap}");
        assert!(snap.contains("cache-bytes=2097152"), "{snap}");
        assert!(snap.contains("probe=1.50ms"), "{snap}");
    }

    #[test]
    fn server_counters_in_snapshot() {
        let s = ServiceStats::new();
        s.busy_rejections.add(3);
        s.sessions_reaped.add(2);
        let snap = s.snapshot();
        assert!(snap.contains("busy=3"));
        assert!(snap.contains("reaped=2"));
        // BUSY replies and reaps must not disturb the admission ledger.
        assert_eq!(s.submitted.get(), 0);
        assert_eq!(s.rejected.get(), 0);
        assert_eq!(s.completed.get(), 0);
    }
}
