//! Job types flowing through the coordinator, generic over keyed
//! records ([`Record`]). The default record parameter is `i32` (the
//! paper's 32-bit integer workloads), so pre-typed-API code that spells
//! plain `JobKind` / `JobResult` keeps compiling unchanged.

use crate::record::Record;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// What a client asks the service to do. Inputs are sorted-by-key
/// record runs; all merging is stable (equal keys keep
/// run-index-then-offset order — see [`crate::record`]).
#[derive(Debug, Clone)]
pub enum JobKind<R: Record = i32> {
    /// Merge two sorted arrays. Stable: on key ties all of `a`'s
    /// records precede `b`'s. Sortedness of each input is validated
    /// per input at admission by the service — there is no separate
    /// whole-job validation pass.
    Merge {
        /// Sorted input A.
        a: Vec<R>,
        /// Sorted input B.
        b: Vec<R>,
    },
    /// Sort one unsorted array (stable by key: equal keys keep their
    /// input order).
    Sort {
        /// Input data.
        data: Vec<R>,
    },
    /// Compact several sorted runs into one (LSM-style k-way merge).
    /// Re-expressed at submit time as a streaming session
    /// ([`super::session`]) — open + chunked feeds + seal — so the
    /// one-shot and streaming paths share a single code path; from the
    /// seal onward it routes to the flat single-pass k-way engine, the
    /// pairwise tree, or — when the output is large enough — the
    /// dispatcher's rank shards (see [`JobKind::CompactShard`]).
    Compact {
        /// The sorted runs. Sortedness is validated chunk by chunk on
        /// the session feed path (bounded per call), not here.
        runs: Vec<Vec<R>>,
    },
    /// One rank-shard of a large compaction. Internal: produced by the
    /// dispatcher's shard expansion ([`super::shard`]); clients cannot
    /// construct a [`super::shard::ShardTask`] and so cannot submit
    /// this kind directly.
    CompactShard {
        /// Which segment of the group's shard plan this job executes.
        shard: super::shard::ShardTask<R>,
    },
    /// Streaming-session message: one validated chunk of one run
    /// (see [`super::session`]). Internal: handled on the dispatcher,
    /// never dispatched to a worker; the payload is only constructible
    /// by [`super::CompactionSession`].
    CompactChunk {
        /// Which session/run the chunk extends, plus the data.
        msg: super::session::ChunkMsg<R>,
    },
    /// Streaming-session message: a run will receive no more chunks.
    CompactSealRun {
        /// Which session/run is sealed.
        msg: super::session::RunSealMsg,
    },
    /// Streaming-session message: no more feeds at all — plan the
    /// remaining rank range and arrange the reply.
    CompactSeal {
        /// Which session is sealed.
        msg: super::session::SealMsg,
    },
    /// One shard of a streamed compaction (eager pre-seal window or
    /// remainder). Internal: produced by the dispatcher's session
    /// planner ([`super::session`]).
    StreamShard {
        /// The shard's input windows and completion slot.
        shard: super::session::StreamShard<R>,
    },
    /// Spill one sealed, sorted run to level 0 of the attached
    /// persistent store ([`crate::store`]). Executes on a pool worker
    /// like any other job; the result's `output` echoes the spilled
    /// records (so wire clients get their RESULT frame) and the
    /// backend tag is `"store-spill"`. Requires a store to be attached
    /// ([`super::MergeService::attach_store`]) — submit fails fast
    /// otherwise. On a store write failure the job's reply channel is
    /// dropped (there is no typed error channel), so `wait()` observes
    /// `Error::Service("job N dropped by service")` and the failure is
    /// counted in `rejected_jobs`.
    Spill {
        /// The sorted run to persist. Sortedness is validated at
        /// admission like `Merge` inputs.
        run: Vec<R>,
    },
    /// Drive the attached store's compaction scheduler synchronously
    /// until every level is within policy (the engine behind the
    /// `FLUSH` wire verb, and the test barrier for "background
    /// compaction has caught up"). Intercepted at `submit` and run on
    /// the *caller's* thread — a flush occupies no pool worker, so the
    /// compactions it drives can never deadlock against it. The
    /// result's `output` is empty and the backend tag is
    /// `"store-flush"`.
    Flush,
}

impl<R: Record> JobKind<R> {
    /// Total number of input elements.
    pub fn input_len(&self) -> usize {
        match self {
            JobKind::Merge { a, b } => a.len() + b.len(),
            JobKind::Sort { data } => data.len(),
            JobKind::Compact { runs } => runs.iter().map(|r| r.len()).sum(),
            JobKind::CompactShard { shard } => shard.len(),
            JobKind::CompactChunk { msg } => msg.len(),
            JobKind::CompactSealRun { .. } | JobKind::CompactSeal { .. } => 0,
            JobKind::StreamShard { shard } => shard.len(),
            JobKind::Spill { run } => run.len(),
            JobKind::Flush => 0,
        }
    }

}

/// An admitted job.
#[derive(Debug)]
pub struct Job<R: Record = i32> {
    /// Monotonic id.
    pub id: u64,
    /// Payload.
    pub kind: JobKind<R>,
    /// Admission time (for queueing-latency metrics).
    pub enqueued_at: Instant,
    /// Completion channel.
    pub reply: Sender<JobResult<R>>,
}

/// Completed job.
#[derive(Debug, Clone)]
pub struct JobResult<R: Record = i32> {
    /// Job id.
    pub id: u64,
    /// Sorted output (stable: equal keys in run-then-offset order).
    pub output: Vec<R>,
    /// Which backend executed it ("native", "native-segmented",
    /// "native-kway", "native-kway-typed" — the flat engine on a
    /// non-scalar record — "native-kway-sharded",
    /// "native-kway-streamed", "xla").
    pub backend: &'static str,
    /// End-to-end latency (ns, from admission).
    pub latency_ns: u64,
}

/// Client-side handle to await a result.
#[derive(Debug)]
pub struct JobHandle<R: Record = i32> {
    /// Job id.
    pub id: u64,
    rx: Receiver<JobResult<R>>,
}

impl<R: Record> JobHandle<R> {
    pub(crate) fn new(id: u64, rx: Receiver<JobResult<R>>) -> Self {
        Self { id, rx }
    }

    /// Block until the job completes.
    pub fn wait(self) -> crate::Result<JobResult<R>> {
        self.rx
            .recv()
            .map_err(|_| crate::Error::Service(format!("job {} dropped by service", self.id)))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<JobResult<R>> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_len_sums() {
        let j = JobKind::Merge { a: vec![1, 2], b: vec![3] };
        assert_eq!(j.input_len(), 3);
        let j = JobKind::Compact { runs: vec![vec![1], vec![2, 3], vec![]] };
        assert_eq!(j.input_len(), 3);
    }

}
