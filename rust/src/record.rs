//! Typed records: the keyed-record abstraction the coordinator is
//! generic over.
//!
//! The Merge Path partition needs nothing but comparisons, and the
//! kernels in [`crate::mergepath`] have always been generic over
//! `T: Ord`. This module is the missing API boundary: a [`Record`] is a
//! fixed-size value with an ordered *key*; the whole serving layer
//! ([`MergeService<R>`](crate::coordinator::MergeService),
//! [`JobKind<R>`](crate::coordinator::JobKind), sessions, shards) is
//! parameterized over it, so key-value compaction — the LSM workload —
//! runs through the exact same engine as the paper's scalar arrays.
//!
//! ## The stability contract
//!
//! Once payloads ride along with keys, stability becomes *observable*:
//! two records can compare equal by key while carrying different
//! payloads. Every merge the coordinator performs is therefore
//! guaranteed **stable**: equal keys keep run-index-then-offset order
//! (for pairwise merges, all of `A`'s ties precede `B`'s; sorts are
//! stable by key). Träff's *Simplified, stable parallel merging* and
//! Siebert & Träff's *Perfectly load-balanced, optimal, stable,
//! parallel merge* show exact rank-splitting loses nothing by promising
//! this; the flat engine's tile invariants already implied it, and
//! [`crate::mergepath::kway_path`] documents + tests it as a contract.
//!
//! Merging compares **keys only** — payload bits never influence the
//! order, which is exactly what makes the run-order guarantee
//! meaningful. Internally the coordinator wraps records in the
//! [`ByKey`] adapter (a `#[repr(transparent)]` newtype whose `Ord` is
//! key-only) before handing slices to the `T: Ord` kernels; the
//! zero-cost casts live here too.
//!
//! ## Implementations
//!
//! - every primitive integer, `bool`, and `char` is a [`Record`] whose
//!   key is itself (`i32` is the classic scalar workload);
//! - `(K, V)` pairs are key-value records keyed on `K`;
//! - [`F32Key`] / [`F64Key`] wrap floats with a total order
//!   (`total_cmp`), since raw floats are not `Ord`.
//!
//! The XLA offload seam is part of the trait: AOT artifacts are baked
//! for `i32` keys, so only [`KeyedI32`] types (today: `i32` itself)
//! can return a witness from [`Record::xla_seam`] — the [`XlaSeam`]
//! constructor is bounded on the marker, so every other instantiation
//! deterministically routes native, enforced at compile time.

use std::cmp::Ordering;

/// A fixed-size keyed record the coordinator can merge, sort and
/// compact. See the [module docs](self) for the stability contract.
///
/// ```
/// use mergeflow::config::MergeflowConfig;
/// use mergeflow::coordinator::{JobKind, MergeService};
///
/// // (key, payload) pairs are records keyed on the first element.
/// let svc = MergeService::<(u64, u64)>::start(MergeflowConfig::default()).unwrap();
/// let runs = vec![
///     vec![(1u64, 100u64), (3, 101)], // run 0
///     vec![(1, 200), (2, 201)],       // run 1
/// ];
/// let out = svc.submit_blocking(JobKind::Compact { runs }).unwrap().output;
/// // Stable: the tie at key 1 keeps run order (run 0 before run 1).
/// assert_eq!(out, vec![(1, 100), (1, 200), (2, 201), (3, 101)]);
/// svc.shutdown();
/// ```
pub trait Record: Copy + Send + Sync + std::fmt::Debug + 'static {
    /// The ordered key merging compares by. Payload bits (anything in
    /// the record beyond the key) never influence merge order.
    type Key: Ord + std::fmt::Debug;

    /// Borrow this record's key.
    fn key(&self) -> &Self::Key;

    /// Whether the record *is* its key (scalar workloads). Non-scalar
    /// records route through the same engines but report the
    /// `"native-kway-typed"` backend tag on the flat k-way path, so
    /// operators can see typed traffic in the stats.
    const IS_SCALAR: bool;

    /// XLA offload seam: `Some` iff this record type can be served by
    /// the AOT merge artifacts, which are baked for `i32` keys. The
    /// returned [`XlaSeam`] witness is constructible **only** for
    /// [`KeyedI32`] types, so an implementation cannot opt into the
    /// route without the marker — the gate holds at compile time. The
    /// default `None` routes every other instantiation native.
    fn xla_seam() -> Option<XlaSeam<Self>> {
        None
    }
}

/// Marker + conversion pair for record types whose memory layout is
/// exactly the `i32` keys the AOT XLA merge artifacts are baked for.
/// Implementing it is what unlocks [`Record::xla_seam`]: the
/// [`XlaSeam`] witness can only be built from these two conversions
/// (its constructor is bounded on this trait), so non-`KeyedI32`
/// instantiations can never reach the XLA backend.
pub trait KeyedI32: Record {
    /// View the records as the artifact's `i32` key buffer.
    fn as_i32_keys(records: &[Self]) -> &[i32];

    /// Rebuild records from the artifact's `i32` output buffer.
    fn from_i32_keys(keys: Vec<i32>) -> Vec<Self>;
}

impl KeyedI32 for i32 {
    #[inline]
    fn as_i32_keys(records: &[Self]) -> &[i32] {
        records
    }

    #[inline]
    fn from_i32_keys(keys: Vec<i32>) -> Vec<Self> {
        keys
    }
}

/// Compile-time witness that a record type is XLA-servable: bundles
/// the two [`KeyedI32`] conversions so a view can never exist without
/// its way back (no half-implemented seam). Only constructible for
/// `R: KeyedI32` — see [`Record::xla_seam`].
#[derive(Clone, Copy)]
pub struct XlaSeam<R: Record> {
    view_fn: fn(&[R]) -> &[i32],
    back_fn: fn(Vec<i32>) -> Vec<R>,
}

impl<R: KeyedI32> XlaSeam<R> {
    /// Build the witness — the only way, and it requires the marker.
    pub fn new() -> Self {
        Self { view_fn: R::as_i32_keys, back_fn: R::from_i32_keys }
    }
}

impl<R: KeyedI32> Default for XlaSeam<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R: Record> XlaSeam<R> {
    /// View records as the baked `i32` key buffer.
    pub fn view<'a>(&self, records: &'a [R]) -> &'a [i32] {
        (self.view_fn)(records)
    }

    /// Rebuild records from the artifact's output buffer.
    pub fn back(&self, keys: Vec<i32>) -> Vec<R> {
        (self.back_fn)(keys)
    }
}

impl Record for i32 {
    type Key = i32;

    #[inline]
    fn key(&self) -> &i32 {
        self
    }

    const IS_SCALAR: bool = true;

    #[inline]
    fn xla_seam() -> Option<XlaSeam<Self>> {
        Some(XlaSeam::new())
    }
}

macro_rules! scalar_record {
    ($($t:ty),* $(,)?) => {$(
        impl Record for $t {
            type Key = $t;

            #[inline]
            fn key(&self) -> &$t {
                self
            }

            const IS_SCALAR: bool = true;
        }
    )*};
}

scalar_record!(i8, i16, i64, i128, isize, u8, u16, u32, u64, u128, usize, bool, char);

/// Key-value pairs are records keyed on the first element; the second
/// is opaque payload carried along by the merge.
impl<K, V> Record for (K, V)
where
    K: Ord + Copy + Send + Sync + std::fmt::Debug + 'static,
    V: Copy + Send + Sync + std::fmt::Debug + 'static,
{
    type Key = K;

    #[inline]
    fn key(&self) -> &K {
        &self.0
    }

    const IS_SCALAR: bool = false;
}

macro_rules! float_key {
    ($($(#[$doc:meta])* $name:ident($t:ty)),* $(,)?) => {$(
        $(#[$doc])*
        #[derive(Debug, Clone, Copy)]
        pub struct $name(pub $t);

        impl PartialEq for $name {
            fn eq(&self, other: &Self) -> bool {
                self.0.total_cmp(&other.0) == Ordering::Equal
            }
        }

        impl Eq for $name {}

        impl PartialOrd for $name {
            fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
                Some(self.cmp(other))
            }
        }

        impl Ord for $name {
            fn cmp(&self, other: &Self) -> Ordering {
                self.0.total_cmp(&other.0)
            }
        }

        impl Record for $name {
            type Key = $name;

            #[inline]
            fn key(&self) -> &$name {
                self
            }

            const IS_SCALAR: bool = true;
        }
    )*};
}

float_key!(
    /// A total-order `f32` key (IEEE 754 `totalOrder`): floats are not
    /// `Ord`, so float-keyed workloads wrap them. `-NaN < -∞ < … <
    /// -0.0 < +0.0 < … < +∞ < +NaN`; `Eq` agrees with the same order
    /// (so `-0.0 != +0.0`, unlike raw `f32`).
    F32Key(f32),
    /// A total-order `f64` key; see [`F32Key`].
    F64Key(f64),
);

/// Key-only ordering adapter: a `#[repr(transparent)]` newtype whose
/// `Ord`/`Eq` compare the record's key and nothing else. This is how
/// records flow through the `T: Ord` kernels in [`crate::mergepath`]
/// without those kernels knowing about payloads — and why a stable
/// kernel yields the run-then-offset tie order the typed API promises.
///
/// The casts below are zero-cost: `repr(transparent)` guarantees
/// `ByKey<R>` and `R` have identical layout.
#[derive(Debug, Clone, Copy)]
#[repr(transparent)]
pub struct ByKey<R: Record>(pub R);

impl<R: Record> PartialEq for ByKey<R> {
    fn eq(&self, other: &Self) -> bool {
        self.0.key() == other.0.key()
    }
}

impl<R: Record> Eq for ByKey<R> {}

impl<R: Record> PartialOrd for ByKey<R> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<R: Record> Ord for ByKey<R> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.key().cmp(other.0.key())
    }
}

/// View a record slice through the key-only ordering (zero-cost).
#[inline]
pub fn as_keyed<R: Record>(records: &[R]) -> &[ByKey<R>] {
    // SAFETY: ByKey<R> is #[repr(transparent)] over R.
    unsafe { std::slice::from_raw_parts(records.as_ptr().cast(), records.len()) }
}

/// Mutable key-only view of a record slice (zero-cost).
#[inline]
pub fn as_keyed_mut<R: Record>(records: &mut [R]) -> &mut [ByKey<R>] {
    // SAFETY: ByKey<R> is #[repr(transparent)] over R.
    unsafe { std::slice::from_raw_parts_mut(records.as_mut_ptr().cast(), records.len()) }
}

/// Rewrap an owned record vector in the key-only ordering (zero-cost:
/// the allocation is reused, nothing is copied).
#[inline]
pub fn into_keyed<R: Record>(records: Vec<R>) -> Vec<ByKey<R>> {
    let mut v = std::mem::ManuallyDrop::new(records);
    // SAFETY: ByKey<R> is #[repr(transparent)] over R (same size and
    // alignment), and R: Copy means neither type has drop glue.
    unsafe { Vec::from_raw_parts(v.as_mut_ptr().cast(), v.len(), v.capacity()) }
}

/// Unwrap a key-ordered vector back into plain records (zero-cost).
#[inline]
pub fn into_records<R: Record>(keyed: Vec<ByKey<R>>) -> Vec<R> {
    let mut v = std::mem::ManuallyDrop::new(keyed);
    // SAFETY: see into_keyed — the transparent cast in reverse.
    unsafe { Vec::from_raw_parts(v.as_mut_ptr().cast(), v.len(), v.capacity()) }
}

/// True iff the records are sorted by key (the admission precondition
/// for every merge/compaction input). Equal keys in any payload order
/// are fine — ordering is key-only by contract.
#[inline]
pub fn is_sorted_by_key<R: Record>(records: &[R]) -> bool {
    records.windows(2).all(|w| w[0].key() <= w[1].key())
}

/// Checked sortedness verification: [`is_sorted_by_key`] as a
/// `Result`, naming `what` and the first offending position. Unlike a
/// `debug_assert!`, this runs in release builds too — it is the output
/// verification the `serve` self-load loop and the wire-protocol tests
/// share.
pub fn ensure_sorted_by_key<R: Record>(what: &str, records: &[R]) -> crate::Result<()> {
    match records.windows(2).position(|w| w[0].key() > w[1].key()) {
        None => Ok(()),
        Some(i) => Err(crate::Error::InvalidInput(format!(
            "{what} is not sorted by key: element {} ({:?}) > element {} ({:?})",
            i,
            records[i].key(),
            i + 1,
            records[i + 1].key()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_key_ignores_payload() {
        let a = ByKey((5u64, 1u64));
        let b = ByKey((5u64, 2u64));
        let c = ByKey((6u64, 0u64));
        assert_eq!(a, b, "payloads must not affect equality");
        assert_eq!(a.cmp(&b), Ordering::Equal);
        assert!(a < c);
        assert!(!<(u64, u64) as Record>::IS_SCALAR);
        assert!(<i32 as Record>::IS_SCALAR);
    }

    #[test]
    fn casts_round_trip() {
        let recs: Vec<(i64, u8)> = vec![(3, 1), (1, 2), (2, 3)];
        let keyed = as_keyed(&recs);
        assert_eq!(keyed.len(), 3);
        assert!(keyed[1] < keyed[2]);
        let mut owned = into_keyed(recs.clone());
        owned.sort(); // stable, key-only
        let back = into_records(owned);
        assert_eq!(back, vec![(1i64, 2u8), (2, 3), (3, 1)]);
        let mut recs = recs;
        as_keyed_mut(&mut recs).sort();
        assert_eq!(recs, back);
    }

    #[test]
    fn sorted_by_key_allows_payload_disorder() {
        assert!(is_sorted_by_key(&[(1u32, 9u32), (1, 2), (3, 0)]));
        assert!(!is_sorted_by_key(&[(2u32, 0u32), (1, 0)]));
        assert!(is_sorted_by_key::<i32>(&[]));
        assert!(is_sorted_by_key(&[1i32, 1, 5]));
        assert!(!is_sorted_by_key(&[2i32, 1]));
    }

    #[test]
    fn ensure_sorted_names_the_offender() {
        assert!(ensure_sorted_by_key("out", &[1i32, 2, 2, 9]).is_ok());
        assert!(ensure_sorted_by_key::<i32>("out", &[]).is_ok());
        let err = ensure_sorted_by_key("served output", &[1i32, 5, 3]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("served output"), "{msg}");
        assert!(msg.contains("element 1"), "{msg}");
        // Payload disorder on equal keys is fine — ordering is key-only.
        assert!(ensure_sorted_by_key("pairs", &[(1u64, 9u64), (1, 2)]).is_ok());
    }

    #[test]
    fn float_keys_totally_ordered() {
        let mut v = vec![
            F64Key(f64::NAN),
            F64Key(1.5),
            F64Key(f64::NEG_INFINITY),
            F64Key(-0.0),
            F64Key(0.0),
        ];
        v.sort();
        assert_eq!(v[0].0, f64::NEG_INFINITY);
        assert!(v[1].0.is_sign_negative() && v[1].0 == 0.0, "-0.0 sorts before +0.0");
        assert!(v[2].0.is_sign_positive() && v[2].0 == 0.0);
        assert_eq!(v[3].0, 1.5);
        assert!(v[4].0.is_nan(), "+NaN sorts last");
        assert_ne!(F32Key(-0.0), F32Key(0.0), "Eq agrees with total order");
        assert_eq!(F32Key(f32::NAN), F32Key(f32::NAN));
    }

    #[test]
    fn xla_seam_is_i32_only() {
        let seam = <i32 as Record>::xla_seam().expect("i32 carries the KeyedI32 seam");
        let a = vec![1i32, 2, 3];
        assert_eq!(seam.view(&a), a.as_slice());
        assert_eq!(seam.back(a.clone()), a);
        // Non-KeyedI32 records have no seam: the router must go native.
        assert!(<(i32, i32) as Record>::xla_seam().is_none());
        assert!(<i64 as Record>::xla_seam().is_none());
        assert!(<F32Key as Record>::xla_seam().is_none());
    }
}
