//! In-place pairwise merge under the Merge Path partition.
//!
//! The allocating kernels in [`super::merge`] need a full output buffer,
//! so a pairwise merge's peak footprint is ~2× its data. This module
//! trades comparisons for memory: a **block-swap in-place merge** in the
//! style of Bramas & Bramas (arxiv 2005.12648), built from the
//! symmetric rotation merge of Kim & Kutzner (*Ratio based stable
//! in-place merging*, the `symMerge` scheme) as the sequential kernel.
//! `O((n_a + n_b) · log(n_a + n_b))` comparisons and moves, **zero heap
//! allocation**, and — load-bearing for the typed-record API — *stable*:
//! equal keys keep A-before-B order, bit-identical to
//! [`super::merge::merge_into`].
//!
//! Parallelisation reuses the paper's machinery unchanged: a cross
//! diagonal `d` is cut with [`super::diagonal::diagonal_intersection`]
//! (A-priority, so ties stay stable), the middle region
//! `buf[a_cut .. mid + b_cut]` is rotated to make both sides of the cut
//! contiguous, and the two halves recurse on disjoint windows — thread
//! counts are halved at each level, so `p` threads cost `O(log p)`
//! sequential rotations of total `O(n · log p)` moves before the leaves
//! merge independently (the same disjoint-window argument as
//! [`super::parallel`], Thm 5). Scratch per thread is `O(log n)` stack
//! frames — the "O(p·L) scratch" in the memory-model budget.

use super::diagonal::diagonal_intersection;
use super::parallel::SliceParts;
use crate::exec::{fork_join, WorkerPool};

/// Stable in-place merge of the two sorted halves `buf[..mid]` and
/// `buf[mid..]`, sequential. Equal keys keep A-before-B order; output
/// is bit-identical to [`super::merge::merge_into`] of the halves.
///
/// `O(n log n)` comparisons and moves, no allocation.
///
/// # Panics
/// If `mid > buf.len()`.
pub fn merge_in_place<T: Ord>(buf: &mut [T], mid: usize) {
    assert!(mid <= buf.len(), "mid out of range");
    debug_assert!(buf[..mid].windows(2).all(|w| w[0] <= w[1]));
    debug_assert!(buf[mid..].windows(2).all(|w| w[0] <= w[1]));
    if mid == 0 || mid == buf.len() {
        return;
    }
    sym_merge(buf, 0, mid, buf.len());
}

/// Symmetric rotation merge of `d[a..m]` and `d[m..b]` (Kim–Kutzner).
///
/// Recursion: binary-search the longest symmetric prefix/suffix pair
/// that is out of order across the boundary, rotate it into place, and
/// recurse on the two halves around the midpoint `(a + b) / 2`. Depth
/// `O(log (b - a))`. Both base cases are stable single-element binary
/// insertions: an A element goes *before* equal B elements, a B element
/// *after* equal A elements.
fn sym_merge<T: Ord>(d: &mut [T], a: usize, m: usize, b: usize) {
    debug_assert!(a < m && m < b);
    if m - a == 1 {
        // Insert the single A element d[a] into d[m..b): find the first
        // B element >= it (ties keep A first), then bubble it up.
        let mut i = m;
        let mut j = b;
        while i < j {
            let h = (i + j) / 2;
            if d[h] < d[a] {
                i = h + 1;
            } else {
                j = h;
            }
        }
        for k in a..i - 1 {
            d.swap(k, k + 1);
        }
        return;
    }
    if b - m == 1 {
        // Insert the single B element d[m] into d[a..m): it goes after
        // every A element <= it (ties keep A first).
        let mut i = a;
        let mut j = m;
        while i < j {
            let h = (i + j) / 2;
            if d[m] >= d[h] {
                i = h + 1;
            } else {
                j = h;
            }
        }
        for k in (i + 1..=m).rev() {
            d.swap(k, k - 1);
        }
        return;
    }
    let mid = (a + b) / 2;
    let n = mid + m;
    let (mut start, mut r) = if m > mid { (n - b, mid) } else { (a, m) };
    // Binary-search the symmetric split: the largest `start` such that
    // the A suffix d[start..m] still belongs after the B prefix
    // d[m..n-start]. The `>=` keeps ties with A (stability).
    let p = n - 1;
    while start < r {
        let c = (start + r) / 2;
        if d[p - c] >= d[c] {
            start = c + 1;
        } else {
            r = c;
        }
    }
    let end = n - start;
    if start < m && m < end {
        d[start..end].rotate_left(m - start);
    }
    if a < start && start < mid {
        sym_merge(d, a, start, mid);
    }
    if mid < end && end < b {
        sym_merge(d, mid, end, b);
    }
}

/// Stable parallel in-place merge of `buf[..mid]` / `buf[mid..]` using
/// `p` threads: Merge Path diagonal cuts + rotations partition the
/// buffer into `p` disjoint windows, each merged in place with
/// [`merge_in_place`]. Output is bit-identical to the sequential merge
/// for every `p`.
///
/// # Panics
/// If `mid > buf.len()` or `p == 0`.
pub fn parallel_inplace_merge<T: Ord + Send>(buf: &mut [T], mid: usize, p: usize) {
    assert!(p > 0);
    run_partitioned(buf, mid, p, |shared, leaves| {
        fork_join(leaves.len(), |tid| {
            let (start, len, m) = leaves[tid];
            // SAFETY: leaf windows are disjoint by construction (each
            // split hands `[0, d)` / `[d, n)` to the two halves).
            let w = unsafe { shared.slice_mut(start, len) };
            merge_in_place(w, m);
        });
    });
}

/// Pool-based variant of [`parallel_inplace_merge`]: identical
/// semantics, runs the leaf merges on a persistent [`WorkerPool`].
pub fn parallel_inplace_merge_with_pool<T: Ord + Send>(
    pool: &WorkerPool,
    buf: &mut [T],
    mid: usize,
    p: usize,
) {
    assert!(p > 0);
    run_partitioned(buf, mid, p, |shared, leaves| {
        pool.run_scoped(leaves.len(), |tid| {
            let (start, len, m) = leaves[tid];
            // SAFETY: leaf windows are disjoint by construction.
            let w = unsafe { shared.slice_mut(start, len) };
            merge_in_place(w, m);
        });
    });
}

/// Shared partition-then-run scaffolding for the two parallel variants.
fn run_partitioned<T, F>(buf: &mut [T], mid: usize, p: usize, run: F)
where
    T: Ord + Send,
    F: FnOnce(&SliceParts<T>, &[(usize, usize, usize)]),
{
    assert!(mid <= buf.len(), "mid out of range");
    let n = buf.len();
    if p == 1 || n < 2 * p {
        merge_in_place(buf, mid);
        return;
    }
    let mut leaves = Vec::with_capacity(p);
    split_windows(buf, mid, p, 0, &mut leaves);
    let shared = SliceParts::new(buf);
    run(&shared, &leaves);
}

/// Recursively cut the window for `p` threads, rotating at each cut so
/// both halves are contiguous `(sorted A part, sorted B part)` windows.
/// Pushes `(absolute start, window length, inner mid)` leaf descriptors.
fn split_windows<T: Ord>(
    buf: &mut [T],
    m: usize,
    p: usize,
    abs: usize,
    leaves: &mut Vec<(usize, usize, usize)>,
) {
    let n = buf.len();
    if p <= 1 || n < 2 * p || m == 0 || m == n {
        leaves.push((abs, n, m));
        return;
    }
    let p_left = p / 2;
    let d = n * p_left / p;
    // A-priority cut of diagonal d: the stable merge's first d outputs
    // are exactly a[..cut.a] ++ b[..cut.b].
    let cut = diagonal_intersection(&buf[..m], &buf[m..], d);
    // Rotate the middle so those d elements become the contiguous left
    // window: [A-prefix | B-prefix | A-suffix | B-suffix]. Each block
    // keeps its internal order, so stability is preserved.
    buf[cut.a..m + cut.b].rotate_left(m - cut.a);
    let (left, right) = buf.split_at_mut(d);
    split_windows(left, cut.a, p_left, abs, leaves);
    split_windows(right, m - cut.a, p - p_left, abs + d, leaves);
}

/// Concatenate two sorted runs into one buffer for in-place merging,
/// growing the **larger** run's allocation by the smaller run's length —
/// the step that makes the in-place route's peak extra footprint
/// `min(|a|, |b|)` elements instead of `|a| + |b|` (the allocating
/// route's fresh output buffer). Returns `(buffer, mid)` with
/// `buffer[..mid] == a` and `buffer[mid..] == b`.
///
/// The growth goes through `Vec::reserve_exact`, i.e. the allocator's
/// `realloc`: for the multi-megabyte runs the in-place route targets
/// that is an address-space remap, not a copy-through-peak, which is
/// why the counting-allocator test accounts realloc as a size delta.
pub fn concat_for_inplace<T: Copy>(a: Vec<T>, b: Vec<T>) -> (Vec<T>, usize) {
    let mid = a.len();
    if b.len() <= a.len() {
        let mut buf = a;
        buf.reserve_exact(b.len());
        buf.extend_from_slice(&b);
        (buf, mid)
    } else {
        // b is larger: grow it and shift its contents up to vacate the
        // prefix for a.
        let blen = b.len();
        let mut buf = b;
        buf.reserve_exact(mid);
        // SAFETY: capacity >= blen + mid after reserve_exact; the two
        // copies stay in bounds, and T: Copy means no drop obligations
        // on the moved-over bytes.
        unsafe {
            let ptr = buf.as_mut_ptr();
            std::ptr::copy(ptr, ptr.add(mid), blen);
            std::ptr::copy_nonoverlapping(a.as_ptr(), ptr, mid);
            buf.set_len(blen + mid);
        }
        (buf, mid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::{as_keyed_mut, ByKey};
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn sequential_matches_oracle() {
        let mut rng = Xoshiro256::seeded(0x17E5);
        for _ in 0..40 {
            let (na, nb) = (rng.range(0, 200), rng.range(0, 200));
            let a = random_sorted(&mut rng, na, 50);
            let b = random_sorted(&mut rng, nb, 50);
            let expected = oracle(&a, &b);
            let mut buf = a.clone();
            buf.extend_from_slice(&b);
            let mid = a.len();
            merge_in_place(&mut buf, mid);
            assert_eq!(buf, expected);
        }
    }

    #[test]
    fn parallel_matches_for_all_p() {
        let mut rng = Xoshiro256::seeded(0xF01D);
        for _ in 0..20 {
            let (na, nb) = (rng.range(0, 400), rng.range(0, 400));
            let a = random_sorted(&mut rng, na, 100);
            let b = random_sorted(&mut rng, nb, 100);
            let expected = oracle(&a, &b);
            for p in [1usize, 2, 3, 4, 7, 8, 16, 33] {
                let mut buf = a.clone();
                buf.extend_from_slice(&b);
                parallel_inplace_merge(&mut buf, a.len(), p);
                assert_eq!(buf, expected, "p={p}");
            }
        }
    }

    #[test]
    fn pool_variant_matches() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0xBEE5);
        for _ in 0..10 {
            let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
            let a = random_sorted(&mut rng, na, 80);
            let b = random_sorted(&mut rng, nb, 80);
            let expected = oracle(&a, &b);
            let mut buf = a.clone();
            buf.extend_from_slice(&b);
            parallel_inplace_merge_with_pool(&pool, &mut buf, a.len(), 4);
            assert_eq!(buf, expected);
        }
    }

    #[test]
    fn adversarial_one_sided() {
        // All of A greater than all of B — the naive-split killer (§1).
        let a: Vec<i64> = (1000..2000).collect();
        let b: Vec<i64> = (0..1000).collect();
        let expected = oracle(&a, &b);
        for p in [1usize, 2, 8, 40] {
            let mut buf = a.clone();
            buf.extend_from_slice(&b);
            parallel_inplace_merge(&mut buf, a.len(), p);
            assert_eq!(buf, expected, "p={p}");
        }
    }

    #[test]
    fn empty_and_degenerate() {
        let mut empty: Vec<i64> = vec![];
        merge_in_place(&mut empty, 0);
        assert!(empty.is_empty());
        let mut one = vec![5i64];
        merge_in_place(&mut one, 0);
        merge_in_place(&mut one, 1);
        assert_eq!(one, vec![5]);
        let mut both = vec![2i64, 1];
        parallel_inplace_merge(&mut both, 1, 8);
        assert_eq!(both, vec![1, 2]);
    }

    /// Stability is observable through payloads: equal keys must keep
    /// A-before-B, and A/B internal order — bit-identical to the stable
    /// allocating kernel for every p, duplicate-heavy included.
    #[test]
    fn stable_for_keyed_records() {
        let mut rng = Xoshiro256::seeded(0x57AB);
        for trial in 0..20 {
            // Tiny key universe → masses of ties.
            let mk = |rng: &mut Xoshiro256, n: usize, side: u32| {
                let mut v: Vec<(u32, u32)> = (0..n)
                    .map(|i| (rng.below(6) as u32, side * 1000 + i as u32))
                    .collect();
                v.sort_by_key(|r| r.0); // stable: offsets stay ordered per key
                v
            };
            let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
            let a = mk(&mut rng, na, 1);
            let b = mk(&mut rng, nb, 2);
            let mut expected = vec![ByKey((0u32, 0u32)); a.len() + b.len()];
            crate::mergepath::merge_into(
                crate::record::as_keyed(&a),
                crate::record::as_keyed(&b),
                &mut expected,
            );
            let expected: Vec<(u32, u32)> = expected.iter().map(|k| k.0).collect();
            for p in [1usize, 2, 4, 8] {
                let mut buf = a.clone();
                buf.extend_from_slice(&b);
                let mid = a.len();
                parallel_inplace_merge(as_keyed_mut(&mut buf), mid, p);
                assert_eq!(buf, expected, "trial {trial} p={p}");
            }
        }
    }

    #[test]
    fn all_ties_keep_run_order() {
        let a: Vec<(u8, u16)> = (0..50).map(|i| (7u8, i as u16)).collect();
        let b: Vec<(u8, u16)> = (0..30).map(|i| (7u8, 1000 + i as u16)).collect();
        let mut buf = a.clone();
        buf.extend_from_slice(&b);
        parallel_inplace_merge(as_keyed_mut(&mut buf), a.len(), 6);
        let expected: Vec<(u8, u16)> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(buf, expected, "ties: all of A, in order, then all of B");
    }

    #[test]
    fn concat_grows_larger_run_both_ways() {
        let a = vec![1i64, 3, 5, 7];
        let b = vec![2i64, 4];
        let (buf, mid) = concat_for_inplace(a.clone(), b.clone());
        assert_eq!(mid, 4);
        assert_eq!(buf, vec![1, 3, 5, 7, 2, 4]);
        // b larger: front-shift path.
        let (buf, mid) = concat_for_inplace(b.clone(), a.clone());
        assert_eq!(mid, 2);
        assert_eq!(buf, vec![2, 4, 1, 3, 5, 7]);
        // Degenerate sides.
        let (buf, mid) = concat_for_inplace(Vec::<i64>::new(), a.clone());
        assert_eq!((buf, mid), (a.clone(), 0));
        let (buf, mid) = concat_for_inplace(a.clone(), Vec::<i64>::new());
        assert_eq!((buf, mid), (a, 4));
    }

    #[test]
    fn concat_then_merge_end_to_end() {
        let mut rng = Xoshiro256::seeded(0xCAFE);
        for _ in 0..20 {
            let (na, nb) = (rng.range(0, 500), rng.range(0, 500));
            let a = random_sorted(&mut rng, na, 200);
            let b = random_sorted(&mut rng, nb, 200);
            let expected = oracle(&a, &b);
            let (mut buf, mid) = concat_for_inplace(a, b);
            parallel_inplace_merge(&mut buf, mid, 4);
            assert_eq!(buf, expected);
        }
    }
}
