//! `ParallelMerge` — Algorithm 1 of the paper.
//!
//! Each of the `p` cores independently binary-searches its starting
//! cross diagonal (Alg 2, [`super::diagonal`]), then merges exactly
//! `N/p` output elements with the sequential kernel
//! ([`super::merge::merge_bounded`]). No locks, no inter-core
//! communication; cores write disjoint output ranges (Thm 5), so the
//! only shared state is read-only input. Time `O(N/p + log N)`, work
//! `O(N + p·log N)`.

use super::diagonal::diagonal_intersection;
use super::kernel::LeafKernel;
use crate::exec::{fork_join, WorkerPool};

/// Merge sorted `a` and `b` into `out` using `p` threads.
///
/// Stable with `A`-priority (equal keys from `a` precede those from
/// `b`), identical to [`super::merge::merge_into`] output for every `p`.
///
/// # Panics
/// If `out.len() != a.len() + b.len()` or `p == 0`.
pub fn parallel_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) {
    parallel_merge_kernel(a, b, out, p, LeafKernel::hybrid());
}

/// [`parallel_merge`] with an explicit per-segment [`LeafKernel`]
/// (resolved once by the caller — typically the coordinator, from the
/// `merge.kernel` knob).
pub fn parallel_merge_kernel<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
    kernel: LeafKernel<T>,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let n = out.len();
    if p == 1 || n < 2 * p {
        // Degenerate sizes: sequential is both correct and faster.
        kernel.merge(a, b, out, n);
        return;
    }
    let shared = SliceParts::new(out);
    fork_join(p, |tid| {
        merge_segment(a, b, &shared, n, p, tid, kernel);
    });
}

/// Pool-based variant: identical semantics to [`parallel_merge`] but
/// runs segments on a persistent [`WorkerPool`] (≥ `p` workers
/// recommended) to amortize thread-spawn cost across merge rounds.
pub fn parallel_merge_with_pool<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
) {
    parallel_merge_with_pool_kernel(pool, a, b, out, p, LeafKernel::hybrid());
}

/// [`parallel_merge_with_pool`] with an explicit per-segment
/// [`LeafKernel`].
pub fn parallel_merge_with_pool_kernel<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    a: &[T],
    b: &[T],
    out: &mut [T],
    p: usize,
    kernel: LeafKernel<T>,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(p > 0);
    let n = out.len();
    if p == 1 || n < 2 * p {
        kernel.merge(a, b, out, n);
        return;
    }
    let shared = SliceParts::new(out);
    pool.run_scoped(p, |tid| {
        merge_segment(a, b, &shared, n, p, tid, kernel);
    });
}

/// One core's work in Algorithm 1: find the start point on diagonal
/// `tid·N/p`, then emit `(tid+1)·N/p − tid·N/p` outputs.
#[inline]
fn merge_segment<T: Ord + Copy>(
    a: &[T],
    b: &[T],
    out: &SliceParts<T>,
    n: usize,
    p: usize,
    tid: usize,
    kernel: LeafKernel<T>,
) {
    let d_start = tid * n / p;
    let d_end = (tid + 1) * n / p;
    if d_start == d_end {
        return;
    }
    let start = diagonal_intersection(a, b, d_start);
    // SAFETY: output ranges [d_start, d_end) are disjoint across tids
    // and tile [0, n) (Thm 9), so each thread gets an exclusive window.
    let chunk = unsafe { out.slice_mut(d_start, d_end - d_start) };
    kernel.merge(&a[start.a..], &b[start.b..], chunk, d_end - d_start);
}

/// Shared-output helper: hands out *disjoint* mutable windows of one
/// slice to multiple threads. Disjointness is the caller's obligation
/// (guaranteed here by the equispaced-diagonal partition).
pub(crate) struct SliceParts<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for SliceParts<T> {}
unsafe impl<T: Send> Sync for SliceParts<T> {}

impl<T> SliceParts<T> {
    pub(crate) fn new(slice: &mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
        }
    }

    /// # Safety
    /// Callers must ensure `[start, start+len)` windows never overlap
    /// across concurrently live borrows.
    #[inline]
    pub(crate) unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        assert!(start + len <= self.len, "window out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn matches_sequential_for_all_p() {
        let mut rng = Xoshiro256::seeded(0xF00D);
        for _ in 0..20 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 100);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 100);
            let expected = oracle(&a, &b);
            for p in [1, 2, 3, 4, 7, 8, 16, 33] {
                let mut out = vec![0i64; a.len() + b.len()];
                parallel_merge(&a, &b, &mut out, p);
                assert_eq!(out, expected, "p={p}");
            }
        }
    }

    #[test]
    fn paper_example() {
        let a = [17i64, 29, 35, 73, 86, 90, 95, 99];
        let b = [3i64, 5, 12, 22, 45, 64, 69, 82];
        let mut out = [0i64; 16];
        parallel_merge(&a, &b, &mut out, 4);
        assert_eq!(
            out,
            [3, 5, 12, 17, 22, 29, 35, 45, 64, 69, 73, 82, 86, 90, 95, 99]
        );
    }

    #[test]
    fn adversarial_one_sided() {
        // All of A greater than all of B — the naive-split killer (§1).
        let a: Vec<i64> = (1000..2000).collect();
        let b: Vec<i64> = (0..1000).collect();
        let expected = oracle(&a, &b);
        for p in [2, 8, 40] {
            let mut out = vec![0i64; 2000];
            parallel_merge(&a, &b, &mut out, p);
            assert_eq!(out, expected);
        }
    }

    #[test]
    fn empty_and_tiny() {
        let e: Vec<i64> = vec![];
        let a = vec![1i64];
        let mut out = vec![0i64; 1];
        parallel_merge(&a, &e, &mut out, 8);
        assert_eq!(out, vec![1]);
        let mut out0: Vec<i64> = vec![];
        parallel_merge(&e, &e, &mut out0, 8);
        assert!(out0.is_empty());
    }

    #[test]
    fn duplicates_heavy() {
        let a = vec![42i64; 500];
        let mut b = vec![42i64; 300];
        b.extend(vec![43i64; 200]);
        let expected = oracle(&a, &b);
        let mut out = vec![0i64; 1000];
        parallel_merge(&a, &b, &mut out, 12);
        assert_eq!(out, expected);
    }

    #[test]
    fn pool_variant_matches() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0xBEEF);
        for _ in 0..10 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 100);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 100);
            let expected = oracle(&a, &b);
            let mut out = vec![0i64; a.len() + b.len()];
            parallel_merge_with_pool(&pool, &a, &b, &mut out, 4);
            assert_eq!(out, expected);
        }
    }

    #[test]
    fn kernel_variants_match_for_all_p() {
        use super::super::kernel::MergeKernel;
        let mut rng = Xoshiro256::seeded(0x6B31);
        for _ in 0..8 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 40);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 40);
            let expected = oracle(&a, &b);
            for req in [
                MergeKernel::Auto,
                MergeKernel::Scalar,
                MergeKernel::Branchless,
                MergeKernel::Hybrid,
                MergeKernel::Simd,
            ] {
                let kernel = LeafKernel::<i64>::select(req);
                for p in [1, 3, 8] {
                    let mut out = vec![0i64; a.len() + b.len()];
                    parallel_merge_kernel(&a, &b, &mut out, p, kernel);
                    assert_eq!(out, expected, "req={req:?} p={p}");
                }
            }
        }
    }

    #[test]
    fn unequal_lengths() {
        let mut rng = Xoshiro256::seeded(0x5EED);
        let a = random_sorted(&mut rng, 1000, 500);
        let b = random_sorted(&mut rng, 13, 500);
        let expected = oracle(&a, &b);
        let mut out = vec![0i64; 1013];
        parallel_merge(&a, &b, &mut out, 6);
        assert_eq!(out, expected);
    }
}
