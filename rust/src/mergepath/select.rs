//! Multiselection on the merge path — the [10] extension ("An Optimal
//! Parallel Algorithm for Merging using Multiselection", §5 of the
//! paper).
//!
//! Given sorted `A`, `B` and a set of output ranks, find all the
//! corresponding path points. Beyond the independent-searches approach
//! of Alg 1 (each rank costs `O(log min(|A|,|B|))`), sorted rank sets
//! admit a divide-and-conquer that shares work between neighbouring
//! ranks: select the middle rank first, then recurse into the two
//! sub-rectangles of the merge matrix — total
//! `O(Σ log)` with strictly shrinking search ranges, and a convenient
//! EREW schedule (no two searches touch the same sub-rectangle).

use super::diagonal::{diagonal_intersection, PathPoint};

/// Find the path points for several ranks by independent binary
/// searches (the Alg 1 / CREW approach).
pub fn multiselect_independent<T: Ord>(a: &[T], b: &[T], ranks: &[usize]) -> Vec<PathPoint> {
    ranks
        .iter()
        .map(|&r| diagonal_intersection(a, b, r))
        .collect()
}

/// Divide-and-conquer multiselection for a **sorted** list of ranks:
/// selects the median rank on the full arrays, then recurses left of
/// it (on the consumed prefixes) and right of it (on the suffixes),
/// so each recursion level's searches run over disjoint, shrinking
/// windows — the EREW-friendly schedule of [10].
///
/// # Panics
/// If `ranks` is not sorted or contains a rank `> |A| + |B|`.
pub fn multiselect<T: Ord>(a: &[T], b: &[T], ranks: &[usize]) -> Vec<PathPoint> {
    assert!(
        ranks.windows(2).all(|w| w[0] <= w[1]),
        "ranks must be sorted"
    );
    if let Some(&max) = ranks.last() {
        assert!(max <= a.len() + b.len(), "rank out of range");
    }
    let mut out = vec![PathPoint { a: 0, b: 0 }; ranks.len()];
    rec(a, b, ranks, 0, 0, &mut out);
    out
}

/// Solve `ranks` (global) against the sub-arrays `a`, `b` whose global
/// offsets are `(a0, b0)`; write results at the matching positions of
/// `out` (parallel array to `ranks`).
fn rec<T: Ord>(
    a: &[T],
    b: &[T],
    ranks: &[usize],
    a0: usize,
    b0: usize,
    out: &mut [PathPoint],
) {
    if ranks.is_empty() {
        return;
    }
    let mid = ranks.len() / 2;
    // Local rank inside this sub-rectangle.
    let local = ranks[mid] - (a0 + b0);
    let pt = diagonal_intersection(a, b, local);
    out[mid] = PathPoint { a: a0 + pt.a, b: b0 + pt.b };
    // Left ranks live in the consumed prefixes; right ranks in the
    // suffixes. Equal ranks resolve identically, so strict split is
    // fine (duplicates of ranks[mid] in the left half recurse onto the
    // same point through a zero-length window).
    let (left_ranks, rest) = ranks.split_at(mid);
    let right_ranks = &rest[1..];
    let (left_out, rest_out) = out.split_at_mut(mid);
    let right_out = &mut rest_out[1..];
    rec(&a[..pt.a], &b[..pt.b], left_ranks, a0, b0, left_out);
    rec(&a[pt.a..], &b[pt.b..], right_ranks, a0 + pt.a, b0 + pt.b, right_out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn agrees_with_independent_searches() {
        let mut rng = Xoshiro256::seeded(0x3E1);
        for _ in 0..40 {
            let n_a = rng.range(0, 150);
            let a = random_sorted(&mut rng, n_a, 60);
            let n_b = rng.range(0, 150);
            let b = random_sorted(&mut rng, n_b, 60);
            let n = a.len() + b.len();
            let mut ranks: Vec<usize> =
                (0..rng.range(0, 20)).map(|_| rng.range(0, n + 1)).collect();
            ranks.sort_unstable();
            let dc = multiselect(&a, &b, &ranks);
            let ind = multiselect_independent(&a, &b, &ranks);
            assert_eq!(dc, ind, "a={a:?} b={b:?} ranks={ranks:?}");
        }
    }

    #[test]
    fn duplicate_and_extreme_ranks() {
        let a: Vec<i64> = (0..50).collect();
        let b: Vec<i64> = (25..75).collect();
        let ranks = vec![0, 0, 50, 50, 50, 100, 100];
        let pts = multiselect(&a, &b, &ranks);
        assert_eq!(pts[0], PathPoint { a: 0, b: 0 });
        assert_eq!(pts[6], PathPoint { a: 50, b: 50 });
        for (r, pt) in ranks.iter().zip(&pts) {
            assert_eq!(pt.diagonal(), *r);
        }
    }

    #[test]
    fn empty_ranks_and_empty_arrays() {
        let a: Vec<i64> = vec![1, 2, 3];
        let e: Vec<i64> = vec![];
        assert!(multiselect(&a, &e, &[]).is_empty());
        let pts = multiselect(&e, &a, &[0, 2, 3]);
        assert_eq!(pts[1], PathPoint { a: 0, b: 2 });
    }

    #[test]
    #[should_panic(expected = "ranks must be sorted")]
    fn unsorted_ranks_rejected() {
        let a: Vec<i64> = vec![1];
        multiselect(&a, &a, &[1, 0]);
    }

    #[test]
    fn equispaced_ranks_match_partition() {
        // multiselect at i·N/p equals partition_merge_path boundaries.
        let mut rng = Xoshiro256::seeded(0x3E2);
        let a = random_sorted(&mut rng, 200, 90);
        let b = random_sorted(&mut rng, 170, 90);
        let n = a.len() + b.len();
        let p = 8;
        let ranks: Vec<usize> = (1..p).map(|i| i * n / p).collect();
        let pts = multiselect(&a, &b, &ranks);
        let segs = crate::mergepath::partition_merge_path(&a, &b, p);
        for (pt, seg) in pts.iter().zip(segs.iter().skip(1)) {
            assert_eq!(pt.a, seg.a_range.start);
            assert_eq!(pt.b, seg.b_range.start);
        }
    }
}
