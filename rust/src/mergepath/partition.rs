//! `p`-way equisized partition of the Merge Path (paper Thm 14).
//!
//! The output array of length `N = |A| + |B|` is cut at `p − 1`
//! equispaced cross diagonals; each diagonal's intersection with the
//! Merge Path is found independently by binary search
//! ([`super::diagonal`]). The result is `p` [`MergeSegment`] descriptors
//! — contiguous sub-slices of `A` and `B` whose merger lands in a
//! contiguous, disjoint range of the output (Thm 5 / Cor. 6, 7) —
//! enabling lock-free, perfectly balanced parallel merging.

use super::diagonal::diagonal_intersection;

/// One core's share of a merge: merge `a[a_range]` with `b[b_range]`
/// into `out[out_range]`. Produced by [`partition_merge_path`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergeSegment {
    /// Sub-range of `A` feeding this segment.
    pub a_range: std::ops::Range<usize>,
    /// Sub-range of `B` feeding this segment.
    pub b_range: std::ops::Range<usize>,
    /// Output range; `out_range.len() == a_range.len() + b_range.len()`.
    pub out_range: std::ops::Range<usize>,
}

impl MergeSegment {
    /// Number of output elements this segment produces.
    pub fn len(&self) -> usize {
        self.out_range.len()
    }

    /// True iff the segment produces no output.
    pub fn is_empty(&self) -> bool {
        self.out_range.is_empty()
    }
}

/// Partition the merge of `a` and `b` into `p` segments of (near-)equal
/// output length. Segment `i` covers output indices
/// `[i·N/p, (i+1)·N/p)` (computed with the balanced `(i·N)/p` split so
/// lengths differ by at most one when `p ∤ N`).
///
/// Each of the `p − 1` interior split points costs one
/// `O(log min(|A|,|B|))` binary search and they are mutually
/// independent — Alg 1 computes them concurrently, one per core.
///
/// # Panics
/// If `p == 0`.
pub fn partition_merge_path<T: Ord>(a: &[T], b: &[T], p: usize) -> Vec<MergeSegment> {
    assert!(p > 0, "need at least one partition");
    let n = a.len() + b.len();
    let mut segments = Vec::with_capacity(p);
    let mut prev = diagonal_intersection(a, b, 0); // (0, 0)
    let mut prev_d = 0usize;
    for i in 1..=p {
        let d = i * n / p;
        let point = if i == p {
            // Last diagonal is the full merge — no search needed.
            super::diagonal::PathPoint { a: a.len(), b: b.len() }
        } else {
            diagonal_intersection(a, b, d)
        };
        segments.push(MergeSegment {
            a_range: prev.a..point.a,
            b_range: prev.b..point.b,
            out_range: prev_d..d,
        });
        prev = point;
        prev_d = d;
    }
    segments
}

/// The split diagonals used by [`partition_merge_path`], exposed so the
/// simulator and benches can time the partition stage in isolation
/// (the paper's §6.1 synchronization probe).
pub fn split_diagonals(n: usize, p: usize) -> Vec<usize> {
    (1..p).map(|i| i * n / p).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mergepath::merge::merge_into;
    use crate::rng::Xoshiro256;

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    /// Check the three partition invariants of Thm 5/9/14.
    fn check_partition(a: &[i64], b: &[i64], p: usize) {
        let segs = partition_merge_path(a, b, p);
        assert_eq!(segs.len(), p);
        let n = a.len() + b.len();

        // 1. Segments tile the output exactly and are equisized ±1.
        let mut expect_start = 0usize;
        for (i, s) in segs.iter().enumerate() {
            assert_eq!(s.out_range.start, expect_start, "segment {i} not contiguous");
            assert_eq!(s.out_range.len(), s.a_range.len() + s.b_range.len());
            let lo = n / p;
            let hi = n.div_ceil(p);
            assert!(
                (lo..=hi).contains(&s.out_range.len()),
                "segment {i} len {} outside [{lo}, {hi}]",
                s.out_range.len()
            );
            expect_start = s.out_range.end;
        }
        assert_eq!(expect_start, n);

        // 2. A- and B- ranges tile their arrays.
        assert_eq!(segs.first().unwrap().a_range.start, 0);
        assert_eq!(segs.last().unwrap().a_range.end, a.len());
        assert_eq!(segs.first().unwrap().b_range.start, 0);
        assert_eq!(segs.last().unwrap().b_range.end, b.len());
        for w in segs.windows(2) {
            assert_eq!(w[0].a_range.end, w[1].a_range.start);
            assert_eq!(w[0].b_range.end, w[1].b_range.start);
        }

        // 3. Merging each segment independently and concatenating equals
        //    the sequential merge (Cor. 6).
        let mut expected = vec![0i64; n];
        merge_into(a, b, &mut expected);
        let mut got = vec![0i64; n];
        for s in &segs {
            merge_into(
                &a[s.a_range.clone()],
                &b[s.b_range.clone()],
                &mut got[s.out_range.clone()],
            );
        }
        assert_eq!(got, expected);
    }

    #[test]
    fn paper_example_partitions() {
        let a = [17i64, 29, 35, 73, 86, 90, 95, 99];
        let b = [3i64, 5, 12, 22, 45, 64, 69, 82];
        for p in 1..=16 {
            check_partition(&a, &b, p);
        }
    }

    #[test]
    fn random_partitions() {
        let mut rng = Xoshiro256::seeded(0xAB);
        for _ in 0..40 {
            let n_a = rng.range(0, 200);
            let a = random_sorted(&mut rng, n_a, 50);
            let n_b = rng.range(0, 200);
            let b = random_sorted(&mut rng, n_b, 50);
            for p in [1, 2, 3, 5, 8, 13] {
                check_partition(&a, &b, p);
            }
        }
    }

    #[test]
    fn more_partitions_than_elements() {
        let a = [1i64, 3];
        let b = [2i64];
        check_partition(&a, &b, 10);
    }

    #[test]
    fn one_sided_inputs() {
        let a: Vec<i64> = (0..100).collect();
        let e: [i64; 0] = [];
        check_partition(&a, &e, 7);
        check_partition(&e, &a, 7);
    }

    #[test]
    fn heavy_duplicates() {
        let a = vec![5i64; 64];
        let b = vec![5i64; 64];
        for p in [2, 4, 7] {
            check_partition(&a, &b, p);
        }
    }

    #[test]
    fn split_diagonals_equispaced() {
        let d = split_diagonals(100, 4);
        assert_eq!(d, vec![25, 50, 75]);
        let d = split_diagonals(10, 3);
        assert_eq!(d, vec![3, 6]);
        assert!(split_diagonals(10, 1).is_empty());
    }

    #[test]
    fn adversarial_all_a_less() {
        let a: Vec<i64> = (0..128).collect();
        let b: Vec<i64> = (1000..1128).collect();
        check_partition(&a, &b, 8);
        check_partition(&b, &a, 8);
    }
}
