//! Sequential merge primitives — the per-segment kernels invoked by the
//! parallel algorithms (Alg 1 / Alg 3) after partitioning.
//!
//! All merges here are *stable with `A`-priority* (on a tie the `A`
//! element is emitted first), matching the Merge Path construction in
//! [`super::diagonal`] — this is what makes independently merged
//! segments concatenate into exactly the sequential result (Thm 5).
//!
//! Every kernel here writes into a caller-provided output buffer, i.e.
//! costs a full second copy of the data; when memory is the constraint,
//! [`super::inplace`] provides a stable zero-allocation alternative
//! with the same output, bit for bit.

/// Classic two-finger merge of the entirety of `a` and `b` into `out`.
///
/// # Panics
/// If `out.len() != a.len() + b.len()`.
pub fn merge_into<T: Ord + Copy>(a: &[T], b: &[T], out: &mut [T]) {
    assert_eq!(
        out.len(),
        a.len() + b.len(),
        "output must hold |A| + |B| elements"
    );
    merge_bounded(a, b, out, out.len());
}

/// Merge the first `len` outputs of the (stable, A-priority) merger of
/// `a` and `b` into `out[..len]`. This is the kernel each core runs on
/// its segment: `a`/`b` are already the sub-slices selected by the
/// partition, and `len` caps the segment length (paper Alg 1's `length`).
///
/// Branch-predictable inner loop with bounds hoisted; no allocation.
pub fn merge_bounded<T: Ord + Copy>(a: &[T], b: &[T], out: &mut [T], len: usize) {
    debug_assert!(len <= a.len() + b.len());
    debug_assert!(out.len() >= len);
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    // Main loop: both inputs non-exhausted.
    while k < len && i < a.len() && j < b.len() {
        // Stable: ties taken from A.
        if a[i] <= b[j] {
            out[k] = a[i];
            i += 1;
        } else {
            out[k] = b[j];
            j += 1;
        }
        k += 1;
    }
    // Tails: exactly one input can be unexhausted here, so the rest is
    // a bulk copy (memcpy) rather than a per-element bounds-checked loop.
    if k < len && i < a.len() {
        let take = (len - k).min(a.len() - i);
        out[k..k + take].copy_from_slice(&a[i..i + take]);
        k += take;
    }
    if k < len && j < b.len() {
        let take = (len - k).min(b.len() - j);
        out[k..k + take].copy_from_slice(&b[j..j + take]);
        k += take;
    }
    debug_assert_eq!(k, len);
}

/// Branch-free merge of the first `len` outputs into `out[..len]`.
///
/// Replaces the data-dependent branch of [`merge_bounded`] with
/// arithmetic selection; on random keys this avoids the ~50%
/// mispredict rate of the two-finger loop. Requires both cursors to be
/// in-bounds, so it runs the branchless loop only while both arrays
/// have elements left and falls back to tail copies afterwards.
pub fn branchless_merge_bounded<T: Ord + Copy>(a: &[T], b: &[T], out: &mut [T], len: usize) {
    debug_assert!(len <= a.len() + b.len());
    debug_assert!(out.len() >= len);
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    // How many iterations are guaranteed to keep both cursors in bounds:
    // each step consumes exactly one element from one of the arrays.
    loop {
        let safe = (a.len() - i).min(b.len() - j).min(len - k);
        if safe == 0 {
            break;
        }
        for _ in 0..safe {
            // `take_a` as 0/1; compiles to setcc + cmov-style selects.
            let take_a = (a[i] <= b[j]) as usize;
            out[k] = if take_a == 1 { a[i] } else { b[j] };
            i += take_a;
            j += 1 - take_a;
            k += 1;
        }
    }
    // Tails as bulk copies, as in `merge_bounded`.
    if k < len && i < a.len() {
        let take = (len - k).min(a.len() - i);
        out[k..k + take].copy_from_slice(&a[i..i + take]);
        k += take;
    }
    if k < len && j < b.len() {
        let take = (len - k).min(b.len() - j);
        out[k..k + take].copy_from_slice(&b[j..j + take]);
        k += take;
    }
    debug_assert_eq!(k, len);
}

/// Adaptive hybrid merge of the first `len` outputs: branchless blocks
/// for interleaved data, escaping into galloping mode when a block is
/// consumed entirely from one side (timsort's MIN_GALLOP idea, block
/// granularity).
///
/// Measured on this host (see EXPERIMENTS.md §Perf): ≈ branchless
/// throughput on uniform keys (~1.8x the two-finger loop) while
/// matching the galloping merge on run-structured and one-sided
/// inputs (~10x the branchless loop there). This is the kernel the
/// parallel algorithms use per segment.
pub fn hybrid_merge_bounded<T: Ord + Copy>(a: &[T], b: &[T], out: &mut [T], len: usize) {
    debug_assert!(len <= a.len() + b.len());
    debug_assert!(out.len() >= len);
    const BLOCK: usize = 64;
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    loop {
        let safe = (a.len() - i).min(b.len() - j).min(len - k);
        if safe == 0 {
            break;
        }
        let block = safe.min(BLOCK);
        let (i0, j0) = (i, j);
        for _ in 0..block {
            let take_a = (a[i] <= b[j]) as usize;
            out[k] = if take_a == 1 { a[i] } else { b[j] };
            i += take_a;
            j += 1 - take_a;
            k += 1;
        }
        // One-sided block → likely inside a long run: gallop it.
        if i - i0 == block && j < b.len() {
            // a is winning: copy the rest of a's run (a[t] <= b[j]).
            let run = gallop_right(&a[i..], &b[j]).min(len - k);
            out[k..k + run].copy_from_slice(&a[i..i + run]);
            i += run;
            k += run;
        } else if j - j0 == block && i < a.len() {
            // b is winning: copy b's run (b[t] < a[i]).
            let run = gallop_left(&b[j..], &a[i]).min(len - k);
            out[k..k + run].copy_from_slice(&b[j..j + run]);
            j += run;
            k += run;
        }
    }
    // Tails.
    if k < len && i < a.len() {
        let take = (len - k).min(a.len() - i);
        out[k..k + take].copy_from_slice(&a[i..i + take]);
        k += take;
        i += take;
    }
    if k < len && j < b.len() {
        let take = (len - k).min(b.len() - j);
        out[k..k + take].copy_from_slice(&b[j..j + take]);
        k += take;
    }
    let _ = i;
    debug_assert_eq!(k, len);
}

/// Galloping (exponential-search) merge: efficient when one input's
/// elements cluster in long runs relative to the other (e.g. merging a
/// small delta into a large sorted run — the LSM-compaction case in
/// `examples/e2e_compaction.rs`).
///
/// Falls back to element-wise behaviour (with ~2x constant) on fully
/// interleaved data, and degrades gracefully: correctness never depends
/// on the run structure.
pub fn gallop_merge_into<T: Ord + Copy>(a: &[T], b: &[T], out: &mut [T]) {
    assert_eq!(out.len(), a.len() + b.len());
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            // Gallop in A: find first index > b[j] ... (ties stay in A).
            let run = gallop_right(&a[i..], &b[j]);
            out[k..k + run].copy_from_slice(&a[i..i + run]);
            i += run;
            k += run;
        } else {
            // Gallop in B: find first index where b >= a[i] (strict loss).
            let run = gallop_left(&b[j..], &a[i]);
            out[k..k + run].copy_from_slice(&b[j..j + run]);
            j += run;
            k += run;
        }
    }
    if i < a.len() {
        out[k..].copy_from_slice(&a[i..]);
    }
    if j < b.len() {
        out[k..].copy_from_slice(&b[j..]);
    }
}

/// Length of the maximal prefix of `xs` with `xs[t] <= key`
/// (exponential probe then binary search).
#[inline]
fn gallop_right<T: Ord>(xs: &[T], key: &T) -> usize {
    // Invariant: everything < lo satisfies <= key; everything >= hi doesn't.
    if xs.is_empty() || xs[0] > *key {
        // Caller guarantees xs[0] <= key, but stay safe.
        return match xs.first() {
            None => 0,
            Some(x) if x > key => 0,
            Some(_) => 1,
        };
    }
    let mut step = 1usize;
    let mut lo = 0usize; // xs[lo] <= key known
    while lo + step < xs.len() && xs[lo + step] <= *key {
        lo += step;
        step <<= 1;
    }
    let hi = (lo + step + 1).min(xs.len());
    // Binary search in (lo, hi) for first index with xs[idx] > key.
    let mut l = lo + 1;
    let mut h = hi;
    while l < h {
        let m = l + (h - l) / 2;
        if xs[m] <= *key {
            l = m + 1;
        } else {
            h = m;
        }
    }
    l
}

/// Length of the maximal prefix of `xs` with `xs[t] < key`.
#[inline]
fn gallop_left<T: Ord>(xs: &[T], key: &T) -> usize {
    if xs.is_empty() || xs[0] >= *key {
        return 0;
    }
    let mut step = 1usize;
    let mut lo = 0usize; // xs[lo] < key known
    while lo + step < xs.len() && xs[lo + step] < *key {
        lo += step;
        step <<= 1;
    }
    let hi = (lo + step + 1).min(xs.len());
    let mut l = lo + 1;
    let mut h = hi;
    while l < h {
        let m = l + (h - l) / 2;
        if xs[m] < *key {
            l = m + 1;
        } else {
            h = m;
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort(); // stable; A elements precede equal B elements because
                  // they come first in the concatenation
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn merge_matches_oracle() {
        let mut rng = Xoshiro256::seeded(1);
        for _ in 0..100 {
            let n_a = rng.range(0, 50);
            let a = random_sorted(&mut rng, n_a, 30);
            let n_b = rng.range(0, 50);
            let b = random_sorted(&mut rng, n_b, 30);
            let mut out = vec![0i64; a.len() + b.len()];
            merge_into(&a, &b, &mut out);
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn branchless_matches_oracle() {
        let mut rng = Xoshiro256::seeded(2);
        for _ in 0..100 {
            let n_a = rng.range(0, 50);
            let a = random_sorted(&mut rng, n_a, 30);
            let n_b = rng.range(0, 50);
            let b = random_sorted(&mut rng, n_b, 30);
            let mut out = vec![0i64; a.len() + b.len()];
            branchless_merge_bounded(&a, &b, &mut out, a.len() + b.len());
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn hybrid_matches_oracle_all_shapes() {
        let mut rng = Xoshiro256::seeded(0x4B1D);
        for _ in 0..100 {
            let n_a = rng.range(0, 400);
            let a = random_sorted(&mut rng, n_a, 64);
            let n_b = rng.range(0, 400);
            let b = random_sorted(&mut rng, n_b, 64);
            let full = oracle(&a, &b);
            let mut out = vec![0i64; a.len() + b.len()];
            let n = out.len();
            hybrid_merge_bounded(&a, &b, &mut out, n);
            assert_eq!(out, full);
            // Bounded prefixes too (the parallel kernels use these).
            for len in [0, 1, full.len() / 3, full.len().saturating_sub(1)] {
                let mut out = vec![0i64; len];
                hybrid_merge_bounded(&a, &b, &mut out, len);
                assert_eq!(out[..], full[..len]);
            }
        }
    }

    #[test]
    fn hybrid_gallops_through_runs() {
        // Long one-sided runs: positions where the gallop path engages.
        let a: Vec<i64> = (0..10_000).collect();
        let b: Vec<i64> = (10_000..20_000).collect();
        let mut out = vec![0i64; 20_000];
        hybrid_merge_bounded(&a, &b, &mut out, 20_000);
        assert_eq!(out, (0..20_000).collect::<Vec<i64>>());
        // Interleaved blocks of 100.
        let a: Vec<i64> = (0..10_000).filter(|x| (x / 100) % 2 == 0).collect();
        let b: Vec<i64> = (0..10_000).filter(|x| (x / 100) % 2 == 1).collect();
        let mut out = vec![0i64; 10_000];
        hybrid_merge_bounded(&a, &b, &mut out, 10_000);
        assert_eq!(out, (0..10_000).collect::<Vec<i64>>());
    }

    #[test]
    fn gallop_matches_oracle() {
        let mut rng = Xoshiro256::seeded(3);
        for _ in 0..100 {
            let n_a = rng.range(0, 80);
            let a = random_sorted(&mut rng, n_a, 10);
            let n_b = rng.range(0, 80);
            let b = random_sorted(&mut rng, n_b, 1000);
            let mut out = vec![0i64; a.len() + b.len()];
            gallop_merge_into(&a, &b, &mut out);
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn bounded_prefix_matches_full() {
        let mut rng = Xoshiro256::seeded(4);
        for _ in 0..50 {
            let n_a = rng.range(1, 30);
            let a = random_sorted(&mut rng, n_a, 20);
            let n_b = rng.range(1, 30);
            let b = random_sorted(&mut rng, n_b, 20);
            let full = oracle(&a, &b);
            for len in 0..=(a.len() + b.len()) {
                let mut out = vec![0i64; len];
                merge_bounded(&a, &b, &mut out, len);
                assert_eq!(out[..], full[..len]);
            }
        }
    }

    #[test]
    fn empty_inputs() {
        let e: [i64; 0] = [];
        let b = [1i64, 2, 3];
        let mut out = vec![0i64; 3];
        merge_into(&e, &b, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        merge_into(&b, &e, &mut out);
        assert_eq!(out, vec![1, 2, 3]);
        let mut empty_out: Vec<i64> = vec![];
        merge_into(&e, &e, &mut empty_out);
        assert!(empty_out.is_empty());
    }

    #[test]
    fn disjoint_ranges() {
        let a = [1i64, 2, 3];
        let b = [10i64, 20, 30];
        let mut out = vec![0i64; 6];
        merge_into(&a, &b, &mut out);
        assert_eq!(out, vec![1, 2, 3, 10, 20, 30]);
        merge_into(&b, &a, &mut out);
        assert_eq!(out, vec![1, 2, 3, 10, 20, 30]);
        gallop_merge_into(&a, &b, &mut out);
        assert_eq!(out, vec![1, 2, 3, 10, 20, 30]);
    }

    #[test]
    fn stability_ties_from_a_first() {
        // Use (key, origin) pairs where Ord only inspects the key — then
        // check origins: A's copy of a tied key precedes B's.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        struct K(i64, u8);
        impl PartialOrd for K {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for K {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0)
            }
        }
        let a = [K(1, 0), K(5, 0), K(5, 0)];
        let b = [K(5, 1), K(6, 1)];
        let mut out = [K(0, 9); 5];
        merge_into(&a, &b, &mut out);
        assert_eq!(
            out.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
            vec![(1, 0), (5, 0), (5, 0), (5, 1), (6, 1)]
        );
    }

    #[test]
    fn gallop_helpers() {
        let xs = [1i64, 2, 2, 2, 5, 9];
        assert_eq!(gallop_right(&xs, &2), 4);
        assert_eq!(gallop_right(&xs, &0), 0);
        assert_eq!(gallop_right(&xs, &100), 6);
        assert_eq!(gallop_left(&xs, &2), 1);
        assert_eq!(gallop_left(&xs, &1), 0);
        assert_eq!(gallop_left(&xs, &100), 6);
    }
}
