//! `SegmentedParallelMerge` (SPM) — Algorithm 3 / §4.3, the
//! cache-efficient variant.
//!
//! The merge path is cut into segments of length `L = C/3` (`C` = cache
//! capacity in elements, Prop. 15: with ≥ 3-way associativity the three
//! live windows — of `A`, `B` and `S` — cannot conflict-miss). Segments
//! are merged **one after another**, each with all `p` cores
//! cooperating; a barrier separates consecutive segments. Lemma 16
//! bounds a length-`L` path segment by `L` consecutive elements of each
//! input, so each iteration's working set is exactly `3L` elements.
//!
//! Complexity (§4.3): work `O(N/C·p·log C + N)`, time
//! `O(N/C·(log C + C/p))` — for `p ≪ C ≪ N` this is `O(N)` / `O(N/p)`,
//! i.e. the segmentation overhead is asymptotically free while the
//! cache-miss count drops to `Θ(N)` with no inter-core line sharing
//! (Table 1).

use super::diagonal::diagonal_intersection;
use super::kernel::LeafKernel;
use super::parallel::SliceParts;
use crate::exec::{fork_join, WorkerPool};

/// Tuning for [`segmented_parallel_merge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentedConfig {
    /// Path-segment length `L` in elements (the paper's `C/3`).
    pub segment_len: usize,
    /// Number of cooperating threads per segment.
    pub threads: usize,
}

impl SegmentedConfig {
    /// Config from a cache capacity `cache_elems` (elements that fit in
    /// the target cache level) per Prop. 15: `L = C/3`.
    pub fn for_cache(cache_elems: usize, threads: usize) -> Self {
        Self {
            segment_len: (cache_elems / 3).max(1),
            threads: threads.max(1),
        }
    }

    /// Number of sequential iterations for a total output length `n`
    /// (the paper's `MAX_iterations = 3(|A|+|B|)/C`).
    pub fn iterations(&self, n: usize) -> usize {
        n.div_ceil(self.segment_len.max(1))
    }
}

/// Merge sorted `a` and `b` into `out` via Segmented Parallel Merge.
///
/// Bit-identical output to [`super::parallel::parallel_merge`] and the
/// sequential merge; only the traversal order (and hence the cache
/// behaviour) differs.
///
/// Per-segment parallelism uses scoped OS threads; inside a service
/// job, use [`segmented_parallel_merge_with_pool`] so the per-segment
/// fork-joins reuse the persistent workers instead of spawning
/// `iterations × (p − 1)` threads per job.
///
/// # Panics
/// If `out.len() != a.len() + b.len()`, or `cfg.segment_len == 0`, or
/// `cfg.threads == 0`.
pub fn segmented_parallel_merge<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    cfg: SegmentedConfig,
) {
    segmented_merge_impl(a, b, out, cfg, None, LeafKernel::hybrid());
}

/// [`segmented_parallel_merge`] with an explicit window-leaf
/// [`LeafKernel`] (resolved once by the caller from the `merge.kernel`
/// knob).
pub fn segmented_parallel_merge_kernel<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    cfg: SegmentedConfig,
    kernel: LeafKernel<T>,
) {
    segmented_merge_impl(a, b, out, cfg, None, kernel);
}

/// [`segmented_parallel_merge`] with every per-segment fork-join
/// executed on a persistent [`WorkerPool`] (identical output). Safe to
/// call from inside a pool worker: the pool's scoped wait is helping
/// (see [`WorkerPool::run_scoped`]), so the Alg 3 barrier per segment
/// cannot deadlock a saturated pool.
pub fn segmented_parallel_merge_with_pool<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    a: &[T],
    b: &[T],
    out: &mut [T],
    cfg: SegmentedConfig,
) {
    segmented_merge_impl(a, b, out, cfg, Some(pool), LeafKernel::hybrid());
}

/// [`segmented_parallel_merge_with_pool`] with an explicit window-leaf
/// [`LeafKernel`].
pub fn segmented_parallel_merge_with_pool_kernel<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    a: &[T],
    b: &[T],
    out: &mut [T],
    cfg: SegmentedConfig,
    kernel: LeafKernel<T>,
) {
    segmented_merge_impl(a, b, out, cfg, Some(pool), kernel);
}

fn segmented_merge_impl<T: Ord + Copy + Send + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    cfg: SegmentedConfig,
    pool: Option<&WorkerPool>,
    kernel: LeafKernel<T>,
) {
    assert_eq!(out.len(), a.len() + b.len());
    assert!(cfg.segment_len > 0, "segment_len must be positive");
    assert!(cfg.threads > 0, "threads must be positive");
    let n = out.len();
    let l = cfg.segment_len;
    let p = cfg.threads;

    // Global path cursor: (a0, b0) elements already consumed.
    let mut a0 = 0usize;
    let mut b0 = 0usize;
    let mut done = 0usize;

    while done < n {
        let wlen = l.min(n - done);
        // Lemma 16: this segment touches at most `wlen` consecutive
        // elements of each input, starting at the cursor.
        let a_win = &a[a0..(a0 + wlen).min(a.len())];
        let b_win = &b[b0..(b0 + wlen).min(b.len())];
        let out_seg = &mut out[done..done + wlen];

        if p == 1 || wlen < 2 * p {
            kernel.merge(a_win, b_win, out_seg, wlen);
        } else {
            // Parallel merge *within* the window: each core searches its
            // sub-diagonal of the window's (local) merge matrix and
            // merges wlen/p outputs. The fork-join (pooled or scoped) is
            // the Alg 3 barrier.
            let shared = SliceParts::new(out_seg);
            let body = |tid: usize| {
                let d_start = tid * wlen / p;
                let d_end = (tid + 1) * wlen / p;
                if d_start == d_end {
                    return;
                }
                let start = diagonal_intersection(a_win, b_win, d_start);
                // SAFETY: [d_start, d_end) windows are disjoint across tids.
                let chunk = unsafe { shared.slice_mut(d_start, d_end - d_start) };
                kernel.merge(&a_win[start.a..], &b_win[start.b..], chunk, d_end - d_start);
            };
            match pool {
                Some(pl) => pl.run_scoped(p, body),
                None => fork_join(p, body),
            }
        }

        // Advance the global cursor to the segment's end point: the
        // window-local intersection at diagonal `wlen`.
        let end = diagonal_intersection(a_win, b_win, wlen);
        a0 += end.a;
        b0 += end.b;
        done += wlen;
    }
    debug_assert_eq!(a0, a.len());
    debug_assert_eq!(b0, b.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn oracle(a: &[i64], b: &[i64]) -> Vec<i64> {
        let mut v: Vec<i64> = a.iter().chain(b.iter()).copied().collect();
        v.sort();
        v
    }

    fn random_sorted(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn matches_sequential_across_configs() {
        let mut rng = Xoshiro256::seeded(0x51_6D);
        for _ in 0..15 {
            let n_a = rng.range(0, 400);
            let a = random_sorted(&mut rng, n_a, 200);
            let n_b = rng.range(0, 400);
            let b = random_sorted(&mut rng, n_b, 200);
            let expected = oracle(&a, &b);
            for l in [1, 3, 16, 64, 1024] {
                for p in [1, 2, 4, 8] {
                    let mut out = vec![0i64; a.len() + b.len()];
                    segmented_parallel_merge(
                        &a,
                        &b,
                        &mut out,
                        SegmentedConfig { segment_len: l, threads: p },
                    );
                    assert_eq!(out, expected, "L={l} p={p}");
                }
            }
        }
    }

    #[test]
    fn segment_larger_than_input() {
        let a = [1i64, 4, 9];
        let b = [2i64, 3, 10];
        let mut out = [0i64; 6];
        segmented_parallel_merge(
            &a,
            &b,
            &mut out,
            SegmentedConfig { segment_len: 1 << 20, threads: 4 },
        );
        assert_eq!(out, [1, 2, 3, 4, 9, 10]);
    }

    #[test]
    fn one_sided_consumption_within_segment() {
        // A segment that consumes only B elements exercises the cursor
        // advance logic (the paper's LRU discussion case).
        let a: Vec<i64> = (1000..1100).collect();
        let b: Vec<i64> = (0..1000).collect();
        let expected = oracle(&a, &b);
        let mut out = vec![0i64; 1100];
        segmented_parallel_merge(
            &a,
            &b,
            &mut out,
            SegmentedConfig { segment_len: 64, threads: 4 },
        );
        assert_eq!(out, expected);
    }

    #[test]
    fn for_cache_constructor() {
        let cfg = SegmentedConfig::for_cache(3 * 1024, 8);
        assert_eq!(cfg.segment_len, 1024);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.iterations(10 * 1024), 10);
        // Degenerate cache still yields a usable config.
        let tiny = SegmentedConfig::for_cache(1, 0);
        assert_eq!(tiny.segment_len, 1);
        assert_eq!(tiny.threads, 1);
    }

    #[test]
    fn duplicates_and_ties() {
        let a = vec![7i64; 333];
        let b = vec![7i64; 334];
        let mut out = vec![0i64; 667];
        segmented_parallel_merge(
            &a,
            &b,
            &mut out,
            SegmentedConfig { segment_len: 50, threads: 3 },
        );
        assert!(out.iter().all(|&x| x == 7));
    }

    #[test]
    fn pool_variant_matches_scoped() {
        let pool = WorkerPool::new(3);
        let mut rng = Xoshiro256::seeded(0x51_6E);
        for _ in 0..8 {
            let n_a = rng.range(0, 500);
            let a = random_sorted(&mut rng, n_a, 300);
            let n_b = rng.range(0, 500);
            let b = random_sorted(&mut rng, n_b, 300);
            let cfg = SegmentedConfig { segment_len: 64, threads: 4 };
            let mut scoped = vec![0i64; a.len() + b.len()];
            segmented_parallel_merge(&a, &b, &mut scoped, cfg);
            let mut pooled = vec![0i64; a.len() + b.len()];
            segmented_parallel_merge_with_pool(&pool, &a, &b, &mut pooled, cfg);
            assert_eq!(scoped, pooled);
            assert_eq!(pooled, oracle(&a, &b));
        }
    }

    #[test]
    fn empty_inputs() {
        let e: Vec<i64> = vec![];
        let mut out: Vec<i64> = vec![];
        segmented_parallel_merge(
            &e,
            &e,
            &mut out,
            SegmentedConfig { segment_len: 8, threads: 2 },
        );
        assert!(out.is_empty());
    }

    #[test]
    fn kernel_variants_match_incl_l1_windows() {
        use super::super::kernel::{LeafKernel, MergeKernel};
        let mut rng = Xoshiro256::seeded(0x6B32);
        for _ in 0..6 {
            let n_a = rng.range(0, 300);
            let a = random_sorted(&mut rng, n_a, 16);
            let n_b = rng.range(0, 300);
            let b = random_sorted(&mut rng, n_b, 16);
            let expected = oracle(&a, &b);
            for req in [
                MergeKernel::Scalar,
                MergeKernel::Branchless,
                MergeKernel::Hybrid,
                MergeKernel::Simd,
            ] {
                let kernel = LeafKernel::<i64>::select(req);
                // L = 1 degenerates every window to a single-output
                // leaf call; larger L exercises in-window parallelism.
                for l in [1, 7, 128] {
                    for p in [1, 4] {
                        let mut out = vec![0i64; a.len() + b.len()];
                        segmented_parallel_merge_kernel(
                            &a,
                            &b,
                            &mut out,
                            SegmentedConfig { segment_len: l, threads: p },
                            kernel,
                        );
                        assert_eq!(out, expected, "req={req:?} L={l} p={p}");
                    }
                }
            }
        }
    }
}
