//! Intersection of the Merge Path with a cross diagonal (paper Alg 2).
//!
//! The *Merge Path* of sorted arrays `A`, `B` is the monotone staircase
//! walk on the `|A|×|B|` grid taken by the two-finger merge: at point
//! `(i, j)` move **down** (consume `A[i]`) if `A[i] <= B[j]`, else move
//! **right** (consume `B[j]`). (The paper states the equivalent
//! "`A[i] > B[j]` ⇒ right"; ties go to `A`, which makes the merge
//! *stable* with `A`-priority.)
//!
//! Lemma 8: the `d`-th point of the path lies on the `d`-th cross
//! diagonal `{(i, j) : i + j = d}`. Prop. 13 + Cor. 12: along a cross
//! diagonal the binary merge-matrix entries `M[i,j] = (A[i] > B[j])` are
//! monotone, so the path's crossing point is the unique `1 → 0`
//! transition and can be found by **binary search** in
//! `O(log min(|A|,|B|))` comparisons — without materialising either the
//! matrix or the path (Thm 14).

/// A point on the merge path expressed as *consumed element counts*:
/// after this point, `a` elements of `A` and `b` elements of `B` have
/// been emitted (`a + b` = output index = diagonal number).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PathPoint {
    /// Number of `A` elements consumed (row coordinate on the grid).
    pub a: usize,
    /// Number of `B` elements consumed (column coordinate on the grid).
    pub b: usize,
}

impl PathPoint {
    /// The diagonal this point lies on (= its output index).
    #[inline]
    pub fn diagonal(&self) -> usize {
        self.a + self.b
    }
}

/// Find the intersection of the Merge Path of `a`/`b` with cross
/// diagonal `diag` (Algorithm 2 of the paper, with the indexing bugs of
/// the pseudocode fixed).
///
/// Returns the unique [`PathPoint`] `(ai, bi)` with `ai + bi == diag`
/// such that the stable (`A`-priority) merge emits exactly the first
/// `ai` elements of `a` and the first `bi` elements of `b` in its first
/// `diag` outputs. Equivalently (Prop. 13): the `1→0` transition of the
/// merge matrix along the diagonal.
///
/// # Preconditions
/// `a` and `b` are sorted ascending; `diag <= a.len() + b.len()`.
/// Violations are caught in debug builds; in release the result is
/// unspecified but memory-safe.
///
/// # Complexity
/// `O(log min(diag, a.len(), b.len()))` comparisons, no allocation.
#[inline]
pub fn diagonal_intersection<T: Ord>(a: &[T], b: &[T], diag: usize) -> PathPoint {
    debug_assert!(diag <= a.len() + b.len(), "diagonal out of range");
    // Feasible range of the A-coordinate on this diagonal.
    let mut lo = diag.saturating_sub(b.len());
    let mut hi = diag.min(a.len());
    // Invariant: the answer `ai` lies in [lo, hi].
    // Predicate (monotone in mid): `A[mid]` is among the first `diag`
    // outputs ⟺ A[mid] <= B[diag - 1 - mid] (its output position is then
    // at most diag-1). While true, the split point is to the right.
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        // Safe: mid < hi <= a.len(); and mid >= lo >= diag - b.len(), so
        // diag - 1 - mid <= b.len() - 1. mid < diag because mid < hi <= diag
        // and if mid == diag then lo == hi already.
        if a[mid] <= b[diag - 1 - mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    PathPoint { a: lo, b: diag - lo }
}

/// Reference O(diag) implementation: walk the merge path step by step.
/// Used by tests and the simulator's ground-truth checks; also handy for
/// very short diagonals where the branchy binary search does not pay off.
pub fn diagonal_intersection_walk<T: Ord>(a: &[T], b: &[T], diag: usize) -> PathPoint {
    debug_assert!(diag <= a.len() + b.len(), "diagonal out of range");
    let (mut ai, mut bi) = (0usize, 0usize);
    while ai + bi < diag {
        if ai < a.len() && (bi >= b.len() || a[ai] <= b[bi]) {
            ai += 1;
        } else {
            bi += 1;
        }
    }
    PathPoint { a: ai, b: bi }
}

/// Validity check used in tests and debug assertions: `(ai, bi)` is a
/// legal split of the stable A-priority merge at output index `ai+bi`.
pub fn is_valid_split<T: Ord>(a: &[T], b: &[T], p: PathPoint) -> bool {
    let PathPoint { a: ai, b: bi } = p;
    if ai > a.len() || bi > b.len() {
        return false;
    }
    // Every consumed A element precedes every remaining B element
    // (ties allow the A element to go first):
    let cond1 = ai == 0 || bi == b.len() || a[ai - 1] <= b[bi];
    // Every consumed B element strictly precedes every remaining A
    // element (on a tie A would have been consumed first):
    let cond2 = bi == 0 || ai == a.len() || b[bi - 1] < a[ai];
    cond1 && cond2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn check_all_diagonals(a: &[i64], b: &[i64]) {
        for d in 0..=(a.len() + b.len()) {
            let fast = diagonal_intersection(a, b, d);
            let slow = diagonal_intersection_walk(a, b, d);
            assert_eq!(fast, slow, "diag {d} on a={a:?} b={b:?}");
            assert_eq!(fast.diagonal(), d);
            assert!(is_valid_split(a, b, fast));
        }
    }

    #[test]
    fn paper_figure1_example() {
        // Fig. 1 of the paper.
        let a = [17, 29, 35, 73, 86, 90, 95, 99];
        let b = [3, 5, 12, 22, 45, 64, 69, 82];
        check_all_diagonals(&a, &b);
        // Middle diagonal (d = 8): merge of the first 8 outputs is
        // [3,5,12,17,22,29,35,45] → 3 from A, 5 from B.
        let p = diagonal_intersection(&a[..], &b[..], 8);
        assert_eq!((p.a, p.b), (3, 5));
    }

    #[test]
    fn all_a_greater_than_b() {
        // The case that breaks the naive equal split (paper §1).
        let a = [100, 101, 102, 103];
        let b = [1, 2, 3, 4];
        check_all_diagonals(&a, &b);
        let p = diagonal_intersection(&a[..], &b[..], 4);
        assert_eq!((p.a, p.b), (0, 4));
    }

    #[test]
    fn empty_arrays() {
        let e: [i64; 0] = [];
        let b = [1i64, 2, 3];
        check_all_diagonals(&e, &b);
        check_all_diagonals(&b, &e);
        check_all_diagonals(&e, &e);
    }

    #[test]
    fn unequal_lengths() {
        let a = [5i64];
        let b = [1i64, 2, 3, 4, 5, 6, 7, 8, 9];
        check_all_diagonals(&a, &b);
        check_all_diagonals(&b, &a);
    }

    #[test]
    fn ties_go_to_a() {
        let a = [5i64, 5, 5];
        let b = [5i64, 5, 5];
        // First 3 outputs must all come from A (stability).
        let p = diagonal_intersection(&a[..], &b[..], 3);
        assert_eq!((p.a, p.b), (3, 0));
        check_all_diagonals(&a, &b);
    }

    #[test]
    fn all_equal_long() {
        let a = vec![7i64; 100];
        let b = vec![7i64; 57];
        check_all_diagonals(&a, &b);
    }

    #[test]
    fn random_arrays_match_walk() {
        let mut rng = Xoshiro256::seeded(0xC0FFEE);
        for trial in 0..50 {
            let la = rng.range(0, 40);
            let lb = rng.range(0, 40);
            let mut a: Vec<i64> = (0..la).map(|_| rng.below(20) as i64).collect();
            let mut b: Vec<i64> = (0..lb).map(|_| rng.below(20) as i64).collect();
            a.sort_unstable();
            b.sort_unstable();
            for d in 0..=(la + lb) {
                let fast = diagonal_intersection(&a, &b, d);
                let slow = diagonal_intersection_walk(&a, &b, d);
                assert_eq!(fast, slow, "trial {trial} diag {d}");
            }
        }
    }

    #[test]
    fn extreme_diagonals() {
        let a = [1i64, 3, 5];
        let b = [2i64, 4, 6];
        assert_eq!(diagonal_intersection(&a[..], &b[..], 0), PathPoint { a: 0, b: 0 });
        assert_eq!(
            diagonal_intersection(&a[..], &b[..], 6),
            PathPoint { a: 3, b: 3 }
        );
    }

    #[test]
    fn i32_min_max_values() {
        let a = [i64::MIN, 0, i64::MAX];
        let b = [i64::MIN, i64::MAX];
        check_all_diagonals(&a, &b);
    }
}
