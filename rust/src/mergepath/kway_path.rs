//! Flat single-pass k-way Merge Path: the §5 multiselection idea
//! generalised from two sorted sequences to `k`.
//!
//! The paper's [10] extension ([`super::select`]) finds the point of the
//! *pairwise* merge path at an arbitrary output rank. Siebert & Träff
//! ("Perfectly load-balanced, optimal, stable, parallel merge") and
//! Träff ("Simplified, stable parallel merging") show the same
//! rank-splitting idea works for `k` sequences: for a global output
//! rank `r` there is a unique *stable* cut — one position per run —
//! such that the selected elements are exactly the first `r` outputs of
//! the stable k-way merge. Computing those cuts at the `p` equispaced
//! ranks `i·N/p` yields `p` [`KwaySegment`] descriptors with the same
//! guarantees as the pairwise partition (Thm 5/9/14 generalised):
//! segments tile the output, are equisized ±1, each run is consumed in
//! `p` contiguous pieces, and every segment can be merged independently
//! with zero synchronization.
//!
//! [`parallel_kway_merge`] uses this to merge all `k` runs in **exactly
//! one pass** over memory — each of the `p` cores loser-tree-merges its
//! private per-run slices into its exclusive output window, like Alg 1.
//! This replaces the `⌈log₂ k⌉` full read+write passes of the pairwise
//! tree ([`super::kway::parallel_tree_merge`]) for the `JobKind::Compact`
//! path — exactly the memory-traffic waste §4.3 of the paper warns
//! about, paid `log k` times over by the tree.
//!
//! ## Stable merge order — a contract, not an accident
//!
//! Ties across runs resolve to the lower-indexed run, and elements
//! within a run keep their order — i.e. elements are ordered by
//! `(value, run index, index in run)`. This matches
//! [`super::kway::loser_tree_merge`] exactly, so segment merges
//! concatenate into a bit-identical result.
//!
//! This is a **guarantee** of every entry point in this module, relied
//! on by the typed-record coordinator ([`crate::record`]): when `T`
//! compares by key only (payloads invisible to `Ord`, e.g.
//! [`ByKey`](crate::record::ByKey)), equal keys keep
//! run-index-then-offset order in the output — for every partition
//! count `p`, at every rank. Concretely: [`kway_rank_split`] returns
//! the per-run prefix lengths of the first `rank` elements of exactly
//! this stable order (so its cuts nest and tile per run), and
//! [`parallel_kway_merge`] reproduces the sequential stable merge bit
//! for bit for every `p`. The property suite pins this down with
//! payload-carrying elements whose `Ord` ignores the payload
//! (`stability_ties_ordered_by_run_index`,
//! `rank_split_stability_contract_with_payloads`).
//!
//! ## Selection algorithm
//!
//! [`kway_rank_split`] maintains per-run bounds `lo[j] ≤ x_j ≤ hi[j]`
//! on the true cut `x` and repeatedly probes the middle element of the
//! widest undecided run as a pivot. One `O(k log n)` counting round
//! locates the pivot's global rank; every run then tightens toward its
//! side of the pivot (prefix property of stable merges), so the probed
//! run's interval at least halves each iteration —
//! `O(k log max|run|)` iterations of `O(k log n)` work, independent of
//! `N`. With `p` independent searches (the Alg 1 / CREW schedule) the
//! partition stage costs `O(p · k² log² n)` comparisons, vanishing
//! against the `Θ(N)` merge for any realistic compaction shape. The
//! searches are mutually independent, so
//! [`partition_kway_merge_path_with_pool`] runs them concurrently on a
//! [`WorkerPool`] — for large `p·k²log²n` the partition stage itself
//! parallelizes, exactly as Alg 1 prescribes for the pairwise case.
//!
//! The same rank-split also powers *rank-sharded compaction* in the
//! coordinator ([`crate::coordinator::shard`]): one cut per shard
//! boundary turns a giant compaction into independent, equisized
//! sub-jobs with zero inter-shard coordination.

use super::parallel::SliceParts;
use crate::exec::{fork_join, WorkerPool};
use std::ops::Range;

/// One core's share of a k-way merge: loser-tree-merge
/// `runs[j][run_ranges[j]]` for every `j` into `out[out_range]`.
/// Produced by [`partition_kway_merge_path`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KwaySegment {
    /// Sub-range of each run feeding this segment
    /// (`run_ranges.len() == k`).
    pub run_ranges: Vec<Range<usize>>,
    /// Output range;
    /// `out_range.len() == Σ run_ranges[j].len()`.
    pub out_range: Range<usize>,
}

impl KwaySegment {
    /// Number of output elements this segment produces.
    pub fn len(&self) -> usize {
        self.out_range.len()
    }

    /// True iff the segment produces no output.
    pub fn is_empty(&self) -> bool {
        self.out_range.is_empty()
    }
}

/// Multi-sequence selection: how many elements of each run belong to
/// the first `rank` outputs of the stable k-way merge (ties to the
/// lower-indexed run). Returns one cut position per run; the cuts sum
/// to `rank`.
///
/// # Panics
/// If `rank` exceeds the total input length.
pub fn kway_rank_split<T: Ord>(runs: &[&[T]], rank: usize) -> Vec<usize> {
    let k = runs.len();
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert!(rank <= total, "rank {rank} out of range (total {total})");
    // Invariant: the true cut x satisfies lo[j] <= x[j] <= hi[j] ∀j.
    let mut lo = vec![0usize; k];
    let mut hi: Vec<usize> = runs.iter().map(|r| r.len().min(rank)).collect();
    let mut before = vec![0usize; k];
    loop {
        let mut sum_lo = 0usize;
        let mut sum_hi = 0usize;
        let mut jp = usize::MAX;
        let mut widest = 0usize;
        for j in 0..k {
            sum_lo += lo[j];
            sum_hi += hi[j];
            let w = hi[j] - lo[j];
            if w > widest {
                widest = w;
                jp = j;
            }
        }
        // Either bound meeting the rank pins the whole cut (x is
        // componentwise between them and sums to `rank`).
        if sum_lo == rank {
            return lo;
        }
        if sum_hi == rank {
            return hi;
        }
        assert!(jp != usize::MAX, "selection bounds collapsed inconsistently");
        // Pivot: middle undecided element of the widest run.
        let m = lo[jp] + (hi[jp] - lo[jp] - 1) / 2;
        let pv = &runs[jp][m];
        // before[j] = elements of run j ordered strictly before the
        // pivot element under (value, run, index) order. The pivot's own
        // run contributes exactly the m elements preceding it; ties in
        // higher-priority runs (j < jp) count, ties in lower-priority
        // runs do not.
        let mut pos = 0usize; // global rank of the pivot element
        for j in 0..k {
            before[j] = if j == jp {
                m
            } else if j < jp {
                runs[j].partition_point(|x| x <= pv)
            } else {
                runs[j].partition_point(|x| x < pv)
            };
            pos += before[j];
        }
        if pos < rank {
            // Pivot is inside the first `rank` outputs — so is every
            // element ordered before it (prefix property).
            for j in 0..k {
                if j == jp {
                    lo[jp] = lo[jp].max(m + 1);
                } else {
                    lo[j] = lo[j].max(before[j].min(hi[j]));
                }
            }
        } else {
            // Pivot is outside — so is every element ordered after it.
            for j in 0..k {
                if j == jp {
                    hi[jp] = hi[jp].min(m);
                } else {
                    hi[j] = hi[j].min(before[j].max(lo[j]));
                }
            }
        }
    }
}

/// Partition the stable k-way merge of `runs` into `p` segments of
/// (near-)equal output length: segment `i` covers output ranks
/// `[i·N/p, (i+1)·N/p)` — the same balanced split as
/// [`super::partition::partition_merge_path`], lengths differing by at
/// most one.
///
/// Invariants (the k-way generalisation of Thm 5/9/14, verified by the
/// property suite):
///
/// - **tiling** — `out_range`s are contiguous and cover `[0, N)`;
/// - **equisize ±1** — every segment length is `⌊N/p⌋` or `⌈N/p⌉`;
/// - **per-run tiling** — for each run `j`, the `run_ranges[j]` of
///   consecutive segments are contiguous and cover that run;
/// - **stability** — concatenating the per-segment stable merges
///   reproduces [`super::kway::loser_tree_merge`] bit for bit.
///
/// The `p − 1` interior rank selections run sequentially here; use
/// [`partition_kway_merge_path_with_pool`] to run them concurrently on
/// a [`WorkerPool`] (they are mutually independent, CREW-style).
///
/// # Panics
/// If `p == 0`.
pub fn partition_kway_merge_path<T: Ord>(runs: &[&[T]], p: usize) -> Vec<KwaySegment> {
    assert!(p > 0, "need at least one partition");
    let n: usize = runs.iter().map(|r| r.len()).sum();
    let cuts: Vec<Vec<usize>> = (1..p).map(|i| kway_rank_split(runs, i * n / p)).collect();
    segments_from_cuts(runs, cuts, n, p)
}

/// [`partition_kway_merge_path`] with the `p − 1` interior rank
/// selections executed concurrently on `pool` (sequentially when
/// `pool` is `None` or the shape is too small to benefit).
///
/// Each output rank has a *unique* stable cut, so computing the cuts
/// independently — in any order, on any thread — yields exactly the
/// same nested sequence as the sequential loop; all documented
/// invariants carry over unchanged. Safe to call from inside a pool
/// worker: the pool's scoped wait is helping (see
/// [`WorkerPool::run_scoped`]).
///
/// # Panics
/// If `p == 0`.
pub fn partition_kway_merge_path_with_pool<T: Ord + Sync>(
    runs: &[&[T]],
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<KwaySegment> {
    assert!(p > 0, "need at least one partition");
    let n: usize = runs.iter().map(|r| r.len()).sum();
    let interior = p - 1;
    // Below 2 interior searches (or with < 2 runs, where each search
    // is a trivial prefix-sum) the scheduling overhead outweighs the
    // selection work — delegate to the sequential partition.
    let Some(pl) = pool.filter(|_| interior >= 2 && runs.len() >= 2 && n > 0) else {
        return partition_kway_merge_path(runs, p);
    };
    // The searches write disjoint k-wide windows of one flat cut
    // buffer, indexed by boundary — the crate's disjoint-window
    // shared-output pattern — so no per-cut lock or allocation is
    // needed to collect them.
    let k = runs.len();
    let mut flat = vec![0usize; interior * k];
    {
        let shared = SliceParts::new(&mut flat);
        pl.run_scoped(interior, |i| {
            let cut = kway_rank_split(runs, (i + 1) * n / p);
            // SAFETY: window [i·k, (i+1)·k) is exclusive to boundary i;
            // the windows tile the buffer and run_scoped's latch gives
            // the read below a happens-before edge on every write.
            let w = unsafe { shared.slice_mut(i * k, k) };
            w.copy_from_slice(&cut);
        });
    }
    let cuts = flat.chunks(k).map(|c| c.to_vec()).collect();
    segments_from_cuts(runs, cuts, n, p)
}

/// Assemble [`KwaySegment`]s from the `p − 1` interior cuts (the final
/// cut — the full input — needs no search).
fn segments_from_cuts<T>(
    runs: &[&[T]],
    cuts: Vec<Vec<usize>>,
    n: usize,
    p: usize,
) -> Vec<KwaySegment> {
    debug_assert_eq!(cuts.len(), p - 1);
    let mut segments = Vec::with_capacity(p);
    let mut prev = vec![0usize; runs.len()];
    let mut prev_d = 0usize;
    let full: Vec<usize> = runs.iter().map(|r| r.len()).collect();
    for (i, cut) in cuts.into_iter().chain(std::iter::once(full)).enumerate() {
        let d = (i + 1) * n / p;
        segments.push(KwaySegment {
            run_ranges: prev.iter().zip(cut.iter()).map(|(&s, &e)| s..e).collect(),
            out_range: prev_d..d,
        });
        prev = cut;
        prev_d = d;
    }
    segments
}

/// Merge `k` sorted runs into `out` in a single pass using `p` threads:
/// partition at the `p − 1` interior ranks, then every core
/// loser-tree-merges its per-run slices into its exclusive output
/// window. Output is bit-identical to
/// [`super::kway::loser_tree_merge`] over the same runs (stable, ties
/// to the lower-indexed run) for every `p`.
///
/// `pool`: optional persistent worker pool (scoped threads otherwise).
/// When a pool is given, both the partition stage (the `p − 1` rank
/// selections) and the per-segment merges run on it; the call is safe
/// from inside a pool worker (helping wait, no nested-fork-join
/// deadlock).
///
/// # Panics
/// If `out.len()` differs from the total input length or `p == 0`.
pub fn parallel_kway_merge<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    out: &mut [T],
    p: usize,
    pool: Option<&WorkerPool>,
) {
    parallel_kway_merge_with(runs, out, p, pool, super::kernel::LeafKernel::hybrid());
}

/// [`parallel_kway_merge`] with an explicit
/// [`LeafKernel`](super::kernel::LeafKernel) for the pairwise
/// (`k == 2`) leaves — both the degenerate sequential pass and every
/// per-segment merge route through
/// [`loser_tree_merge_with`](super::kway::loser_tree_merge_with), so
/// two-run jobs run on the configured kernel while true k-way shapes
/// use the tournament unchanged.
pub fn parallel_kway_merge_with<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    out: &mut [T],
    p: usize,
    pool: Option<&WorkerPool>,
    kernel: super::kernel::LeafKernel<T>,
) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(out.len(), total, "output must hold all input elements");
    assert!(p > 0);
    if total == 0 {
        return;
    }
    if p == 1 || total < 2 * p || runs.len() < 2 {
        // Degenerate shapes: one sequential pass is both correct and
        // faster than any parallel setup.
        super::kway::loser_tree_merge_with(runs, out, kernel);
        return;
    }
    let segments = partition_kway_merge_path_with_pool(runs, p, pool);
    let shared = SliceParts::new(out);
    let body = |tid: usize| {
        let seg = &segments[tid];
        if seg.is_empty() {
            return;
        }
        let parts: Vec<&[T]> = seg
            .run_ranges
            .iter()
            .zip(runs)
            .map(|(r, run)| &run[r.clone()])
            .collect();
        // SAFETY: out_ranges are disjoint across tids and tile
        // [0, total) by construction, so each thread gets an exclusive
        // window.
        let chunk = unsafe { shared.slice_mut(seg.out_range.start, seg.out_range.len()) };
        super::kway::loser_tree_merge_with(&parts, chunk, kernel);
    };
    match pool {
        Some(pl) => pl.run_scoped(p, body),
        None => fork_join(p, body),
    }
}

/// Tuning for [`segmented_kway_merge`] — the k-way generalisation of
/// [`SegmentedConfig`](super::segmented::SegmentedConfig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KwaySegmentedConfig {
    /// Output elements per path window (`L`). The Prop. 15 pick for a
    /// cache of `C` elements and `k` runs is `C/(k+1)`: the `k` live
    /// input windows plus the output window then fit together
    /// ([`KwaySegmentedConfig::for_cache`]).
    pub segment_elems: usize,
    /// Number of threads (each windows its own rank segment).
    pub threads: usize,
}

impl KwaySegmentedConfig {
    /// Config from a cache capacity of `cache_elems` elements per the
    /// k-way Prop. 15: `L = C/(k+1)`, so all `k + 1` live windows of a
    /// window iteration are cache-resident together.
    pub fn for_cache(cache_elems: usize, k: usize, threads: usize) -> Self {
        Self {
            segment_elems: (cache_elems / (k + 1).max(2)).max(1),
            threads: threads.max(1),
        }
    }

    /// Window iterations for a total output length `n` (per thread the
    /// count divides by `threads`; this is the k-way analogue of the
    /// paper's `MAX_iterations`).
    pub fn iterations(&self, n: usize) -> usize {
        n.div_ceil(self.segment_elems.max(1))
    }
}

/// Segmented (cache-efficient) flat k-way merge — §4.3's Algorithm 3
/// generalised from two runs to `k`, on top of the same balanced
/// stable-cut partition as [`parallel_kway_merge`].
///
/// The `p − 1` interior rank selections split the output into `p`
/// equisized rank segments exactly as the flat engine does; each
/// thread then walks its segment in path windows of
/// `cfg.segment_elems` outputs, merging every window with the
/// cursor-carrying bounded kernel
/// ([`loser_tree_merge_bounded`](super::kway::loser_tree_merge_bounded)).
/// The cursors left by one window *are* the stable cut where the next
/// window begins — the window-local frontier — so no further
/// [`kway_rank_split`] is ever run inside a segment. By the k-way
/// Lemma 16 a window of `L` outputs consumes at most `L` consecutive
/// elements of each run, so each iteration's working set is bounded by
/// `(k + 1)·L` elements: pick `L = C/(k+1)` ([`KwaySegmentedConfig::for_cache`])
/// and the `k` input windows and the output window stay cache-resident
/// while the bounded kernel touches each input element exactly once.
///
/// Output is **bit-identical** to
/// [`loser_tree_merge`](super::kway::loser_tree_merge) (stable:
/// equal keys keep run-index-then-offset order) for every `p` and
/// every `segment_elems` — the traversal bounds change, the merge
/// order does not. The stability contract of this module applies
/// unchanged.
///
/// `pool`: optional persistent worker pool (scoped threads otherwise);
/// safe to call from inside a pool worker (helping wait).
///
/// # Panics
/// If `out.len()` differs from the total input length,
/// `cfg.segment_elems == 0`, or `cfg.threads == 0`.
pub fn segmented_kway_merge<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    out: &mut [T],
    cfg: KwaySegmentedConfig,
    pool: Option<&WorkerPool>,
) {
    segmented_kway_merge_with(runs, out, cfg, pool, super::kernel::LeafKernel::hybrid());
}

/// [`segmented_kway_merge`] with an explicit
/// [`LeafKernel`](super::kernel::LeafKernel) for the pairwise window
/// leaves (via
/// [`loser_tree_merge_segmented_with`](super::kway::loser_tree_merge_segmented_with));
/// true k-way shapes use the bounded tournament unchanged.
pub fn segmented_kway_merge_with<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    out: &mut [T],
    cfg: KwaySegmentedConfig,
    pool: Option<&WorkerPool>,
    kernel: super::kernel::LeafKernel<T>,
) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(out.len(), total, "output must hold all input elements");
    assert!(cfg.segment_elems > 0, "segment_elems must be positive");
    assert!(cfg.threads > 0, "threads must be positive");
    if total == 0 {
        return;
    }
    let p = cfg.threads;
    if p == 1 || total < 2 * p || runs.len() < 2 {
        // Degenerate parallel shapes still merge windowed — the cache
        // bound is the point of this entry, not the thread count.
        super::kway::loser_tree_merge_segmented_with(runs, out, cfg.segment_elems, kernel);
        return;
    }
    let segments = partition_kway_merge_path_with_pool(runs, p, pool);
    let shared = SliceParts::new(out);
    let body = |tid: usize| {
        let seg = &segments[tid];
        if seg.is_empty() {
            return;
        }
        let parts: Vec<&[T]> = seg
            .run_ranges
            .iter()
            .zip(runs)
            .map(|(r, run)| &run[r.clone()])
            .collect();
        // SAFETY: out_ranges are disjoint across tids and tile
        // [0, total) by construction (same invariant as the flat
        // engine), so each thread gets an exclusive window.
        let chunk = unsafe { shared.slice_mut(seg.out_range.start, seg.out_range.len()) };
        super::kway::loser_tree_merge_segmented_with(&parts, chunk, cfg.segment_elems, kernel);
    };
    match pool {
        Some(pl) => pl.run_scoped(p, body),
        None => fork_join(p, body),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mergepath::kway::loser_tree_merge;
    use crate::rng::Xoshiro256;

    fn random_runs(rng: &mut Xoshiro256, k: usize, max_len: usize) -> Vec<Vec<i64>> {
        (0..k)
            .map(|_| {
                let n = rng.range(0, max_len.max(1));
                let mut v: Vec<i64> = (0..n).map(|_| rng.below(400) as i64).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    fn oracle(runs: &[Vec<i64>]) -> Vec<i64> {
        let mut v: Vec<i64> = runs.iter().flatten().copied().collect();
        v.sort();
        v
    }

    fn refs(runs: &[Vec<i64>]) -> Vec<&[i64]> {
        runs.iter().map(|r| r.as_slice()).collect()
    }

    /// The k-way analogue of `partition.rs::check_partition`: tiling,
    /// equisize ±1, per-run tiling, and concatenation == sequential.
    fn check_partition(runs: &[Vec<i64>], p: usize) {
        let refs = refs(runs);
        let k = refs.len();
        let n: usize = refs.iter().map(|r| r.len()).sum();
        let segs = partition_kway_merge_path(&refs, p);
        assert_eq!(segs.len(), p);

        // 1. Segments tile the output exactly and are equisized ±1.
        let (min_len, max_len) = (n / p, n.div_ceil(p));
        let mut at = 0usize;
        for (i, s) in segs.iter().enumerate() {
            assert_eq!(s.out_range.start, at, "segment {i} not contiguous");
            assert_eq!(s.run_ranges.len(), k);
            assert_eq!(
                s.out_range.len(),
                s.run_ranges.iter().map(|r| r.len()).sum::<usize>(),
                "segment {i} length inconsistent"
            );
            assert!(
                (min_len..=max_len).contains(&s.out_range.len()),
                "segment {i} len {} outside [{min_len}, {max_len}]",
                s.out_range.len()
            );
            at = s.out_range.end;
        }
        assert_eq!(at, n);

        // 2. Each run's ranges tile that run.
        for j in 0..k {
            assert_eq!(segs.first().unwrap().run_ranges[j].start, 0);
            assert_eq!(segs.last().unwrap().run_ranges[j].end, refs[j].len());
            for w in segs.windows(2) {
                assert_eq!(w[0].run_ranges[j].end, w[1].run_ranges[j].start);
            }
        }

        // 3. Merging each segment independently and concatenating equals
        //    the sequential k-way merge.
        let mut expected = vec![0i64; n];
        loser_tree_merge(&refs, &mut expected);
        assert_eq!(expected, oracle(runs));
        let mut got = vec![0i64; n];
        for s in &segs {
            let parts: Vec<&[i64]> = s
                .run_ranges
                .iter()
                .zip(&refs)
                .map(|(r, run)| &run[r.clone()])
                .collect();
            loser_tree_merge(&parts, &mut got[s.out_range.clone()]);
        }
        assert_eq!(got, expected);
    }

    #[test]
    fn rank_split_explicit_example() {
        let a: Vec<i64> = vec![1, 4, 7];
        let b: Vec<i64> = vec![2, 4, 9];
        let c: Vec<i64> = vec![4, 4];
        let runs: Vec<&[i64]> = vec![&a, &b, &c];
        // Stable order: 1a 2b 4a 4b 4c 4c 7a 9b.
        assert_eq!(kway_rank_split(&runs, 0), vec![0, 0, 0]);
        assert_eq!(kway_rank_split(&runs, 2), vec![1, 1, 0]);
        assert_eq!(kway_rank_split(&runs, 3), vec![2, 1, 0]);
        assert_eq!(kway_rank_split(&runs, 4), vec![2, 2, 0]);
        assert_eq!(kway_rank_split(&runs, 5), vec![2, 2, 1]);
        assert_eq!(kway_rank_split(&runs, 6), vec![2, 2, 2]);
        assert_eq!(kway_rank_split(&runs, 8), vec![3, 3, 2]);
    }

    #[test]
    fn rank_split_sums_and_nests() {
        let mut rng = Xoshiro256::seeded(0x6B01);
        for _ in 0..20 {
            let k = rng.range(1, 9);
            let runs = random_runs(&mut rng, k, 50);
            let rr = refs(&runs);
            let n: usize = rr.iter().map(|r| r.len()).sum();
            let mut prev = vec![0usize; k];
            for rank in 0..=n {
                let cut = kway_rank_split(&rr, rank);
                assert_eq!(cut.iter().sum::<usize>(), rank);
                for j in 0..k {
                    assert!(cut[j] >= prev[j], "cuts must be nested");
                    assert!(cut[j] <= rr[j].len());
                }
                prev = cut;
            }
        }
    }

    #[test]
    fn partition_random_shapes() {
        let mut rng = Xoshiro256::seeded(0x6B02);
        for _ in 0..25 {
            let k = rng.range(0, 10);
            let runs = random_runs(&mut rng, k, 80);
            for p in [1, 2, 3, 5, 8, 13] {
                check_partition(&runs, p);
            }
        }
    }

    #[test]
    fn partition_edge_shapes() {
        // Empty run set, all-empty runs, single run, more parts than
        // elements.
        check_partition(&[], 4);
        check_partition(&[vec![], vec![], vec![]], 3);
        check_partition(&[(0..100).collect::<Vec<i64>>()], 7);
        check_partition(&[vec![1i64], vec![2i64], vec![3i64]], 10);
    }

    #[test]
    fn partition_heavy_duplicates() {
        let runs: Vec<Vec<i64>> = (0..6).map(|_| vec![5i64; 40]).collect();
        for p in [2, 4, 7] {
            check_partition(&runs, p);
        }
        // Duplicates split across runs with distinct fills.
        let runs = vec![vec![5i64; 30], vec![3i64; 10], vec![5i64; 25], vec![7i64; 5]];
        check_partition(&runs, 8);
    }

    #[test]
    fn partition_one_sided_runs() {
        // Disjoint value ranges: the naive-split killer, k-way version.
        let runs: Vec<Vec<i64>> = (0..5)
            .map(|i| ((i * 1000)..(i * 1000 + 128)).collect())
            .collect();
        check_partition(&runs, 8);
        let rev: Vec<Vec<i64>> = runs.into_iter().rev().collect();
        check_partition(&rev, 8);
    }

    #[test]
    fn parallel_matches_loser_tree_all_p() {
        let mut rng = Xoshiro256::seeded(0x6B03);
        for _ in 0..15 {
            let k = rng.range(0, 12);
            let runs = random_runs(&mut rng, k, 120);
            let rr = refs(&runs);
            let n: usize = rr.iter().map(|r| r.len()).sum();
            let mut expected = vec![0i64; n];
            loser_tree_merge(&rr, &mut expected);
            for p in [1, 2, 3, 4, 8, 16, 33] {
                let mut out = vec![0i64; n];
                parallel_kway_merge(&rr, &mut out, p, None);
                assert_eq!(out, expected, "k={k} p={p}");
            }
        }
    }

    #[test]
    fn parallel_k_exceeding_p() {
        let mut rng = Xoshiro256::seeded(0x6B04);
        let runs = random_runs(&mut rng, 64, 60);
        let rr = refs(&runs);
        let n: usize = rr.iter().map(|r| r.len()).sum();
        let mut out = vec![0i64; n];
        parallel_kway_merge(&rr, &mut out, 4, None);
        assert_eq!(out, oracle(&runs));
    }

    #[test]
    fn pooled_partition_matches_sequential() {
        // The pooled partition must produce byte-identical segments to
        // the sequential loop for every (k, p) — the cuts are unique,
        // so only the schedule differs.
        let pool = WorkerPool::new(3);
        let mut rng = Xoshiro256::seeded(0x6B06);
        for _ in 0..10 {
            let k = rng.range(0, 10);
            let runs = random_runs(&mut rng, k, 90);
            let rr = refs(&runs);
            // High p included: the disjoint-window cut collection must
            // stay byte-identical when boundaries outnumber both the
            // workers and the elements.
            for p in [1, 2, 3, 5, 9, 16, 64, 257] {
                let seq = partition_kway_merge_path(&rr, p);
                let pooled = partition_kway_merge_path_with_pool(&rr, p, Some(&pool));
                assert_eq!(seq, pooled, "k={k} p={p}");
                let unpooled = partition_kway_merge_path_with_pool(&rr, p, None);
                assert_eq!(seq, unpooled, "k={k} p={p} (no pool)");
            }
        }
    }

    #[test]
    fn parallel_with_pool() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0x6B05);
        let runs = random_runs(&mut rng, 9, 300);
        let rr = refs(&runs);
        let n: usize = rr.iter().map(|r| r.len()).sum();
        let mut out = vec![0i64; n];
        parallel_kway_merge(&rr, &mut out, 4, Some(&pool));
        assert_eq!(out, oracle(&runs));
    }

    #[test]
    fn segmented_kway_bit_identical_across_property_sweep() {
        // The acceptance sweep: every workload kind × k × p × segment
        // length (dense duplicates included via Skewed and the
        // dedicated case below) must reproduce loser_tree_merge bit
        // for bit — including L = 1 and window-larger-than-input.
        use crate::bench::workload::{gen_sorted_runs, WorkloadKind};
        for (w, kind) in WorkloadKind::all().iter().enumerate() {
            for &k in &[2usize, 3, 9, 17] {
                let runs = gen_sorted_runs(*kind, k, 400, 0x5E6 + w as u64);
                let refs: Vec<&[i32]> = runs.iter().map(|r| r.as_slice()).collect();
                let n: usize = refs.iter().map(|r| r.len()).sum();
                let mut expected = vec![0i32; n];
                loser_tree_merge(&refs, &mut expected);
                for &p in &[1usize, 2, 5, 8] {
                    for &l in &[1usize, 13, 256, 1 << 20] {
                        let mut out = vec![0i32; n];
                        segmented_kway_merge(
                            &refs,
                            &mut out,
                            KwaySegmentedConfig { segment_elems: l, threads: p },
                            None,
                        );
                        assert_eq!(out, expected, "{kind:?} k={k} p={p} L={l}");
                    }
                }
            }
        }
    }

    #[test]
    fn segmented_kway_dense_duplicates_keep_provenance() {
        // All-identical keys with key-only Ord: window and segment
        // boundaries all land inside one giant tie group, so any
        // ordering mixup is visible in the payloads.
        use crate::record::{as_keyed, into_records, ByKey};
        let runs: Vec<Vec<(i64, u32)>> = (0..5u32)
            .map(|run| (0..200u32).map(|off| (7i64, run * 1000 + off)).collect())
            .collect();
        let keyed: Vec<&[ByKey<(i64, u32)>]> =
            runs.iter().map(|r| as_keyed(r.as_slice())).collect();
        let expected: Vec<(i64, u32)> = runs.iter().flatten().copied().collect();
        for &p in &[1usize, 3, 8] {
            for &l in &[1usize, 7, 64] {
                let mut out = vec![ByKey((0i64, 0u32)); 1000];
                segmented_kway_merge(
                    &keyed,
                    &mut out,
                    KwaySegmentedConfig { segment_elems: l, threads: p },
                    None,
                );
                assert_eq!(into_records(out), expected, "p={p} L={l}");
            }
        }
    }

    #[test]
    fn segmented_kway_with_pool_and_empty_runs() {
        let pool = WorkerPool::new(3);
        let mut rng = Xoshiro256::seeded(0x6B07);
        let mut runs = random_runs(&mut rng, 7, 200);
        runs.insert(2, vec![]);
        runs.push(vec![]);
        let rr = refs(&runs);
        let n: usize = rr.iter().map(|r| r.len()).sum();
        let mut expected = vec![0i64; n];
        loser_tree_merge(&rr, &mut expected);
        let mut out = vec![0i64; n];
        segmented_kway_merge(
            &rr,
            &mut out,
            KwaySegmentedConfig { segment_elems: 37, threads: 4 },
            Some(&pool),
        );
        assert_eq!(out, expected);
        // Degenerate shapes: no runs / all-empty runs.
        let mut empty: Vec<i64> = vec![];
        segmented_kway_merge(
            &[],
            &mut empty,
            KwaySegmentedConfig { segment_elems: 8, threads: 2 },
            None,
        );
        let e: Vec<i64> = vec![];
        segmented_kway_merge(
            &[&e, &e],
            &mut empty,
            KwaySegmentedConfig { segment_elems: 8, threads: 2 },
            Some(&pool),
        );
    }

    #[test]
    fn kway_segmented_config_for_cache() {
        // L = C/(k+1), floored at 1; thread floor at 1.
        let cfg = KwaySegmentedConfig::for_cache(12_000, 5, 8);
        assert_eq!(cfg.segment_elems, 2000);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.iterations(10_000), 5);
        let tiny = KwaySegmentedConfig::for_cache(1, 100, 0);
        assert_eq!(tiny.segment_elems, 1);
        assert_eq!(tiny.threads, 1);
        // k = 0/1 still sizes sanely (divisor floored at 2).
        assert_eq!(KwaySegmentedConfig::for_cache(600, 0, 1).segment_elems, 300);
    }

    #[test]
    fn rank_split_stability_contract_with_payloads() {
        // The stability contract at the selection level: with key-only
        // ordering ([`crate::record::ByKey`]) over (key, payload)
        // records carrying dense duplicate keys, the cut at every rank
        // selects exactly the first `rank` elements of the stable
        // (key, run, offset) order — the property the typed coordinator
        // (eager streaming, rank sharding) builds on.
        use crate::record::{as_keyed, into_records, ByKey};
        let runs: Vec<Vec<(i64, u32)>> = (0..4)
            .map(|run| {
                (0..50u32)
                    .map(|off| ((off / 10) as i64, run * 100 + off))
                    .collect()
            })
            .collect();
        let keyed: Vec<&[ByKey<(i64, u32)>]> =
            runs.iter().map(|r| as_keyed(r.as_slice())).collect();
        // Stable oracle: flatten in run order (offsets already
        // ascending), then stable-sort by key.
        let mut expected: Vec<(i64, u32)> = runs.iter().flatten().copied().collect();
        expected.sort_by_key(|r| r.0);
        for p in [1, 2, 3, 7] {
            let mut out = vec![ByKey((0i64, 0u32)); 200];
            parallel_kway_merge(&keyed, &mut out, p, None);
            assert_eq!(into_records(out), expected, "p={p}");
        }
        for rank in [0usize, 1, 37, 100, 123, 199, 200] {
            let cut = kway_rank_split(&keyed, rank);
            assert_eq!(cut.iter().sum::<usize>(), rank);
            // The selected per-run prefixes, replayed through the
            // stable order, are exactly the first `rank` outputs.
            let mut selected: Vec<(i64, u32)> = Vec::with_capacity(rank);
            for (j, &c) in cut.iter().enumerate() {
                selected.extend_from_slice(&runs[j][..c]);
            }
            selected.sort_by_key(|r| r.0); // stable
            assert_eq!(selected, expected[..rank], "rank={rank}");
        }
    }

    #[test]
    fn stability_ties_ordered_by_run_index() {
        // (key, origin) pairs where Ord only inspects the key; the flat
        // engine must order tied keys by run index, like the loser tree.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        struct K(i64, u8);
        impl PartialOrd for K {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for K {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0)
            }
        }
        let r0: Vec<K> = (0..40i64).map(|i| K(i / 4, 0)).collect();
        let r1: Vec<K> = (0..40i64).map(|i| K(i / 4, 1)).collect();
        let r2: Vec<K> = (0..40i64).map(|i| K(i / 4, 2)).collect();
        let rr: Vec<&[K]> = vec![&r0, &r1, &r2];
        let mut expected = vec![K(0, 9); 120];
        loser_tree_merge(&rr, &mut expected);
        for p in [2, 5, 8] {
            let mut out = vec![K(0, 9); 120];
            parallel_kway_merge(&rr, &mut out, p, None);
            assert_eq!(
                out.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
                expected.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
                "p={p}"
            );
        }
    }
}
