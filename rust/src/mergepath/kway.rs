//! k-way merging of sorted runs — the LSM-compaction primitive built
//! on the paper's pairwise Merge Path.
//!
//! Engines:
//! - [`loser_tree_merge`] — sequential tournament merge: linear argmin
//!   for small `k`, binary min-heap beyond — `O(N log k)` comparisons
//!   in one pass; the baseline and the small-job fast path.
//! - [`loser_tree_merge_bounded`] — the *cursor-carrying, bounded*
//!   kernel behind §4.3's windowing generalised to `k` runs
//!   ([`super::kway_path::segmented_kway_merge`]): merges exactly
//!   `out.len()` elements starting from per-run cursors and advances
//!   them, keeping the current head **values** in a thread-local array
//!   so each input element is touched exactly once (the argmin engine
//!   above re-touches every run head per output — fine while the
//!   `k + 1` live lines fit in cache, ruinous past it).
//! - [`parallel_tree_merge`] — a balanced binary tree of pairwise
//!   [`parallel_merge`](super::parallel::parallel_merge) rounds:
//!   `⌈log₂ k⌉` fully-parallel levels, `O(N log k)` work,
//!   `O(N/p·log k + log N·log k)` time. Every level's pairwise merges
//!   are Merge-Path partitioned, so load balance is exact at every
//!   level (Cor. 7 applied per pair).
//!
//! The tree makes `⌈log₂ k⌉` full passes over memory; the flat
//! single-pass engine in [`super::kway_path`] avoids that and is the
//! coordinator's default for moderate `k`. The tree remains as the
//! large-`k` fallback and as the oracle the flat engine is benchmarked
//! against (`benches/kway_flat_vs_tree.rs`).

use super::diagonal::diagonal_intersection;
use super::kernel::LeafKernel;
use crate::exec::WorkerPool;

/// Sequential k-way tournament merge (linear argmin for `k ≤ 16`,
/// binary heap beyond). `out.len()` must equal the total input length.
/// Stable across runs: ties resolve to the lower-indexed run.
pub fn loser_tree_merge<T: Ord + Copy>(runs: &[&[T]], out: &mut [T]) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(out.len(), total, "output must hold all input elements");
    let k = runs.len();
    if k == 0 {
        return;
    }
    if k == 1 {
        out.copy_from_slice(runs[0]);
        return;
    }
    // Cursor per run; `None` key = exhausted (sorts after everything).
    let mut cursors = vec![0usize; k];
    let key = |runs: &[&[T]], cursors: &[usize], i: usize| -> Option<T> {
        runs[i].get(cursors[i]).copied()
    };
    // Simple binary-heap-free tournament over a power-of-two bracket.
    // For the k in compaction workloads (≤ 64) a linear argmin is
    // competitive and far simpler; measured equivalent for k ≤ 16 and
    // within 20% at k = 64, so the tree is only engaged for larger k.
    if k <= 16 {
        for slot in out.iter_mut() {
            let mut best = usize::MAX;
            let mut best_key: Option<T> = None;
            for i in 0..k {
                if let Some(v) = key(runs, &cursors, i) {
                    let better = match best_key {
                        Some(b) => v < b,
                        None => true,
                    };
                    if better {
                        best = i;
                        best_key = Some(v);
                    }
                }
            }
            *slot = best_key.expect("output longer than inputs");
            cursors[best] += 1;
        }
        return;
    }
    // Large k: binary min-heap of (head key, run index) — `O(N log k)`
    // comparisons, ties resolved by run index (stability).
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(T, usize)>> = BinaryHeap::with_capacity(k);
    for i in 0..k {
        if let Some(v) = key(runs, &cursors, i) {
            heap.push(Reverse((v, i)));
        }
    }
    for slot in out.iter_mut() {
        let Reverse((v, i)) = heap.pop().expect("output longer than inputs");
        *slot = v;
        cursors[i] += 1;
        if let Some(nv) = key(runs, &cursors, i) {
            heap.push(Reverse((nv, i)));
        }
    }
}

/// [`loser_tree_merge`] with an explicit [`LeafKernel`] for the
/// pairwise case: at `k == 2` the tournament is just a two-way merge,
/// and the tournament's tie rule (lower run index wins) coincides with
/// the kernel contract's A-priority — so the configured leaf kernel
/// can serve the whole merge, bit-identically. Other `k` delegate to
/// [`loser_tree_merge`] unchanged.
pub fn loser_tree_merge_with<T: Ord + Copy>(
    runs: &[&[T]],
    out: &mut [T],
    kernel: LeafKernel<T>,
) {
    if runs.len() == 2 {
        let total = runs[0].len() + runs[1].len();
        assert_eq!(out.len(), total, "output must hold all input elements");
        kernel.merge(runs[0], runs[1], out, total);
        return;
    }
    loser_tree_merge(runs, out);
}

/// Cursor-carrying bounded k-way merge: emit exactly `out.len()`
/// elements of the stable merge of `runs` (ties to the lower-indexed
/// run, offsets in order — the same `(value, run, index)` order as
/// [`loser_tree_merge`]) starting at `cursors`, and advance `cursors`
/// to the consumed positions.
///
/// Splitting one merge into consecutive bounded calls over the same
/// cursor state reproduces the unsplit merge bit for bit — the cursors
/// are the *whole* state of the stable merge, which is what lets
/// [`super::kway_path::segmented_kway_merge`] advance window by window
/// via this local frontier instead of re-running a global
/// [`kway_rank_split`](super::kway_path::kway_rank_split) per window.
///
/// Unlike the argmin loop of [`loser_tree_merge`], the current head of
/// every run is cached *by value* in a local array (small `k`) or heap
/// (large `k`), so each input element is read from its run exactly
/// once — together with the §4.3 window bound (a length-`L` output
/// window consumes at most `L` consecutive elements of each run, the
/// k-way Lemma 16) this keeps the working set of a window at
/// `(k + 1)·L` elements.
///
/// # Panics
/// If `cursors.len() != runs.len()`, any cursor is past its run's end,
/// or `out` wants more elements than remain.
pub fn loser_tree_merge_bounded<T: Ord + Copy>(
    runs: &[&[T]],
    cursors: &mut [usize],
    out: &mut [T],
) {
    let k = runs.len();
    assert_eq!(cursors.len(), k, "one cursor per run");
    let remaining: usize = runs
        .iter()
        .zip(cursors.iter())
        .map(|(r, &c)| {
            assert!(c <= r.len(), "cursor {c} past run end {}", r.len());
            r.len() - c
        })
        .sum();
    assert!(
        out.len() <= remaining,
        "bounded merge wants {} of {remaining} remaining elements",
        out.len()
    );
    if out.is_empty() {
        return;
    }
    if k == 1 {
        let c = cursors[0];
        out.copy_from_slice(&runs[0][c..c + out.len()]);
        cursors[0] += out.len();
        return;
    }
    if k <= 16 {
        let mut heads = fill_heads(runs, cursors);
        argmin_bounded(runs, cursors, &mut heads, out);
        return;
    }
    let mut heap = fill_heap(runs, cursors);
    heap_bounded(runs, cursors, &mut heap, out);
}

/// Current head value of every run (`None` = exhausted) — the state
/// the bounded argmin kernel advances.
fn fill_heads<T: Ord + Copy>(runs: &[&[T]], cursors: &[usize]) -> Vec<Option<T>> {
    runs.iter()
        .zip(cursors.iter())
        .map(|(r, &c)| r.get(c).copied())
        .collect()
}

/// Cached-heads argmin: same selection rule as [`loser_tree_merge`]
/// (first strictly-smaller head wins, so equal keys keep the lower run
/// index), but heads live in the caller-provided array and a run is
/// re-read only when its head is consumed.
fn argmin_bounded<T: Ord + Copy>(
    runs: &[&[T]],
    cursors: &mut [usize],
    heads: &mut [Option<T>],
    out: &mut [T],
) {
    for slot in out.iter_mut() {
        let mut best = usize::MAX;
        let mut best_key: Option<T> = None;
        for (j, head) in heads.iter().enumerate() {
            if let Some(v) = head {
                let better = match best_key {
                    Some(b) => *v < b,
                    None => true,
                };
                if better {
                    best = j;
                    best_key = Some(*v);
                }
            }
        }
        *slot = best_key.expect("out longer than remaining input");
        cursors[best] += 1;
        heads[best] = runs[best].get(cursors[best]).copied();
    }
}

type HeadHeap<T> = std::collections::BinaryHeap<std::cmp::Reverse<(T, usize)>>;

/// Min-heap of `(head key, run index)` over the runs' current heads —
/// ties resolve by run index, matching [`loser_tree_merge`] exactly.
fn fill_heap<T: Ord + Copy>(runs: &[&[T]], cursors: &[usize]) -> HeadHeap<T> {
    let mut heap = HeadHeap::with_capacity(runs.len());
    for (j, (r, &c)) in runs.iter().zip(cursors.iter()).enumerate() {
        if let Some(v) = r.get(c) {
            heap.push(std::cmp::Reverse((*v, j)));
        }
    }
    heap
}

/// Large-k bounded merge over a caller-provided head heap.
fn heap_bounded<T: Ord + Copy>(
    runs: &[&[T]],
    cursors: &mut [usize],
    heap: &mut HeadHeap<T>,
    out: &mut [T],
) {
    for slot in out.iter_mut() {
        let std::cmp::Reverse((v, j)) = heap.pop().expect("out longer than remaining input");
        *slot = v;
        cursors[j] += 1;
        if let Some(nv) = runs[j].get(cursors[j]) {
            heap.push(std::cmp::Reverse((*nv, j)));
        }
    }
}

/// Sequential windowed k-way merge: the whole merge executed as
/// consecutive [`loser_tree_merge_bounded`] windows of `segment_elems`
/// outputs each, so the live working set stays at `(k + 1)` windows
/// (§4.3 generalised — see
/// [`super::kway_path::segmented_kway_merge`]). `segment_elems == 0`
/// means unwindowed: delegate to [`loser_tree_merge`].
///
/// Output is bit-identical to [`loser_tree_merge`] for every
/// `segment_elems`. This is the per-shard kernel of the rank-sharded
/// and streamed compaction routes when segmented merging is enabled.
///
/// The per-run head state (value array / heap) is built once and
/// carried across windows — the hot loop allocates nothing per window,
/// so even the `L = 1` degenerate costs only the loop bound.
pub fn loser_tree_merge_segmented<T: Ord + Copy>(
    runs: &[&[T]],
    out: &mut [T],
    segment_elems: usize,
) {
    let k = runs.len();
    if segment_elems == 0 || k <= 1 {
        // Unwindowed delegate (0) or shapes with nothing to window.
        loser_tree_merge(runs, out);
        return;
    }
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(out.len(), total, "output must hold all input elements");
    let mut cursors = vec![0usize; k];
    let mut done = 0usize;
    if k <= 16 {
        let mut heads = fill_heads(runs, &cursors);
        while done < total {
            let wlen = segment_elems.min(total - done);
            argmin_bounded(runs, &mut cursors, &mut heads, &mut out[done..done + wlen]);
            done += wlen;
        }
    } else {
        let mut heap = fill_heap(runs, &cursors);
        while done < total {
            let wlen = segment_elems.min(total - done);
            heap_bounded(runs, &mut cursors, &mut heap, &mut out[done..done + wlen]);
            done += wlen;
        }
    }
}

/// [`loser_tree_merge_segmented`] with an explicit [`LeafKernel`] for
/// the pairwise case: at `k == 2` each output window is a two-way
/// window merge under the Alg 3 cursor walk (bit-identical to the
/// tournament — same tie rule, see [`loser_tree_merge_with`]), so the
/// window leaves run on the configured kernel. Other `k` delegate to
/// [`loser_tree_merge_segmented`] unchanged.
pub fn loser_tree_merge_segmented_with<T: Ord + Copy>(
    runs: &[&[T]],
    out: &mut [T],
    segment_elems: usize,
    kernel: LeafKernel<T>,
) {
    if runs.len() != 2 {
        loser_tree_merge_segmented(runs, out, segment_elems);
        return;
    }
    let (a, b) = (runs[0], runs[1]);
    let total = a.len() + b.len();
    assert_eq!(out.len(), total, "output must hold all input elements");
    if segment_elems == 0 {
        kernel.merge(a, b, out, total);
        return;
    }
    // Serial Alg 3 walk: merge one `segment_elems`-output window at a
    // time; Lemma 16 bounds each window's inputs to `wlen` consecutive
    // elements of each run starting at the cursor.
    let mut a0 = 0usize;
    let mut b0 = 0usize;
    let mut done = 0usize;
    while done < total {
        let wlen = segment_elems.min(total - done);
        let a_win = &a[a0..(a0 + wlen).min(a.len())];
        let b_win = &b[b0..(b0 + wlen).min(b.len())];
        kernel.merge(a_win, b_win, &mut out[done..done + wlen], wlen);
        let end = diagonal_intersection(a_win, b_win, wlen);
        a0 += end.a;
        b0 += end.b;
        done += wlen;
    }
    debug_assert_eq!(a0, a.len());
    debug_assert_eq!(b0, b.len());
}

/// One tree-level pair merge into a freshly allocated buffer, routed
/// through the pool when one is provided. Shared by both tree entry
/// points so the uninit-buffer handling lives in exactly one place.
fn merge_pair<T: Ord + Copy + Send + Sync>(
    x: &[T],
    y: &[T],
    p: usize,
    pool: Option<&WorkerPool>,
    kernel: LeafKernel<T>,
) -> Vec<T> {
    // Fully overwritten by the merge below (see crate::uninit_vec).
    let mut out = crate::uninit_vec(x.len() + y.len());
    match pool {
        Some(pl) => {
            super::parallel::parallel_merge_with_pool_kernel(pl, x, y, &mut out, p, kernel)
        }
        None => super::parallel::parallel_merge_kernel(x, y, &mut out, p, kernel),
    }
    out
}

/// Parallel k-way merge: balanced tree of pairwise Merge-Path merges.
/// Consumes the runs, freeing each buffer as its first-round merge
/// completes — the coordinator's large-`k` fallback. `pool`: optional
/// persistent worker pool (spawns scoped threads otherwise). Returns
/// the merged vector.
pub fn parallel_tree_merge<T: Ord + Copy + Send + Sync>(
    runs: Vec<Vec<T>>,
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<T> {
    parallel_tree_merge_kernel(runs, p, pool, LeafKernel::hybrid())
}

/// [`parallel_tree_merge`] with an explicit [`LeafKernel`] threaded
/// into every pairwise level's per-segment leaves.
pub fn parallel_tree_merge_kernel<T: Ord + Copy + Send + Sync>(
    mut runs: Vec<Vec<T>>,
    p: usize,
    pool: Option<&WorkerPool>,
    kernel: LeafKernel<T>,
) -> Vec<T> {
    assert!(p > 0);
    runs.retain(|r| !r.is_empty());
    if runs.is_empty() {
        return vec![];
    }
    while runs.len() > 1 {
        let mut next = Vec::with_capacity(runs.len().div_ceil(2));
        let mut it = runs.into_iter();
        while let Some(x) = it.next() {
            match it.next() {
                Some(y) => next.push(merge_pair(&x, &y, p, pool, kernel)),
                None => next.push(x),
            }
        }
        runs = next;
    }
    runs.pop().unwrap()
}

/// Tree merge starting from *borrowed* runs: the first round merges
/// pairs of input slices into freshly allocated buffers (work any tree
/// engine must do anyway), then [`parallel_tree_merge`] consumes the
/// intermediates. For callers that only hold `&[&[T]]` — the
/// flat-vs-tree bench and other oracle comparisons. (The coordinator's
/// large-`k` fallback uses the owning [`parallel_tree_merge`] instead,
/// which can free run buffers progressively.)
pub fn parallel_tree_merge_refs<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<T> {
    assert!(p > 0);
    let runs: Vec<&[T]> = runs.iter().copied().filter(|r| !r.is_empty()).collect();
    if runs.is_empty() {
        return vec![];
    }
    if runs.len() == 1 {
        return runs[0].to_vec();
    }
    let mut next: Vec<Vec<T>> = Vec::with_capacity(runs.len().div_ceil(2));
    for pair in runs.chunks(2) {
        match pair {
            [single] => next.push(single.to_vec()),
            _ => next.push(merge_pair(pair[0], pair[1], p, pool, LeafKernel::hybrid())),
        }
    }
    parallel_tree_merge(next, p, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_runs(rng: &mut Xoshiro256, k: usize, max_len: usize) -> Vec<Vec<i64>> {
        (0..k)
            .map(|_| {
                let n = rng.range(0, max_len.max(1));
                let mut v: Vec<i64> = (0..n).map(|_| rng.below(500) as i64).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    fn oracle(runs: &[Vec<i64>]) -> Vec<i64> {
        let mut v: Vec<i64> = runs.iter().flatten().copied().collect();
        v.sort();
        v
    }

    #[test]
    fn loser_tree_small_k() {
        let mut rng = Xoshiro256::seeded(0x4B);
        for _ in 0..30 {
            let k = rng.range(1, 9);
            let runs = random_runs(&mut rng, k, 60);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut out = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut out);
            assert_eq!(out, oracle(&runs));
        }
    }

    #[test]
    fn loser_tree_large_k() {
        let mut rng = Xoshiro256::seeded(0x4C);
        for k in [17, 33, 64] {
            let runs = random_runs(&mut rng, k, 40);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut out = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut out);
            assert_eq!(out, oracle(&runs), "k={k}");
        }
    }

    #[test]
    fn loser_tree_edges() {
        let mut out: Vec<i64> = vec![];
        loser_tree_merge(&[], &mut out);
        let one = vec![1i64, 5, 9];
        let mut out = vec![0i64; 3];
        loser_tree_merge(&[&one], &mut out);
        assert_eq!(out, one);
        // Empty runs mixed in.
        let e: Vec<i64> = vec![];
        let a = vec![2i64, 4];
        let b = vec![1i64, 3];
        let mut out = vec![0i64; 4];
        loser_tree_merge(&[&e, &a, &e, &b, &e], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn bounded_windows_reproduce_full_merge() {
        // Splitting the merge into arbitrary bounded windows over one
        // cursor state must reproduce the one-shot merge bit for bit —
        // across the argmin (k <= 16) and heap (k > 16) regimes.
        let mut rng = Xoshiro256::seeded(0x52);
        for k in [1usize, 2, 5, 16, 17, 33] {
            let runs = random_runs(&mut rng, k, 70);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let n: usize = refs.iter().map(|r| r.len()).sum();
            let mut expected = vec![0i64; n];
            loser_tree_merge(&refs, &mut expected);
            for window in [1usize, 3, 7, 64, 1 << 20] {
                let mut out = vec![0i64; n];
                let mut cursors = vec![0usize; k];
                let mut done = 0usize;
                while done < n {
                    let wlen = window.min(n - done);
                    loser_tree_merge_bounded(&refs, &mut cursors, &mut out[done..done + wlen]);
                    done += wlen;
                }
                assert_eq!(out, expected, "k={k} window={window}");
                assert!(
                    cursors.iter().zip(&refs).all(|(&c, r)| c == r.len()),
                    "all runs fully consumed"
                );
            }
        }
    }

    #[test]
    fn bounded_keeps_stable_tie_order() {
        // Key-only Ord with provenance payloads: window boundaries land
        // inside tie groups, and the continuation must keep the
        // (run index, offset) order.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        struct K(i64, u8);
        impl PartialOrd for K {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for K {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0)
            }
        }
        let runs: Vec<Vec<K>> = (0..3u8)
            .map(|run| (0..30i64).map(|i| K(i / 10, run)).collect())
            .collect();
        let refs: Vec<&[K]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut expected = vec![K(0, 9); 90];
        loser_tree_merge(&refs, &mut expected);
        let mut out = vec![K(0, 9); 90];
        loser_tree_merge_segmented(&refs, &mut out, 7);
        assert_eq!(
            out.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
            expected.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn segmented_wrapper_edge_cases() {
        // 0 = unwindowed delegate; empty inputs; window > input.
        let mut out: Vec<i64> = vec![];
        loser_tree_merge_segmented(&[], &mut out, 8);
        let a = vec![1i64, 4];
        let b = vec![2i64, 3];
        let refs: Vec<&[i64]> = vec![&a, &b];
        for window in [0usize, 1, 1 << 30] {
            let mut out = vec![0i64; 4];
            loser_tree_merge_segmented(&refs, &mut out, window);
            assert_eq!(out, vec![1, 2, 3, 4], "window={window}");
        }
    }

    #[test]
    #[should_panic(expected = "bounded merge wants")]
    fn bounded_rejects_overlong_output() {
        let a = vec![1i64];
        let refs: Vec<&[i64]> = vec![&a];
        let mut cursors = vec![0usize];
        let mut out = vec![0i64; 2];
        loser_tree_merge_bounded(&refs, &mut cursors, &mut out);
    }

    #[test]
    fn kernel_variants_match_tournament() {
        use super::super::kernel::MergeKernel;
        let mut rng = Xoshiro256::seeded(0x6B33);
        for k in [0usize, 1, 2, 3, 5] {
            let runs = random_runs(&mut rng, k, 90);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let n: usize = refs.iter().map(|r| r.len()).sum();
            let mut expected = vec![0i64; n];
            loser_tree_merge(&refs, &mut expected);
            for req in [
                MergeKernel::Scalar,
                MergeKernel::Branchless,
                MergeKernel::Hybrid,
                MergeKernel::Simd,
            ] {
                let kernel = LeafKernel::<i64>::select(req);
                let mut out = vec![0i64; n];
                loser_tree_merge_with(&refs, &mut out, kernel);
                assert_eq!(out, expected, "unsegmented req={req:?} k={k}");
                for window in [0usize, 1, 7, 1 << 20] {
                    let mut out = vec![0i64; n];
                    loser_tree_merge_segmented_with(&refs, &mut out, window, kernel);
                    assert_eq!(out, expected, "req={req:?} k={k} window={window}");
                }
            }
        }
    }

    #[test]
    fn parallel_tree_matches_oracle() {
        let mut rng = Xoshiro256::seeded(0x4D);
        for _ in 0..15 {
            let k = rng.range(0, 12);
            let runs = random_runs(&mut rng, k, 200);
            let expected = oracle(&runs);
            for p in [1, 3, 8] {
                let got = parallel_tree_merge(runs.clone(), p, None);
                assert_eq!(got, expected, "p={p}");
            }
        }
    }

    #[test]
    fn parallel_tree_with_pool() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0x4E);
        let runs = random_runs(&mut rng, 9, 500);
        let expected = oracle(&runs);
        let got = parallel_tree_merge(runs, 4, Some(&pool));
        assert_eq!(got, expected);
    }

    #[test]
    fn tree_refs_matches_owned() {
        let mut rng = Xoshiro256::seeded(0x50);
        for k in [0usize, 1, 2, 5, 9, 17] {
            let runs = random_runs(&mut rng, k, 70);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let owned = parallel_tree_merge(runs.clone(), 4, None);
            let borrowed = parallel_tree_merge_refs(&refs, 4, None);
            assert_eq!(owned, borrowed, "k={k}");
            assert_eq!(borrowed, oracle(&runs), "k={k}");
        }
    }

    #[test]
    fn engines_agree() {
        let mut rng = Xoshiro256::seeded(0x4F);
        for k in [2, 5, 20] {
            let runs = random_runs(&mut rng, k, 80);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut seq = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut seq);
            let par = parallel_tree_merge(runs.clone(), 4, None);
            assert_eq!(seq, par, "k={k}");
        }
    }
}
