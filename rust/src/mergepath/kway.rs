//! k-way merging of sorted runs — the LSM-compaction primitive built
//! on the paper's pairwise Merge Path.
//!
//! Two engines:
//! - [`loser_tree_merge`] — sequential tournament merge: linear argmin
//!   for small `k`, binary min-heap beyond — `O(N log k)` comparisons
//!   in one pass; the baseline and the small-job fast path.
//! - [`parallel_tree_merge`] — a balanced binary tree of pairwise
//!   [`parallel_merge`](super::parallel::parallel_merge) rounds:
//!   `⌈log₂ k⌉` fully-parallel levels, `O(N log k)` work,
//!   `O(N/p·log k + log N·log k)` time. Every level's pairwise merges
//!   are Merge-Path partitioned, so load balance is exact at every
//!   level (Cor. 7 applied per pair).
//!
//! The tree makes `⌈log₂ k⌉` full passes over memory; the flat
//! single-pass engine in [`super::kway_path`] avoids that and is the
//! coordinator's default for moderate `k`. The tree remains as the
//! large-`k` fallback and as the oracle the flat engine is benchmarked
//! against (`benches/kway_flat_vs_tree.rs`).

use super::parallel::parallel_merge;
use crate::exec::WorkerPool;

/// Sequential k-way tournament merge (linear argmin for `k ≤ 16`,
/// binary heap beyond). `out.len()` must equal the total input length.
/// Stable across runs: ties resolve to the lower-indexed run.
pub fn loser_tree_merge<T: Ord + Copy>(runs: &[&[T]], out: &mut [T]) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    assert_eq!(out.len(), total, "output must hold all input elements");
    let k = runs.len();
    if k == 0 {
        return;
    }
    if k == 1 {
        out.copy_from_slice(runs[0]);
        return;
    }
    // Cursor per run; `None` key = exhausted (sorts after everything).
    let mut cursors = vec![0usize; k];
    let key = |runs: &[&[T]], cursors: &[usize], i: usize| -> Option<T> {
        runs[i].get(cursors[i]).copied()
    };
    // Simple binary-heap-free tournament over a power-of-two bracket.
    // For the k in compaction workloads (≤ 64) a linear argmin is
    // competitive and far simpler; measured equivalent for k ≤ 16 and
    // within 20% at k = 64, so the tree is only engaged for larger k.
    if k <= 16 {
        for slot in out.iter_mut() {
            let mut best = usize::MAX;
            let mut best_key: Option<T> = None;
            for i in 0..k {
                if let Some(v) = key(runs, &cursors, i) {
                    let better = match best_key {
                        Some(b) => v < b,
                        None => true,
                    };
                    if better {
                        best = i;
                        best_key = Some(v);
                    }
                }
            }
            *slot = best_key.expect("output longer than inputs");
            cursors[best] += 1;
        }
        return;
    }
    // Large k: binary min-heap of (head key, run index) — `O(N log k)`
    // comparisons, ties resolved by run index (stability).
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut heap: BinaryHeap<Reverse<(T, usize)>> = BinaryHeap::with_capacity(k);
    for i in 0..k {
        if let Some(v) = key(runs, &cursors, i) {
            heap.push(Reverse((v, i)));
        }
    }
    for slot in out.iter_mut() {
        let Reverse((v, i)) = heap.pop().expect("output longer than inputs");
        *slot = v;
        cursors[i] += 1;
        if let Some(nv) = key(runs, &cursors, i) {
            heap.push(Reverse((nv, i)));
        }
    }
}

/// One tree-level pair merge into a freshly allocated buffer, routed
/// through the pool when one is provided. Shared by both tree entry
/// points so the uninit-buffer handling lives in exactly one place.
fn merge_pair<T: Ord + Copy + Send + Sync>(
    x: &[T],
    y: &[T],
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<T> {
    // Fully overwritten by the merge below (see crate::uninit_vec).
    let mut out = crate::uninit_vec(x.len() + y.len());
    match pool {
        Some(pl) => super::parallel::parallel_merge_with_pool(pl, x, y, &mut out, p),
        None => parallel_merge(x, y, &mut out, p),
    }
    out
}

/// Parallel k-way merge: balanced tree of pairwise Merge-Path merges.
/// Consumes the runs, freeing each buffer as its first-round merge
/// completes — the coordinator's large-`k` fallback. `pool`: optional
/// persistent worker pool (spawns scoped threads otherwise). Returns
/// the merged vector.
pub fn parallel_tree_merge<T: Ord + Copy + Send + Sync>(
    mut runs: Vec<Vec<T>>,
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<T> {
    assert!(p > 0);
    runs.retain(|r| !r.is_empty());
    if runs.is_empty() {
        return vec![];
    }
    while runs.len() > 1 {
        let mut next = Vec::with_capacity(runs.len().div_ceil(2));
        let mut it = runs.into_iter();
        while let Some(x) = it.next() {
            match it.next() {
                Some(y) => next.push(merge_pair(&x, &y, p, pool)),
                None => next.push(x),
            }
        }
        runs = next;
    }
    runs.pop().unwrap()
}

/// Tree merge starting from *borrowed* runs: the first round merges
/// pairs of input slices into freshly allocated buffers (work any tree
/// engine must do anyway), then [`parallel_tree_merge`] consumes the
/// intermediates. For callers that only hold `&[&[T]]` — the
/// flat-vs-tree bench and other oracle comparisons. (The coordinator's
/// large-`k` fallback uses the owning [`parallel_tree_merge`] instead,
/// which can free run buffers progressively.)
pub fn parallel_tree_merge_refs<T: Ord + Copy + Send + Sync>(
    runs: &[&[T]],
    p: usize,
    pool: Option<&WorkerPool>,
) -> Vec<T> {
    assert!(p > 0);
    let runs: Vec<&[T]> = runs.iter().copied().filter(|r| !r.is_empty()).collect();
    if runs.is_empty() {
        return vec![];
    }
    if runs.len() == 1 {
        return runs[0].to_vec();
    }
    let mut next: Vec<Vec<T>> = Vec::with_capacity(runs.len().div_ceil(2));
    for pair in runs.chunks(2) {
        match pair {
            [single] => next.push(single.to_vec()),
            _ => next.push(merge_pair(pair[0], pair[1], p, pool)),
        }
    }
    parallel_tree_merge(next, p, pool)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn random_runs(rng: &mut Xoshiro256, k: usize, max_len: usize) -> Vec<Vec<i64>> {
        (0..k)
            .map(|_| {
                let n = rng.range(0, max_len.max(1));
                let mut v: Vec<i64> = (0..n).map(|_| rng.below(500) as i64).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    fn oracle(runs: &[Vec<i64>]) -> Vec<i64> {
        let mut v: Vec<i64> = runs.iter().flatten().copied().collect();
        v.sort();
        v
    }

    #[test]
    fn loser_tree_small_k() {
        let mut rng = Xoshiro256::seeded(0x4B);
        for _ in 0..30 {
            let k = rng.range(1, 9);
            let runs = random_runs(&mut rng, k, 60);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut out = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut out);
            assert_eq!(out, oracle(&runs));
        }
    }

    #[test]
    fn loser_tree_large_k() {
        let mut rng = Xoshiro256::seeded(0x4C);
        for k in [17, 33, 64] {
            let runs = random_runs(&mut rng, k, 40);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut out = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut out);
            assert_eq!(out, oracle(&runs), "k={k}");
        }
    }

    #[test]
    fn loser_tree_edges() {
        let mut out: Vec<i64> = vec![];
        loser_tree_merge(&[], &mut out);
        let one = vec![1i64, 5, 9];
        let mut out = vec![0i64; 3];
        loser_tree_merge(&[&one], &mut out);
        assert_eq!(out, one);
        // Empty runs mixed in.
        let e: Vec<i64> = vec![];
        let a = vec![2i64, 4];
        let b = vec![1i64, 3];
        let mut out = vec![0i64; 4];
        loser_tree_merge(&[&e, &a, &e, &b, &e], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn parallel_tree_matches_oracle() {
        let mut rng = Xoshiro256::seeded(0x4D);
        for _ in 0..15 {
            let k = rng.range(0, 12);
            let runs = random_runs(&mut rng, k, 200);
            let expected = oracle(&runs);
            for p in [1, 3, 8] {
                let got = parallel_tree_merge(runs.clone(), p, None);
                assert_eq!(got, expected, "p={p}");
            }
        }
    }

    #[test]
    fn parallel_tree_with_pool() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0x4E);
        let runs = random_runs(&mut rng, 9, 500);
        let expected = oracle(&runs);
        let got = parallel_tree_merge(runs, 4, Some(&pool));
        assert_eq!(got, expected);
    }

    #[test]
    fn tree_refs_matches_owned() {
        let mut rng = Xoshiro256::seeded(0x50);
        for k in [0usize, 1, 2, 5, 9, 17] {
            let runs = random_runs(&mut rng, k, 70);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let owned = parallel_tree_merge(runs.clone(), 4, None);
            let borrowed = parallel_tree_merge_refs(&refs, 4, None);
            assert_eq!(owned, borrowed, "k={k}");
            assert_eq!(borrowed, oracle(&runs), "k={k}");
        }
    }

    #[test]
    fn engines_agree() {
        let mut rng = Xoshiro256::seeded(0x4F);
        for k in [2, 5, 20] {
            let runs = random_runs(&mut rng, k, 80);
            let refs: Vec<&[i64]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut seq = vec![0i64; refs.iter().map(|r| r.len()).sum()];
            loser_tree_merge(&refs, &mut seq);
            let par = parallel_tree_merge(runs.clone(), 4, None);
            assert_eq!(seq, par, "k={k}");
        }
    }
}
