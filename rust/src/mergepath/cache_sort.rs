//! Cache-efficient parallel sort (§4.4 of the paper).
//!
//! Three stages:
//! 1. Partition the unsorted input into blocks of (a fraction of) the
//!    cache size `C`.
//! 2. Sort the blocks **one by one**, each with the full `p`-thread
//!    parallel sort — sorting blocks one at a time keeps the cache
//!    footprint to a single block (the paper explicitly rejects sorting
//!    them concurrently for this reason).
//! 3. Merge rounds: pairs of sorted blocks are merged with the
//!    cache-efficient [`segmented_parallel_merge`] until one run remains.
//!
//! Time `O(N/p·log N + N/C·log p·log C)` — slightly more work than the
//! plain parallel sort, traded for `Θ(N)` cache misses.

use super::segmented::{segmented_parallel_merge, SegmentedConfig};
use super::sort::parallel_merge_sort;

/// Tuning for [`cache_efficient_sort`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheSortConfig {
    /// Cache capacity in *elements* (the paper's `C`).
    pub cache_elems: usize,
    /// Threads used in every stage.
    pub threads: usize,
}

impl CacheSortConfig {
    /// Initial block size: the paper sizes stage-1 blocks as a fraction
    /// of `C`; we use `C/2` so a block plus its sort scratch fits.
    pub fn block_len(&self) -> usize {
        (self.cache_elems / 2).max(1)
    }

    /// Merge-stage segment config per Prop. 15 (`L = C/3`).
    pub fn merge_config(&self) -> SegmentedConfig {
        SegmentedConfig::for_cache(self.cache_elems, self.threads)
    }
}

/// Sort `data` in place with the cache-efficient parallel sort.
pub fn cache_efficient_sort<T: Ord + Copy + Send + Sync>(
    data: &mut [T],
    cfg: CacheSortConfig,
) {
    assert!(cfg.threads > 0);
    assert!(cfg.cache_elems > 0);
    let n = data.len();
    if n <= 1 {
        return;
    }
    let block = cfg.block_len();

    // Stage 1+2: sort cache-sized blocks one after another, each with
    // all p threads (cache footprint = one block).
    let mut starts: Vec<usize> = (0..n).step_by(block).collect();
    starts.push(n);
    for w in starts.windows(2) {
        parallel_merge_sort(&mut data[w[0]..w[1]], cfg.threads);
    }

    // Stage 3: pairwise SPM merge rounds over a ping-pong buffer.
    let mut bounds = starts;
    if bounds.len() <= 2 {
        return; // single block: already sorted
    }
    let mut buf: Vec<T> = Vec::with_capacity(n);
    #[allow(clippy::uninit_vec)]
    unsafe {
        buf.set_len(n);
    }
    let mcfg = cfg.merge_config();
    let mut src_is_data = true;
    while bounds.len() > 2 {
        let pairs = (bounds.len() - 1) / 2;
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_data {
                (&*data, &mut buf)
            } else {
                (&*buf, data)
            };
            for k in 0..pairs {
                let (s0, s1, s2) = (bounds[2 * k], bounds[2 * k + 1], bounds[2 * k + 2]);
                segmented_parallel_merge(
                    &src[s0..s1],
                    &src[s1..s2],
                    &mut dst[s0..s2],
                    mcfg,
                );
            }
            if (bounds.len() - 1) % 2 == 1 {
                let s = bounds[bounds.len() - 2];
                let e = bounds[bounds.len() - 1];
                dst[s..e].copy_from_slice(&src[s..e]);
            }
        }
        let mut nb = Vec::with_capacity(bounds.len() / 2 + 1);
        let mut i = 0;
        while i < bounds.len() {
            nb.push(bounds[i]);
            i += 2;
        }
        if *nb.last().unwrap() != n {
            nb.push(n);
        }
        bounds = nb;
        src_is_data = !src_is_data;
    }
    if !src_is_data {
        data.copy_from_slice(&buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn check(v: Vec<i64>, cache: usize, p: usize) {
        let mut expected = v.clone();
        expected.sort();
        let mut got = v;
        cache_efficient_sort(&mut got, CacheSortConfig { cache_elems: cache, threads: p });
        assert_eq!(got, expected, "C={cache} p={p}");
    }

    #[test]
    fn sorts_random_across_cache_sizes() {
        let mut rng = Xoshiro256::seeded(0xCAC4E);
        for _ in 0..8 {
            let n = rng.range(0, 3000);
            let v: Vec<i64> = (0..n).map(|_| rng.next_i32() as i64).collect();
            for cache in [4, 64, 1024, 1 << 20] {
                for p in [1, 4] {
                    check(v.clone(), cache, p);
                }
            }
        }
    }

    #[test]
    fn tiny_cache_still_correct() {
        let mut rng = Xoshiro256::seeded(0x71);
        let v: Vec<i64> = (0..511).map(|_| rng.next_i32() as i64).collect();
        check(v, 1, 2); // pathological: 1-element "cache"
    }

    #[test]
    fn block_count_edge_cases() {
        // Exactly one block, exactly two, odd number of blocks.
        let mut rng = Xoshiro256::seeded(0x72);
        let mk = |n: usize, rng: &mut Xoshiro256| -> Vec<i64> {
            (0..n).map(|_| rng.next_i32() as i64).collect()
        };
        check(mk(100, &mut rng), 400, 4); // one block (block=200 > 100)
        check(mk(200, &mut rng), 200, 4); // two blocks of 100
        check(mk(500, &mut rng), 200, 4); // five blocks of 100
    }

    #[test]
    fn config_derivation() {
        let cfg = CacheSortConfig { cache_elems: 3000, threads: 8 };
        assert_eq!(cfg.block_len(), 1500);
        assert_eq!(cfg.merge_config().segment_len, 1000);
        assert_eq!(cfg.merge_config().threads, 8);
    }

    #[test]
    fn presorted_and_reverse() {
        check((0..2500).collect(), 512, 4);
        check((0..2500).rev().collect(), 512, 4);
    }
}
