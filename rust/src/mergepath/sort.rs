//! Parallel merge sort (§3 of the paper).
//!
//! Structure: each core sequentially sorts an `N/p` chunk, then
//! `⌈log₂ p⌉` rounds of merging follow. While more than `p` merge pairs
//! remain the pairs themselves run in parallel (each merge sequential);
//! once pairs are scarce every merge runs as a Merge-Path
//! [`parallel_merge`](super::parallel::parallel_merge) across all `p`
//! cores — this is exactly the regime the paper motivates (§1: "the
//! early rounds are trivially parallelizable … no longer the case in
//! later rounds").
//!
//! Time `O(N/p·log N + log p·log N)`.

use super::kernel::LeafKernel;
use super::parallel::SliceParts;
use crate::exec::{fork_join, WorkerPool};

/// Sort `data` in place (stable) using `p` threads.
pub fn parallel_merge_sort<T: Ord + Copy + Send + Sync>(data: &mut [T], p: usize) {
    parallel_merge_sort_kernel(data, p, LeafKernel::hybrid());
}

/// [`parallel_merge_sort`] with an explicit [`LeafKernel`] for every
/// pairwise merge leaf of the sort's merge tree (the base-case chunk
/// sorts are unaffected — they use the standard library's stable sort).
pub fn parallel_merge_sort_kernel<T: Ord + Copy + Send + Sync>(
    data: &mut [T],
    p: usize,
    kernel: LeafKernel<T>,
) {
    assert!(p > 0);
    let n = data.len();
    if n <= 1 {
        return;
    }
    if p == 1 || n < 4 * p {
        data.sort();
        return;
    }
    let mut buf: Vec<T> = Vec::with_capacity(n);
    // SAFETY: fully overwritten before any read (ping-pong buffer).
    #[allow(clippy::uninit_vec)]
    unsafe {
        buf.set_len(n);
    }
    sort_rounds(data, &mut buf, p, None, kernel);
}

/// Pool variant of [`parallel_merge_sort`].
pub fn parallel_merge_sort_with_pool<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    data: &mut [T],
    p: usize,
) {
    parallel_merge_sort_with_pool_kernel(pool, data, p, LeafKernel::hybrid());
}

/// [`parallel_merge_sort_with_pool`] with an explicit [`LeafKernel`]
/// for the merge-tree leaves.
pub fn parallel_merge_sort_with_pool_kernel<T: Ord + Copy + Send + Sync>(
    pool: &WorkerPool,
    data: &mut [T],
    p: usize,
    kernel: LeafKernel<T>,
) {
    assert!(p > 0);
    let n = data.len();
    if n <= 1 {
        return;
    }
    if p == 1 || n < 4 * p {
        data.sort();
        return;
    }
    let mut buf: Vec<T> = Vec::with_capacity(n);
    #[allow(clippy::uninit_vec)]
    unsafe {
        buf.set_len(n);
    }
    sort_rounds(data, &mut buf, p, Some(pool), kernel);
}

/// Chunk boundaries `i·n/p` used for the base sorting stage.
fn boundaries(n: usize, parts: usize) -> Vec<usize> {
    (0..=parts).map(|i| i * n / parts).collect()
}

fn sort_rounds<T: Ord + Copy + Send + Sync>(
    data: &mut [T],
    buf: &mut [T],
    p: usize,
    pool: Option<&WorkerPool>,
    kernel: LeafKernel<T>,
) {
    let n = data.len();
    // Round up the leaf count to a power of two so the merge tree is a
    // clean binary tree; empty leaves cost nothing.
    let leaves = p.next_power_of_two();
    let mut bounds = boundaries(n, leaves);

    // Stage 1: sort each leaf chunk, chunks in parallel (p at a time).
    {
        let shared = SliceParts::new(data);
        let bounds_ref = &bounds;
        let body = |tid: usize| {
            // Leaf i handled by thread tid = i % p in a strided loop.
            let mut i = tid;
            while i < leaves {
                let (s, e) = (bounds_ref[i], bounds_ref[i + 1]);
                if e > s {
                    // SAFETY: leaf ranges are disjoint.
                    let chunk = unsafe { shared.slice_mut(s, e - s) };
                    chunk.sort();
                }
                i += p;
            }
        };
        match pool {
            Some(pl) => pl.run_scoped(p, body),
            None => fork_join(p, body),
        }
    }

    // Stage 2: merge rounds over the ping-pong buffers.
    let mut src_is_data = true;
    while bounds.len() > 2 {
        let pairs = (bounds.len() - 1) / 2;
        let (src, dst): (&mut [T], &mut [T]) = if src_is_data {
            (data, &mut *buf)
        } else {
            (&mut *buf, data)
        };
        let src = &*src; // merges read src, write dst
        if pairs >= p {
            // Many pairs: one (sequential) merge per task, p at a time.
            let shared = SliceParts::new(dst);
            let bounds_ref = &bounds;
            let body = |tid: usize| {
                let mut k = tid;
                while k < pairs {
                    let (s0, s1, s2) =
                        (bounds_ref[2 * k], bounds_ref[2 * k + 1], bounds_ref[2 * k + 2]);
                    // SAFETY: output ranges [s0, s2) disjoint across pairs.
                    let out = unsafe { shared.slice_mut(s0, s2 - s0) };
                    kernel.merge(&src[s0..s1], &src[s1..s2], out, s2 - s0);
                    k += p;
                }
            };
            match pool {
                Some(pl) => pl.run_scoped(p, body),
                None => fork_join(p, body),
            }
        } else {
            // Few pairs: each merge is itself a p-way Merge-Path merge.
            for k in 0..pairs {
                let (s0, s1, s2) = (bounds[2 * k], bounds[2 * k + 1], bounds[2 * k + 2]);
                let out = &mut dst[s0..s2];
                match pool {
                    Some(pl) => super::parallel::parallel_merge_with_pool_kernel(
                        pl,
                        &src[s0..s1],
                        &src[s1..s2],
                        out,
                        p,
                        kernel,
                    ),
                    None => super::parallel::parallel_merge_kernel(
                        &src[s0..s1],
                        &src[s1..s2],
                        out,
                        p,
                        kernel,
                    ),
                }
            }
        }
        // Odd trailing chunk (only possible while bounds count is odd):
        if (bounds.len() - 1) % 2 == 1 {
            let s = bounds[bounds.len() - 2];
            let e = bounds[bounds.len() - 1];
            dst[s..e].copy_from_slice(&src[s..e]);
        }
        // Collapse bounds: keep every second boundary.
        let mut nb = Vec::with_capacity(bounds.len() / 2 + 1);
        let mut i = 0;
        while i < bounds.len() {
            nb.push(bounds[i]);
            i += 2;
        }
        if *nb.last().unwrap() != n {
            nb.push(n);
        }
        bounds = nb;
        src_is_data = !src_is_data;
    }

    if !src_is_data {
        // Result currently lives in buf; copy back.
        data.copy_from_slice(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn check(v: Vec<i64>, p: usize) {
        let mut expected = v.clone();
        expected.sort();
        let mut got = v;
        parallel_merge_sort(&mut got, p);
        assert_eq!(got, expected, "p={p}");
    }

    #[test]
    fn sorts_random_inputs_all_p() {
        let mut rng = Xoshiro256::seeded(0x5047);
        for _ in 0..10 {
            let n = rng.range(0, 2000);
            let v: Vec<i64> = (0..n).map(|_| rng.next_i32() as i64).collect();
            for p in [1, 2, 3, 4, 8, 13] {
                check(v.clone(), p);
            }
        }
    }

    #[test]
    fn sorts_edge_shapes() {
        check(vec![], 4);
        check(vec![1], 4);
        check(vec![2, 1], 4);
        check((0..100).rev().collect(), 8); // descending
        check((0..100).collect(), 8); // ascending
        check(vec![5; 1000], 8); // constant
    }

    #[test]
    fn sorts_sawtooth_and_organpipe() {
        let saw: Vec<i64> = (0..997).map(|i| (i % 13) as i64).collect();
        check(saw, 6);
        let organ: Vec<i64> = (0..500).chain((0..500).rev()).map(|x| x as i64).collect();
        check(organ, 6);
    }

    #[test]
    fn pool_variant_matches() {
        let pool = WorkerPool::new(4);
        let mut rng = Xoshiro256::seeded(0x7001);
        for _ in 0..5 {
            let n = rng.range(100, 3000);
            let v: Vec<i64> = (0..n).map(|_| rng.next_i32() as i64).collect();
            let mut expected = v.clone();
            expected.sort();
            let mut got = v;
            parallel_merge_sort_with_pool(&pool, &mut got, 4);
            assert_eq!(got, expected);
        }
    }

    #[test]
    fn kernel_variants_sort_identically() {
        use super::super::kernel::MergeKernel;
        let mut rng = Xoshiro256::seeded(0x6B34);
        let v: Vec<i64> = (0..3000).map(|_| rng.next_i32() as i64).collect();
        let mut expected = v.clone();
        expected.sort();
        for req in [
            MergeKernel::Scalar,
            MergeKernel::Branchless,
            MergeKernel::Hybrid,
            MergeKernel::Simd,
        ] {
            let mut got = v.clone();
            parallel_merge_sort_kernel(&mut got, 4, LeafKernel::select(req));
            assert_eq!(got, expected, "req={req:?}");
        }
    }

    #[test]
    fn non_power_of_two_threads() {
        let mut rng = Xoshiro256::seeded(0x99);
        let v: Vec<i64> = (0..5000).map(|_| rng.next_i32() as i64).collect();
        for p in [3, 5, 6, 7, 12, 40] {
            check(v.clone(), p);
        }
    }
}
