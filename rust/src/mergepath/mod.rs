//! The paper's core contribution: Merge Path partitioning and the
//! parallel merge / sort algorithms built on it.
//!
//! Layout follows the paper:
//!
//! - [`diagonal`] — §2.2–2.4, Alg 2: intersection of the Merge Path with
//!   a cross diagonal by binary search.
//! - [`partition`] — Thm 14: `p`-way equisized partition of the path.
//! - [`merge`] — sequential merge primitives (the per-segment kernels).
//! - [`kernel`] — leaf-kernel dispatch: branchless / hybrid / SIMD
//!   bitonic-network bounded merges behind one per-job [`LeafKernel`]
//!   function pointer, selected by the `merge.kernel` knob.
//! - [`inplace`] — block-swap in-place pairwise merge (zero-allocation,
//!   stable) under the same diagonal partition (arxiv 2005.12648).
//! - [`parallel`] — Alg 1: `ParallelMerge`.
//! - [`segmented`] — Alg 3: `SegmentedParallelMerge` (cache-efficient, §4.3).
//! - [`sort`] — §3: parallel merge sort.
//! - [`cache_sort`] — §4.4: cache-efficient parallel sort.
//! - [`kway`] — k-way merging (loser tree, bounded/windowed loser tree,
//!   parallel pairwise tree).
//! - [`kway_path`] — flat single-pass k-way merge via multi-sequence
//!   selection (§5 generalised to k runs, after Siebert & Träff), and
//!   its segmented (cache-efficient) variant (§4.3 generalised to k).
//! - [`select`] — multiselection on the merge path ([10], §5).

pub mod cache_sort;
pub mod diagonal;
pub mod inplace;
pub mod kernel;
pub mod kway;
pub mod kway_path;
pub mod merge;
pub mod parallel;
pub mod partition;
pub mod segmented;
pub mod select;
pub mod sort;

pub use diagonal::{diagonal_intersection, PathPoint};
pub use kernel::{cpu_features, tagged_backend, CpuFeatures, KernelKind, LeafKernel, MergeKernel};
pub use inplace::{
    concat_for_inplace, merge_in_place, parallel_inplace_merge,
    parallel_inplace_merge_with_pool,
};
pub use merge::{
    branchless_merge_bounded, gallop_merge_into, hybrid_merge_bounded, merge_bounded, merge_into,
};
pub use parallel::{
    parallel_merge, parallel_merge_kernel, parallel_merge_with_pool,
    parallel_merge_with_pool_kernel,
};
pub use partition::{partition_merge_path, MergeSegment};
pub use segmented::{
    segmented_parallel_merge, segmented_parallel_merge_kernel,
    segmented_parallel_merge_with_pool, segmented_parallel_merge_with_pool_kernel,
    SegmentedConfig,
};
pub use sort::{
    parallel_merge_sort, parallel_merge_sort_kernel, parallel_merge_sort_with_pool,
    parallel_merge_sort_with_pool_kernel,
};
pub use cache_sort::{cache_efficient_sort, CacheSortConfig};
pub use kway::{
    loser_tree_merge, loser_tree_merge_bounded, loser_tree_merge_segmented,
    loser_tree_merge_segmented_with, loser_tree_merge_with, parallel_tree_merge,
    parallel_tree_merge_kernel, parallel_tree_merge_refs,
};
pub use kway_path::{
    kway_rank_split, parallel_kway_merge, parallel_kway_merge_with,
    partition_kway_merge_path, partition_kway_merge_path_with_pool, segmented_kway_merge,
    segmented_kway_merge_with, KwaySegment, KwaySegmentedConfig,
};
pub use select::{multiselect, multiselect_independent};
