//! SSE4.2/AVX2 bitonic-network merge kernels for fixed-width scalar
//! keys (`i32`/`u32`/`i64`/`u64`).
//!
//! # Algorithm
//!
//! The classic in-register streaming merge (Chhugani et al., also
//! surveyed in arxiv 2202.08463): keep one vector register `va` of the
//! `W` smallest in-flight elements. Each iteration merges `va` with a
//! freshly loaded vector `vb` through a **bitonic merge network** —
//! reverse `vb`, take lane-wise min/max (yielding two bitonic
//! `W`-sequences), then sort each with `log2 W` compare–exchange
//! stages — emits the low `W` results to the output, keeps the high
//! `W` as the new `va`, and refills `vb` from whichever input stream
//! has the smaller head (`a` on ties). The scalar heads it compares
//! are exactly the next *unloaded* elements, so every element in
//! flight is ≤ both stream heads and the emitted low half is final.
//!
//! When either stream has fewer than `W` elements left (or fewer than
//! `W` output slots remain), the loop stops: the `W` elements still in
//! `va` are **not** necessarily ≤ the remaining stream heads (only ≤
//! the *unloaded* suffix of their own stream — the other stream may
//! hold smaller still-unloaded elements). They are therefore spilled
//! to a stack buffer and drained by a three-way scalar merge against
//! both stream heads; the rest is delegated to
//! [`branchless_merge_bounded`].
//!
//! # Stability
//!
//! The network routes elements through min/max lanes and cannot track
//! which input an element came from, so it cannot implement
//! "A-priority on ties" positionally. It doesn't have to: these
//! kernels are only dispatched (see
//! [`LeafKernel::select`](super::LeafKernel::select)) for bare scalar
//! keys, where two equal keys are bit-identical values — any tie order
//! produces bit-identical output, which is the contract
//! ([`merge_bounded`](crate::mergepath::merge::merge_bounded)
//! equivalence) the tests below check.
//!
//! # Safety
//!
//! All `unsafe` here is (a) `#[target_feature]` intrinsic calls, made
//! sound by the `cpu_features()` runtime check in the public wrappers,
//! and (b) raw vector loads/stores whose bounds are established by the
//! loop guards (`i + W <= a.len()`, `j + W <= b.len()`, `k + W <= len
//! <= out.len()`) — the wrappers assert the
//! [`merge_bounded`](crate::mergepath::merge::merge_bounded) contract
//! before entering the unsafe fns.

use super::cpu_features;
use crate::mergepath::merge::branchless_merge_bounded;
use core::arch::x86_64::*;

// ---------------------------------------------------------------------
// Unaligned load/store helpers. 128-bit forms are baseline x86_64
// (SSE2); the 256-bit forms carry the AVX target feature so they
// inline cleanly into the AVX2 kernels.
// ---------------------------------------------------------------------

#[inline(always)]
unsafe fn ld128<T>(p: *const T) -> __m128i {
    _mm_loadu_si128(p.cast())
}

#[inline(always)]
unsafe fn st128<T>(p: *mut T, v: __m128i) {
    _mm_storeu_si128(p.cast(), v)
}

#[inline]
#[target_feature(enable = "avx")]
unsafe fn ld256<T>(p: *const T) -> __m256i {
    _mm256_loadu_si256(p.cast())
}

#[inline]
#[target_feature(enable = "avx")]
unsafe fn st256<T>(p: *mut T, v: __m256i) {
    _mm256_storeu_si256(p.cast(), v)
}

// ---------------------------------------------------------------------
// 64-bit lane-wise min/max. SSE/AVX2 have no 64-bit integer min/max
// instructions, so build them from cmpgt + blendv; unsigned variants
// bias both operands by i64::MIN (an order-preserving map from u64 to
// i64) before the signed compare.
// ---------------------------------------------------------------------

#[inline]
#[target_feature(enable = "sse4.2")]
unsafe fn sse_minmax_i64(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
    let gt = _mm_cmpgt_epi64(a, b);
    (_mm_blendv_epi8(a, b, gt), _mm_blendv_epi8(b, a, gt))
}

#[inline]
#[target_feature(enable = "sse4.2")]
unsafe fn sse_minmax_u64(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
    let bias = _mm_set1_epi64x(i64::MIN);
    let gt = _mm_cmpgt_epi64(_mm_xor_si128(a, bias), _mm_xor_si128(b, bias));
    (_mm_blendv_epi8(a, b, gt), _mm_blendv_epi8(b, a, gt))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn avx_minmax_i64(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    let gt = _mm256_cmpgt_epi64(a, b);
    (_mm256_blendv_epi8(a, b, gt), _mm256_blendv_epi8(b, a, gt))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn avx_minmax_u64(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    let bias = _mm256_set1_epi64x(i64::MIN);
    let gt = _mm256_cmpgt_epi64(_mm256_xor_si256(a, bias), _mm256_xor_si256(b, bias));
    (_mm256_blendv_epi8(a, b, gt), _mm256_blendv_epi8(b, a, gt))
}

// ---------------------------------------------------------------------
// Bitonic merge networks. Each `$bmerge(va, vb)` takes two ascending
// vectors and returns (low half, high half) of their 2W-element merge:
// reverse vb, lane-wise min/max (two bitonic W-sequences), then log2 W
// compare–exchange stages per half.
// ---------------------------------------------------------------------

/// 32-bit × 4 lanes (SSE4.2 — the blends/min/max are SSE4.1 forms).
macro_rules! sse_net32 {
    ($bmerge:ident, $sort:ident, $min:ident, $max:ident) => {
        /// Sort a bitonic 4-sequence: distance-2 then distance-1
        /// compare–exchange.
        #[inline]
        #[target_feature(enable = "sse4.2")]
        unsafe fn $sort(v: __m128i) -> __m128i {
            // Distance 2: pairs (0,2),(1,3); 0x4E swaps the 64-bit halves.
            let p = _mm_shuffle_epi32::<0x4E>(v);
            let v = _mm_blend_epi16::<0xF0>($min(v, p), $max(v, p));
            // Distance 1: pairs (0,1),(2,3); 0xB1 swaps within halves.
            let p = _mm_shuffle_epi32::<0xB1>(v);
            _mm_blend_epi16::<0xCC>($min(v, p), $max(v, p))
        }

        #[inline]
        #[target_feature(enable = "sse4.2")]
        unsafe fn $bmerge(va: __m128i, vb: __m128i) -> (__m128i, __m128i) {
            // Reverse vb (0x1B = lanes 3,2,1,0) so va ++ vb is bitonic.
            let vb = _mm_shuffle_epi32::<0x1B>(vb);
            ($sort($min(va, vb)), $sort($max(va, vb)))
        }
    };
}

/// 64-bit × 2 lanes (SSE4.2 for `_mm_cmpgt_epi64`).
macro_rules! sse_net64 {
    ($bmerge:ident, $minmax:ident) => {
        #[inline]
        #[target_feature(enable = "sse4.2")]
        unsafe fn $bmerge(va: __m128i, vb: __m128i) -> (__m128i, __m128i) {
            // Reverse vb: 0x4E swaps the two 64-bit lanes.
            let vb = _mm_shuffle_epi32::<0x4E>(vb);
            let (lo, hi) = $minmax(va, vb);
            // Sort each bitonic pair: one distance-1 exchange.
            let (l, lx) = $minmax(lo, _mm_shuffle_epi32::<0x4E>(lo));
            let (h, hx) = $minmax(hi, _mm_shuffle_epi32::<0x4E>(hi));
            (_mm_blend_epi16::<0xF0>(l, lx), _mm_blend_epi16::<0xF0>(h, hx))
        }
    };
}

/// 32-bit × 8 lanes (AVX2).
macro_rules! avx_net32 {
    ($bmerge:ident, $sort:ident, $min:ident, $max:ident) => {
        /// Sort a bitonic 8-sequence: distance-4, -2, -1 exchanges.
        #[inline]
        #[target_feature(enable = "avx2")]
        unsafe fn $sort(v: __m256i) -> __m256i {
            // Distance 4: swap the 128-bit halves.
            let p = _mm256_permute2x128_si256::<0x01>(v, v);
            let v = _mm256_blend_epi32::<0xF0>($min(v, p), $max(v, p));
            // Distance 2 within each half.
            let p = _mm256_shuffle_epi32::<0x4E>(v);
            let v = _mm256_blend_epi32::<0xCC>($min(v, p), $max(v, p));
            // Distance 1 within each half.
            let p = _mm256_shuffle_epi32::<0xB1>(v);
            _mm256_blend_epi32::<0xAA>($min(v, p), $max(v, p))
        }

        #[inline]
        #[target_feature(enable = "avx2")]
        unsafe fn $bmerge(va: __m256i, vb: __m256i) -> (__m256i, __m256i) {
            let rev = _mm256_setr_epi32(7, 6, 5, 4, 3, 2, 1, 0);
            let vb = _mm256_permutevar8x32_epi32(vb, rev);
            ($sort($min(va, vb)), $sort($max(va, vb)))
        }
    };
}

/// 64-bit × 4 lanes (AVX2).
macro_rules! avx_net64 {
    ($bmerge:ident, $sort:ident, $minmax:ident) => {
        /// Sort a bitonic 4-sequence of 64-bit lanes.
        #[inline]
        #[target_feature(enable = "avx2")]
        unsafe fn $sort(v: __m256i) -> __m256i {
            // Distance 2: lanes (0,2),(1,3); permute4x64 0x4E = 2,3,0,1.
            let p = _mm256_permute4x64_epi64::<0x4E>(v);
            let (mn, mx) = $minmax(v, p);
            let v = _mm256_blend_epi32::<0xF0>(mn, mx);
            // Distance 1: lanes (0,1),(2,3); 0xB1 = 1,0,3,2.
            let p = _mm256_permute4x64_epi64::<0xB1>(v);
            let (mn, mx) = $minmax(v, p);
            _mm256_blend_epi32::<0xCC>(mn, mx)
        }

        #[inline]
        #[target_feature(enable = "avx2")]
        unsafe fn $bmerge(va: __m256i, vb: __m256i) -> (__m256i, __m256i) {
            // Reverse vb: 0x1B = lanes 3,2,1,0.
            let vb = _mm256_permute4x64_epi64::<0x1B>(vb);
            let (lo, hi) = $minmax(va, vb);
            ($sort(lo), $sort(hi))
        }
    };
}

sse_net32!(sse_bmerge_i32, sse_sort4_i32, _mm_min_epi32, _mm_max_epi32);
sse_net32!(sse_bmerge_u32, sse_sort4_u32, _mm_min_epu32, _mm_max_epu32);
sse_net64!(sse_bmerge_i64, sse_minmax_i64);
sse_net64!(sse_bmerge_u64, sse_minmax_u64);
avx_net32!(avx_bmerge_i32, avx_sort8_i32, _mm256_min_epi32, _mm256_max_epi32);
avx_net32!(avx_bmerge_u32, avx_sort8_u32, _mm256_min_epu32, _mm256_max_epu32);
avx_net64!(avx_bmerge_i64, avx_sort4_i64, avx_minmax_i64);
avx_net64!(avx_bmerge_u64, avx_sort4_u64, avx_minmax_u64);

// ---------------------------------------------------------------------
// The streaming merge loop, instantiated per (type, width, ISA).
// ---------------------------------------------------------------------

macro_rules! simd_merge_loop {
    ($name:ident, $ty:ty, $w:expr, $load:ident, $store:ident, $bmerge:ident, $feat:literal) => {
        /// Merge the first `len` outputs of the stable merge of `a`
        /// and `b` into `out[..len]`.
        ///
        /// Safety: requires the `$feat` target feature at runtime and
        /// `len <= a.len() + b.len()`, `out.len() >= len` (checked by
        /// the public wrapper).
        #[target_feature(enable = $feat)]
        unsafe fn $name(a: &[$ty], b: &[$ty], out: &mut [$ty], len: usize) {
            const W: usize = $w;
            let mut i = 0usize;
            let mut j = 0usize;
            let mut k = 0usize;
            let mut tmp: [$ty; W] = [0; W];
            let mut have_tail = false;
            if a.len() >= W && b.len() >= W && len >= W {
                let mut va = $load(a.as_ptr());
                let mut vb = $load(b.as_ptr());
                i = W;
                j = W;
                loop {
                    let (lo, hi) = $bmerge(va, vb);
                    // In range: k + W <= len <= out.len() (first
                    // iteration by the guard above, later ones by the
                    // break check below).
                    $store(out.as_mut_ptr().add(k), lo);
                    k += W;
                    va = hi;
                    if k + W > len || i + W > a.len() || j + W > b.len() {
                        break;
                    }
                    // Refill from the stream with the smaller head
                    // (`<=` keeps the A-then-B order; for these scalar
                    // types equal keys are bit-identical, so either
                    // order yields identical bytes). The W elements
                    // starting at the head are in range per the break
                    // check.
                    if a[i] <= b[j] {
                        vb = $load(a.as_ptr().add(i));
                        i += W;
                    } else {
                        vb = $load(b.as_ptr().add(j));
                        j += W;
                    }
                }
                $store(tmp.as_mut_ptr(), va);
                have_tail = true;
            }
            // Drain the spilled register three-ways against both
            // stream heads: tmp is sorted and <= the *unloaded* suffix
            // of the stream each element came from, but not
            // necessarily <= the other stream's head, so it must
            // compete element-wise.
            let mut t = if have_tail { 0 } else { W };
            while t < W && k < len {
                let x = tmp[t];
                if (i >= a.len() || x <= a[i]) && (j >= b.len() || x <= b[j]) {
                    out[k] = x;
                    t += 1;
                } else if i < a.len() && (j >= b.len() || a[i] <= b[j]) {
                    out[k] = a[i];
                    i += 1;
                } else {
                    out[k] = b[j];
                    j += 1;
                }
                k += 1;
            }
            if k < len {
                branchless_merge_bounded(&a[i..], &b[j..], &mut out[k..len], len - k);
            }
        }
    };
}

simd_merge_loop!(sse_merge_i32, i32, 4, ld128, st128, sse_bmerge_i32, "sse4.2");
simd_merge_loop!(sse_merge_u32, u32, 4, ld128, st128, sse_bmerge_u32, "sse4.2");
simd_merge_loop!(sse_merge_i64, i64, 2, ld128, st128, sse_bmerge_i64, "sse4.2");
simd_merge_loop!(sse_merge_u64, u64, 2, ld128, st128, sse_bmerge_u64, "sse4.2");
simd_merge_loop!(avx_merge_i32, i32, 8, ld256, st256, avx_bmerge_i32, "avx2");
simd_merge_loop!(avx_merge_u32, u32, 8, ld256, st256, avx_bmerge_u32, "avx2");
simd_merge_loop!(avx_merge_i64, i64, 4, ld256, st256, avx_bmerge_i64, "avx2");
simd_merge_loop!(avx_merge_u64, u64, 4, ld256, st256, avx_bmerge_u64, "avx2");

// ---------------------------------------------------------------------
// Safe wrappers: assert the merge_bounded contract, pick the widest
// detected ISA, fall back to the branchless scalar loop when neither
// vector path is available (defensive — dispatch shouldn't route here
// without SSE4.2, but the wrappers stay safe regardless).
// ---------------------------------------------------------------------

macro_rules! simd_wrapper {
    ($(#[$doc:meta])* $name:ident, $ty:ty, $sse:ident, $avx:ident) => {
        $(#[$doc])*
        ///
        /// Same contract as
        /// [`merge_bounded`](crate::mergepath::merge::merge_bounded):
        /// writes the first `len` outputs of the stable merge of `a`
        /// and `b` into `out[..len]`.
        ///
        /// # Panics
        ///
        /// If `len > a.len() + b.len()` or `out.len() < len`.
        pub fn $name(a: &[$ty], b: &[$ty], out: &mut [$ty], len: usize) {
            assert!(len <= a.len() + b.len(), "len exceeds total input");
            assert!(out.len() >= len, "output shorter than len");
            let feats = cpu_features();
            if feats.avx2 {
                // SAFETY: AVX2 detected at runtime; bounds asserted.
                unsafe { $avx(a, b, out, len) }
            } else if feats.sse42 {
                // SAFETY: SSE4.2 detected at runtime; bounds asserted.
                unsafe { $sse(a, b, out, len) }
            } else {
                branchless_merge_bounded(a, b, out, len);
            }
        }
    };
}

simd_wrapper!(
    /// Vectorized bounded merge for `i32` keys (AVX2 → SSE4.2 → branchless).
    merge_i32,
    i32,
    sse_merge_i32,
    avx_merge_i32
);
simd_wrapper!(
    /// Vectorized bounded merge for `u32` keys (AVX2 → SSE4.2 → branchless).
    merge_u32,
    u32,
    sse_merge_u32,
    avx_merge_u32
);
simd_wrapper!(
    /// Vectorized bounded merge for `i64` keys (AVX2 → SSE4.2 → branchless).
    merge_i64,
    i64,
    sse_merge_i64,
    avx_merge_i64
);
simd_wrapper!(
    /// Vectorized bounded merge for `u64` keys (AVX2 → SSE4.2 → branchless).
    merge_u64,
    u64,
    sse_merge_u64,
    avx_merge_u64
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mergepath::merge::merge_bounded;
    use crate::rng::Xoshiro256;

    /// Conformance sweep for one element type: random duplicate-heavy
    /// and wide universes, varying lengths (below/at/above the vector
    /// width), every interesting bounded prefix, plus disjoint-range
    /// and one-sided shapes — each checked bit-for-bit against
    /// `merge_bounded` on both the SSE and (when detected) AVX2 paths.
    macro_rules! conformance {
        ($test:ident, $ty:ty, $w:expr, $sse:ident, $avx:ident, $wrapper:ident) => {
            #[test]
            fn $test() {
                let feats = cpu_features();
                if !feats.sse42 {
                    eprintln!("skipping {}: no SSE4.2 at runtime", stringify!($test));
                    return;
                }
                let w: usize = $w;
                let mut cases: Vec<(Vec<$ty>, Vec<$ty>)> = Vec::new();
                let mut rng = Xoshiro256::seeded(0x51D0 + w as u64);
                for round in 0..60 {
                    let universe: u64 = match round % 4 {
                        0 => 2,
                        1 => 8,
                        2 => 64,
                        _ => 1 << 20,
                    };
                    let mut a: Vec<$ty> = (0..rng.range(0, 130))
                        .map(|_| <$ty>::try_from(rng.below(universe)).unwrap())
                        .collect();
                    a.sort_unstable();
                    let mut b: Vec<$ty> = (0..rng.range(0, 130))
                        .map(|_| <$ty>::try_from(rng.below(universe)).unwrap())
                        .collect();
                    b.sort_unstable();
                    cases.push((a, b));
                }
                // Disjoint ranges (forces long same-stream runs), a
                // strict interleave, one-sided and empty inputs.
                let lo: Vec<$ty> = (0u64..97).map(|x| <$ty>::try_from(x).unwrap()).collect();
                let hi: Vec<$ty> =
                    (1000u64..1113).map(|x| <$ty>::try_from(x).unwrap()).collect();
                let even: Vec<$ty> =
                    (0u64..80).map(|x| <$ty>::try_from(2 * x).unwrap()).collect();
                let odd: Vec<$ty> =
                    (0u64..80).map(|x| <$ty>::try_from(2 * x + 1).unwrap()).collect();
                cases.push((lo.clone(), hi.clone()));
                cases.push((hi, lo.clone()));
                cases.push((even, odd));
                cases.push((lo.clone(), Vec::new()));
                cases.push((Vec::new(), lo));
                cases.push((Vec::new(), Vec::new()));
                for (a, b) in cases {
                    let total = a.len() + b.len();
                    let mut lens = vec![0, 1, w - 1, w, w + 1, total / 2, total];
                    lens.push(total.saturating_sub(1));
                    for len in lens {
                        let len = len.min(total);
                        let mut want = vec![<$ty>::default(); len];
                        merge_bounded(&a, &b, &mut want, len);
                        let mut got = vec![<$ty>::default(); len];
                        // SAFETY: SSE4.2 checked above; buffers sized.
                        unsafe { $sse(&a, &b, &mut got, len) };
                        assert_eq!(got, want, "sse len={len} |a|={} |b|={}", a.len(), b.len());
                        if feats.avx2 {
                            let mut got = vec![<$ty>::default(); len];
                            // SAFETY: AVX2 checked; buffers sized.
                            unsafe { $avx(&a, &b, &mut got, len) };
                            assert_eq!(
                                got,
                                want,
                                "avx len={len} |a|={} |b|={}",
                                a.len(),
                                b.len()
                            );
                        }
                        let mut got = vec![<$ty>::default(); len];
                        super::$wrapper(&a, &b, &mut got, len);
                        assert_eq!(got, want, "wrapper len={len}");
                    }
                }
            }
        };
    }

    conformance!(conformance_i32, i32, 4, sse_merge_i32, avx_merge_i32, merge_i32);
    conformance!(conformance_u32, u32, 4, sse_merge_u32, avx_merge_u32, merge_u32);
    conformance!(conformance_i64, i64, 2, sse_merge_i64, avx_merge_i64, merge_i64);
    conformance!(conformance_u64, u64, 2, sse_merge_u64, avx_merge_u64, merge_u64);
}
