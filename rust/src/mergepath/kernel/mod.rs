//! Leaf merge kernels — the innermost two-way merge loops that every
//! engine in this crate bottoms out in, plus the per-job dispatch that
//! picks one.
//!
//! The Merge Path partition (Alg 1/Alg 3) makes the *placement* of work
//! optimal; after PR 5's segmented engine the per-thread windows are
//! cache-resident, so the remaining cost is the per-element compare in
//! the leaf loop itself. This module concentrates those leaves behind
//! one dispatch point:
//!
//! - **scalar** — the classic branchy two-finger loop
//!   ([`merge_bounded`](super::merge::merge_bounded)); the baseline.
//! - **branchless** — conditional-move selection
//!   ([`branchless_merge_bounded`](super::merge::branchless_merge_bounded));
//!   on random keys it avoids the ~50% mispredict rate of the scalar
//!   loop and is the portable default fallback.
//! - **hybrid** — branchless blocks with a galloping escape
//!   ([`hybrid_merge_bounded`](super::merge::hybrid_merge_bounded));
//!   the incumbent default: branchless throughput on interleaved keys,
//!   gallop throughput on run-structured ones.
//! - **simd** — an in-register bitonic merge network over SSE4.2/AVX2
//!   vectors ([`simd`] — `cargo` feature `simd`, runtime-detected),
//!   available for the fixed-width scalar key types `i32`/`u32`/
//!   `i64`/`u64` (bare or behind [`ByKey`](crate::record::ByKey)).
//!
//! Dispatch is **once per job**: the coordinator resolves the
//! `merge.kernel` knob ([`MergeKernel`]) into a [`LeafKernel`] function
//! pointer and threads it through the engines, so the hot loops contain
//! no per-element (or even per-window) dispatch.
//!
//! # Stability
//!
//! Every kernel produces output bit-identical to
//! [`merge_into`](super::merge::merge_into): stable with `A`-priority
//! (on a tie the `A` element is emitted first). For the scalar,
//! branchless and hybrid kernels this is by construction (`a[i] <=
//! b[j]` takes from `A`). The SIMD network reorders *loaded* elements
//! through min/max lanes and therefore cannot track element origin —
//! which is exactly why its dispatch is restricted to scalar key types,
//! where equal keys are bit-identical values and any tie order is the
//! same bits; see [`simd`] for the full argument.

use super::merge::{branchless_merge_bounded, hybrid_merge_bounded, merge_bounded};
use crate::{Error, Result};

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod simd;

/// The `merge.kernel` configuration knob: which leaf kernel jobs should
/// use. Parsed from `"auto"` / `"scalar"` / `"branchless"` /
/// `"hybrid"` / `"simd"`.
///
/// Everything except [`MergeKernel::Auto`] is a *request*: requests the
/// build or the CPU cannot honor degrade along the documented fallback
/// chain (see [`LeafKernel::select`]) rather than fail, and the
/// degraded pick is what shows up in the stats tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MergeKernel {
    /// Pick per record type at dispatch: the SIMD network when the
    /// build, the CPU and the key type all support it, the hybrid
    /// kernel otherwise. The default.
    #[default]
    Auto,
    /// Force the branchy two-finger baseline
    /// ([`merge_bounded`](super::merge::merge_bounded)) — for
    /// benchmarking and bisection.
    Scalar,
    /// Force the branchless conditional-move loop
    /// ([`branchless_merge_bounded`](super::merge::branchless_merge_bounded)).
    Branchless,
    /// Force the branchless+gallop hybrid
    /// ([`hybrid_merge_bounded`](super::merge::hybrid_merge_bounded)).
    Hybrid,
    /// Request the SSE4.2/AVX2 bitonic network ([`simd`]); degrades to
    /// branchless when the `simd` feature is off, the CPU lacks
    /// SSE4.2, or the record type is not a routed scalar key.
    Simd,
}

impl MergeKernel {
    /// The knob's config spelling (the string [`FromStr`] accepts).
    ///
    /// [`FromStr`]: std::str::FromStr
    pub fn name(self) -> &'static str {
        match self {
            MergeKernel::Auto => "auto",
            MergeKernel::Scalar => "scalar",
            MergeKernel::Branchless => "branchless",
            MergeKernel::Hybrid => "hybrid",
            MergeKernel::Simd => "simd",
        }
    }
}

impl std::str::FromStr for MergeKernel {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(MergeKernel::Auto),
            "scalar" => Ok(MergeKernel::Scalar),
            "branchless" => Ok(MergeKernel::Branchless),
            "hybrid" => Ok(MergeKernel::Hybrid),
            "simd" => Ok(MergeKernel::Simd),
            other => Err(Error::Config(format!("unknown merge kernel `{other}`"))),
        }
    }
}

/// The kernel a job actually resolved to — [`MergeKernel`] minus
/// `Auto`, after every degrade rule has been applied. This is what the
/// stats layer counts and what backend tags are suffixed with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelKind {
    /// Branchy two-finger baseline.
    Scalar,
    /// Conditional-move branchless loop.
    Branchless,
    /// Branchless blocks + galloping escape (the default pick).
    Hybrid,
    /// SSE4.2/AVX2 bitonic merge network.
    Simd,
}

impl KernelKind {
    /// Short name used in stats tags and the `kernels` CLI output.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Branchless => "branchless",
            KernelKind::Hybrid => "hybrid",
            KernelKind::Simd => "simd",
        }
    }
}

/// A resolved leaf kernel for element type `T`: one function pointer
/// with the [`merge_bounded`](super::merge::merge_bounded) contract
/// (merge the first `len` outputs of the stable A-priority merge of
/// `a` and `b` into `out[..len]`), plus the [`KernelKind`] it resolved
/// to for accounting.
///
/// `LeafKernel` is `Copy` (a tag and a function pointer), so the
/// engines thread it by value down to every leaf; dispatch cost is one
/// indirect call per *leaf invocation* — per segment, window, or tree
/// pair — never per element.
#[derive(Debug, Clone, Copy)]
pub struct LeafKernel<T> {
    kind: KernelKind,
    merge: fn(&[T], &[T], &mut [T], usize),
}

impl<T: Ord + Copy> LeafKernel<T> {
    /// The branchy two-finger baseline kernel.
    pub fn scalar() -> Self {
        Self { kind: KernelKind::Scalar, merge: merge_bounded::<T> }
    }

    /// The branchless conditional-move kernel.
    pub fn branchless() -> Self {
        Self { kind: KernelKind::Branchless, merge: branchless_merge_bounded::<T> }
    }

    /// The branchless+gallop hybrid kernel (the non-SIMD default).
    pub fn hybrid() -> Self {
        Self { kind: KernelKind::Hybrid, merge: hybrid_merge_bounded::<T> }
    }

    /// What this kernel resolved to (for stats tags and counters).
    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// Merge the first `len` outputs of the stable A-priority merge of
    /// `a` and `b` into `out[..len]` — the
    /// [`merge_bounded`](super::merge::merge_bounded) contract,
    /// whichever kernel is behind the pointer.
    #[inline]
    pub fn merge(&self, a: &[T], b: &[T], out: &mut [T], len: usize) {
        (self.merge)(a, b, out, len)
    }
}

impl<T: Ord + Copy + 'static> LeafKernel<T> {
    /// Resolve a [`MergeKernel`] request for element type `T`.
    ///
    /// Degrade rules (applied in order, never failing):
    /// - `Auto` → the SIMD network when available for `T` on this
    ///   build+CPU, the hybrid kernel otherwise.
    /// - `Simd` → the SIMD network when available, **branchless**
    ///   otherwise (the explicitly-requested-but-unavailable case
    ///   degrades to the portable branchless loop so the stats tag
    ///   makes the miss visible, per the knob's contract).
    /// - `Scalar` / `Branchless` / `Hybrid` → exactly that kernel.
    ///
    /// "Available for `T`" means: built with the `simd` cargo feature,
    /// on `x86_64`, with SSE4.2 detected at runtime, and `T` is one of
    /// `i32`/`u32`/`i64`/`u64` — bare or wrapped in
    /// [`ByKey`](crate::record::ByKey), whose `repr(transparent)`
    /// layout and key-only `Ord` coincide with the underlying scalar's.
    pub fn select(req: MergeKernel) -> Self {
        match req {
            MergeKernel::Scalar => Self::scalar(),
            MergeKernel::Branchless => Self::branchless(),
            MergeKernel::Hybrid => Self::hybrid(),
            MergeKernel::Simd => Self::simd_kernel().unwrap_or_else(Self::branchless),
            MergeKernel::Auto => Self::simd_kernel().unwrap_or_else(Self::hybrid),
        }
    }

    /// The SIMD kernel for `T`, when the build, the CPU and the type
    /// all permit it.
    fn simd_kernel() -> Option<Self> {
        simd_merge_fn::<T>().map(|merge| Self { kind: KernelKind::Simd, merge })
    }
}

/// Routed SIMD merge function for `T`, or `None` when unavailable.
///
/// The `TypeId` match routes the four supported scalar key types and
/// their [`ByKey`](crate::record::ByKey) wrappers to the monomorphic
/// vector kernels in [`simd`]. The function-pointer transmute is sound
/// because `TypeId` equality proves the types identical up to the
/// `repr(transparent)` `ByKey` wrapper, whose key-only `Ord` is the
/// scalar's own order.
#[allow(unused_mut, clippy::let_and_return)]
fn simd_merge_fn<T: Ord + Copy + 'static>() -> Option<fn(&[T], &[T], &mut [T], usize)> {
    let mut found: Option<fn(&[T], &[T], &mut [T], usize)> = None;
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if cpu_features().sse42 {
        use crate::record::ByKey;
        use std::any::TypeId;
        let id = TypeId::of::<T>();
        macro_rules! route {
            ($ty:ty, $f:expr) => {
                if found.is_none()
                    && (id == TypeId::of::<$ty>() || id == TypeId::of::<ByKey<$ty>>())
                {
                    // SAFETY: `T` is `$ty` or `ByKey<$ty>` (TypeId
                    // equality up to the repr(transparent) wrapper), so
                    // the two fn-pointer types have identical ABIs and
                    // identical Ord semantics.
                    found = Some(unsafe {
                        std::mem::transmute::<
                            fn(&[$ty], &[$ty], &mut [$ty], usize),
                            fn(&[T], &[T], &mut [T], usize),
                        >($f)
                    });
                }
            };
        }
        route!(i32, simd::merge_i32);
        route!(u32, simd::merge_u32);
        route!(i64, simd::merge_i64);
        route!(u64, simd::merge_u64);
    }
    found
}

/// CPU vector features relevant to the SIMD kernels, detected once per
/// process. On non-`x86_64` targets or builds without the `simd`
/// feature both flags are `false`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CpuFeatures {
    /// SSE4.2 available (128-bit kernels; implies the SSE4.1 min/max
    /// and blend forms the 32-bit network uses).
    pub sse42: bool,
    /// AVX2 available (256-bit kernels; preferred over SSE when both
    /// are present).
    pub avx2: bool,
}

/// Detected [`CpuFeatures`] (cached after the first call).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn cpu_features() -> CpuFeatures {
    static FEATURES: std::sync::OnceLock<CpuFeatures> = std::sync::OnceLock::new();
    *FEATURES.get_or_init(|| CpuFeatures {
        sse42: std::arch::is_x86_feature_detected!("sse4.2"),
        avx2: std::arch::is_x86_feature_detected!("avx2"),
    })
}

/// Detected [`CpuFeatures`] — this build has no SIMD kernels (feature
/// off or non-x86_64 target), so nothing is ever detected.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn cpu_features() -> CpuFeatures {
    CpuFeatures::default()
}

/// Suffix a backend tag with the kernel that served the job:
/// `"native" + Branchless → "native+branchless"`. Interned so the
/// result is `&'static str` like every other backend tag (the
/// combination space is |backends| × |kernels|, so the leaked set is
/// small and bounded). [`ServiceStats::record_completion`] strips the
/// suffix before routing to per-backend counters.
///
/// [`ServiceStats::record_completion`]: crate::coordinator::ServiceStats::record_completion
pub fn tagged_backend(base: &'static str, kind: KernelKind) -> &'static str {
    use std::collections::BTreeMap;
    use std::sync::Mutex;
    static INTERNED: Mutex<BTreeMap<(&str, KernelKind), &'static str>> =
        Mutex::new(BTreeMap::new());
    let mut map = INTERNED.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(&tag) = map.get(&(base, kind)) {
        return tag;
    }
    let tag: &'static str = Box::leak(format!("{base}+{}", kind.name()).into_boxed_str());
    map.insert((base, kind), tag);
    tag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::ByKey;
    use crate::rng::Xoshiro256;

    fn random_sorted_i64(rng: &mut Xoshiro256, n: usize, universe: u64) -> Vec<i64> {
        let mut v: Vec<i64> = (0..n).map(|_| rng.below(universe) as i64).collect();
        v.sort_unstable();
        v
    }

    fn all_requests() -> [MergeKernel; 5] {
        [
            MergeKernel::Auto,
            MergeKernel::Scalar,
            MergeKernel::Branchless,
            MergeKernel::Hybrid,
            MergeKernel::Simd,
        ]
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        for req in all_requests() {
            assert_eq!(req.name().parse::<MergeKernel>().unwrap(), req);
        }
        assert!("".parse::<MergeKernel>().is_err());
        assert!("avx512".parse::<MergeKernel>().is_err());
        assert_eq!(MergeKernel::default(), MergeKernel::Auto);
    }

    /// Satellite property sweep: the branchless loop is bit-identical
    /// to `merge_bounded` for every bounded prefix, including empty and
    /// one-sided inputs and duplicate-heavy universes.
    #[test]
    fn branchless_property_sweep_vs_merge_bounded() {
        let mut rng = Xoshiro256::seeded(0x5EAF);
        for round in 0..60 {
            // Duplicate-heavy small universes in half the rounds.
            let universe = if round % 2 == 0 { 8 } else { 1000 };
            let n_a = rng.range(0, 70);
            let a = random_sorted_i64(&mut rng, n_a, universe);
            let n_b = rng.range(0, 70);
            let b = random_sorted_i64(&mut rng, n_b, universe);
            for len in 0..=(a.len() + b.len()) {
                let mut want = vec![0i64; len];
                merge_bounded(&a, &b, &mut want, len);
                let mut got = vec![0i64; len];
                branchless_merge_bounded(&a, &b, &mut got, len);
                assert_eq!(got, want, "len={len}");
            }
        }
        // One-sided: the branchless safe-count loop must hand off to
        // the tail copies immediately.
        let a: Vec<i64> = (0..100).collect();
        let e: Vec<i64> = vec![];
        let mut out = vec![0i64; 100];
        branchless_merge_bounded(&a, &e, &mut out, 100);
        assert_eq!(out, a);
        branchless_merge_bounded(&e, &a, &mut out, 100);
        assert_eq!(out, a);
    }

    /// Every selectable kernel is bit-identical to `merge_bounded` on
    /// i64 (routed for SIMD) across shapes and bounded prefixes.
    #[test]
    fn all_kernels_bit_identical_i64() {
        let mut rng = Xoshiro256::seeded(0xC0DE);
        for _ in 0..40 {
            let n_a = rng.range(0, 200);
            let a = random_sorted_i64(&mut rng, n_a, 50);
            let n_b = rng.range(0, 200);
            let b = random_sorted_i64(&mut rng, n_b, 50);
            let total = a.len() + b.len();
            let mut want = vec![0i64; total];
            merge_bounded(&a, &b, &mut want, total);
            for req in all_requests() {
                let kernel = LeafKernel::<i64>::select(req);
                for len in [0, 1, total / 2, total] {
                    let mut got = vec![0i64; len];
                    kernel.merge(&a, &b, &mut got, len);
                    assert_eq!(got[..], want[..len], "req={req:?} len={len}");
                }
            }
        }
    }

    /// ByKey-wrapped scalars route exactly like the bare scalar and
    /// stay bit-identical.
    #[test]
    fn bykey_routes_like_bare_scalar() {
        assert_eq!(
            LeafKernel::<ByKey<u64>>::select(MergeKernel::Simd).kind(),
            LeafKernel::<u64>::select(MergeKernel::Simd).kind(),
        );
        let mut rng = Xoshiro256::seeded(0xB5);
        let a: Vec<ByKey<u64>> = {
            let mut v: Vec<u64> = (0..300).map(|_| rng.below(40)).collect();
            v.sort_unstable();
            v.into_iter().map(ByKey).collect()
        };
        let b: Vec<ByKey<u64>> = {
            let mut v: Vec<u64> = (0..277).map(|_| rng.below(40)).collect();
            v.sort_unstable();
            v.into_iter().map(ByKey).collect()
        };
        let total = a.len() + b.len();
        let mut want = vec![ByKey(0u64); total];
        merge_bounded(&a, &b, &mut want, total);
        for req in all_requests() {
            let kernel = LeafKernel::<ByKey<u64>>::select(req);
            let mut got = vec![ByKey(0u64); total];
            kernel.merge(&a, &b, &mut got, total);
            assert!(
                got.iter().zip(&want).all(|(g, w)| g.0 == w.0),
                "req={req:?}"
            );
        }
    }

    /// Key-only-Ord records must keep A-priority through every kernel
    /// that serves them (the SIMD route never serves them — `select`
    /// degrades — so all selected kernels are origin-preserving).
    #[test]
    fn stability_ties_from_a_for_payload_records() {
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        struct K(i64, u8);
        impl PartialOrd for K {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for K {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                self.0.cmp(&o.0)
            }
        }
        let a: Vec<K> = (0..40).map(|i| K(i / 8, 0)).collect();
        let b: Vec<K> = (0..40).map(|i| K(i / 8, 1)).collect();
        let mut want = vec![K(0, 9); 80];
        merge_bounded(&a, &b, &mut want, 80);
        for req in all_requests() {
            let kernel = LeafKernel::<K>::select(req);
            assert_ne!(kernel.kind(), KernelKind::Simd, "payload records never SIMD");
            let mut got = vec![K(0, 9); 80];
            kernel.merge(&a, &b, &mut got, 80);
            assert_eq!(
                got.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
                want.iter().map(|k| (k.0, k.1)).collect::<Vec<_>>(),
                "req={req:?}"
            );
        }
    }

    #[test]
    fn select_degrades_as_documented() {
        // Unrouted element types degrade: Simd → branchless, Auto → hybrid.
        assert_eq!(
            LeafKernel::<(i64, i64)>::select(MergeKernel::Simd).kind(),
            KernelKind::Branchless
        );
        assert_eq!(
            LeafKernel::<(i64, i64)>::select(MergeKernel::Auto).kind(),
            KernelKind::Hybrid
        );
        // Forced kernels resolve exactly.
        assert_eq!(LeafKernel::<i64>::select(MergeKernel::Scalar).kind(), KernelKind::Scalar);
        assert_eq!(
            LeafKernel::<i64>::select(MergeKernel::Branchless).kind(),
            KernelKind::Branchless
        );
        assert_eq!(LeafKernel::<i64>::select(MergeKernel::Hybrid).kind(), KernelKind::Hybrid);
        // Routed scalar: SIMD iff this build+CPU has it, else the
        // documented fallbacks.
        let simd_available = cpu_features().sse42
            && cfg!(all(feature = "simd", target_arch = "x86_64"));
        let forced = LeafKernel::<i64>::select(MergeKernel::Simd).kind();
        let auto = LeafKernel::<i64>::select(MergeKernel::Auto).kind();
        if simd_available {
            assert_eq!(forced, KernelKind::Simd);
            assert_eq!(auto, KernelKind::Simd);
        } else {
            assert_eq!(forced, KernelKind::Branchless);
            assert_eq!(auto, KernelKind::Hybrid);
        }
    }

    #[test]
    fn tagged_backend_interns() {
        let t1 = tagged_backend("native", KernelKind::Branchless);
        assert_eq!(t1, "native+branchless");
        let t2 = tagged_backend("native", KernelKind::Branchless);
        // Same interned pointer, not merely equal contents.
        assert!(std::ptr::eq(t1.as_ptr(), t2.as_ptr()));
        assert_eq!(tagged_backend("native-segmented", KernelKind::Simd), "native-segmented+simd");
    }
}
